"""Decoder stack: scan-over-periods forward, KV/SSM-cache decode.

Three entry points (what the launcher lowers):
  forward(cfg, params, tokens, prefix_emb)        → logits (train/prefill)
  init_cache(cfg, batch, max_len, dtype)          → decode cache pytree
  decode_step(cfg, params, cache, tokens)         → (logits, cache)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.config import ArchConfig
from repro.models.sharding import set_profile, shard


def _apply_layer_train(p, cfg: ArchConfig, spec, x):
    h = blocks.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        if spec.attn == "mla":
            h = blocks.mla_train(p, cfg, spec, h)
        else:
            h = blocks.attn_train(p, cfg, spec, h)
    else:
        h = blocks.mamba_train(p, cfg, h)
    x = x + h
    if spec.ff != "none":
        h = blocks.rmsnorm(p["ln2"], x, cfg.norm_eps)
        h = blocks.moe(p, cfg, h) if spec.ff == "moe" else blocks.mlp(p, cfg, h)
        x = x + h
    return x


def forward(
    cfg: ArchConfig,
    params: dict,
    tokens: jnp.ndarray,  # (B, S_text) int32
    prefix_emb: jnp.ndarray | None = None,  # (B, S_prefix, d) stub frontend
    remat: bool = False,
    last_only: bool = False,
    unroll: bool = False,
) -> jnp.ndarray:
    """Full-sequence causal LM forward → logits (B, S_total, V).

    ``remat``: activation-checkpoint at period granularity (training).
    ``last_only``: head applied to the final position only (prefill —
    avoids materializing (B, S, V) logits).
    ``unroll``: unroll the period scan — used by the roofline lowering
    so cost_analysis counts every layer (XLA counts a while body once;
    see launch/roofline.py)."""
    set_profile(cfg.sharding_profile)
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb @ params["proj"], x], axis=1)
    x = shard(x, "batch", None, None)

    def period_fn(x, stacked):
        for spec, p in zip(cfg.period, stacked):
            x = _apply_layer_train(p, cfg, spec, x)
        return x, None

    if remat:
        period_fn = jax.checkpoint(period_fn)
    x, _ = jax.lax.scan(period_fn, x, params["layers"], unroll=cfg.n_periods if unroll else 1)
    x = blocks.rmsnorm(params["norm_f"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if last_only:
        return x[:, -1:, :] @ head
    logits = x @ head
    return shard(logits, "batch", None, "vocab")


def lm_loss(
    cfg: ArchConfig, params: dict, tokens, targets, mask=None, prefix_emb=None,
    remat: bool = False, unroll: bool = False,
) -> jnp.ndarray:
    """Mean next-token cross entropy (f32 logits path)."""
    logits = forward(cfg, params, tokens, prefix_emb, remat=remat, unroll=unroll)
    if prefix_emb is not None:
        logits = logits[:, prefix_emb.shape[1] :, :]
    logits = shard(logits.astype(jnp.float32), "batch", None, "vocab")
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = shard(logz - gold, "batch", None)
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


# ------------------------------------------------------------- decode


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    """Per-period-position stacked caches + position scalar."""
    per_pos = []
    for spec in cfg.period:
        if spec.mixer == "attn":
            if spec.attn == "mla":
                one = blocks.init_mla_cache(cfg, batch, max_len, dtype)
            else:
                one = blocks.init_attn_cache(cfg, spec, batch, max_len, dtype)
        else:
            one = blocks.init_mamba_state(cfg, batch, dtype)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape), one
        )
        per_pos.append(stacked)
    return {"layers": tuple(per_pos), "pos": jnp.zeros((), jnp.int32)}


def decode_step(
    cfg: ArchConfig, params: dict, cache: dict, tokens: jnp.ndarray,
    unroll: bool = False,
) -> tuple[jnp.ndarray, dict]:
    """One token per sequence: tokens (B, 1) → logits (B, 1, V)."""
    set_profile(cfg.sharding_profile)
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)

    def period_fn(x, scanned):
        stacked_p, stacked_c = scanned
        new_cs = []
        for spec, p, c in zip(cfg.period, stacked_p, stacked_c):
            h = blocks.rmsnorm(p["ln1"], x, cfg.norm_eps)
            if spec.mixer == "attn":
                if spec.attn == "mla":
                    h, c = blocks.mla_decode(p, cfg, spec, h, c, pos)
                else:
                    h, c = blocks.attn_decode(p, cfg, spec, h, c, pos)
            else:
                h, c = blocks.mamba_decode(p, cfg, h, c, pos)
            x = x + h
            if spec.ff != "none":
                h = blocks.rmsnorm(p["ln2"], x, cfg.norm_eps)
                h = blocks.moe(p, cfg, h) if spec.ff == "moe" else blocks.mlp(p, cfg, h)
                x = x + h
            new_cs.append(c)
        return x, tuple(new_cs)

    x, new_layers = jax.lax.scan(
        period_fn, x, (params["layers"], cache["layers"]),
        unroll=cfg.n_periods if unroll else 1,
    )
    x = blocks.rmsnorm(params["norm_f"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, {"layers": new_layers, "pos": pos + 1}
