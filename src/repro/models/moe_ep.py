"""Expert-parallel MoE via nested shard_map + all_to_all.

Why: lax.ragged_dot has no GSPMD partitioning rule, so under pure auto
sharding XLA replicates the grouped-matmul operands — measured 370 GB/dev
temp on jamba train_4k (EXPERIMENTS.md §Perf P-ep). The scalable layout
is true expert parallelism (the assignment's "expert-parallel sharding …
all-to-all"):

  * experts are sharded over the "model" axis (E/m per rank — the
    paper's p_c exact-sharding role);
  * tokens are block-split over the model axis inside the manual
    region (padded when not divisible, e.g. decode's few tokens);
  * one all_to_all routes token copies to their experts' owners, a
    second routes results back; each rank runs a *local* ragged_dot
    over its resident experts (a purely local op — no GSPMD rule
    needed);
  * an all_gather over the model axis restores the activation layout.

Capacity: each (src, dst) pair carries cap = ceil(T_src·k·cf / m)
slots; overflow copies are dropped (capacity-factor routing, cf = 2)
and the surviving router weights keep their normalization (drop = lost
contribution, exactly like dropped-token MoE implementations).

Fallback when E is not divisible by the model axis (granite-moe: 40
experts, 16-way axis): experts replicated inside the manual region
(they are small in every such config), tokens still split over model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig


def _act(name: str, x):
    return jax.nn.silu(x) if name == "silu" else jax.nn.gelu(x)


def _local_expert_mlp(cfg, t_sorted, group_sizes, w_gate, w_up, w_down):
    """Grouped matmul over this rank's resident experts (local op)."""
    h = jax.lax.ragged_dot(t_sorted, w_gate, group_sizes)
    h = _act(cfg.mlp_act, h) * jax.lax.ragged_dot(t_sorted, w_up, group_sizes)
    return jax.lax.ragged_dot(h, w_down, group_sizes)


def _route(cfg, t, router):
    e = cfg.moe
    logits = t.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, e.top_k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p.astype(t.dtype), top_i


def _my_tokens(t_all, m: int, r):
    """Contiguous block split of T_loc tokens over m ranks, padded so
    every rank holds T_pad = ceil(T_loc/m); returns (t, valid)."""
    T_loc, d = t_all.shape
    T_pad = -(-T_loc // m)
    idx = r * T_pad + jnp.arange(T_pad)
    valid = idx < T_loc
    t = jnp.take(t_all, jnp.minimum(idx, T_loc - 1), axis=0)
    return jnp.where(valid[:, None], t, 0), valid, T_pad


def _dispatch_slots(dst, n_dst: int, cap: int):
    """Slot in the (n_dst · cap) send buffer per pair, -1 on overflow.
    ``dst`` may contain the sentinel n_dst-1 for invalid pairs; the
    sentinel bucket's slots are discarded by the caller."""
    n = dst.shape[0]
    order = jnp.argsort(dst)
    sorted_dst = dst[order]
    counts = jnp.bincount(dst, length=n_dst)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_group = jnp.arange(n) - starts[sorted_dst]
    slot_sorted = jnp.where(pos_in_group < cap, sorted_dst * cap + pos_in_group, -1)
    return jnp.zeros(n, jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))


def _moe_ep_body(cfg: ArchConfig, t_all, router, wg, wu, wd, axis: str, cf: float):
    """Manual region, expert-parallel path. t_all: (T_loc, d) replicated
    over ``axis``; wg/wu/wd: this rank's (E/m, d, ffe) expert slices."""
    e = cfg.moe
    from repro import compat

    m = compat.axis_size(axis)
    r = jax.lax.axis_index(axis)
    d = t_all.shape[-1]
    k = e.top_k
    e_per_rank = wg.shape[0]  # padded-E/m: pads are never routed to

    t, tok_valid, T_pad = _my_tokens(t_all, m, r)
    top_p, top_i = _route(cfg, t, router)  # (T_pad, k)

    pairs_e = top_i.reshape(-1)
    pair_valid = jnp.repeat(tok_valid, k)
    dst = jnp.where(pair_valid, pairs_e // e_per_rank, m)  # sentinel bucket m
    cap = max(-(-T_pad * k * int(cf * 4)) // (4 * m), 1)  # ceil(T_pad·k·cf/m)

    slot = _dispatch_slots(dst, m + 1, cap)
    slot = jnp.where((slot >= 0) & (slot < m * cap), slot, -1)
    ok = slot >= 0
    safe = jnp.where(ok, slot, 0)

    t_pairs = jnp.repeat(t, k, axis=0)
    send = jnp.zeros((m * cap, d), t.dtype).at[safe].add(jnp.where(ok[:, None], t_pairs, 0))
    send_eid = jnp.full((m * cap,), e_per_rank, jnp.int32).at[safe].min(
        jnp.where(ok, (pairs_e % e_per_rank).astype(jnp.int32), e_per_rank)
    )

    recv = jax.lax.all_to_all(send.reshape(m, cap, d), axis, 0, 0)
    recv_eid = jax.lax.all_to_all(send_eid.reshape(m, cap), axis, 0, 0)
    recv_flat = recv.reshape(m * cap, d)
    eid_flat = recv_eid.reshape(m * cap)

    order = jnp.argsort(eid_flat)  # pads (eid = e_per_rank) sort last
    t_sorted = recv_flat[order]
    group_sizes = jnp.bincount(eid_flat, length=e_per_rank + 1)[:e_per_rank].astype(jnp.int32)
    y_sorted = _local_expert_mlp(cfg, t_sorted, group_sizes, wg, wu, wd)
    processed = jnp.arange(m * cap) < group_sizes.sum()
    y_sorted = jnp.where(processed[:, None], y_sorted, 0)
    y_flat = jnp.zeros_like(y_sorted).at[order].set(y_sorted)

    y_back = jax.lax.all_to_all(y_flat.reshape(m, cap, d), axis, 0, 0)
    y_slots = y_back.reshape(m * cap, d)

    y_pairs = jnp.where(ok[:, None], y_slots[safe], 0)
    y_tok = jnp.einsum("tkd,tk->td", y_pairs.reshape(T_pad, k, d), top_p.astype(y_pairs.dtype))

    out = jax.lax.all_gather(y_tok, axis, axis=0, tiled=True)  # (m·T_pad, d)
    return out[: t_all.shape[0]]


def _moe_repl_body(cfg: ArchConfig, t_all, router, wg, wu, wd, axis: str):
    """Fallback: experts replicated, tokens split over ``axis``."""
    e = cfg.moe
    from repro import compat

    m = compat.axis_size(axis)
    r = jax.lax.axis_index(axis)
    d = t_all.shape[-1]
    k = e.top_k
    t, tok_valid, T_pad = _my_tokens(t_all, m, r)
    top_p, top_i = _route(cfg, t, router)
    top_p = top_p * tok_valid[:, None]
    flat_e = top_i.reshape(-1)
    order = jnp.argsort(flat_e)
    inv = jnp.argsort(order)
    t_rep = jnp.repeat(t, k, axis=0)[order]
    group_sizes = jnp.bincount(flat_e, length=wg.shape[0]).astype(jnp.int32)
    y = _local_expert_mlp(cfg, t_rep, group_sizes, wg, wu, wd)
    y = y[inv].reshape(T_pad, k, d)
    y_tok = jnp.einsum("tkd,tk->td", y, top_p.astype(y.dtype))
    out = jax.lax.all_gather(y_tok, axis, axis=0, tiled=True)
    return out[: t_all.shape[0]]


def moe_ep(cfg: ArchConfig, p: dict, x: jnp.ndarray, cf: float = 2.0) -> jnp.ndarray:
    """Expert-parallel MoE over the active mesh. x: (B, S, d)."""
    e = cfg.moe
    B, S, d = x.shape
    from repro import compat

    am = compat.get_abstract_mesh()
    sizes = dict(zip(am.axis_names, am.axis_sizes))
    manual = compat.manual_axes(am)
    m = sizes.get("model", 1)
    batch_axes = tuple(
        a for a in ("pod", "data")
        if a in sizes and sizes[a] > 1 and a not in manual
    )
    btotal = 1
    for a in batch_axes:
        btotal *= sizes[a]
    if B % btotal:
        batch_axes = ()
    bspec_entry = (
        None if not batch_axes else (batch_axes[0] if len(batch_axes) == 1 else batch_axes)
    )
    from repro.models.init import padded_experts

    ep = padded_experts(e.n_experts) % m == 0

    # FSDP for the expert weights: stored with dim-1 sharded over
    # "data" (348 GB of jamba expert params cannot live 16-way-sharded:
    # 43 GB/dev — EXPERIMENTS.md §Perf P-efsdp). They are all-gathered
    # over "data" per layer inside the manual region; the transpose
    # (grads) is automatically a reduce-scatter.
    dsize = sizes.get("data", 1)
    fsdp = (
        ep and not cfg.expert_weight_stationary
        and "data" in batch_axes and d % dsize == 0 and e.d_ff_expert % dsize == 0
    )

    def body(x_loc, router, wg, wu, wd):
        t_all = x_loc.reshape(-1, d)
        if fsdp:
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=1, tiled=True)
        if ep:
            out = _moe_ep_body(cfg, t_all, router, wg, wu, wd, "model", cf)
        else:
            out = _moe_repl_body(cfg, t_all, router, wg, wu, wd, "model")
        return out.reshape(x_loc.shape)

    if ep:
        wspec = P("model", "data") if fsdp else P("model")
    else:
        wspec = P()
    smap = compat.shard_map(
        body,
        mesh=am,
        in_specs=(P(bspec_entry), P(), wspec, wspec, wspec),
        out_specs=P(bspec_entry),
        axis_names=frozenset(batch_axes) | {"model"},
    )
    y = smap(x, p["router"].astype(x.dtype), p["w_gate_e"], p["w_up_e"], p["w_down_e"])

    if e.n_shared:
        sh = _act(cfg.mlp_act, x @ p["w_gate_sh"]) * (x @ p["w_up_sh"])
        y = y + sh @ p["w_down_sh"]
    return y
