"""Layer blocks: norms, RoPE, attention (GQA/MQA/SWA/MLA), gated MLP,
MoE (ragged_dot grouped matmul), Mamba-1.

All blocks are pure functions (params-dict first). Each mixer has a
full-sequence form (training / prefill) and a single-token decode form
threading an explicit cache/state — the decode forms are what
``serve_step`` lowers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, LayerSpec
from repro.models.sharding import shard


def rmsnorm(g: jnp.ndarray, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


# ---------------------------------------------------------------- RoPE


def rope_frequencies(head_dim: int, theta: float, positions: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for ``positions`` (any shape) × head_dim/2."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., n_heads, head_dim); cos/sin broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ----------------------------------------------------------- attention


def _sdpa(q, k, v, mask, scale, kv_seq_sharded: bool = False) -> jnp.ndarray:
    """Grouped-query attention without materializing repeated KV.

    q: (B,S,H,D); k/v: (B,L,KV,D) with H = KV·G. The KV tensors are
    used as-is (repeating them 3-6× was measured to force an 8.6 GB/dev
    cache all-gather on seq-sharded decode — EXPERIMENTS.md §Perf).

    ``kv_seq_sharded``: constrain the score/prob tensors so their L dim
    inherits the cache's "model"-axis sharding — XLA then psums the
    tiny (B,S,H,D) contraction instead of gathering the cache.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    q5 = q.reshape(B, S, KV, G, D)
    logits = jnp.einsum("bskgd,blkd->bkgsl", q5, k) * scale
    if kv_seq_sharded or PIN_SCORE_BATCH:
        logits = shard(logits, "batch", None, None, None, "cache_seq" if kv_seq_sharded else None)
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    if kv_seq_sharded or PIN_SCORE_BATCH:
        probs = shard(probs, "batch", None, None, None, "cache_seq" if kv_seq_sharded else None)
    out = jnp.einsum("bkgsl,blkd->bskgd", probs, v)
    return out.reshape(B, S, H, D)


def _repeat_kv_flat(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, S, KV, D) → (B, S, H, D), sharded over heads where divisible."""
    KV = k.shape[2]
    if KV != n_heads:
        k = jnp.repeat(k, n_heads // KV, axis=2)
    return shard(k, "batch", None, "heads", None)


def _sdpa_flat(q, k, v, mask, scale) -> jnp.ndarray:
    """Flat-head attention (train path): q/k/v (B, S, H, D); scores
    (B, H, S, L) shard over heads on the model axis."""
    logits = jnp.einsum("bshd,blhd->bhsl", q, k) * scale
    logits = shard(logits, "batch", "heads", None, None)
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    probs = shard(probs, "batch", "heads", None, None)
    return jnp.einsum("bhsl,blhd->bshd", probs, v)


# sequences longer than this use the query-chunked (flash-style) path:
# the (S × S) score matrix at 32k+ was measured at 25.8 GB/dev/layer on
# granite-34b prefill (EXPERIMENTS.md §Perf P-flash)
CHUNKED_ATTN_THRESHOLD = 8192
ATTN_Q_CHUNK = 1024
# pin the batch dim of attention scores (ablation toggle, §Perf-1)
PIN_SCORE_BATCH = True


def _sdpa_chunked(q, k, v, scale, window: int = 0, q_chunk: int = ATTN_Q_CHUNK):
    """Causal flat-head attention with softmax over query chunks —
    bounds score memory at (B, H, q_chunk, S) instead of (…, S, S).
    Pure JAX (lax.scan over chunks); the TPU-kernel analogue is flash
    attention, this is its memory behaviour at the XLA level. Expects
    k/v already head-repeated (train path)."""
    B, S, H, D = q.shape
    n_chunks = S // q_chunk
    q5 = q.reshape(B, n_chunks, q_chunk, H, D).swapaxes(0, 1)
    kpos = jnp.arange(S)

    def chunk(carry, inp):
        ci, qc = inp  # qc: (B, q_chunk, H, D)
        qpos = ci * q_chunk + jnp.arange(q_chunk)
        logits = jnp.einsum("bshd,blhd->bhsl", qc, k).astype(jnp.float32) * scale
        logits = shard(logits, "batch", "heads", None, None)
        mask = qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        logits = jnp.where(mask[None, None], logits, -1e30)
        m = jnp.max(logits, axis=-1, keepdims=True)
        p_ = jnp.exp(logits - m)
        l = jnp.sum(p_, axis=-1)
        o = jnp.einsum("bhsl,blhd->bshd", p_.astype(q.dtype), v)
        o = o / l.swapaxes(1, 2)[..., None].astype(o.dtype)
        return carry, o

    _, outs = jax.lax.scan(chunk, (), (jnp.arange(n_chunks), q5))
    return outs.swapaxes(0, 1).reshape(B, S, H, D)


def attn_train(p, cfg: ArchConfig, spec: LayerSpec, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence causal attention (training / prefill)."""
    B, S, _ = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].reshape(cfg.d_model, H, D))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].reshape(cfg.d_model, KV, D))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].reshape(cfg.d_model, KV, D))
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(H, D)
        k = k + p["bk"].reshape(KV, D)
        v = v + p["bv"].reshape(KV, D)
    pos = jnp.arange(S)
    cos, sin = rope_frequencies(D, cfg.rope_theta, pos)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    window = cfg.sliding_window if (spec.attn == "swa" and cfg.sliding_window) else 0
    # TRAIN path uses flat heads with repeated KV: the grouped (KV, G)
    # reshape breaks head sharding when KV doesn't divide the model
    # axis (jamba: KV=8 on a 16-way axis), which was measured to
    # replicate every head's (S×S) scores on every device — 4.3 GB ×85
    # buffers (§Perf-3). Repeating KV costs only (B,S,H,D) here (train
    # KV is small; decode keeps the grouped no-repeat form).
    kr = _repeat_kv_flat(k, H)
    vr = _repeat_kv_flat(v, H)
    if S > CHUNKED_ATTN_THRESHOLD and S % ATTN_Q_CHUNK == 0:
        out = _sdpa_chunked(q, kr, vr, D**-0.5, window=window, q_chunk=ATTN_Q_CHUNK)
    else:
        causal = pos[:, None] >= pos[None, :]
        if window:
            causal &= pos[:, None] - pos[None, :] < window
        out = _sdpa_flat(q, kr, vr, causal[None, None], D**-0.5)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].reshape(H, D, cfg.d_model))


def init_attn_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, max_len: int, dtype):
    L = min(cfg.sliding_window, max_len) if spec.attn == "swa" and cfg.sliding_window else max_len
    KV, D = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, L, KV, D), dtype),
        "v": jnp.zeros((batch, L, KV, D), dtype),
    }


def attn_decode(p, cfg: ArchConfig, spec: LayerSpec, x: jnp.ndarray, cache, pos):
    """One-token decode. x: (B, 1, d); pos: scalar current position."""
    B = x.shape[0]
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    L = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].reshape(cfg.d_model, H, D))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].reshape(cfg.d_model, KV, D))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].reshape(cfg.d_model, KV, D))
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(H, D)
        k = k + p["bk"].reshape(KV, D)
        v = v + p["bv"].reshape(KV, D)
    cos, sin = rope_frequencies(D, cfg.rope_theta, jnp.full((1,), pos))
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = pos % L  # ring buffer (SWA) / direct slot (full, L = max_len)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    ck = shard(ck, "batch", "cache_seq", None, None)
    cv = shard(cv, "batch", "cache_seq", None, None)
    idx = jnp.arange(L)
    valid = jnp.where(pos >= L, jnp.ones((L,), bool), idx <= slot)
    out = _sdpa(q, ck, cv, valid[None, None, None, None, :], D**-0.5, kv_seq_sharded=True)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].reshape(H, D, cfg.d_model))
    return y, {"k": ck, "v": cv}


# ------------------------------------------------- MLA (DeepSeek-V2)


def _mla_qkv(p, cfg: ArchConfig, x, positions):
    m = cfg.mla
    H = cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].reshape(cfg.d_model, H, qd))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    cos, sin = rope_frequencies(m.qk_rope_head_dim, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)
    ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])  # (B,S,lora)
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["w_kr"])  # shared rope key
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def _mla_attend(p, cfg: ArchConfig, q_nope, q_rope, ckv, k_rope, mask, kv_seq_sharded=False):
    """Latent-space attention: queries are absorbed into the KV-LoRA
    basis so the cache stays (lora + rope) wide — MLA's memory win."""
    m = cfg.mla
    H = cfg.n_heads
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    # absorb: q̃ = q_nope · W_UKᵀ lives in the lora space
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)
    logits = jnp.einsum("bshr,blr->bhsl", q_lat, ckv)
    logits += jnp.einsum("bshk,blk->bhsl", q_rope, k_rope)
    if kv_seq_sharded:
        # pin L to the cache's "model" sharding — without this XLA was
        # measured to all-gather the full 537 MB f32 ckv cache per
        # decode layer (§Perf-4)
        logits = shard(logits, "batch", None, None, "cache_seq")
    elif PIN_SCORE_BATCH:
        logits = shard(logits, "batch", None, None, None)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    logits = jnp.where(mask, logits * scale, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q_nope.dtype)
    if kv_seq_sharded:
        probs = shard(probs, "batch", None, None, "cache_seq")
    elif PIN_SCORE_BATCH:
        probs = shard(probs, "batch", None, None, None)
    ctx = jnp.einsum("bhsl,blr->bshr", probs, ckv)  # context in lora space
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhk->bshk", ctx, w_uv)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].reshape(H, m.v_head_dim, cfg.d_model))


def _mla_attend_chunked(p, cfg: ArchConfig, q_nope, q_rope, ckv, k_rope, q_chunk: int = ATTN_Q_CHUNK):
    """Query-chunked MLA (same memory bound as _sdpa_chunked)."""
    B, S, H, _ = q_nope.shape
    n_chunks = S // q_chunk
    kpos = jnp.arange(S)
    qn = q_nope.reshape(B, n_chunks, q_chunk, H, -1).swapaxes(0, 1)
    qr = q_rope.reshape(B, n_chunks, q_chunk, H, -1).swapaxes(0, 1)

    def chunk(carry, inp):
        ci, qn_c, qr_c = inp
        qpos = ci * q_chunk + jnp.arange(q_chunk)
        mask = (qpos[:, None] >= kpos[None, :])[None, None]
        o = _mla_attend(p, cfg, qn_c, qr_c, ckv, k_rope, mask)
        return carry, o

    _, outs = jax.lax.scan(chunk, (), (jnp.arange(n_chunks), qn, qr))
    return outs.swapaxes(0, 1).reshape(B, S, cfg.d_model)


def mla_train(p, cfg: ArchConfig, spec: LayerSpec, x: jnp.ndarray) -> jnp.ndarray:
    S = x.shape[1]
    pos = jnp.arange(S)
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, pos)
    if S > CHUNKED_ATTN_THRESHOLD and S % ATTN_Q_CHUNK == 0:
        return _mla_attend_chunked(p, cfg, q_nope, q_rope, ckv, k_rope, q_chunk=ATTN_Q_CHUNK)
    mask = (pos[:, None] >= pos[None, :])[None, None]
    return _mla_attend(p, cfg, q_nope, q_rope, ckv, k_rope, mask)


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_decode(p, cfg: ArchConfig, spec: LayerSpec, x, cache, pos):
    q_nope, q_rope, ckv_new, kr_new = _mla_qkv(p, cfg, x, jnp.full((1,), pos))
    L = cache["ckv"].shape[1]
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, pos, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_new, pos, axis=1)
    ckv = shard(ckv, "batch", "cache_seq", None)
    valid = jnp.arange(L) <= pos
    y = _mla_attend(p, cfg, q_nope, q_rope, ckv, kr, valid[None, None, None, :],
                    kv_seq_sharded=True)
    return y, {"ckv": ckv, "kr": kr}


# ------------------------------------------------------------ MLP/MoE


def _act(name: str, x):
    return jax.nn.silu(x) if name == "silu" else jax.nn.gelu(x)


def mlp(p, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Gated MLP (SwiGLU / GeGLU)."""
    h = _act(cfg.mlp_act, x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, "batch", None, "ff")
    return h @ p["w_down"]


def moe(p, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Token-choice top-k MoE via sort + jax.lax.ragged_dot.

    Grouped matmuls count only *active* FLOPs in cost_analysis (unlike a
    dense every-expert-every-token dispatch, which would inflate the
    roofline 10-30×). On a mesh with a non-trivial "model" axis the
    expert-parallel all_to_all path (repro.models.moe_ep) is used;
    the local path below serves single-device runs and smoke tests.
    """
    from repro import compat

    mesh = compat.get_abstract_mesh()
    if mesh is not None and not mesh.empty and cfg.sharding_profile == "tp":
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        if sizes.get("model", 1) > 1:
            from repro.models.moe_ep import moe_ep

            return moe_ep(cfg, p, x)
    e = cfg.moe
    B, S, d = x.shape
    t = x.reshape(B * S, d)
    logits = t @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, e.top_k)  # (T, k)
    top_p = (top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    flat_expert = top_i.reshape(-1)  # (T·k,)
    order = jnp.argsort(flat_expert)
    inv = jnp.argsort(order)
    t_rep = jnp.repeat(t, e.top_k, axis=0)[order]  # sorted by expert
    group_sizes = jnp.bincount(flat_expert, length=p["w_gate_e"].shape[0]).astype(jnp.int32)

    h = jax.lax.ragged_dot(t_rep, p["w_gate_e"], group_sizes)
    h = _act(cfg.mlp_act, h) * jax.lax.ragged_dot(t_rep, p["w_up_e"], group_sizes)
    y = jax.lax.ragged_dot(h, p["w_down_e"], group_sizes)
    y = y[inv].reshape(B * S, e.top_k, d)
    y = jnp.einsum("tkd,tk->td", y, top_p.astype(y.dtype))

    if e.n_shared:
        sh = _act(cfg.mlp_act, t @ p["w_gate_sh"]) * (t @ p["w_up_sh"])
        y = y + sh @ p["w_down_sh"]
    return y.reshape(B, S, d)


# ------------------------------------------------------------- Mamba-1


def _mamba_dims(cfg: ArchConfig):
    mb = cfg.mamba
    d_in = mb.expand * cfg.d_model
    dt_rank = mb.dt_rank or -(-cfg.d_model // 16)
    return mb, d_in, dt_rank


def _ssm_scan_chunked(dt, xi, Bc, Cc, A, h0, chunk: int):
    """Selective scan with the (B, ·, d_in, N) discretized tensors
    materialized only PER CHUNK: sequential lax.scan over S/chunk
    chunks, associative scan inside each. Discretizing the whole
    sequence up front was measured at 268 GB/dev on jamba train_4k
    (EXPERIMENTS.md §Perf P-ssm); per-chunk it is chunk/S of that.

    The recurrence runs in f32 (bf16 state drifts over long sequences).
    Returns (y: (B,S,D) f32, h_last: (B,D,N) f32).
    """
    B, S, D = dt.shape
    N = A.shape[1]
    n_chunks = S // chunk

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    def step(h, inputs):
        dt_c, xi_c, b_c, c_c = inputs  # (B, chunk, ·)
        a_bar = jnp.exp(dt_c[..., None].astype(jnp.float32) * A)  # (B,chunk,D,N)
        bx = ((dt_c * xi_c)[..., None] * b_c[:, :, None, :]).astype(jnp.float32)
        a_acc, b_acc = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
        hs = a_acc * h[:, None] + b_acc  # prefix states within the chunk
        y_c = jnp.einsum("bsdn,bsn->bsd", hs, c_c.astype(jnp.float32))
        return hs[:, -1], y_c

    split = lambda t: t.reshape(B, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)
    h_last, ys = jax.lax.scan(step, h0, (split(dt), split(xi), split(Bc), split(Cc)))
    return ys.swapaxes(0, 1).reshape(B, S, D), h_last


def mamba_train(p, cfg: ArchConfig, x: jnp.ndarray, chunk: int = 256) -> jnp.ndarray:
    """Full-sequence Mamba-1 (selective SSM) forward."""
    mb, d_in, dt_rank = _mamba_dims(cfg)
    B, S, _ = x.shape
    xz = x @ p["in_proj"]  # (B,S,2*d_in)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = shard(xi, "batch", None, "d_inner")
    # causal depthwise conv over time
    pad = jnp.pad(xi, ((0, 0), (mb.d_conv - 1, 0), (0, 0)))
    xi = sum(
        pad[:, i : i + S, :] * p["conv_w"][:, i] for i in range(mb.d_conv)
    ) + p["conv_b"]
    xi = jax.nn.silu(xi)

    proj = xi @ p["x_proj"]  # (B,S,dt_rank+2N)
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + mb.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])  # (B,S,d_in)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (d_in, N)

    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fallback: single associative scan
    h0 = jnp.zeros((B, d_in, mb.d_state), jnp.float32)
    y, _ = _ssm_scan_chunked(dt, xi, Bc, Cc, A, h0, chunk)
    y = y + (xi * p["D"]).astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"]


def init_mamba_state(cfg: ArchConfig, batch: int, dtype):
    mb, d_in, _ = _mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, mb.d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, mb.d_state), jnp.float32),
    }


def mamba_decode(p, cfg: ArchConfig, x, state, pos):
    """Single-token recurrence — O(1) state, the long_500k enabler."""
    del pos
    mb, d_in, dt_rank = _mamba_dims(cfg)
    B = x.shape[0]
    xz = x[:, 0, :] @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, d_in)
    window = jnp.concatenate([state["conv"], xi[:, None, :]], axis=1)  # (B,d_conv,d_in)
    xi = jnp.einsum("bcd,dc->bd", window, p["conv_w"]) + p["conv_b"]
    xi = jax.nn.silu(xi)
    proj = xi @ p["x_proj"]
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + mb.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a_bar = jnp.exp(dt[..., None] * A)  # (B,d_in,N)
    h = a_bar * state["ssm"] + (dt * xi)[..., None] * Bc[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cc) + xi * p["D"]
    y = y * jax.nn.silu(z)
    y = (y.astype(x.dtype) @ p["out_proj"])[:, None, :]
    return y, {"conv": window[:, 1:, :], "ssm": h}
