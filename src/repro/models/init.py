"""Parameter initialization + sharding specs for the decoder zoo.

Params are a pytree:
  {"embed": (V,d), "proj": (d,d)?, "norm_f": (d,), "lm_head": (d,V)?,
   "layers": tuple(per period position) of dicts whose arrays all carry
   a leading n_periods axis (scanned)}

``param_pspecs`` returns the same-structure tree of PartitionSpecs:
model-parallel dims on "model" (the paper's p_c role), FSDP dim on
"data" where divisible (DESIGN.md §4). Falls back to replicated on any
non-divisible dim so every assigned arch lowers on every mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig, LayerSpec


def padded_experts(n_experts: int) -> int:
    """Experts allocated, padded to the 16-wide production model axis
    (only when ≥16 — reduced smoke configs stay unpadded)."""
    return -(-n_experts // 16) * 16 if n_experts >= 16 else n_experts


def _norm(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _init_layer(key, cfg: ArchConfig, spec: LayerSpec, dtype) -> dict:
    d = cfg.d_model
    ks = iter(jax.random.split(key, 32))
    p: dict = {"ln1": jnp.ones((d,), dtype)}
    if spec.mixer == "attn":
        H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        if spec.attn == "mla":
            m = cfg.mla
            qd = m.qk_nope_head_dim + m.qk_rope_head_dim
            p |= {
                "wq": _norm(next(ks), (d, H * qd), d**-0.5, dtype),
                "w_dkv": _norm(next(ks), (d, m.kv_lora_rank), d**-0.5, dtype),
                "w_kr": _norm(next(ks), (d, m.qk_rope_head_dim), d**-0.5, dtype),
                "w_uk": _norm(next(ks), (m.kv_lora_rank, H * m.qk_nope_head_dim), m.kv_lora_rank**-0.5, dtype),
                "w_uv": _norm(next(ks), (m.kv_lora_rank, H * m.v_head_dim), m.kv_lora_rank**-0.5, dtype),
                "wo": _norm(next(ks), (H * m.v_head_dim, d), (H * m.v_head_dim) ** -0.5, dtype),
            }
        else:
            p |= {
                "wq": _norm(next(ks), (d, H * D), d**-0.5, dtype),
                "wk": _norm(next(ks), (d, KV * D), d**-0.5, dtype),
                "wv": _norm(next(ks), (d, KV * D), d**-0.5, dtype),
                "wo": _norm(next(ks), (H * D, d), (H * D) ** -0.5, dtype),
            }
            if cfg.qkv_bias:
                p |= {
                    "bq": jnp.zeros((H * D,), dtype),
                    "bk": jnp.zeros((KV * D,), dtype),
                    "bv": jnp.zeros((KV * D,), dtype),
                }
    else:  # mamba
        mb = cfg.mamba
        d_in = mb.expand * d
        dt_rank = mb.dt_rank or -(-d // 16)
        p |= {
            "in_proj": _norm(next(ks), (d, 2 * d_in), d**-0.5, dtype),
            "conv_w": _norm(next(ks), (d_in, mb.d_conv), mb.d_conv**-0.5, dtype),
            "conv_b": jnp.zeros((d_in,), dtype),
            "x_proj": _norm(next(ks), (d_in, dt_rank + 2 * mb.d_state), d_in**-0.5, dtype),
            "dt_proj": _norm(next(ks), (dt_rank, d_in), dt_rank**-0.5, dtype),
            "dt_bias": jnp.full((d_in,), -4.6, dtype),  # softplus ≈ 0.01
            "A_log": jnp.log(
                jnp.broadcast_to(jnp.arange(1, mb.d_state + 1, dtype=jnp.float32), (d_in, mb.d_state))
            ),
            "D": jnp.ones((d_in,), dtype),
            "out_proj": _norm(next(ks), (d_in, d), d_in**-0.5, dtype),
        }
    if spec.ff != "none":
        p["ln2"] = jnp.ones((d,), dtype)
    if spec.ff == "dense":
        p |= {
            "w_gate": _norm(next(ks), (d, cfg.d_ff), d**-0.5, dtype),
            "w_up": _norm(next(ks), (d, cfg.d_ff), d**-0.5, dtype),
            "w_down": _norm(next(ks), (cfg.d_ff, d), cfg.d_ff**-0.5, dtype),
        }
    elif spec.ff == "moe":
        e = cfg.moe
        # expert dim padded to a multiple of the production model-axis
        # size (16): 40 experts → 48 zero rows. The router stays (d, E)
        # so pads are never routed to; this turns granite-moe's
        # replicated-expert fallback into true expert parallelism
        # (§Perf-2: 378 MB/layer f32 weight gathers → token all_to_all).
        e_pad = padded_experts(e.n_experts)
        p |= {
            "router": _norm(next(ks), (d, e.n_experts), d**-0.5, jnp.float32),
            "w_gate_e": _norm(next(ks), (e_pad, d, e.d_ff_expert), d**-0.5, dtype),
            "w_up_e": _norm(next(ks), (e_pad, d, e.d_ff_expert), d**-0.5, dtype),
            "w_down_e": _norm(next(ks), (e_pad, e.d_ff_expert, d), e.d_ff_expert**-0.5, dtype),
        }
        if e.n_shared:
            ff_sh = e.n_shared * e.d_ff_expert
            p |= {
                "w_gate_sh": _norm(next(ks), (d, ff_sh), d**-0.5, dtype),
                "w_up_sh": _norm(next(ks), (d, ff_sh), d**-0.5, dtype),
                "w_down_sh": _norm(next(ks), (ff_sh, d), ff_sh**-0.5, dtype),
            }
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> dict:
    keys = jax.random.split(key, len(cfg.period) + 3)
    layers = []
    for i, spec in enumerate(cfg.period):
        per_period = [
            _init_layer(jax.random.fold_in(keys[i], r), cfg, spec, dtype)
            for r in range(cfg.n_periods)
        ]
        layers.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_period))
    params = {
        "embed": _norm(keys[-3], (cfg.vocab_size, cfg.d_model), cfg.d_model**-0.5, dtype),
        "norm_f": jnp.ones((cfg.d_model,), dtype),
        "layers": tuple(layers),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _norm(keys[-2], (cfg.d_model, cfg.vocab_size), cfg.d_model**-0.5, dtype)
    if cfg.frontend != "none":
        params["proj"] = _norm(keys[-1], (cfg.d_model, cfg.d_model), cfg.d_model**-0.5, dtype)
    return params


# ---------------------------------------------------------------- specs


def _div(size: int, axes: tuple[str, ...], mesh_sizes: dict[str, int]) -> bool:
    total = 1
    for a in axes:
        total *= mesh_sizes.get(a, 1)
    return size % total == 0


def _wspec(shape, want: tuple[tuple[str, ...] | None, ...], mesh_sizes) -> P:
    """Build a PartitionSpec for a (possibly period-stacked) weight,
    dropping any axis group that does not divide its dim."""
    entries = []
    for size, axes in zip(shape, want):
        if not axes:
            entries.append(None)
            continue
        axes = tuple(a for a in axes if a in mesh_sizes)
        if axes and _div(size, axes, mesh_sizes):
            entries.append(axes[0] if len(axes) == 1 else axes)
        else:
            entries.append(None)
    return P(*entries)


_MODEL = ("model",)
_FSDP = ("data",)

# per-param logical layout: map name -> tuple of axis-groups per dim
# (None = replicated). Leading n_periods dim handled by caller.
_LAYOUTS = {
    "wq": (_FSDP, _MODEL), "wk": (_FSDP, _MODEL), "wv": (_FSDP, _MODEL),
    "wo": (_MODEL, _FSDP),
    "bq": (_MODEL,), "bk": (_MODEL,), "bv": (_MODEL,),
    "w_dkv": (_FSDP, None), "w_kr": (_FSDP, None),
    "w_uk": (None, _MODEL), "w_uv": (None, _MODEL),
    "w_gate": (_FSDP, _MODEL), "w_up": (_FSDP, _MODEL), "w_down": (_MODEL, _FSDP),
    "router": (_FSDP, None),
    # experts: E over the model axis (expert parallelism) and dim-1
    # FSDP over data (all-gathered per layer inside models/moe_ep.py).
    # Falls back to replicated when E is not divisible (granite-moe).
    "w_gate_e": (_MODEL, _FSDP, None), "w_up_e": (_MODEL, _FSDP, None),
    "w_down_e": (_MODEL, _FSDP, None),
    "w_gate_sh": (_FSDP, _MODEL), "w_up_sh": (_FSDP, _MODEL), "w_down_sh": (_MODEL, _FSDP),
    "in_proj": (_FSDP, _MODEL), "out_proj": (_MODEL, _FSDP),
    "conv_w": (_MODEL, None), "conv_b": (_MODEL,),
    "x_proj": (_MODEL, None), "dt_proj": (None, _MODEL), "dt_bias": (_MODEL,),
    "A_log": (_MODEL, None), "D": (_MODEL,),
    "ln1": (None,), "ln2": (None,),
}


_DP_FSDP = ("data", "model")  # "dp" profile: model axis folds into FSDP


def param_pspecs(cfg: ArchConfig, params_shape, mesh) -> dict:
    """PartitionSpec tree matching ``params_shape`` (a tree of
    ShapeDtypeStruct or arrays). Honors cfg.sharding_profile: "dp"
    shards every weight's dim-0 over ("data","model") and nothing else
    (pure FSDP — EXPERIMENTS.md §Perf-1)."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.shape.values() if hasattr(mesh.shape, "values") else mesh.axis_sizes))
    dp = cfg.sharding_profile == "dp"

    def leaf_spec(path: tuple, leaf) -> P:
        shape = leaf.shape
        name = path[-1]
        if dp:
            if name in ("norm_f", "ln1", "ln2") or len(shape) < 2:
                return P(*([None] * len(shape)))
            if name == "embed":
                # keep vocab-parallel even under dp: unsharded logits
                # were measured at +19 GB/dev peak (§Perf-1)
                return _wspec(shape, (_MODEL, _FSDP), mesh_sizes)
            if name == "lm_head":
                return _wspec(shape, (_FSDP, _MODEL), mesh_sizes)
            if name == "proj":
                return _wspec(shape, (_DP_FSDP, None), mesh_sizes)
            # layer params carry the leading n_periods axis: FSDP dim-1
            want = (None, _DP_FSDP) + (None,) * (len(shape) - 2)
            return _wspec(shape, want, mesh_sizes)
        if name == "embed":
            # vocab-parallel (Megatron-style): d_model replicated so the
            # logits matmul contracts locally — FSDP-sharding d here was
            # measured to cost a 119 GB/dev logits all-reduce on gemma
            # (EXPERIMENTS.md §Perf, iteration 0)
            return _wspec(shape, (_MODEL, None), mesh_sizes)
        if name == "lm_head":
            return _wspec(shape, (None, _MODEL), mesh_sizes)
        if name in ("norm_f",):
            return P(None)
        if name == "proj":
            return _wspec(shape, (_FSDP, _MODEL), mesh_sizes)
        layout = _LAYOUTS.get(name)
        if layout is None:
            return P(*([None] * len(shape)))
        if cfg.expert_weight_stationary and name in ("w_gate_e", "w_up_e", "w_down_e"):
            # serving: experts resident per rank — E over "model" only
            return _wspec(shape, (None, _MODEL, None, None), mesh_sizes)
        # layer params carry a leading n_periods axis
        return _wspec(shape, (None,) + tuple(layout), mesh_sizes)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, path + (str(i),)) for i, v in enumerate(tree))
        return leaf_spec(path, tree)

    return walk(params_shape)
