"""Logical → physical sharding for the model zoo.

Activations and parameters are annotated with *logical* dims; the rules
table maps them to mesh axes (single-pod ("data", "model") or multi-pod
("pod", "data", "model")). Annotations are no-ops when no mesh is
active (single-device smoke tests).

The paper's mesh semantics (DESIGN.md §2): "data" (+ "pod") is the
FedAvg/row-team axis p_r — batch-parallel, τ-deferrable; "model" is the
column axis p_c — exact parameter sharding, the n/p_c role.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# The active profile is set by the model entry points (forward /
# decode_step) from cfg.sharding_profile; "dp" folds the model axis
# into the batch dims and disables TP rules.
_PROFILE = "tp"


def set_profile(profile: str) -> None:
    global _PROFILE
    _PROFILE = profile


RULES_DP: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data", "model"),
    "cache_seq": ("model",),  # decode caches may still seq-shard
    "vocab": ("model",),  # vocab-parallel head survives under dp
    "d_inner": (),
    None: (),
}


def _rules() -> dict[str, tuple[str, ...]]:
    return RULES_DP if _PROFILE == "dp" else RULES


# logical dim -> tuple of mesh axes (joined if several exist)
RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),  # unsharded by default
    "act_seq": ("model",),  # sequence-parallel residual stream (Megatron-SP)
    "cache_seq": ("model",),  # KV-cache seq dim: sequence-parallel reads
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "embed": (),  # d_model replicated on the model axis
    "embed_fsdp": ("data",),  # FSDP: weight-stationary dim over data
    "experts": ("model",),
    "d_inner": ("model",),  # mamba channel parallelism
    "lora": (),
    None: (),
}


def _active_axes() -> frozenset[str]:
    """Mesh axes usable in with_sharding_constraint here: Auto/Explicit
    only — axes that are Manual (inside an enclosing shard_map, e.g. the
    hybrid-2D "pod" axis) cannot appear in a constraint."""
    from repro import compat

    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return frozenset()
    return frozenset(mesh.axis_names) - compat.manual_axes(mesh)


def spec_for(*dims: str | None, axes: frozenset[str] | None = None) -> P:
    """PartitionSpec for logical dims, filtered to the active mesh."""
    active = _active_axes() if axes is None else axes
    rules = _rules()
    entries = []
    for dim in dims:
        axs = tuple(a for a in rules.get(dim, ()) if a in active)
        if not axs:
            entries.append(None)
        elif len(axs) == 1:
            entries.append(axs[0])
        else:
            entries.append(axs)
    return P(*entries)


def shard(x: jax.Array, *dims: str | None) -> jax.Array:
    """with_sharding_constraint on logical dims; no-op without a mesh or
    when a dim is not divisible by its axis size."""
    active = _active_axes()
    if not active:
        return x
    from repro import compat

    mesh = compat.get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    rules = _rules()
    entries: list = []
    used: set[str] = set()
    for dim, size in zip(dims, x.shape):
        axs = tuple(a for a in rules.get(dim, ()) if a in active and a not in used)
        # greedy prefix: drop trailing axes until the dim divides
        while axs:
            total = 1
            for a in axs:
                total *= sizes[a]
            if size % total == 0:
                break
            axs = axs[:-1]
        if axs:
            used.update(axs)
            entries.append(axs[0] if len(axs) == 1 else axs)
        else:
            entries.append(None)
    return jax.lax.with_sharding_constraint(x, P(*entries))
