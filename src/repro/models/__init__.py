"""Model zoo: config-driven decoder covering the 10 assigned archs."""

from repro.models.config import ArchConfig, LayerSpec, MLAConfig, MambaConfig, MoEConfig
from repro.models.init import init_params, param_pspecs
from repro.models.transformer import decode_step, forward, init_cache, lm_loss

__all__ = [
    "ArchConfig",
    "LayerSpec",
    "MLAConfig",
    "MambaConfig",
    "MoEConfig",
    "init_params",
    "param_pspecs",
    "decode_step",
    "forward",
    "init_cache",
    "lm_loss",
]
