"""Architecture configuration for the assigned-architecture zoo.

One flexible decoder covers all 10 assigned architectures. A model is a
stack of ``n_periods`` repetitions of a *period* — a short list of
``LayerSpec``s (length 1 for homogeneous models; 8 for Jamba's 1-attn +
7-mamba interleave). Parameters are stacked over periods and scanned,
keeping the lowered HLO size independent of depth.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

AttnKind = Literal["full", "swa", "mla", "none"]
FFKind = Literal["dense", "moe", "none"]
MixerKind = Literal["attn", "mamba"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer within a period."""

    mixer: MixerKind = "attn"
    attn: AttnKind = "full"  # only read when mixer == "attn"
    ff: FFKind = "dense"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 1024
    n_shared: int = 0  # shared (always-on) experts, DeepSeek-style
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention dims."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 → ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads
    period: tuple[LayerSpec, ...] = (LayerSpec(),)
    mlp_act: Literal["silu", "gelu"] = "silu"  # SwiGLU vs GeGLU gate
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # >0 enables SWA for attn == "swa"
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mamba: MambaConfig | None = None
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # multimodal stub: number of prefix embedding positions fed directly
    # (ViT patches / audio frames); 0 = text-only
    frontend: Literal["none", "vision", "audio"] = "none"
    max_seq_len: int = 32_768
    # mesh-role profile (the paper's regime-aware mesh selection applied
    # to NN training — EXPERIMENTS.md §Perf-1): "tp" uses the "model"
    # axis for tensor/expert parallelism; "dp" folds the "model" axis
    # into batch/FSDP (small dense models whose heads/ffn cannot使用 a
    # 16-way TP axis profitably).
    sharding_profile: Literal["tp", "dp"] = "tp"
    # serving (decode) keeps expert weights resident instead of
    # FSDP-regathering them per layer per token (§Perf-2/4)
    expert_weight_stationary: bool = False

    def __post_init__(self):
        if self.n_layers % len(self.period):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"period length {len(self.period)}"
            )
        for spec in self.period:
            if spec.ff == "moe" and self.moe is None:
                raise ValueError(f"{self.name}: MoE layer without moe config")
            if spec.mixer == "mamba" and self.mamba is None:
                raise ValueError(f"{self.name}: mamba layer without mamba config")
            if spec.attn == "mla" and self.mla is None:
                raise ValueError(f"{self.name}: MLA layer without mla config")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def has_attention(self) -> bool:
        return any(s.mixer == "attn" for s in self.period)

    @property
    def subquadratic(self) -> bool:
        """True if the arch can decode with O(1)-ish per-token state at
        500k context: SSM/hybrid or sliding-window attention."""
        return all(
            s.mixer == "mamba" or (s.mixer == "attn" and s.attn == "swa")
            for s in self.period
        ) or (
            any(s.mixer == "mamba" for s in self.period)  # hybrid: bounded attn share
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for spec in self.period:
            layer = 0
            if spec.mixer == "attn":
                if spec.attn == "mla":
                    m = self.mla
                    q_dim = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    layer += d * q_dim
                    layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    layer += self.n_heads * m.v_head_dim * d
                else:
                    layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    layer += self.n_heads * hd * d
            else:
                mb = self.mamba
                d_in = mb.expand * d
                dt_rank = mb.dt_rank or -(-d // 16)
                layer += d * 2 * d_in + d_in * mb.d_conv
                layer += d_in * (dt_rank + 2 * mb.d_state) + dt_rank * d_in
                layer += d_in * mb.d_state + d_in + d_in * d
            if spec.ff == "dense":
                layer += 3 * d * self.d_ff
            elif spec.ff == "moe":
                e = self.moe
                layer += d * e.n_experts  # router
                layer += e.n_experts * 3 * d * e.d_ff_expert
                layer += e.n_shared * 3 * d * e.d_ff_expert
            total += layer * self.n_periods
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        e = self.moe
        moe_layers = sum(1 for s in self.period if s.ff == "moe") * self.n_periods
        unused = (e.n_experts - e.top_k) * 3 * self.d_model * e.d_ff_expert
        return full - moe_layers * unused
