"""Deterministic fault injection — the repo's chaos seam.

Production runs lose workers, tear checkpoints, and hit transient I/O;
the paper's trade-off space (§5) assumes none of that. This module
gives the runtime *one* place where such failures are injected, so the
fault-tolerance layer (Session autosave, sweep retry/quarantine,
elastic re-planning) can be driven deterministically in tests instead
of hoping a real preemption lands in the right window.

A ``FaultPlan`` is a list of ``FaultEvent``s — (kind, site, at) plus
kind-specific knobs — either hand-written or generated deterministically
from a seed (``FaultPlan.from_seed``; seed a plan from a spec's
``content_hash()`` to make chaos reproducible per experiment). A plan
is ``install``-ed for a scope; instrumented code consults the seam at
named *sites* via ``poke``:

  site "round"    ``Session.step_rounds`` after every completed round
                  boundary (``at`` = global rounds done). Backend-
                  neutral: the Session drives both the simulated engine
                  and the shard_map driver, so both backends honor the
                  same plan.
  site "commit"   ``train.checkpoint._write_atomic`` between temp-write
                  and rename — the atomicity window.
  site "save"     after a session checkpoint is durably committed
                  (``at`` = rounds_done; ``path`` = the final .npz) —
                  where ``ckpt_truncate`` tears the file.
  site "point"    ``repro.api.sweep`` immediately before a sweep point
                  runs (``at`` = point index).

Kinds:

  kill           SIGKILL the process (``install(..., hard_kill=True)``
                 — a real worker death, nothing runs after it) or raise
                 ``WorkerKilled`` (the in-process stand-in).
  io_error       raise ``TransientIOError`` — clears after ``times``
                 firings (a retry eventually succeeds).
  stall          sleep ``delay_s`` — a slow round / straggler.
  ckpt_truncate  truncate the just-committed checkpoint payload by
                 ``truncate_bytes`` — a torn write the integrity hashes
                 must catch on restore.

When no plan is installed every ``poke`` is a no-op — the seam costs
one ContextVar read on the host between rounds, nothing inside jit.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
import signal
import time
from contextvars import ContextVar

import numpy as np

FAULT_KINDS = ("kill", "io_error", "stall", "ckpt_truncate")
FAULT_SITES = ("round", "commit", "save", "point")


class InjectedFault(RuntimeError):
    """Base of every exception the seam raises."""


class WorkerKilled(InjectedFault):
    """In-process stand-in for a worker death (soft ``kill``)."""


class TransientIOError(OSError, InjectedFault):
    """An injected transient I/O failure — retriable by policy."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One planned fault.

    kind            one of ``FAULT_KINDS``.
    site            where it fires (``FAULT_SITES``).
    at              fire when the site's counter equals this (round
                    index for "round"/"save", point index for "point");
                    None = fire at every visit (until ``times`` runs out).
    times           how many firings before the event is spent (an
                    ``io_error`` with times=1 is transient: the retry
                    sails through).
    delay_s         stall duration ("stall").
    truncate_bytes  bytes chopped off the payload ("ckpt_truncate").
    """

    kind: str
    site: str = "round"
    at: int | None = None
    times: int = 1
    delay_s: float = 0.05
    truncate_bytes: int = 128

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"kind={self.kind!r} not in {FAULT_KINDS}")
        if self.site not in FAULT_SITES:
            raise ValueError(f"site={self.site!r} not in {FAULT_SITES}")
        if self.times < 1:
            raise ValueError(f"times={self.times} must be ≥ 1")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic list of faults for one run."""

    events: tuple[FaultEvent, ...] = ()

    def __init__(self, events=()):
        object.__setattr__(self, "events", tuple(events))

    @classmethod
    def from_seed(
        cls,
        seed: int | str,
        rounds: int,
        kinds: tuple[str, ...] = ("stall", "io_error"),
        n_faults: int = 2,
        delay_s: float = 0.05,
    ) -> "FaultPlan":
        """Generate a reproducible plan. ``seed`` may be an int or any
        string (pass a spec's ``content_hash()`` to key the chaos to the
        experiment); identical seeds always produce identical plans."""
        if isinstance(seed, str):
            seed = int(hashlib.sha256(seed.encode()).hexdigest()[:12], 16)
        rng = np.random.default_rng(int(seed))
        events = []
        for _ in range(int(n_faults)):
            kind = kinds[int(rng.integers(len(kinds)))]
            at = int(rng.integers(1, max(int(rounds), 2)))
            site = "save" if kind == "ckpt_truncate" else "round"
            events.append(FaultEvent(kind=kind, site=site, at=at, delay_s=delay_s))
        events.sort(key=lambda e: (e.at if e.at is not None else -1, e.kind))
        return cls(events)


class FaultInjector:
    """The live seam: matches ``poke`` calls against the plan's
    remaining events and fires them. ``fired`` is the audit log —
    (kind, site, at) per firing — so tests can assert what the chaos
    actually did."""

    def __init__(self, plan: FaultPlan, hard_kill: bool = False):
        self.plan = plan
        self.hard_kill = hard_kill
        self._remaining = [e.times for e in plan.events]
        self.fired: list[tuple[str, str, int]] = []

    def poke(self, site: str, at: int, path=None) -> None:
        for i, ev in enumerate(self.plan.events):
            if self._remaining[i] < 1 or ev.site != site:
                continue
            if ev.at is not None and ev.at != at:
                continue
            self._remaining[i] -= 1
            self.fired.append((ev.kind, site, int(at)))
            self._fire(ev, path)

    def _fire(self, ev: FaultEvent, path) -> None:
        if ev.kind == "stall":
            time.sleep(ev.delay_s)
        elif ev.kind == "io_error":
            raise TransientIOError(
                f"injected transient I/O error at {ev.site}:{ev.at}"
            )
        elif ev.kind == "kill":
            if self.hard_kill:
                os.kill(os.getpid(), signal.SIGKILL)  # nothing runs after this
            raise WorkerKilled(f"injected worker kill at {ev.site}:{ev.at}")
        elif ev.kind == "ckpt_truncate":
            if path is None:
                return  # site passed no file — nothing to tear
            size = os.path.getsize(path)
            os.truncate(path, max(0, size - ev.truncate_bytes))


_ACTIVE: ContextVar[FaultInjector | None] = ContextVar("fault_injector", default=None)


def active() -> FaultInjector | None:
    """The installed injector, or None (the normal, fault-free case)."""
    return _ACTIVE.get()


@contextlib.contextmanager
def install(plan: FaultPlan, hard_kill: bool = False):
    """Install ``plan`` for the dynamic extent of the with-block and
    yield the live ``FaultInjector`` (its ``fired`` log is the test
    oracle)."""
    inj = FaultInjector(plan, hard_kill=hard_kill)
    token = _ACTIVE.set(inj)
    try:
        yield inj
    finally:
        _ACTIVE.reset(token)


def poke(site: str, at: int, path=None) -> None:
    """Consult the seam at an instrumented site — no-op unless a plan
    is installed."""
    inj = _ACTIVE.get()
    if inj is not None:
        inj.poke(site, at, path=path)
