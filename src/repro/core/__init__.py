"""The paper's contribution: one 2D-parallel SGD family, in JAX.

The unified engine (repro.core.engine) implements the whole
(p_r, p_c, s, τ) family with one inner loop on the scatter-free Pallas
Gram path:

  run_parallel_sgd     the engine — any point of the family
  ParallelSGDSchedule  the knob object (corners by name: mb_sgd,
                       sstep, fedavg, hybrid)
  bundle_gram_v        the shared s-bundle primitive (G, v)

Configured corners, kept as thin wrappers for compatibility:

  run_sgd              Algorithm 1 — sequential mini-batch SGD
  run_sstep_sgd        Algorithm 3 — s-step (communication-avoiding) SGD
  run_fedavg           Algorithm 2 — FedAvg / local SGD
  run_hybrid_sgd       HybridSGD, exact simulated-rank semantics
  run_hybrid_distributed  HybridSGD under shard_map on a 2D device mesh
                          (consumes the same ParallelSGDSchedule and
                          shares the engine's bundle primitive)
  HybridDriver         the round-incremental form of the same executor
                       (device-resident carry; advance k rounds at a
                       time — what repro.api.Session drives)
  run_engine_chunk     the simulated engine's round-incremental entry
                       (jit-cached chunk executable, traced offset)

Corner identities (tested): hybrid(p_r=1) ≡ s-step; hybrid(p_r=p, s=1)
≡ FedAvg; s-step(s=1) ≡ SGD; fedavg(τ=1) ≡ synchronous MB-SGD.

Experiment-level code should normally enter through the declarative
front door instead: repro.api (ExperimentSpec → plan → run → RunReport)
plans a spec with the cost model and dispatches it to either the
simulated engine or the shard_map executor.
"""

from repro.core.comm import (
    COUNTING,
    MESH,
    TIMED,
    Collectives,
    CommLedger,
    CommRate,
)
from repro.core.faults import (
    FaultEvent,
    FaultInjector,
    FaultPlan,
    InjectedFault,
    TransientIOError,
    WorkerKilled,
)
from repro.core.objective import (
    LOGISTIC,
    OBJECTIVES,
    LeastSquaresObjective,
    LogisticObjective,
    Objective,
    SquaredHingeObjective,
    get_objective,
)
from repro.core.problem import (
    LogisticProblem,  # deprecated alias of Problem
    Problem,
    full_loss,  # deprecated: use problem_loss
    make_problem,
    problem_loss,
    sigmoid_residual,  # deprecated: use LOGISTIC.residual
)
from repro.core.engine import (
    ParallelSGDSchedule,
    bundle_gram_v,
    engine_comm_ledger,
    inner_corrections,
    run_engine_chunk,
    run_parallel_sgd,
    single_team,
)
from repro.core.sgd import run_sgd, sgd_step
from repro.core.sstep import run_sstep_sgd
from repro.core.teams import TeamProblem, global_problem, stack_row_teams
from repro.core.fedavg import run_fedavg
from repro.core.hybrid import run_hybrid_sgd
from repro.core.distributed import (
    Hybrid2DProblem,
    HybridDriver,
    build_2d_problem,
    gather_x,
    hybrid_comm_ledger,
    make_hybrid_step,
    run_hybrid_distributed,
    scatter_x,
)

__all__ = [
    "COUNTING",
    "MESH",
    "TIMED",
    "Collectives",
    "CommLedger",
    "CommRate",
    "engine_comm_ledger",
    "hybrid_comm_ledger",
    "FaultEvent",
    "FaultInjector",
    "FaultPlan",
    "InjectedFault",
    "TransientIOError",
    "WorkerKilled",
    "LOGISTIC",
    "OBJECTIVES",
    "Objective",
    "LogisticObjective",
    "SquaredHingeObjective",
    "LeastSquaresObjective",
    "get_objective",
    "Problem",
    "problem_loss",
    "LogisticProblem",
    "full_loss",
    "make_problem",
    "sigmoid_residual",
    "ParallelSGDSchedule",
    "bundle_gram_v",
    "inner_corrections",
    "run_engine_chunk",
    "run_parallel_sgd",
    "single_team",
    "run_sgd",
    "sgd_step",
    "run_sstep_sgd",
    "TeamProblem",
    "global_problem",
    "stack_row_teams",
    "run_fedavg",
    "run_hybrid_sgd",
    "Hybrid2DProblem",
    "HybridDriver",
    "build_2d_problem",
    "gather_x",
    "make_hybrid_step",
    "run_hybrid_distributed",
    "scatter_x",
]
