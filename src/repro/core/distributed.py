"""HybridSGD over a real 2D device mesh (shard_map).

This is the production distribution of the paper's algorithm. The mesh
axes are ("rows", "cols") = (p_r, p_c):

  device (i, j) holds the ELL block of diag(y)·A for row-team i and
  column-partition j (columns locally renumbered in partition order),
  plus its n_loc-word shard of the weight vector.

Per s-bundle (the paper's row-team Allreduce):
  G_partial, v_partial computed locally via the engine's shared bundle
  primitive (repro.core.engine.bundle_gram_v — scatter-free) → psum
  over "cols" (exactly the (s²b² + sb)-word payload of Table 3); the
  weight update Yᵀu is fully local under column partitioning.
Per τ inner iterations (the paper's column Allreduce):
  x_local ← pmean over "rows" (n/p_c words per rank).

Numerics match repro.core.engine.run_parallel_sgd exactly (tested in a
multi-device subprocess); the simulated version is the oracle.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.engine import bundle_gram_v, inner_corrections
from repro.sparse.csr import CSRMatrix
from repro.sparse.ell import EllBlock, ell_rmatvec
from repro.sparse.partition import ColumnPartition, partition_columns, partition_rows


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Hybrid2DProblem:
    """Device-layout HybridSGD problem.

    indices/values: (p_r, p_c, rows_local, width) — ELL blocks, column
    ids local to each column shard.
    col_sizes: (p_c,) true (unpadded) columns per shard; shards pad to
    n_loc = max(col_sizes).
    """

    indices: jnp.ndarray
    values: jnp.ndarray
    col_sizes: jnp.ndarray
    p_r: int = dataclasses.field(metadata=dict(static=True))
    p_c: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    n_loc: int = dataclasses.field(metadata=dict(static=True))

    @property
    def rows_local(self) -> int:
        return int(self.indices.shape[2])

    @property
    def width(self) -> int:
        return int(self.indices.shape[3])


def build_2d_problem(
    a: CSRMatrix,
    y: np.ndarray,
    p_r: int,
    p_c: int,
    partitioner: str,
    row_multiple: int = 1,
    dtype=jnp.float32,
) -> tuple[Hybrid2DProblem, ColumnPartition]:
    """Partition (A, y) onto the p_r × p_c mesh. Row bounds match
    repro.core.teams.stack_row_teams so simulated and distributed
    sample sequences agree."""
    ya = a.scale_rows(np.asarray(y, dtype=np.float64))
    cp = partition_columns(a, p_c, partitioner)
    rb = partition_rows(a.m, p_r)
    rows_local = max(int(rb[i + 1] - rb[i]) for i in range(p_r))
    rows_local = -(-rows_local // row_multiple) * row_multiple
    n_loc = int(cp.n_local.max())

    blocks = []
    width = 1
    for i in range(p_r):
        row_blk = ya.row_block(int(rb[i]), int(rb[i + 1]))
        row = [row_blk.select_columns(cp.rank_cols(j)) for j in range(p_c)]
        blocks.append(row)
        for blk in row:
            if blk.nnz:
                width = max(width, int(blk.nnz_per_row.max()))

    idx = np.zeros((p_r, p_c, rows_local, width), dtype=np.int32)
    val = np.zeros((p_r, p_c, rows_local, width), dtype=np.float64)
    for i in range(p_r):
        for j in range(p_c):
            blk = blocks[i][j]
            for r in range(blk.m):
                lo, hi = int(blk.indptr[r]), int(blk.indptr[r + 1])
                k = hi - lo
                idx[i, j, r, :k] = blk.indices[lo:hi]
                val[i, j, r, :k] = blk.data[lo:hi]
    prob = Hybrid2DProblem(
        indices=jnp.asarray(idx),
        values=jnp.asarray(val, dtype=dtype),
        col_sizes=jnp.asarray(np.asarray(cp.n_local, np.int32)),
        p_r=p_r,
        p_c=p_c,
        m=a.m,
        n=a.n,
        n_loc=n_loc,
    )
    return prob, cp


def scatter_x(x: np.ndarray, cp: ColumnPartition, n_loc: int) -> np.ndarray:
    """Global (n,) weights → padded sharded layout (p_c · n_loc,)."""
    out = np.zeros(cp.p * n_loc, dtype=x.dtype)
    for j in range(cp.p):
        cols = cp.rank_cols(j)
        out[j * n_loc : j * n_loc + len(cols)] = x[cols]
    return out


def gather_x(x_pad: np.ndarray, cp: ColumnPartition, n_loc: int, n: int) -> np.ndarray:
    """Inverse of scatter_x."""
    out = np.zeros(n, dtype=x_pad.dtype)
    for j in range(cp.p):
        cols = cp.rank_cols(j)
        out[cols] = x_pad[j * n_loc : j * n_loc + len(cols)]
    return out


def make_hybrid_step(
    mesh: Mesh,
    prob: Hybrid2DProblem,
    s: int,
    b: int,
    tau: int,
    eta: float,
    gram: str = "blocked",
    bk: int = 512,
):
    """Return a jitted fn (indices, values, x_pad, round_idx) → x_pad
    executing one HybridSGD round (τ inner s-step iterations + column
    average) under shard_map on ``mesh`` (axes "rows", "cols").

    ``gram`` selects the bundle backend (see engine.GRAM_METHODS);
    "blocked" is the scatter-free panel-streaming path, safe inside
    shard_map on every backend."""
    if tau % s:
        raise ValueError("tau must be divisible by s")
    sb = s * b
    n_loc = prob.n_loc
    bundles = tau // s

    def round_fn(idx_blk, val_blk, x_loc, round_idx):
        # shapes inside shard_map: idx/val (1, 1, rows_local, width),
        # x_loc (n_loc,)
        idx_blk = idx_blk[0, 0]
        val_blk = val_blk[0, 0]
        m_local = idx_blk.shape[0]

        def bundle(x_loc, t):
            k0 = round_idx * bundles + t
            start = (k0 * sb) % m_local
            bi = jax.lax.dynamic_slice_in_dim(idx_blk, start, sb, axis=0)
            bv = jax.lax.dynamic_slice_in_dim(val_blk, start, sb, axis=0)
            # local partial (G, v) via the engine's shared primitive —
            # then the row-team Allreduce (paper Table 3 payload)
            g_part, v_part = bundle_gram_v(bi, bv, x_loc, n_loc, gram=gram, bk=bk)
            g = jax.lax.psum(g_part, "cols")
            v = jax.lax.psum(v_part, "cols")
            u = inner_corrections(g, v, s, b, eta)
            # Yᵀu stays local under column partitioning
            blk = EllBlock(indices=bi, values=bv, n=n_loc)
            return x_loc + (eta / b) * ell_rmatvec(blk, u).astype(x_loc.dtype), None

        x_loc, _ = jax.lax.scan(bundle, x_loc, jnp.arange(bundles))
        # column Allreduce: FedAvg averaging across row teams (n/p_c words)
        x_loc = jax.lax.pmean(x_loc, "rows")
        return x_loc[None, None]  # restore mesh dims for out_specs

    smapped = shard_map(
        round_fn,
        mesh=mesh,
        in_specs=(P("rows", "cols"), P("rows", "cols"), P("cols"), P()),
        out_specs=P("rows", "cols"),
    )

    @jax.jit
    def step(idx, val, x_pad, round_idx):
        out = smapped(idx, val, x_pad, round_idx)
        # out: (p_r, p_c·n_loc) replicated content along rows — take row 0
        return out[0].reshape(-1)

    return step


def run_hybrid_distributed(
    mesh: Mesh,
    prob: Hybrid2DProblem,
    cp: ColumnPartition,
    x0: np.ndarray,
    s: int,
    b: int,
    eta: float,
    tau: int,
    rounds: int,
    gram: str = "blocked",
):
    """Convenience driver: place data, run ``rounds`` rounds, gather x."""
    step = make_hybrid_step(mesh, prob, s, b, tau, eta, gram=gram)
    data_sh = NamedSharding(mesh, P("rows", "cols"))
    x_sh = NamedSharding(mesh, P("cols"))
    idx = jax.device_put(prob.indices, data_sh)
    val = jax.device_put(prob.values, data_sh)
    x_pad = jax.device_put(jnp.asarray(scatter_x(np.asarray(x0), cp, prob.n_loc)), x_sh)
    for r in range(rounds):
        x_pad = step(idx, val, x_pad, jnp.int32(r))
        x_pad = jax.device_put(x_pad, x_sh)
    return gather_x(np.asarray(x_pad), cp, prob.n_loc, prob.n)
