"""HybridSGD over a real 2D device mesh (shard_map).

This is the production distribution of the paper's algorithm. The mesh
axes are ("rows", "cols") = (p_r, p_c):

  device (i, j) holds the ELL block of diag(y)·A for row-team i and
  column-partition j (columns locally renumbered in partition order),
  plus its n_loc-word shard of the weight vector.

Per s-bundle (the paper's row-team Allreduce):
  G_partial, v_partial computed locally via the engine's shared bundle
  primitive (repro.core.engine.bundle_gram_v — scatter-free) → psum
  over "cols" (exactly the (s²b² + sb)-word payload of Table 3); the
  weight update Yᵀu is fully local under column partitioning.
Per τ inner iterations (the paper's column Allreduce):
  x_local ← pmean over "rows" (n/p_c words per rank).

Both collectives are issued through repro.core.comm (the mesh — or,
for calibration, timed — collectives): ``hybrid_comm_ledger`` captures
the round body's exact spans and payloads into a ``CommLedger``, and
``HybridDriver`` commits rounds (and, timed, per-round wall seconds)
into it as it advances.

The execution knobs arrive as one ``ParallelSGDSchedule`` — the same
object the simulated engine consumes — so the two paths cannot drift on
plumbing. The legacy loose-scalar signatures (s=..., b=..., ...) are
kept as deprecated shims.

Numerics match repro.core.engine.run_parallel_sgd exactly (tested in a
multi-device subprocess); the simulated version is the oracle.
"""

from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import comm as comm_plane
from repro.core.comm import MESH, Collectives, CommLedger
from repro.core.engine import (
    ParallelSGDSchedule,
    bundle_gram_v,
    check_delay,
    delayed_bundle_scan,
    inner_corrections,
    unwire_gv,
    wire_gv,
)
from repro.core.objective import LOGISTIC, Objective, get_objective
from repro.core.problem import Problem, problem_loss
from repro.sparse.csr import CSRMatrix
from repro.sparse.ell import EllBlock, ell_rmatvec
from repro.sparse.partition import ColumnPartition, partition_columns, partition_rows


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Hybrid2DProblem:
    """Device-layout HybridSGD problem.

    indices/values: (p_r, p_c, rows_local, width) — ELL blocks, column
    ids local to each column shard.
    col_sizes: (p_c,) true (unpadded) columns per shard; shards pad to
    n_loc = max(col_sizes).
    """

    indices: jnp.ndarray
    values: jnp.ndarray
    col_sizes: jnp.ndarray
    p_r: int = dataclasses.field(metadata=dict(static=True))
    p_c: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    n_loc: int = dataclasses.field(metadata=dict(static=True))
    objective: Objective = dataclasses.field(
        default=LOGISTIC, metadata=dict(static=True)
    )

    @property
    def rows_local(self) -> int:
        return int(self.indices.shape[2])

    @property
    def width(self) -> int:
        return int(self.indices.shape[3])


def build_2d_problem(
    a: CSRMatrix,
    y: np.ndarray,
    p_r: int,
    p_c: int,
    partitioner: str,
    row_multiple: int = 1,
    dtype=jnp.float32,
    objective: str | Objective = LOGISTIC,
) -> tuple[Hybrid2DProblem, ColumnPartition]:
    """Partition (A, y) onto the p_r × p_c mesh. Row bounds match
    repro.core.teams.stack_row_teams so simulated and distributed
    sample sequences agree; ``objective`` is the shared convex loss."""
    obj = get_objective(objective)
    ya = a.scale_rows(np.asarray(y, dtype=np.float64))
    cp = partition_columns(a, p_c, partitioner)
    rb = partition_rows(a.m, p_r)
    rows_local = max(int(rb[i + 1] - rb[i]) for i in range(p_r))
    rows_local = -(-rows_local // row_multiple) * row_multiple
    n_loc = int(cp.n_local.max())

    blocks = []
    width = 1
    for i in range(p_r):
        row_blk = ya.row_block(int(rb[i]), int(rb[i + 1]))
        row = [row_blk.select_columns(cp.rank_cols(j)) for j in range(p_c)]
        blocks.append(row)
        for blk in row:
            if blk.nnz:
                width = max(width, int(blk.nnz_per_row.max()))

    idx = np.zeros((p_r, p_c, rows_local, width), dtype=np.int32)
    val = np.zeros((p_r, p_c, rows_local, width), dtype=np.float64)
    for i in range(p_r):
        for j in range(p_c):
            blk = blocks[i][j]
            for r in range(blk.m):
                lo, hi = int(blk.indptr[r]), int(blk.indptr[r + 1])
                k = hi - lo
                idx[i, j, r, :k] = blk.indices[lo:hi]
                val[i, j, r, :k] = blk.data[lo:hi]
    prob = Hybrid2DProblem(
        indices=jnp.asarray(idx),
        values=jnp.asarray(val, dtype=dtype),
        col_sizes=jnp.asarray(np.asarray(cp.n_local, np.int32)),
        p_r=p_r,
        p_c=p_c,
        m=a.m,
        n=a.n,
        n_loc=n_loc,
        objective=obj,
    )
    return prob, cp


def scatter_x(x: np.ndarray, cp: ColumnPartition, n_loc: int) -> np.ndarray:
    """Global (n,) weights → padded sharded layout (p_c · n_loc,)."""
    out = np.zeros(cp.p * n_loc, dtype=x.dtype)
    for j in range(cp.p):
        cols = cp.rank_cols(j)
        out[j * n_loc : j * n_loc + len(cols)] = x[cols]
    return out


def gather_x(x_pad: np.ndarray, cp: ColumnPartition, n_loc: int, n: int) -> np.ndarray:
    """Inverse of scatter_x."""
    out = np.zeros(n, dtype=x_pad.dtype)
    for j in range(cp.p):
        cols = cp.rank_cols(j)
        out[cols] = x_pad[j * n_loc : j * n_loc + len(cols)]
    return out


def _legacy_schedule(
    p_r: int, s, b, eta, tau, rounds, gram: str, caller: str
) -> ParallelSGDSchedule:
    """Adapt the pre-API loose-scalar knobs into a schedule (deprecated)."""
    warnings.warn(
        f"{caller}(s=..., b=..., tau=..., ...) with loose scalars is deprecated; "
        f"pass a repro.core.ParallelSGDSchedule (or use the repro.api front door)",
        DeprecationWarning,
        stacklevel=3,
    )
    if b is None or eta is None or tau is None:
        raise TypeError(f"legacy {caller} call is missing one of (b, eta, tau)")
    return ParallelSGDSchedule.hybrid(
        p_r, int(s), int(b), float(eta), int(tau),
        rounds=int(rounds) if rounds is not None else 1, gram=gram or "blocked",
    )


def _reject_scalars_with_schedule(caller: str, **scalars) -> None:
    """A schedule is the whole configuration — a scalar knob alongside
    it would be silently ignored, so make that a hard error."""
    extras = [k for k, v in scalars.items() if v is not None]
    if extras:
        raise TypeError(
            f"{caller}: got both a ParallelSGDSchedule and scalar knob(s) "
            f"{extras} — the schedule carries all knobs; use "
            f"dataclasses.replace(sched, ...) instead"
        )


def _build_round_fn(prob: Hybrid2DProblem, sched: ParallelSGDSchedule,
                    comm: Collectives = MESH):
    """The per-rank round body (what shard_map maps): τ inner s-step
    iterations + the column average, all communication issued through
    the ``comm`` collectives. Shared by ``make_hybrid_step`` (which
    shard_maps and jits it) and ``hybrid_comm_ledger`` (which captures
    it abstractly) — one function, so the ledger cannot drift from the
    executed collectives."""
    s, b_, eta_ = sched.s, sched.b, sched.eta
    sb = s * b_
    n_loc = prob.n_loc
    bundles = sched.tau // s
    objective = prob.objective
    lam = objective.l2
    # "pallas" is the simulated engine's default; inside shard_map the
    # same math runs on the blocked panel-streaming path (shard_map-safe
    # everywhere, incl. CPU interpret containers).
    gram_ = "blocked" if sched.gram == "pallas" else sched.gram
    bk_ = sched.bk

    def round_fn(idx_blk, val_blk, x_loc, round_idx):
        # shapes inside shard_map: idx/val (1, 1, rows_local, width),
        # x_loc (n_loc,)
        idx_blk = idx_blk[0, 0]
        val_blk = val_blk[0, 0]
        m_local = idx_blk.shape[0]

        if sched.delay:
            # Delay-D pipeline: the per-bundle psum is *issued* at
            # bundle t and first *consumed* at bundle t+D, so XLA's
            # async dispatch has D bundle-computes of independent work
            # to run while the reduction is in flight. The staging
            # logic is the engine's shared scan — both backends execute
            # the same pipelined math by construction.
            def slice_bundle(t):
                k0 = round_idx * bundles + t
                start = (k0 * sb) % m_local
                bi = jax.lax.dynamic_slice_in_dim(idx_blk, start, sb, axis=0)
                bv = jax.lax.dynamic_slice_in_dim(val_blk, start, sb, axis=0)
                return bi, bv

            x_loc = delayed_bundle_scan(
                x_loc, slice_bundle=slice_bundle, bundles=bundles, n=n_loc,
                sched=sched, eta=eta_, objective=objective, comm=comm,
                gram=gram_,
            )
            return comm.allmean_rows(x_loc)

        def bundle(x_loc, t):
            k0 = round_idx * bundles + t
            start = (k0 * sb) % m_local
            bi = jax.lax.dynamic_slice_in_dim(idx_blk, start, sb, axis=0)
            bv = jax.lax.dynamic_slice_in_dim(val_blk, start, sb, axis=0)
            # local partial (G, v) via the engine's shared primitive —
            # then the row-team Allreduce (paper Table 3 payload; bf16
            # words under the precision knob — the psum sums narrow
            # payloads, corrections run on the f32 upcast)
            g_part, v_part = bundle_gram_v(
                bi, bv, x_loc, n_loc, gram=gram_, bk=bk_, bm=sched.bm,
                precision=sched.precision,
            )
            g, v = comm.allreduce_cols(
                wire_gv((g_part, v_part), sched.precision),
                calls_per_round=bundles,
            )
            g, v = unwire_gv((g, v), sched.precision)
            u = inner_corrections(g, v, s, b_, eta_, objective)
            # Yᵀu stays local under column partitioning
            blk = EllBlock(indices=bi, values=bv, n=n_loc)
            if lam == 0.0:
                return x_loc + (eta_ / b_) * ell_rmatvec(blk, u).astype(x_loc.dtype), None
            # decay-folded update, exact under column sharding: the
            # L2 decay is elementwise, so each shard decays its own
            # slice (padded slots stay zero: ρ·0 + 0).
            rho_s = jnp.asarray(1.0 - eta_ * lam, x_loc.dtype) ** s
            return (
                rho_s * x_loc + (eta_ / b_) * ell_rmatvec(blk, u).astype(x_loc.dtype),
                None,
            )

        x_loc, _ = jax.lax.scan(bundle, x_loc, jnp.arange(bundles))
        # column Allreduce: FedAvg averaging across row teams (n/p_c
        # words) — the result is row-replicated, so the out_spec can
        # drop the "rows" axis.
        return comm.allmean_rows(x_loc)

    return round_fn


def hybrid_comm_ledger(prob: Hybrid2DProblem, sched: ParallelSGDSchedule,
                       comm: Collectives = MESH) -> CommLedger:
    """Per-rank ``CommLedger`` of the shard_map execution: the *same*
    round body ``make_hybrid_step`` runs, traced abstractly
    (``jax.eval_shape`` — no devices, no mesh needed) with the comm
    recorder installed. Every psum/pmean the step will issue records its
    span and per-rank payload from the traced per-shard shapes."""
    round_fn = _build_round_fn(prob, sched, comm)
    rates = comm_plane.capture_rates(
        round_fn,
        jax.ShapeDtypeStruct((1, 1, prob.rows_local, prob.width), prob.indices.dtype),
        jax.ShapeDtypeStruct((1, 1, prob.rows_local, prob.width), prob.values.dtype),
        jax.ShapeDtypeStruct((prob.n_loc,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        spans={"cols": prob.p_c, "rows": prob.p_r},
    )
    return CommLedger(rates=rates, delay=sched.delay)


def make_hybrid_step(
    mesh: Mesh,
    prob: Hybrid2DProblem,
    sched: ParallelSGDSchedule | int | None = None,
    b: int | None = None,
    tau: int | None = None,
    eta: float | None = None,
    gram: str | None = None,
    bk: int | None = None,
    *,
    s: int | None = None,
    comm: Collectives = MESH,
):
    """Return a jitted fn (indices, values, x_pad, round_idx) → x_pad
    executing one HybridSGD round (τ inner s-step iterations + column
    average) under shard_map on ``mesh`` (axes "rows", "cols").

    ``sched`` is the same ``ParallelSGDSchedule`` the simulated engine
    consumes; its ``gram`` selects the bundle backend (a schedule-level
    "pallas" is executed as "blocked" here — identical math, and the
    panel-streaming jnp path is safe inside shard_map on every backend).
    All collectives are issued through ``comm`` (repro.core.comm; the
    mesh/timed kinds run the same psum/pmean this module always issued).

    The returned step donates ``x_pad`` and pins its output to the
    ``P("cols")`` sharding of the input, so drivers can chain rounds
    without re-placing the weights (no per-round sync + copy).

    The legacy signature ``make_hybrid_step(mesh, prob, s, b, tau, eta,
    gram=..., bk=...)`` still works but emits a DeprecationWarning.
    """
    if isinstance(sched, ParallelSGDSchedule):
        _reject_scalars_with_schedule(
            "make_hybrid_step", s=s, b=b, tau=tau, eta=eta, gram=gram, bk=bk
        )
    else:
        s_val = sched if sched is not None else s
        if s_val is None:
            raise TypeError("make_hybrid_step needs a ParallelSGDSchedule (or legacy s=...)")
        sched = _legacy_schedule(prob.p_r, s_val, b, eta, tau, None, gram, "make_hybrid_step")
        if bk is not None:
            sched = dataclasses.replace(sched, bk=bk)
    if sched.tau % sched.s:
        raise ValueError(f"tau={sched.tau} must be divisible by s={sched.s}")
    if tuple(mesh.axis_names) != ("rows", "cols"):
        raise ValueError(f'mesh axes must be ("rows", "cols"), got {mesh.axis_names}')
    if dict(mesh.shape) != {"rows": prob.p_r, "cols": prob.p_c}:
        raise ValueError(
            f"mesh {dict(mesh.shape)} does not match problem layout "
            f"{prob.p_r}×{prob.p_c}"
        )
    if sched.eta <= 0:
        raise ValueError(f"eta={sched.eta} must be > 0 to run the solver")
    check_delay(sched)
    if not comm.on_mesh:
        raise ValueError(
            f"make_hybrid_step needs mesh collectives (mesh/timed), got {comm.kind!r}"
        )
    round_fn = _build_round_fn(prob, sched, comm)

    smapped = shard_map(
        round_fn,
        mesh=mesh,
        in_specs=(P("rows", "cols"), P("rows", "cols"), P("cols"), P()),
        out_specs=P("cols"),
    )

    x_sh = NamedSharding(mesh, P("cols"))
    step = jax.jit(smapped, out_shardings=x_sh, donate_argnums=(2,))
    return step


class HybridDriver:
    """Round-incremental shard_map executor — the chunkable form of the
    old run-everything loop.

    Holds the device-resident state (placed ELL blocks + the sharded,
    donated weight vector) between calls, so drivers above it — the
    ``repro.api.Session`` lifecycle, dashboards, async averaging — can
    advance the computation ``k`` rounds at a time, probe the objective,
    checkpoint, and keep going, with the same chain-of-async-dispatches
    execution the monolithic loop had (one jitted step, donated carry,
    no per-round host sync).

    The round counter is part of the carry: ``advance(k)`` runs global
    rounds ``rounds_done .. rounds_done+k-1``, so chunked execution
    reproduces the uninterrupted loop's sample sequence exactly.

    The driver owns the run's ``CommLedger``: the collectives of the
    round body are captured once at construction (``hybrid_comm_ledger``
    on the very round_fn the step executes) and committed per advanced
    round. With ``comm=TIMED`` each round blocks on completion and its
    wall seconds land in the ledger — the §6.5 calibration input
    (repro.costmodel.calibrate).
    """

    def __init__(
        self,
        mesh: Mesh,
        prob: Hybrid2DProblem,
        cp: ColumnPartition,
        x0: np.ndarray,
        sched: ParallelSGDSchedule,
        loss_problem: Problem | None = None,
        rounds_done: int = 0,
        comm: Collectives = MESH,
    ):
        self.prob = prob
        self.cp = cp
        self.sched = sched
        self.loss_problem = loss_problem
        self.rounds_done = int(rounds_done)
        self.comm = comm
        self.ledger = hybrid_comm_ledger(prob, sched, comm)
        self.ledger.rounds = self.rounds_done
        self._step = make_hybrid_step(mesh, prob, sched, comm=comm)
        self._mesh = mesh
        data_sh = NamedSharding(mesh, P("rows", "cols"))
        self._data_sh = data_sh
        self._x_sh = NamedSharding(mesh, P("cols"))
        self._idx = jax.device_put(prob.indices, data_sh)
        self._val = jax.device_put(prob.values, data_sh)
        self._x_pad = jax.device_put(
            jnp.asarray(scatter_x(np.asarray(x0), cp, prob.n_loc)), self._x_sh
        )

    def advance(self, k: int) -> None:
        """Run ``k`` rounds; weights stay device-resident (async).
        Timed collectives block per round and record wall seconds."""
        for _ in range(int(k)):
            t0 = time.perf_counter() if self.comm.timed else 0.0
            self._x_pad = self._step(
                self._idx, self._val, self._x_pad, jnp.int32(self.rounds_done)
            )
            if self.comm.timed:
                jax.block_until_ready(self._x_pad)
                self.ledger.add_round_seconds(time.perf_counter() - t0)
            self.rounds_done += 1
        self.ledger.rounds = self.rounds_done

    def advance_stream(self, indices: np.ndarray, values: np.ndarray) -> None:
        """Run ONE round over streamed data instead of the resident
        blocks: ``(p_r, p_c, rows_local, width)`` ELL shards with
        shard-local column ids (``repro.serve.ingest.stream_shard_arrays``
        builds them from a micro-batch). The round body slices bundles
        modulo the operand's row count, so with ``rows_local = τ·b`` the
        τ/s bundles walk the fresh rows exactly once at *any* round
        index — the step function is the resident one, jit-cached per
        data shape (fixed-shape streams compile once)."""
        t0 = time.perf_counter() if self.comm.timed else 0.0
        idx = jax.device_put(jnp.asarray(indices, jnp.int32), self._data_sh)
        val = jax.device_put(jnp.asarray(values, jnp.float32), self._data_sh)
        self._x_pad = self._step(idx, val, self._x_pad, jnp.int32(self.rounds_done))
        if self.comm.timed:
            jax.block_until_ready(self._x_pad)
            self.ledger.add_round_seconds(time.perf_counter() - t0)
        self.rounds_done += 1
        self.ledger.rounds = self.rounds_done

    def sync(self) -> None:
        """Block until all dispatched rounds complete — no host copy.
        The tracing seam uses this so a round span's wall covers the
        work it dispatched (observer effect on timing only; the async
        chain and its numerics are identical either way)."""
        jax.block_until_ready(self._x_pad)

    def phase_probes(self) -> dict:
        """Jitted per-phase probes over this driver's real payload
        shapes — the §6.5 phase split, measured *outside* the training
        step so its compiled round body is never touched.

        Returns ``{phase: (fn, args, calls_per_round)}``:

          bundle_compute  one rank's local partial (G, v) over an
                          (s·b, width) ELL bundle (Eq. 4's γ term);
          allreduce_gv    the (s²b² + sb)-word psum over "cols" on the
                          real mesh (Table 3's row-team payload);
          param_avg       the n_loc-word pmean over "rows" (the column
                          weight sync).

        Probes run on zero-filled payloads of the true shapes — comm
        cost is shape-dependent, data-independent.
        """
        sched, prob, mesh = self.sched, self.prob, self._mesh
        sb = sched.s * sched.b
        bundles = sched.tau // sched.s
        gram_ = "blocked" if sched.gram == "pallas" else sched.gram
        reps = -(-sb // prob.rows_local)
        bi = jnp.tile(prob.indices[0, 0], (reps, 1))[:sb]
        bv = jnp.tile(prob.values[0, 0], (reps, 1))[:sb]
        x_loc = jnp.zeros((prob.n_loc,), jnp.float32)
        compute = jax.jit(
            lambda i, v, x: bundle_gram_v(
                i, v, x, prob.n_loc, gram=gram_, bk=sched.bk, bm=sched.bm,
                precision=sched.precision,
            )
        )
        # the probed psum carries the wire dtype: a bf16 schedule's
        # measured allreduce_gv reflects the halved payload
        gv_dt = jnp.bfloat16 if sched.precision == "bf16" else jnp.float32
        g0 = jnp.zeros((sb, sb), gv_dt)
        v0 = jnp.zeros((sb,), gv_dt)
        ar = jax.jit(shard_map(
            lambda g, v: (jax.lax.psum(g, "cols"), jax.lax.psum(v, "cols")),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        ))
        xp = jax.device_put(
            jnp.zeros(prob.p_c * prob.n_loc, jnp.float32), self._x_sh
        )
        pm = jax.jit(shard_map(
            lambda x: jax.lax.pmean(x, "rows"),
            mesh=mesh, in_specs=P("cols"), out_specs=P("cols"),
        ))
        return {
            "bundle_compute": (compute, (bi, bv, x_loc), bundles),
            "allreduce_gv": (ar, (g0, v0), bundles),
            "param_avg": (pm, (xp,), 1),
        }

    def gather(self) -> np.ndarray:
        """Current global weights (n,) — blocks on the dispatch chain."""
        return gather_x(np.asarray(self._x_pad), self.cp, self.prob.n_loc, self.prob.n)

    def set_x(self, x: np.ndarray) -> None:
        """Replace the weights (checkpoint restore). Padded layout slots
        never receive updates (no row references them), so a
        gather → set_x round trip is lossless."""
        self._x_pad = jax.device_put(
            jnp.asarray(scatter_x(np.asarray(x), self.cp, self.prob.n_loc)), self._x_sh
        )

    def loss(self) -> float:
        """Full global objective (under ``loss_problem``'s objective)
        at the current iterate."""
        if self.loss_problem is None:
            raise ValueError("HybridDriver was built without loss_problem")
        return float(problem_loss(self.loss_problem, jnp.asarray(self.gather())))


def run_hybrid_distributed(
    mesh: Mesh,
    prob: Hybrid2DProblem,
    cp: ColumnPartition,
    x0: np.ndarray,
    sched: ParallelSGDSchedule | int | None = None,
    b: int | None = None,
    eta: float | None = None,
    tau: int | None = None,
    rounds: int | None = None,
    gram: str | None = None,
    *,
    s: int | None = None,
    loss_problem: Problem | None = None,
):
    """Driver: place data once, run ``sched.rounds`` rounds, gather x.

    Now a thin loop over ``HybridDriver`` — one ``advance`` per
    loss-sampling chunk. Returns ``(x, losses)`` — the same contract as
    the simulated engine's ``run_parallel_sgd``: the full global
    objective is sampled every ``sched.loss_every`` rounds (empty trace
    when 0). Sampling the loss needs the global problem, so pass
    ``loss_problem`` (the repro.api front door wires this
    automatically).

    The legacy signature ``run_hybrid_distributed(mesh, prob, cp, x0,
    s, b, eta, tau, rounds, gram=...)`` still works (returning bare
    ``x``, its old contract) but emits a DeprecationWarning.
    """
    legacy = not isinstance(sched, ParallelSGDSchedule)
    if legacy:
        s_val = sched if sched is not None else s
        if s_val is None:
            raise TypeError(
                "run_hybrid_distributed needs a ParallelSGDSchedule (or legacy s=...)"
            )
        sched = _legacy_schedule(
            prob.p_r, s_val, b, eta, tau, rounds, gram, "run_hybrid_distributed"
        )
    else:
        _reject_scalars_with_schedule(
            "run_hybrid_distributed", s=s, b=b, eta=eta, tau=tau, rounds=rounds, gram=gram
        )
    if sched.loss_every and loss_problem is None:
        raise ValueError("loss_every > 0 needs loss_problem (the global Problem)")

    driver = HybridDriver(mesh, prob, cp, x0, sched, loss_problem=loss_problem)
    losses = []
    chunk = sched.loss_every if sched.loss_every else sched.rounds
    while driver.rounds_done < sched.rounds:
        driver.advance(min(chunk, sched.rounds - driver.rounds_done))
        if sched.loss_every and driver.rounds_done % sched.loss_every == 0:
            losses.append(driver.loss())
    x = driver.gather()
    if legacy:
        return x
    return x, np.asarray(losses, dtype=np.float32)
