"""The unified parallel-SGD engine — one inner loop for the whole
(p_r, p_c, s, τ) family.

The paper's four algorithms are corners of a single 2D-parallel method:
p_r row teams each run τ inner iterations of s-step SGD (τ/s s-bundles)
between parameter averagings. One engine therefore subsumes them all:

  corner                      schedule
  ------------------------    ------------------------------------
  mini-batch SGD (Alg. 1)     p_r = 1, s = 1, τ = 1
  s-step SGD     (Alg. 3)     p_r = 1, τ = s         (no averaging)
  FedAvg         (Alg. 2)     s = 1                  (no Gram work)
  HybridSGD      (§4.1)       general (p_r, s, τ)

p_c is a *communication* knob, not a numerical one: it decides where
columns live (and hence what is Allreduced — see
repro.core.distributed), never what is computed. The engine here
implements the exact simulated-rank semantics on one device; the
shard_map execution in repro.core.distributed shares this module's
bundle primitive and inner-correction loop, so the two paths cannot
drift.

The s-bundle computation G = tril(Y Yᵀ, -1), v = Y x routes through the
scatter-free Pallas ELL-Gram kernel (repro.kernels.ell_gram) — the old
per-bundle densify into a (sb × n) scratch matrix survives only as the
parity oracle in repro.kernels.ref.

The *loss* is pluggable (repro.core.objective): the engine reads the
residual map u(z) = -ℓ′(z), the pointwise loss, and the optional L2
decay from the problem's ``objective`` — the logistic default routes
through bitwise the same computation as the pre-objective engine, and
λ > 0 is exact via the decay-aware correction recurrence.

*Communication* is explicit (repro.core.comm): the round body issues
its two collectives — the per-bundle row-team (G, v) Allreduce and the
per-round p_r-team average — through the counting collectives (the
identity on this backend's already-global values), so
``engine_comm_ledger`` can capture exactly what a run communicates and
reports can place it next to the Eq. 4 model's predictions.

repro.core.{sgd,sstep,fedavg,hybrid} re-export configured engine calls
for backwards compatibility.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import comm as comm_plane
from repro.core.comm import COUNTING, CommLedger
from repro.core.objective import LOGISTIC, Objective
from repro.core.problem import Problem, problem_loss
from repro.core.teams import TeamProblem, global_problem
from repro.kernels.ell_gram import ell_gram_and_v, ell_gram_and_v_blocked
from repro.kernels.ref import ell_gram_and_v_ref
from repro.sparse.ell import EllBlock, ell_matvec, ell_rmatvec

GRAM_METHODS = ("pallas", "blocked", "dense")


@dataclasses.dataclass(frozen=True)
class ParallelSGDSchedule:
    """The knobs of the 2D-parallel SGD family (paper Table 3 row
    "HybridSGD"; see docs/paper_map.md for the paper→code map).

    p_r     row teams (FedAvg axis); must equal the TeamProblem's p.
    s       bundle depth — SGD steps fused per Gram round-trip.
    b       mini-batch rows per SGD step (bundle = s·b rows).
    tau     inner iterations between row-team averagings; s | τ.
    eta     step size.
    rounds  outer rounds (total SGD-equivalent iterations = rounds·τ).
    loss_every   sample the full objective every this many rounds
                 (0 = never; the returned loss trace is then empty).
    gram    bundle (G, v) backend: "pallas" (scatter-free ELL kernel,
            the production path), "blocked" (same math as pure jnp —
            what shard_map uses), "dense" (the retired densify oracle,
            kernels/ref.py — tests only; also what the profile-driven
            auto-select picks for heavy-tailed ELL widths).
    bk      column-panel width for the Gram kernels. ``None`` opts into
            the autotuner: the api layer resolves it to the cached
            tuned value at build time (repro.kernels.tune); direct
            engine callers fall back to the static 512.
    bm      optional row tile for the panel expansion (the autotuner's
            second knob). None = single-shot expansion (the original
            path, and bitwise-identical to any bm).
    precision   "fp32" (default — bitwise the pre-precision engine) or
            "bf16": panels and MXU dots run bf16-compute /
            fp32-accumulate, and the per-bundle (G, v) Allreduce ships
            bf16 words (half the β·bytes payload; word counts, and
            hence the Table 2–3 closed forms, are unchanged).
    interpret   Pallas interpret mode — True off-TPU (this container),
            False for the compiled Mosaic kernel on real hardware.
    p_c     column shards. Communication-only: it never changes the
            numerics (kept here so one object describes the full mesh;
            repro.core.distributed consumes it).
    delay   DaSGD-style staleness D (0 = synchronous, the default and
            bitwise-identical to the pre-delay engine). With D ≥ 1 the
            (G, v) collective of bundle t is *issued* at t but
            *consumed* at bundle t+D — the in-flight Allreduce rides a
            D-deep staging buffer and overlaps the next D bundles'
            Gram compute; the last D bundles drain before the round's
            parameter average, so round boundaries (checkpoints,
            chunking, averaging cadence) are unchanged. A numerical
            knob: D ≥ 1 changes the iterates (each bundle's gradient
            is D bundles stale), not the communication volume. Must
            satisfy D ≤ τ/s (the per-round bundle count).
    """

    p_r: int = 1
    s: int = 1
    b: int = 8
    tau: int = 1
    eta: float = 0.05
    rounds: int = 1
    loss_every: int = 0
    gram: str = "pallas"
    bk: int | None = 512
    interpret: bool = True
    p_c: int = 1
    delay: int = 0
    bm: int | None = None
    precision: str = "fp32"

    def __post_init__(self):
        # NOTE: s | τ is required by the *solver* (checked in
        # run_parallel_sgd), not here: the NN trainer reuses this object
        # with s = grad-accum microsteps, where the coupling is absent.
        # Likewise η > 0 is a solver-entry check (run_parallel_sgd /
        # make_hybrid_step): the engine internally normalizes schedules
        # to η = 0 for jit-cache keying, so only η < 0 is nonsense here.
        for knob in ("p_r", "s", "b", "tau", "rounds", "p_c"):
            v = getattr(self, knob)
            if v < 1:
                raise ValueError(f"{knob}={v!r} must be a positive integer")
        for knob in ("bk", "bm"):  # None = resolve via the autotuner
            v = getattr(self, knob)
            if v is not None and v < 1:
                raise ValueError(f"{knob}={v!r} must be a positive integer or None")
        if self.precision not in ("fp32", "bf16"):
            raise ValueError(
                f"precision={self.precision!r} must be 'fp32' or 'bf16'"
            )
        if self.loss_every < 0:
            raise ValueError(f"loss_every={self.loss_every} must be ≥ 0")
        if self.delay < 0:
            raise ValueError(f"delay={self.delay} must be ≥ 0")
        if self.eta < 0:
            raise ValueError(f"eta={self.eta} must be ≥ 0")
        if self.loss_every and self.rounds % self.loss_every:
            raise ValueError(
                f"rounds={self.rounds} must be divisible by loss_every={self.loss_every}"
            )
        if self.gram not in GRAM_METHODS:
            raise ValueError(f"gram={self.gram!r} not in {GRAM_METHODS}")

    # ---- the paper's corners, by name ----

    @classmethod
    def mb_sgd(cls, b: int, eta: float, iters: int, loss_every: int = 0, **kw):
        """Algorithm 1: synchronous mini-batch SGD."""
        return cls(p_r=1, s=1, b=b, tau=1, eta=eta, rounds=iters, loss_every=loss_every, **kw)

    @classmethod
    def sstep(cls, s: int, b: int, eta: float, iters: int, loss_every: int = 0, **kw):
        """Algorithm 3: 1D s-step SGD — iters/s bundles, one bundle per
        round, no averaging (p_r = 1).

        ``loss_every`` counts SGD-equivalent iterations (like ``iters``)
        and must be a multiple of s: one round = s iterations, so any
        other cadence cannot be sampled exactly.
        """
        if iters % s:
            raise ValueError(f"iters={iters} must be divisible by s={s}")
        if loss_every and loss_every % s:
            raise ValueError(
                f"loss_every={loss_every} must be divisible by s={s}: the loss is "
                f"sampled on round (= s-iteration) boundaries"
            )
        return cls(
            p_r=1, s=s, b=b, tau=s, eta=eta, rounds=iters // s,
            loss_every=loss_every // s, **kw,
        )

    @classmethod
    def fedavg(cls, p: int, b: int, eta: float, tau: int, rounds: int,
               loss_every: int = 0, **kw):
        """Algorithm 2: FedAvg / local SGD — s = 1, so no Gram work."""
        return cls(p_r=p, s=1, b=b, tau=tau, eta=eta, rounds=rounds,
                   loss_every=loss_every, **kw)

    @classmethod
    def hybrid(cls, p_r: int, s: int, b: int, eta: float, tau: int, rounds: int,
               loss_every: int = 0, **kw):
        """HybridSGD (§4.1): the general 2D point."""
        return cls(p_r=p_r, s=s, b=b, tau=tau, eta=eta, rounds=rounds,
                   loss_every=loss_every, **kw)


def bundle_gram_v(
    indices, values, x, n: int, *, gram: str = "pallas", bk: int | None = 512,
    bm: int | None = None, precision: str = "fp32", interpret: bool = True,
):
    """The shared s-bundle primitive: local (G, v) = (tril(YYᵀ,-1), Yx)
    for the ELL bundle Y, without densifying Y to (sb, n) in HBM.

    Under column partitioning each shard computes its partial (G, v)
    with this same function and the row-team Allreduce (psum over
    "cols") sums them — tril commutes with the sum, so the simulated
    and distributed paths share one primitive.

    ``bk=None`` (the autotune sentinel, normally resolved at build time
    by the api layer) falls back to the static 512 here. The dense
    oracle has no panels, so bk/bm/precision do not apply to it — its
    (G, v) is always the fp32 reference."""
    bk = 512 if bk is None else bk
    if gram == "pallas":
        return ell_gram_and_v(
            indices, values, x, n=n, bk=bk, bm=bm, precision=precision,
            interpret=interpret,
        )
    if gram == "blocked":
        return ell_gram_and_v_blocked(
            indices, values, x, n=n, bk=bk, bm=bm, precision=precision
        )
    if gram == "dense":
        return ell_gram_and_v_ref(indices, values, x, n)
    raise ValueError(f"gram={gram!r} not in {GRAM_METHODS}")


def wire_gv(tree, precision: str):
    """Cast a (G, v) payload to its on-wire dtype: bf16 under the bf16
    precision knob (half the collective's bytes), untouched at fp32 —
    both backends cast at the same point, so parity holds."""
    if precision != "bf16":
        return tree
    return jax.tree_util.tree_map(lambda t: t.astype(jnp.bfloat16), tree)


def unwire_gv(tree, precision: str, dtype=jnp.float32):
    """Undo ``wire_gv`` after the collective: corrections and updates
    accumulate in ``dtype`` (f32) regardless of the wire dtype."""
    if precision != "bf16":
        return tree
    return jax.tree_util.tree_map(lambda t: t.astype(dtype), tree)


def inner_corrections(
    g, v, s: int, b: int, eta: float, objective: Objective = LOGISTIC
) -> jnp.ndarray:
    """Algorithm 3 lines 9-14: the s deferred-update corrections under
    any registered objective.

    Unregularized (objective.l2 == 0 — special-cased at trace time so
    the default path is bitwise-unchanged):

        u_j = residual(v_j + (η/b) Σ_{l<j} G_{jl} u_l)

    G is strictly lower so in-block terms multiply zeros. With L2 decay
    λ > 0 and ρ = 1 - ηλ the exact unrolled recurrence is

        z_j = ρ^j·v_j + (η/b) Σ_{l<j} ρ^{j-1-l}·G_{jl}·u_l

    implemented by carrying the ρ-rescaled residual vector: after step
    j the carry holds [ρ^{j-l}·u_l]_{l≤j}, so the returned vector is
    exactly the ρ^{s-1-l}-weighted u the caller's Yᵀ apply (and ρ^s·x
    decay-fold) needs. Shared by the engine and the shard_map path (and
    mirrored VMEM-resident by repro.kernels.sstep_inner for the
    logistic default)."""
    lam = objective.l2

    if lam == 0.0:

        def inner(u_acc, j):
            zj = jax.lax.dynamic_slice_in_dim(v, j * b, b) + (eta / b) * (
                jax.lax.dynamic_slice_in_dim(g, j * b, b, axis=0) @ u_acc
            )
            uj = objective.residual(zj)
            return jax.lax.dynamic_update_slice_in_dim(u_acc, uj, j * b, axis=0), None

        u, _ = jax.lax.scan(inner, jnp.zeros(s * b, v.dtype), jnp.arange(s))
        return u

    rho = jnp.asarray(1.0 - eta * lam, v.dtype)

    def inner_decay(carry, j):
        u_acc, rho_j = carry  # u_acc_l = ρ^{j-1-l}·u_l (l < j); rho_j = ρ^j
        zj = rho_j * jax.lax.dynamic_slice_in_dim(v, j * b, b) + (eta / b) * (
            jax.lax.dynamic_slice_in_dim(g, j * b, b, axis=0) @ u_acc
        )
        uj = objective.residual(zj)
        u_acc = jax.lax.dynamic_update_slice_in_dim(rho * u_acc, uj, j * b, axis=0)
        return (u_acc, rho_j * rho), None

    carry0 = (jnp.zeros(s * b, v.dtype), jnp.ones((), v.dtype))
    (u, _), _ = jax.lax.scan(inner_decay, carry0, jnp.arange(s))
    return u


def delayed_bundle_scan(x, *, slice_bundle, bundles: int, n: int,
                        sched: ParallelSGDSchedule, eta,
                        objective: Objective = LOGISTIC,
                        comm=COUNTING, gram: str | None = None):
    """The delay-D software pipeline over one round's τ/s bundles —
    the shared round-body core of both backends when ``sched.delay ≥ 1``
    (DaSGD, arXiv:2006.00441).

    At step t the body computes bundle t's local (G, v) at the current
    (D-bundle-stale) iterate and *issues* its row-team Allreduce
    (``comm.issue_allreduce_cols``); the staged result rides a D-deep
    FIFO in the scan carry and is *consumed* (``comm.await_allreduce``
    → corrections → weight update) at step t+D — so on a mesh the
    in-flight psum has the next D bundles' Gram compute to hide behind
    (the data dependency lands D iterations later, which is the window
    XLA's scheduler overlaps). After the main scan the last D staged
    entries drain synchronously, *before* the caller's parameter
    average: every round boundary carries only ``x``, so chunking,
    checkpointing, and the τ-cadence averaging are exactly where the
    synchronous schedule puts them.

    Warmup steps (t < D) consume the zero-initialized buffer and are
    masked out with ``jnp.where`` rather than ``lax.cond`` — no
    collectives inside conditionals (shard_map-safe), deterministic
    wasted work on D dummy entries per round. Exactly ``bundles``
    updates (and, under L2, exactly ``bundles`` decay folds) are
    applied per round, same as the synchronous path.

    ``slice_bundle(t) -> (idx, val)`` supplies the (s·b, width) ELL
    bundle; ``comm`` is COUNTING on the simulated engine (identity —
    the staged value is already globally reduced) and MESH/TIMED under
    shard_map. ``gram`` overrides the schedule's bundle backend (the
    shard_map path runs "pallas" as "blocked")."""
    s, b = sched.s, sched.b
    sb = s * b
    d = sched.delay
    lam = objective.l2
    gram_ = sched.gram if gram is None else gram

    def compute_issue(x, t):
        idx, val = slice_bundle(t)
        g, v = bundle_gram_v(idx, val, x, n, gram=gram_, bk=sched.bk,
                             bm=sched.bm, precision=sched.precision,
                             interpret=sched.interpret)
        # issued here, consumed D bundles later (the s = 1 corner
        # stages the full (G, v) too — its distributed twin psums the
        # dense block either way, so counted payloads stay pinned).
        # Under bf16 the staged payload is the wire dtype: the FIFO
        # holds exactly what the in-flight Allreduce carries.
        g, v = comm.issue_allreduce_cols(
            wire_gv((g, v), sched.precision), calls_per_round=bundles
        )
        return idx, val, g, v

    def consume_apply(x, entry, live):
        idx, val, g, v = entry
        g, v = comm.await_allreduce((g, v))
        g, v = unwire_gv((g, v), sched.precision)
        u = inner_corrections(g, v, s, b, eta, objective)
        blk = EllBlock(indices=idx, values=val, n=n)
        upd = (eta / b) * ell_rmatvec(blk, u).astype(x.dtype)
        if lam == 0.0:
            return jnp.where(live, x + upd, x)
        rho_s = jnp.asarray(1.0 - eta * lam, x.dtype) ** s
        return jnp.where(live, rho_s * x + upd, x)

    # the D-deep staging FIFO: buf[0] is the oldest in-flight bundle.
    # Shapes/dtypes are written out by hand (an eval_shape through
    # compute_issue would double-record the collective under the
    # ledger's capture recorder).
    idx0, val0 = slice_bundle(0)
    width = idx0.shape[-1]
    gv_dtype = jnp.result_type(val0.dtype, x.dtype)
    if sched.precision == "bf16":
        gv_dtype = jnp.bfloat16  # the FIFO stages the wire payload
    buf = (
        jnp.zeros((d, sb, width), idx0.dtype),
        jnp.zeros((d, sb, width), val0.dtype),
        jnp.zeros((d, sb, sb), gv_dtype),
        jnp.zeros((d, sb), gv_dtype),
    )

    def body(carry, t):
        x, buf = carry
        new = compute_issue(x, t)
        oldest = jax.tree_util.tree_map(lambda a: a[0], buf)
        buf = jax.tree_util.tree_map(
            lambda a, e: jnp.concatenate([a[1:], e[None]], axis=0), buf, new
        )
        x = consume_apply(x, oldest, t >= d)
        return (x, buf), None

    (x, buf), _ = jax.lax.scan(body, (x, buf), jnp.arange(bundles))

    def drain(x, j):
        entry = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, j, keepdims=False), buf
        )
        return consume_apply(x, entry, jnp.bool_(True)), None

    x, _ = jax.lax.scan(drain, x, jnp.arange(d))
    return x


def _team_inner_iterations(indices, values, n: int, x, round_idx, eta,
                           sched: ParallelSGDSchedule,
                           objective: Objective = LOGISTIC):
    """τ inner iterations (= τ/s s-bundles) on one row team's ELL rows.
    ``eta`` is a traced scalar (sweep-friendly: no recompile per value);
    ``objective`` supplies the residual and (when l2 > 0) the decay
    fold — exact on every corner, since the s-bundle recurrence in
    ``inner_corrections`` is decay-aware."""
    m_local = indices.shape[0]
    bundles = sched.tau // sched.s
    s, b = sched.s, sched.b
    sb = s * b
    lam = objective.l2

    if sched.delay:
        def slice_bundle(t):
            k0 = round_idx * bundles + t
            start = (k0 * sb) % m_local
            idx = jax.lax.dynamic_slice_in_dim(indices, start, sb, axis=0)
            val = jax.lax.dynamic_slice_in_dim(values, start, sb, axis=0)
            return idx, val

        return delayed_bundle_scan(
            x, slice_bundle=slice_bundle, bundles=bundles, n=n, sched=sched,
            eta=eta, objective=objective, comm=COUNTING,
        )

    def bundle_step(x, t):
        k0 = round_idx * bundles + t
        start = (k0 * sb) % m_local
        idx = jax.lax.dynamic_slice_in_dim(indices, start, sb, axis=0)
        val = jax.lax.dynamic_slice_in_dim(values, start, sb, axis=0)
        bundle = EllBlock(indices=idx, values=val, n=n)
        if s == 1:
            # FedAvg/MB-SGD corner: the Gram is empty (no deferred
            # updates to correct) — one SpMV + one SpMVᵀ, exactly
            # Algorithm 2's local step. The simulated body only
            # materializes v = Yx, but the distributed corner psums the
            # full (G, v) bundle even at s = 1 (G rides the wire though
            # numerically unused), so the counted payload is pinned to
            # the same sb² + sb words.
            yx = COUNTING.allreduce_cols(
                wire_gv(ell_matvec(bundle, x), sched.precision),
                calls_per_round=bundles,
                words_per_call=sb * sb + sb,
            )
            yx = unwire_gv(yx, sched.precision, x.dtype)
            u = objective.residual(yx)
        else:
            g, v = bundle_gram_v(idx, val, x, n, gram=sched.gram, bk=sched.bk,
                                 bm=sched.bm, precision=sched.precision,
                                 interpret=sched.interpret)
            # row-team Allreduce of the bundle (G, v) — identity here
            # (the simulated rank computes the full reduction), the
            # recorded payload when the round body is captured.
            g, v = COUNTING.allreduce_cols(
                wire_gv((g, v), sched.precision), calls_per_round=bundles
            )
            g, v = unwire_gv((g, v), sched.precision)
            u = inner_corrections(g, v, s, b, eta, objective)
        if lam == 0.0:
            return x + (eta / b) * ell_rmatvec(bundle, u).astype(x.dtype), None
        # decay-folded update: x_s = ρ^s·x + (η/b)·Yᵀ·[ρ^{s-1-l}·u_l]
        # (inner_corrections already returns the ρ-weighted u; for
        # s = 1 the weight is ρ^0 = 1). Exact on the s = 1 corners.
        rho_s = jnp.asarray(1.0 - eta * lam, x.dtype) ** s
        return rho_s * x + (eta / b) * ell_rmatvec(bundle, u).astype(x.dtype), None

    x, _ = jax.lax.scan(bundle_step, x, jnp.arange(bundles))
    return x


def _one_round(tp, x, r, eta, sched):
    """One outer round: τ inner iterations per row team + the p_r-team
    average. The single shared round body — the monolithic scan and the
    chunked session path both close over exactly this function, so the
    two cannot drift (and stay bitwise-identical)."""

    def team(args):
        idx, val = args
        return _team_inner_iterations(idx, val, tp.n, x, r, eta, sched, tp.objective)

    if sched.s == 1 and not sched.delay:
        # FedAvg/MB-SGD corner: per-team working set is one (b, w)
        # batch — run all teams batched (the old run_fedavg vmap).
        # The delayed path materializes the full (G, v) even at s = 1
        # (its distributed twin psums the dense block), so it takes the
        # sequential branch like every Gram-bearing schedule.
        xs = jax.vmap(team)((tp.indices, tp.values))
    else:
        # lax.map (not vmap): teams run sequentially on one device,
        # bounding peak memory at one team's bundle working set.
        xs = jax.lax.map(team, (tp.indices, tp.values))
    # column Allreduce: the p_r-team average, issued through the comm
    # plane (numerically the same stacked mean; the per-rank payload is
    # the balanced ⌈n/p_c⌉-word weight shard — Table 3's sync column).
    return COUNTING.allmean_teams(xs, words_per_call=-(-tp.n // sched.p_c))


@partial(jax.jit, static_argnames=("sched",))
def _run_engine(tp, x0, eta, sched):
    gp = global_problem(tp)

    chunk = sched.loss_every if sched.loss_every else sched.rounds
    n_chunks = max(sched.rounds // chunk, 1)

    def one_round(x, r):
        return _one_round(tp, x, r, eta, sched), None

    def outer(x, c):
        x, _ = jax.lax.scan(one_round, x, c * chunk + jnp.arange(chunk))
        return x, problem_loss(gp, x)

    x, losses = jax.lax.scan(outer, x0, jnp.arange(n_chunks))
    if not sched.loss_every:
        losses = jnp.zeros((0,), losses.dtype)
    return x, losses


# ---- round-incremental (chunked) execution --------------------------
#
# The Session front door (repro.api.session) advances the engine k
# rounds at a time instead of one scan over all of them. The chunk
# entry point below is jitted with a *normalized* schedule (loop-shape
# knobs zeroed) and a static chunk length, so one compiled executable
# is shared across chunks, across sessions, and across schedules that
# differ only in (rounds, loss_every, eta) — the carry in/out is just
# the weight vector, and the round index arrives as a traced operand so
# chunk r0..r0+k matches rounds r0..r0+k of the monolithic scan
# bitwise.


def check_delay(sched: ParallelSGDSchedule) -> None:
    """Solver-entry validation of the delay knob: the staging buffer
    drains inside the round, so D cannot exceed the per-round bundle
    count (entries past it would never be issued)."""
    bundles = sched.tau // sched.s
    if sched.delay > bundles:
        raise ValueError(
            f"delay={sched.delay} must be ≤ τ/s={bundles} (the per-round "
            f"bundle count): the staging buffer drains before each round's "
            f"parameter average"
        )


def _normalize_for_chunk(sched: ParallelSGDSchedule) -> ParallelSGDSchedule:
    """Zero every knob the per-round math does not read (η is traced;
    rounds/loss_every belong to the driver; p_c is communication-only)
    so the jit cache keys only on what changes the computation.
    ``delay`` is *kept*: D ≥ 1 pipelines the bundle loop and changes
    the iterates, so it must key the compiled round body."""
    return dataclasses.replace(sched, eta=0.0, rounds=1, loss_every=0, p_c=1)


@partial(jax.jit, static_argnames=("sched", "k"))
def _engine_chunk(tp, x, r0, eta, sched, k):
    """Advance rounds r0 .. r0+k-1 from carry ``x`` (chunk of the same
    scan the monolithic path runs — identical per-round graph)."""

    def one_round(x, r):
        return _one_round(tp, x, r, eta, sched), None

    x, _ = jax.lax.scan(one_round, x, r0 + jnp.arange(k))
    return x


@jax.jit
def engine_loss(gp, x):
    """The session's loss probe — same ``problem_loss`` (under ``gp``'s
    objective) the monolithic scan samples at chunk boundaries."""
    return problem_loss(gp, x)


def run_engine_chunk(
    tp: TeamProblem,
    x: jnp.ndarray,
    round_offset: int,
    k: int,
    sched: ParallelSGDSchedule,
) -> jnp.ndarray:
    """Run ``k`` rounds starting at global round ``round_offset`` and
    return the new weights (device-resident; no host sync).

    This is the carry-in/carry-out primitive under ``repro.api.Session``
    — calling it with offsets 0, k, 2k, … reproduces
    ``run_parallel_sgd``'s iterate sequence bitwise, because both paths
    scan the same ``_one_round`` body over the same round indices."""
    if sched.eta <= 0:
        raise ValueError(f"eta={sched.eta} must be > 0 to run the solver")
    check_delay(sched)
    eta = jnp.asarray(sched.eta, x.dtype)
    return _engine_chunk(
        tp, x, jnp.int32(round_offset), eta, _normalize_for_chunk(sched), int(k)
    )


def run_parallel_sgd(
    tp: TeamProblem,
    x0: jnp.ndarray,
    sched: ParallelSGDSchedule,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run the full 2D family point described by ``sched`` on the
    stacked row teams ``tp`` (exact simulated-rank semantics).

    Each of ``sched.rounds`` outer rounds = τ inner s-step iterations
    per row team + one averaging across the p_r teams (identity when
    p_r = 1). Returns (x, losses) with the full global objective
    sampled every ``loss_every`` rounds.

    η enters the compiled computation as a traced operand, so an
    η-sweep over otherwise-identical schedules reuses one executable.
    """
    if sched.eta <= 0:
        raise ValueError(f"eta={sched.eta} must be > 0 to run the solver")
    if sched.tau % sched.s:
        raise ValueError(
            f"tau={sched.tau} must be divisible by s={sched.s} (paper requires s ≤ τ)"
        )
    check_delay(sched)
    if tp.p != sched.p_r:
        raise ValueError(f"TeamProblem has p={tp.p} teams but schedule p_r={sched.p_r}")
    if tp.rows_local % (sched.s * sched.b):
        raise ValueError(
            f"local rows {tp.rows_local} must be divisible by s·b={sched.s * sched.b}"
        )
    eta = jnp.asarray(sched.eta, x0.dtype)
    return _run_engine(tp, x0, eta, dataclasses.replace(sched, eta=0.0))


def engine_comm_ledger(
    sched: ParallelSGDSchedule,
    n: int,
    tp: TeamProblem | None = None,
    width: int = 2,
) -> CommLedger:
    """The simulated engine's per-rank ``CommLedger``: every collective
    the round body issues, captured by tracing ``_one_round`` abstractly
    (``jax.eval_shape`` — no FLOPs run, no dataset needed).

    With ``tp`` given the capture traces the real problem's shapes;
    without it a shape-only stand-in is synthesized (``width`` nonzeros
    per row, one bundle of rows per team) — the communication structure
    depends only on the schedule and n, never on the data, so both
    forms record identical rates. Spans come from the schedule's
    (p_r, p_c): the ledger of the simulated run *is* the ledger of the
    mesh execution it simulates (tested against
    ``repro.core.distributed.hybrid_comm_ledger``)."""
    if tp is None:
        sb = sched.s * sched.b
        tp = TeamProblem(
            indices=jax.ShapeDtypeStruct((sched.p_r, sb, width), jnp.int32),
            values=jax.ShapeDtypeStruct((sched.p_r, sb, width), jnp.float32),
            rows_valid=jax.ShapeDtypeStruct((sched.p_r, sb), jnp.bool_),
            p=sched.p_r,
            m=sched.p_r * sb,
            n=n,
        )
    rates = comm_plane.capture_rates(
        partial(_one_round, sched=sched),
        tp,
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
        spans={"cols": sched.p_c, "rows": sched.p_r},
    )
    return CommLedger(rates=rates, delay=sched.delay)


def engine_phase_probes(tp: TeamProblem, sched: ParallelSGDSchedule) -> dict:
    """Jitted per-phase probes for the simulated backend — the §6.5
    phase split (compute vs. the two comm phases) measured on the round
    body's real payload shapes, *outside* the training step (its
    compiled numerics are never touched).

    Returns ``{phase: (fn, args, calls_per_round)}``. On this backend
    the Gram "allreduce" is the identity (the simulated ranks already
    hold globally reduced values) and the parameter average is a real
    ``jnp.mean`` over the stacked team iterates — so the probed comm
    phases measure what the one-device simulation actually pays, not
    what a mesh would."""
    sb = sched.s * sched.b
    bundles = sched.tau // sched.s
    m_local = int(tp.indices.shape[1])
    reps = -(-sb // m_local)
    bi = jnp.tile(tp.indices[0], (reps, 1))[:sb]
    bv = jnp.tile(tp.values[0], (reps, 1))[:sb]
    x0 = jnp.zeros((tp.n,), jnp.float32)
    compute = jax.jit(
        lambda i, v, x: bundle_gram_v(
            i, v, x, tp.n, gram=sched.gram, bk=sched.bk, bm=sched.bm,
            precision=sched.precision, interpret=sched.interpret,
        )
    )
    g0 = jnp.zeros((sb, sb), jnp.float32)
    v0 = jnp.zeros((sb,), jnp.float32)
    ident = jax.jit(lambda g, v: (g + 0.0, v + 0.0))
    xs = jnp.zeros((sched.p_r, tp.n), jnp.float32)
    avg = jax.jit(lambda t: jnp.mean(t, axis=0))
    return {
        "bundle_compute": (compute, (bi, bv, x0), bundles),
        "allreduce_gv": (ident, (g0, v0), bundles),
        "param_avg": (avg, (xs,), 1),
    }


def single_team(problem: Problem) -> TeamProblem:
    """View a Problem as a 1-team TeamProblem (p_r = 1 corners); the
    objective rides along."""
    return TeamProblem(
        indices=problem.ya.indices[None],
        values=problem.ya.values[None],
        rows_valid=problem.rows_valid[None],
        p=1,
        m=problem.m,
        n=problem.n,
        objective=problem.objective,
    )
