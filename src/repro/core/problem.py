"""The optimization problem (paper §3): unregularized logistic regression.

    min_x f(x) = (1/m) Σ_i log(1 + exp(-y_i · a_i x))

diag(y)·A is precomputed once (the paper does the same), so the gradient
at a sampled row set S is  g = -(1/b) (S·diag(y)A)^T u  with
u = sigmoid(-S·diag(y)A·x) = 1/(1+exp(S·diag(y)A·x)).
"""

from __future__ import annotations

import dataclasses

import jax

import jax.numpy as jnp
import numpy as np

from repro.sparse.csr import CSRMatrix
from repro.sparse.ell import EllBlock, ell_from_csr


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LogisticProblem:
    """diag(y)·A in padded-ELL layout + metadata.

    ``rows_valid`` masks padded (all-zero) rows out of the loss; padded
    rows contribute zero gradient automatically (zero A-row).
    """

    ya: EllBlock  # diag(y)·A, possibly row-padded
    rows_valid: jnp.ndarray  # (padded_m,) bool
    m: int = dataclasses.field(metadata=dict(static=True))  # true sample count
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def padded_m(self) -> int:
        return self.ya.rows


def pad_rows_to(a: CSRMatrix, multiple: int) -> int:
    return -(-a.m // multiple) * multiple


def make_problem(
    a: CSRMatrix, y: np.ndarray, row_multiple: int = 1, dtype=jnp.float32,
    ell_width: int | None = None,
) -> LogisticProblem:
    """Build the device problem. Rows are padded to ``row_multiple`` (the
    paper pads m ≡ 0 mod s_max·b so cyclic batches never wrap)."""
    ya_csr = a.scale_rows(y)
    padded_m = pad_rows_to(a, row_multiple)
    ell = ell_from_csr(ya_csr, width=ell_width, dtype=dtype)
    if padded_m > a.m:
        pad = padded_m - a.m
        ell = EllBlock(
            indices=jnp.concatenate([ell.indices, jnp.zeros((pad, ell.width), jnp.int32)]),
            values=jnp.concatenate([ell.values, jnp.zeros((pad, ell.width), ell.values.dtype)]),
            n=ell.n,
        )
    valid = jnp.arange(padded_m) < a.m
    return LogisticProblem(ya=ell, m=a.m, n=a.n, rows_valid=valid)


def sigmoid_residual(z: jnp.ndarray) -> jnp.ndarray:
    """u = 1/(1+exp(z)), computed stably for large |z|."""
    return jnp.where(z >= 0, jnp.exp(-z) / (1 + jnp.exp(-z)), 1 / (1 + jnp.exp(z)))


def full_loss(problem: LogisticProblem, x: jnp.ndarray) -> jnp.ndarray:
    """f(x) over all m samples. log(1+exp(z)) with z = y·a·x sign folded
    into ya (so the loss argument is -z_row of ya·x ... note ya = diag(y)A
    ⇒ margin = (ya x) and loss = log(1+exp(-margin))."""
    from repro.sparse.ell import ell_matvec

    margin = ell_matvec(problem.ya, x)
    # stable log1p(exp(-margin))
    losses = jnp.logaddexp(0.0, -margin)
    losses = jnp.where(problem.rows_valid, losses, 0.0)
    return jnp.sum(losses) / problem.m
