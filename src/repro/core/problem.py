"""The optimization problem (paper §3), generalized to any registered
convex objective (repro.core.objective):

    min_x f(x) = (1/m) Σ_i ℓ(y_i · a_i x) + (λ/2)‖x‖²

diag(y)·A is precomputed once (the paper does the same), so the sampled
mini-batch gradient is  g = -(1/b) (S·diag(y)A)ᵀ u + λx  with
u = objective.residual(S·diag(y)A·x). The paper's logistic model is the
default objective; ``squared_hinge`` and ``least_squares`` plug into
the identical machinery.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax

import jax.numpy as jnp
import numpy as np

from repro.core.objective import LOGISTIC, Objective, get_objective
from repro.sparse.csr import CSRMatrix
from repro.sparse.ell import EllBlock, ell_from_csr


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Problem:
    """diag(y)·A in padded-ELL layout + metadata + the objective.

    ``rows_valid`` masks padded (all-zero) rows out of the loss; padded
    rows contribute zero gradient automatically (zero A-row).
    ``objective`` is static: changing the loss re-specializes the
    jitted engine exactly like changing a shape would.
    """

    ya: EllBlock  # diag(y)·A, possibly row-padded
    rows_valid: jnp.ndarray  # (padded_m,) bool
    m: int = dataclasses.field(metadata=dict(static=True))  # true sample count
    n: int = dataclasses.field(metadata=dict(static=True))
    objective: Objective = dataclasses.field(
        default=LOGISTIC, metadata=dict(static=True)
    )

    @property
    def padded_m(self) -> int:
        return self.ya.rows


# Deprecated alias (one release): the problem is no longer
# logistic-specific — construct a ``Problem`` (or pass ``objective=`` to
# ``make_problem``). Kept as a true alias so isinstance checks and
# pytree registration keep working for old imports.
LogisticProblem = Problem


def pad_rows_to(a: CSRMatrix, multiple: int) -> int:
    return -(-a.m // multiple) * multiple


def make_problem(
    a: CSRMatrix, y: np.ndarray, row_multiple: int = 1, dtype=jnp.float32,
    ell_width: int | None = None, objective: str | Objective = LOGISTIC,
) -> Problem:
    """Build the device problem. Rows are padded to ``row_multiple`` (the
    paper pads m ≡ 0 mod s_max·b so cyclic batches never wrap).
    ``objective`` is a registry name or an ``Objective`` instance."""
    obj = get_objective(objective)
    ya_csr = a.scale_rows(y)
    padded_m = pad_rows_to(a, row_multiple)
    ell = ell_from_csr(ya_csr, width=ell_width, dtype=dtype)
    if padded_m > a.m:
        pad = padded_m - a.m
        ell = EllBlock(
            indices=jnp.concatenate([ell.indices, jnp.zeros((pad, ell.width), jnp.int32)]),
            values=jnp.concatenate([ell.values, jnp.zeros((pad, ell.width), ell.values.dtype)]),
            n=ell.n,
        )
    valid = jnp.arange(padded_m) < a.m
    return Problem(ya=ell, m=a.m, n=a.n, rows_valid=valid, objective=obj)


def problem_loss(problem: Problem, x: jnp.ndarray) -> jnp.ndarray:
    """f(x) over all m samples under the problem's objective:
    (1/m) Σ ℓ(margin) + (l2/2)‖x‖², with margin = (ya·x) — the label
    sign is folded into ya = diag(y)A."""
    from repro.sparse.ell import ell_matvec

    margin = ell_matvec(problem.ya, x)
    losses = problem.objective.pointwise_loss(margin)
    losses = jnp.where(problem.rows_valid, losses, 0.0)
    f = jnp.sum(losses) / problem.m
    if problem.objective.l2:
        f = f + 0.5 * problem.objective.l2 * jnp.sum(x * x)
    return f


# ---- deprecated re-exports (one release) ----------------------------
#
# The canonical implementations moved to the objective layer
# (repro.core.objective.LogisticObjective) and ``problem_loss``. These
# wrappers keep old imports working — downstream code and
# docs/paper_map.md references don't silently break — but warn.


def sigmoid_residual(z: jnp.ndarray) -> jnp.ndarray:
    """Deprecated: use ``Objective.residual`` (the logistic instance is
    ``repro.core.objective.LOGISTIC``)."""
    warnings.warn(
        "sigmoid_residual is deprecated; use repro.core.objective.LOGISTIC"
        ".residual (or the problem's own objective)",
        DeprecationWarning,
        stacklevel=2,
    )
    return LOGISTIC.residual(z)


def full_loss(problem: Problem, x: jnp.ndarray) -> jnp.ndarray:
    """Deprecated: use ``problem_loss`` — the objective-aware full
    objective (identical values for the default logistic problem)."""
    warnings.warn(
        "full_loss is deprecated; use repro.core.problem.problem_loss",
        DeprecationWarning,
        stacklevel=2,
    )
    return problem_loss(problem, x)
