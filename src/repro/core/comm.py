"""The explicit communication plane — one collectives layer under both
backends, with a per-round communication ledger.

The paper's thesis is that communication, not compute, bounds parallel
SGD (Eq. 4, Tables 2–3). This module makes that quantity first-class:
every collective either backend issues goes through one ``Collectives``
object, and the structure of what was issued — op, mesh axis, span,
payload words, calls per round — is recorded into a ``CommLedger`` that
reports can place next to the Hockney model's predictions.

Three implementations, one protocol:

  counting   the simulated engine's ops. Numerically the identity /
             plain team mean (the simulated ranks already hold globally
             reduced values), but the call sites are the same ones the
             mesh path reduces over — so counting them *is* counting
             the algorithm's communication.
  mesh       shard_map execution: real ``psum`` over the "cols" axis
             (row-team Gram Allreduce) and ``pmean`` over "rows" (the
             column weight sync) — exactly the collectives
             repro.core.distributed issued before this layer existed,
             bitwise.
  timed      mesh + host-side per-round wall timing (the driver blocks
             after each round and appends seconds to the ledger) — the
             §6.5 calibration input (repro.costmodel.calibrate).

Ledger capture is *structural*, not statistical: ``capture_rates`` runs
the actual round body once under ``jax.eval_shape`` (abstract — no
FLOPs, no devices) with a recorder installed; every collective call
records its span and payload from the real traced shapes. A collective
added to (or dropped from) a round body is therefore seen immediately —
the ledger cannot drift from the code the way a hand-maintained formula
can. Real jit traces never record (the recorder is a ContextVar that is
only set inside ``capture_rates``), so compiled numerics are untouched.

Accounting conventions (shared with the Table 2–3 closed forms in
``repro.costmodel.hockney.schedule_comm_volume``):

* words are **per rank** per call, counted from the buffers actually
  reduced — the dense (sb, sb) Gram block plus the (sb,) residual, i.e.
  s²b² + sb words per bundle (the strictly-lower-triangular s(s-1)b²/2
  of Table 3 is the payload's information content; the wire carries the
  dense block);
* a collective whose span is 1 rank moves nothing: it is recorded (the
  call exists) but contributes zero words and zero calls to the counted
  totals;
* the column weight-sync payload is the per-rank weight shard —
  ⌈n/p_c⌉ words under a balanced partition. Unbalanced partitioners pad
  shards to the max (n_loc ≥ ⌈n/p_c⌉) and the mesh ledger counts that
  real padded payload, so counted-vs-modeled exposes padding overhead.
"""

from __future__ import annotations

import dataclasses
import math
import statistics
from contextvars import ContextVar

import jax
import jax.numpy as jnp

__all__ = [
    "COUNTING",
    "MESH",
    "TIMED",
    "Collectives",
    "CommLedger",
    "CommRate",
    "capture_rates",
    "time_dispatch",
    "time_phase",
]

COLLECTIVE_KINDS = ("counting", "mesh", "timed")


@dataclasses.dataclass(frozen=True)
class CommRate:
    """One collective call site of a round body, as captured.

    op              "allreduce" (sum) or "allmean" (average).
    axis            mesh axis reduced over: "cols" (row-team Gram
                    Allreduce) or "rows" (column weight sync).
    span            ranks the collective spans (p_c for "cols", p_r for
                    "rows"); span 1 moves no bytes.
    words_per_call  per-rank payload words of one call.
    calls_per_round how many times the site executes per outer round
                    (the s-bundle loop issues τ/s Gram Allreduces).
    word_bytes      on-wire bytes per word of this payload, captured
                    from the traced leaf dtype (2 for a bf16 (G, v)
                    collective, 4 for fp32 — the default). The word
                    *counts* above stay the Table 2–3 closed forms
                    regardless of precision; this is the β multiplier's
                    other factor.
    """

    op: str
    axis: str
    span: int
    words_per_call: int
    calls_per_round: int
    word_bytes: int = 4

    @property
    def phases_per_call(self) -> int:
        """Hockney latency phases: 2⌈log₂ span⌉ (reduce-scatter +
        all-gather), 0 when the span is a single rank."""
        if self.span <= 1:
            return 0
        return 2 * math.ceil(math.log2(self.span))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.word_bytes == 4:
            # emitted only when non-default: fp32 ledgers serialize
            # byte-identically to every pre-precision release.
            del d["word_bytes"]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CommRate":
        return cls(**d)


@dataclasses.dataclass
class CommLedger:
    """What a run communicated: captured per-round rates × committed
    rounds, plus (timed runs) host-measured per-round wall seconds.

    rates          the round body's collective call sites (captured
                   once at build; identical every round — the schedule
                   is static).
    rounds         rounds accounted so far (the driver commits them as
                   it advances).
    round_seconds  per-round wall seconds, appended by the timed
                   executor; empty for counting/mesh runs.
    phase_seconds  per-round seconds attributed to each §6.5 phase
                   ("bundle_compute" / "allreduce_gv" / "param_avg"),
                   measured once per timed run by the phase probes
                   (separate jitted probes over the round's real payload
                   shapes — the training step itself is never split, so
                   its compiled numerics stay untouched).
    delay          the schedule's staleness D. D ≥ 1 pipelines the
                   (G, v) Allreduce D bundles deep, so each collective
                   has D bundle-computes to hide behind — the exposed
                   (critical-path) comm time drops below the total
                   while the counted volume is unchanged.
    """

    rates: tuple[CommRate, ...] = ()
    rounds: int = 0
    round_seconds: list[float] = dataclasses.field(default_factory=list)
    phase_seconds: dict[str, float] = dataclasses.field(default_factory=dict)
    delay: int = 0

    # ---- accumulation (driver-side) ----

    def add_rounds(self, k: int) -> None:
        self.rounds += int(k)

    def add_round_seconds(self, dt: float) -> None:
        self.round_seconds.append(float(dt))

    def set_phase_seconds(self, phases: dict[str, float]) -> None:
        self.phase_seconds = {k: float(v) for k, v in phases.items()}

    def snapshot(self) -> "CommLedger":
        """An independent copy (what RoundEvent/RunReport carry)."""
        return CommLedger(
            rates=self.rates,
            rounds=self.rounds,
            round_seconds=list(self.round_seconds),
            phase_seconds=dict(self.phase_seconds),
            delay=self.delay,
        )

    # ---- counted totals (span-1 collectives move nothing) ----

    def _per_round(self, axis: str, field: str) -> int:
        return sum(
            getattr(r, field) * (r.calls_per_round if field != "calls_per_round" else 1)
            for r in self.rates
            if r.axis == axis and r.span > 1
        )

    def counted_words(self, rounds: int | None = None) -> dict[str, float]:
        """Per-rank communicated words over ``rounds`` (default: the
        committed count) — same keys as the modeled dict, so reports can
        print the two side by side."""
        r = self.rounds if rounds is None else int(rounds)
        gram = float(r * self._per_round("cols", "words_per_call"))
        sync = float(r * self._per_round("rows", "words_per_call"))
        return {"gram_words": gram, "sync_words": sync, "total_words": gram + sync}

    def counted_calls(self, rounds: int | None = None) -> dict[str, int]:
        """Collective calls that actually spanned >1 rank."""
        r = self.rounds if rounds is None else int(rounds)
        return {
            "gram_calls": r * self._per_round("cols", "calls_per_round"),
            "sync_calls": r * self._per_round("rows", "calls_per_round"),
        }

    def phases_per_round(self) -> int:
        """Hockney α-phases per round: Σ calls · 2⌈log₂ span⌉."""
        return sum(
            r.calls_per_round * r.phases_per_call for r in self.rates if r.span > 1
        )

    def bytes_per_round(self, word_bytes: int | None = None) -> float:
        """On-wire bytes per rank per round (the β multiplier).

        With ``word_bytes=None`` each call site is priced at its own
        captured ``word_bytes`` (so a bf16 (G, v) Allreduce counts half
        the fp32 bytes); an explicit ``word_bytes`` keeps the legacy
        uniform override (every word priced at the machine's word)."""
        if word_bytes is None:
            return float(sum(
                r.words_per_call * r.calls_per_round * r.word_bytes
                for r in self.rates
                if r.span > 1
            ))
        return float(word_bytes) * (
            self._per_round("cols", "words_per_call")
            + self._per_round("rows", "words_per_call")
        )

    def counted_bytes(self, rounds: int | None = None) -> dict[str, float]:
        """Per-rank on-wire bytes over ``rounds``, at each call site's
        captured ``word_bytes`` — the precision-aware twin of
        ``counted_words`` (whose word counts are invariant)."""
        r = self.rounds if rounds is None else int(rounds)

        def axis_bytes(axis):
            return float(r * sum(
                rt.words_per_call * rt.calls_per_round * rt.word_bytes
                for rt in self.rates
                if rt.axis == axis and rt.span > 1
            ))

        gram, sync = axis_bytes("cols"), axis_bytes("rows")
        return {"gram_bytes": gram, "sync_bytes": sync, "total_bytes": gram + sync}

    # ---- measured (timed runs) ----

    @property
    def seconds_per_round(self) -> float | None:
        """Median measured round wall (None when the run was untimed)."""
        if not self.round_seconds:
            return None
        return statistics.median(self.round_seconds)

    @property
    def total_comm_s(self) -> float | None:
        """Total communication time over the committed rounds: the
        per-round comm phases ("allreduce_gv" + "param_avg") × rounds —
        what the run pays on the wire regardless of overlap. None until
        the phase probes have run."""
        comm = [v for k, v in self.phase_seconds.items() if k != "bundle_compute"]
        if not comm:
            return None
        return float(sum(comm)) * self.rounds

    @property
    def exposed_comm_s(self) -> float | None:
        """Communication time on the *critical path* over the committed
        rounds. At delay 0 nothing overlaps, so exposed ≡ total. At
        delay D ≥ 1 each per-bundle (G, v) Allreduce is consumed D
        bundles after it is issued, so it has D bundle-computes to hide
        behind: the exposed Gram-phase remainder per round is
        max(allreduce_gv − D · bundle_compute, 0) (the positive part
        commutes with the per-round scaling, since both phases count
        the same τ/s calls). The parameter average stays synchronous at
        the round boundary and is always exposed. None until the phase
        probes have run."""
        comm = {k: v for k, v in self.phase_seconds.items() if k != "bundle_compute"}
        if not comm:
            return None
        gv = comm.pop("allreduce_gv", 0.0)
        if self.delay:
            compute = self.phase_seconds.get("bundle_compute", 0.0)
            gv = max(gv - self.delay * compute, 0.0)
        return float(gv + sum(comm.values())) * self.rounds

    @property
    def overlap_efficiency(self) -> float | None:
        """exposed_comm_s / total_comm_s — the fraction of paid comm
        time still on the critical path (1.0 = nothing hidden, the
        delay-0 value; lower is better). None until the phase probes
        have run."""
        total = self.total_comm_s
        exposed = self.exposed_comm_s
        if total is None or exposed is None:
            return None
        if total <= 0.0:
            return 1.0
        return exposed / total

    # ---- serialization ----

    def to_dict(self) -> dict:
        d = {
            "rates": [r.to_dict() for r in self.rates],
            "rounds": self.rounds,
            "round_seconds": list(self.round_seconds),
            # derived, for human-readable reports (ignored on load)
            "counted": self.counted_words(),
        }
        if any(r.word_bytes != 4 for r in self.rates):
            # bytes are derived too, but only interesting (and only
            # emitted) when some payload is narrower than a word —
            # fp32 ledgers keep their pre-precision serialization.
            d["counted_bytes"] = self.counted_bytes()
        if self.delay:
            # emitted only when nonzero: delay-0 ledgers serialize
            # byte-identically to every pre-overlap release.
            d["delay"] = self.delay
        if self.phase_seconds:
            d["phase_seconds"] = dict(self.phase_seconds)
            # derived trio, for human-readable reports (ignored on load)
            d["exposed_comm_s"] = self.exposed_comm_s
            d["total_comm_s"] = self.total_comm_s
            d["overlap_efficiency"] = self.overlap_efficiency
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CommLedger":
        return cls(
            rates=tuple(CommRate.from_dict(r) for r in d.get("rates", ())),
            rounds=int(d.get("rounds", 0)),
            round_seconds=[float(v) for v in d.get("round_seconds", ())],
            phase_seconds={
                k: float(v) for k, v in d.get("phase_seconds", {}).items()
            },
            delay=int(d.get("delay", 0)),
        )


# ---- capture machinery -------------------------------------------------
#
# Recording is scoped to capture_rates via a ContextVar: inside it the
# collective ops append a CommRate (from the traced payload shapes) and
# return their input unchanged — the abstract trace needs no mesh axes.
# Outside it (every real trace and execution) the ops are exactly the
# pre-layer computation.


@dataclasses.dataclass
class _Recorder:
    spans: dict[str, int]
    rates: list[CommRate]


_RECORDER: ContextVar[_Recorder | None] = ContextVar("repro_comm_recorder", default=None)


def capture_rates(fn, *abstract_args, spans: dict[str, int]) -> tuple[CommRate, ...]:
    """Trace ``fn`` abstractly (``jax.eval_shape`` — no FLOPs, no
    devices) with recording on, and return every collective call site it
    issued. ``spans`` maps mesh axis name → rank count ({"cols": p_c,
    "rows": p_r})."""
    rec = _Recorder(spans=dict(spans), rates=[])
    token = _RECORDER.set(rec)
    try:
        jax.eval_shape(fn, *abstract_args)
    finally:
        _RECORDER.reset(token)
    return tuple(rec.rates)


def _tree_words(tree) -> int:
    return int(sum(math.prod(leaf.shape) for leaf in jax.tree_util.tree_leaves(tree)))


def _tree_word_bytes(tree) -> int:
    """On-wire bytes per word, from the traced leaf dtypes (the widest
    leaf prices the payload; 4 when the tree carries no leaves)."""
    sizes = [leaf.dtype.itemsize for leaf in jax.tree_util.tree_leaves(tree)]
    return int(max(sizes)) if sizes else 4


@dataclasses.dataclass(frozen=True)
class Collectives:
    """The collective ops a round body issues, by kind.

    Frozen and stateless: instances hash and compare by ``kind``, so
    closing a jitted round body over one never fragments the jit cache.
    The module singletons ``COUNTING`` / ``MESH`` / ``TIMED`` are the
    three implementations; ``TIMED`` shares ``MESH``'s ops — the timing
    itself is host-side, in the driver (``HybridDriver.advance`` /
    ``Session._advance`` block per round and append to the ledger).
    """

    kind: str = "counting"

    def __post_init__(self):
        if self.kind not in COLLECTIVE_KINDS:
            raise ValueError(f"kind={self.kind!r} not in {COLLECTIVE_KINDS}")

    @property
    def timed(self) -> bool:
        return self.kind == "timed"

    @property
    def on_mesh(self) -> bool:
        return self.kind in ("mesh", "timed")

    # ---- the row-team (Gram) Allreduce: sum over column shards ----

    def allreduce_cols(self, tree, *, calls_per_round: int = 1,
                       words_per_call: int | None = None):
        """Sum ``tree`` across the "cols" mesh axis (the per-bundle
        (G, v) Allreduce — Table 3's row-team payload).

        counting: identity — the simulated ranks compute the full (G, v)
        directly, so the reduced value is already in hand. mesh/timed:
        one ``psum`` per leaf (separate binds, exactly the two psum
        calls the pre-layer code issued — bitwise-identical HLO).

        ``words_per_call`` overrides the payload derived from the traced
        leaf shapes — the s = 1 engine corner uses it to account the
        full (G, v) payload its distributed twin puts on the wire even
        though the simulated body only materializes v.
        """
        rec = _RECORDER.get()
        if rec is not None:
            words = words_per_call if words_per_call is not None else _tree_words(tree)
            rec.rates.append(CommRate(
                op="allreduce",
                axis="cols",
                span=rec.spans.get("cols", 1),
                words_per_call=int(words),
                calls_per_round=int(calls_per_round),
                word_bytes=_tree_word_bytes(tree),
            ))
            return tree
        if not self.on_mesh:
            return tree
        return jax.tree_util.tree_map(lambda t: jax.lax.psum(t, "cols"), tree)

    # ---- the async-dispatch-shaped split of the Gram Allreduce ----
    #
    # JAX collectives are dispatched asynchronously: the Python call
    # returns a future-backed array and the host only blocks when a
    # value is needed. The delay-D pipeline makes that explicit at the
    # call-site level — ``issue_allreduce_cols`` at bundle k starts the
    # reduction, ``await_allreduce`` at bundle k+D marks where its value
    # is first consumed. Under XLA the issue *is* the psum (recorded
    # once, same payload accounting as the fused call) and the await is
    # the identity: the D bundle-computes the scheduler runs in between
    # are what actually hides the transfer.

    def issue_allreduce_cols(self, tree, *, calls_per_round: int = 1,
                             words_per_call: int | None = None):
        """Start the per-bundle (G, v) Allreduce for a delayed schedule.
        Same reduction, recording, and payload conventions as
        ``allreduce_cols`` — the split exists so traces and ledgers can
        attribute the in-flight window."""
        return self.allreduce_cols(
            tree, calls_per_round=calls_per_round, words_per_call=words_per_call
        )

    def await_allreduce(self, tree):
        """Consume a previously issued Allreduce. Identity on every
        kind and never recorded — the payload was counted at issue
        time; this marks the critical-path join point."""
        return tree

    # ---- the column Allreduce: average weights across row teams ----

    def allmean_rows(self, x, *, calls_per_round: int = 1,
                     words_per_call: int | None = None):
        """Average the per-shard weight slab across the "rows" mesh axis
        (the per-τ-iterations FedAvg sync — Table 3's column payload).
        Mesh/timed only; the simulated engine's stacked form is
        ``allmean_teams``."""
        rec = _RECORDER.get()
        if rec is not None:
            words = words_per_call if words_per_call is not None else _tree_words(x)
            rec.rates.append(CommRate(
                op="allmean",
                axis="rows",
                span=rec.spans.get("rows", 1),
                words_per_call=int(words),
                calls_per_round=int(calls_per_round),
                word_bytes=_tree_word_bytes(x),
            ))
            return x
        if not self.on_mesh:
            return x
        return jax.lax.pmean(x, "rows")

    def allmean_teams(self, xs, *, words_per_call: int,
                      calls_per_round: int = 1):
        """The simulated form of ``allmean_rows``: the p_r team iterates
        arrive stacked as ``xs`` (p_r, n) and the mean over the leading
        axis *is* the collective (exact SPMD semantics on one device).
        ``words_per_call`` is the per-rank shard payload ⌈n/p_c⌉ — the
        stacked shape carries the global n, not the per-rank slab, so
        the caller supplies it."""
        rec = _RECORDER.get()
        if rec is not None:
            rec.rates.append(CommRate(
                op="allmean",
                axis="rows",
                span=rec.spans.get("rows", 1),
                words_per_call=int(words_per_call),
                calls_per_round=int(calls_per_round),
                word_bytes=_tree_word_bytes(xs),
            ))
        return jnp.mean(xs, axis=0)


COUNTING = Collectives("counting")
MESH = Collectives("mesh")
TIMED = Collectives("timed")


def time_phase(fn, *args, repeats: int = 5) -> float:
    """Median wall seconds of one call to a compiled phase probe
    (blocks on the result; one unmeasured warmup call absorbs the
    compile). The §6.5 per-phase measurement primitive."""
    import time as _time

    jax.block_until_ready(fn(*args))  # warmup / compile
    walls = []
    for _ in range(int(repeats)):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(*args))
        walls.append(_time.perf_counter() - t0)
    return statistics.median(walls)


def time_dispatch(fn, *args, repeats: int = 5) -> float:
    """Median wall seconds to *dispatch* one call of a compiled probe —
    the host returns as soon as the async runtime has enqueued the work,
    without blocking on the value. This is what an issued collective
    costs the critical path while its transfer is in flight; the
    complement ``time_phase − time_dispatch`` is the hideable window.
    Each repeat still drains the device afterwards (outside the timed
    region) so queued work from one repeat never backs up into the
    next."""
    import time as _time

    jax.block_until_ready(fn(*args))  # warmup / compile
    walls = []
    for _ in range(int(repeats)):
        t0 = _time.perf_counter()
        out = fn(*args)
        walls.append(_time.perf_counter() - t0)
        jax.block_until_ready(out)
    return statistics.median(walls)
