"""Algorithm 1 — mini-batch SGD (the sequential baseline).

Row sub-sampling is cyclic, i = (i + b) mod m, exactly as the paper
(§5): it makes the sample sequence reproducible across solvers so the
s-step ≡ SGD identity can be tested to floating-point error.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.problem import LogisticProblem, full_loss, sigmoid_residual
from repro.sparse.ell import EllBlock, ell_matvec, ell_rmatvec


def batch_rows(ell: EllBlock, k: jnp.ndarray, b: int) -> EllBlock:
    """Rows [k·b mod m, +b) — static size b, dynamic start."""
    m = ell.rows
    start = (k * b) % m
    idx = jax.lax.dynamic_slice_in_dim(ell.indices, start, b, axis=0)
    val = jax.lax.dynamic_slice_in_dim(ell.values, start, b, axis=0)
    return EllBlock(indices=idx, values=val, n=ell.n)


def sgd_step(ell: EllBlock, x: jnp.ndarray, k: jnp.ndarray, b: int, eta: float) -> jnp.ndarray:
    """One mini-batch SGD step (Algorithm 1 lines 3-6)."""
    batch = batch_rows(ell, k, b)
    z = ell_matvec(batch, x)  # S·diag(y)·A·x
    u = sigmoid_residual(z)  # 1/(1+exp(z))
    # g = -(1/b) (S diag(y) A)^T u  ⇒  x ← x + (η/b) Yᵀu
    return x + (eta / b) * ell_rmatvec(batch, u)


@partial(jax.jit, static_argnames=("b", "K", "loss_every"))
def run_sgd(
    problem: LogisticProblem,
    x0: jnp.ndarray,
    b: int,
    eta: float,
    K: int,
    loss_every: int = 0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x_K, losses) where losses is the full objective sampled
    every ``loss_every`` iterations (empty if 0)."""
    ell = problem.ya
    if ell.rows % b:
        raise ValueError(f"padded m={ell.rows} must be divisible by b={b}")

    chunk = loss_every if loss_every else K
    n_chunks, rem = divmod(K, chunk)
    if rem:
        raise ValueError(f"K={K} must be divisible by loss_every={loss_every}")

    def inner(x, k):
        return sgd_step(ell, x, k, b, eta), None

    def outer(x, c):
        x, _ = jax.lax.scan(inner, x, c * chunk + jnp.arange(chunk))
        return x, full_loss(problem, x)

    x, losses = jax.lax.scan(outer, x0, jnp.arange(n_chunks))
    if not loss_every:
        losses = jnp.zeros((0,), losses.dtype)
    return x, losses
