"""Algorithm 1 — mini-batch SGD (the sequential baseline).

DEPRECATED module layout: ``run_sgd`` is now a thin wrapper over the
unified engine (repro.core.engine) at the corner p_r = 1, s = 1, τ = 1.
``sgd_step``/``batch_rows`` remain the standalone single-step helpers
(used by kernel tests and docs).

Row sub-sampling is cyclic, i = (i + b) mod m, exactly as the paper
(§5): it makes the sample sequence reproducible across solvers so the
s-step ≡ SGD identity can be tested to floating-point error.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import ParallelSGDSchedule, run_parallel_sgd, single_team
from repro.core.objective import LOGISTIC
from repro.core.problem import Problem
from repro.sparse.ell import EllBlock, ell_matvec, ell_rmatvec


def batch_rows(ell: EllBlock, k: jnp.ndarray, b: int) -> EllBlock:
    """Rows [k·b mod m, +b) — static size b, dynamic start."""
    m = ell.rows
    start = (k * b) % m
    idx = jax.lax.dynamic_slice_in_dim(ell.indices, start, b, axis=0)
    val = jax.lax.dynamic_slice_in_dim(ell.values, start, b, axis=0)
    return EllBlock(indices=idx, values=val, n=ell.n)


def sgd_step(ell: EllBlock, x: jnp.ndarray, k: jnp.ndarray, b: int, eta: float) -> jnp.ndarray:
    """One mini-batch SGD step (Algorithm 1 lines 3-6)."""
    batch = batch_rows(ell, k, b)
    z = ell_matvec(batch, x)  # S·diag(y)·A·x
    u = LOGISTIC.residual(z)  # 1/(1+exp(z))
    # g = -(1/b) (S diag(y) A)^T u  ⇒  x ← x + (η/b) Yᵀu
    return x + (eta / b) * ell_rmatvec(batch, u)


def run_sgd(
    problem: Problem,
    x0: jnp.ndarray,
    b: int,
    eta: float,
    K: int,
    loss_every: int = 0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Engine corner (p_r=1, s=1, τ=1). Returns (x_K, losses) where
    losses is the full objective sampled every ``loss_every``
    iterations (empty if 0)."""
    if problem.ya.rows % b:
        raise ValueError(f"padded m={problem.ya.rows} must be divisible by b={b}")
    if loss_every and K % loss_every:
        raise ValueError(f"K={K} must be divisible by loss_every={loss_every}")
    sched = ParallelSGDSchedule.mb_sgd(b, eta, K, loss_every=loss_every)
    return run_parallel_sgd(single_team(problem), x0, sched)
