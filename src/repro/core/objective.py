"""Pluggable convex objectives — the paper's §3 problem statement,
generalized to the regularized GLM / margin-loss family.

The HybridSGD machinery (s-step Gram bundles, inner corrections,
row-team averaging) is derived for logistic regression but is generic
to any pointwise *margin* loss: with Y = S·diag(y)·A the sampled rows
and z = Y·x the margins, the mini-batch gradient of

    f(x) = (1/m) Σ_i ℓ(z_i) + (λ/2)‖x‖²          (λ = l2, optional)

is  g = -(1/b)·Yᵀ·u(z) + λ·x  with  u(z) = -ℓ′(z),  so one SGD step is

    x ← (1 - ηλ)·x + (η/b)·Yᵀ·u(Y·x).

Everything the engine needs from the model is therefore two pointwise
maps — ``residual(z) = -ℓ′(z)`` and ``pointwise_loss(z) = ℓ(z)`` — plus
the decay scalar ``l2``. An ``Objective`` packages exactly that; the
engine, the shard_map executor, and the loss probes consume it and
never mention a specific loss again (Devarakonda & Demmel apply the
same s-step trick to the whole regularized GLM family).

Registered losses (margins z = y·aᵀx, labels y ∈ {±1} folded into Y):

  logistic       ℓ(z) = log(1 + e^{-z});        u(z) = 1/(1 + e^{z})
  squared_hinge  ℓ(z) = max(0, 1 - z)²;         u(z) = 2·max(0, 1 - z)
  least_squares  ℓ(z) = ½(1 - z)²;              u(z) = 1 - z

L2 semantics in the s-step bundle (exact, not approximate): with
ρ = 1 - ηλ the unrolled recurrence is

    z_j = ρ^j·v_j + (η/b)·Σ_{l<j} ρ^{j-1-l}·G_{jl}·u_l
    x_s = ρ^s·x_0 + (η/b)·Yᵀ·[ρ^{s-1-l}·u_l]_l

which ``repro.core.engine.inner_corrections`` implements by carrying
the ρ-rescaled residual vector (so the returned u is already the
ρ^{s-1-l}-weighted one the Yᵀ apply needs). At λ = 0 every factor is
skipped at trace time — the logistic default routes through bitwise the
same computation as before this layer existed.
"""

from __future__ import annotations

import dataclasses
import math
from typing import ClassVar

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Objective:
    """A pointwise margin loss + optional L2 decay.

    Frozen and hashable on purpose: objectives ride as *static* fields
    on the problem pytrees (``Problem`` / ``TeamProblem`` /
    ``Hybrid2DProblem``), so a change of objective re-specializes the
    jitted engine exactly like a change of shape would.

    l2   the ridge coefficient λ in f(x) = (1/m)Σℓ + (λ/2)‖x‖².
         0.0 (default) means unregularized — and is special-cased at
         trace time so the λ = 0 computation is bitwise identical to
         the pre-objective code path.
    """

    l2: float = 0.0
    name: ClassVar[str] = "abstract"

    def __post_init__(self):
        if not math.isfinite(self.l2) or self.l2 < 0.0:
            raise ValueError(f"l2={self.l2} must be finite and ≥ 0")

    # -- the two pointwise maps the engine consumes --

    def residual(self, z: jnp.ndarray) -> jnp.ndarray:
        """u(z) = -ℓ′(z): the batch update is x += (η/b)·Yᵀ·u(Yx)."""
        raise NotImplementedError

    def pointwise_loss(self, z: jnp.ndarray) -> jnp.ndarray:
        """ℓ(z) per sample (the L2 term is added by the problem-level
        loss, where ‖x‖² is available)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class LogisticObjective(Objective):
    """ℓ(z) = log(1 + e^{-z}) — the paper's model, computed stably."""

    name: ClassVar[str] = "logistic"

    def residual(self, z: jnp.ndarray) -> jnp.ndarray:
        # u = 1/(1+exp(z)), stable for large |z| (the historical
        # sigmoid_residual expression, kept verbatim for bitwise parity)
        return jnp.where(z >= 0, jnp.exp(-z) / (1 + jnp.exp(-z)), 1 / (1 + jnp.exp(z)))

    def pointwise_loss(self, z: jnp.ndarray) -> jnp.ndarray:
        return jnp.logaddexp(0.0, -z)


@dataclasses.dataclass(frozen=True)
class SquaredHingeObjective(Objective):
    """ℓ(z) = max(0, 1 - z)² — the L2-SVM loss (differentiable, convex;
    the margin form Local-SGD papers evaluate)."""

    name: ClassVar[str] = "squared_hinge"

    def residual(self, z: jnp.ndarray) -> jnp.ndarray:
        return 2.0 * jnp.maximum(0.0, 1.0 - z)

    def pointwise_loss(self, z: jnp.ndarray) -> jnp.ndarray:
        return jnp.square(jnp.maximum(0.0, 1.0 - z))


@dataclasses.dataclass(frozen=True)
class LeastSquaresObjective(Objective):
    """ℓ(z) = ½(1 - z)² — least-squares classification on ±1 labels
    (equivalently ridge regression on the margins)."""

    name: ClassVar[str] = "least_squares"

    def residual(self, z: jnp.ndarray) -> jnp.ndarray:
        return 1.0 - z

    def pointwise_loss(self, z: jnp.ndarray) -> jnp.ndarray:
        return 0.5 * jnp.square(1.0 - z)


OBJECTIVES: dict[str, type[Objective]] = {
    LogisticObjective.name: LogisticObjective,
    SquaredHingeObjective.name: SquaredHingeObjective,
    LeastSquaresObjective.name: LeastSquaresObjective,
}

LOGISTIC = LogisticObjective()


def get_objective(objective: str | Objective, l2: float = 0.0) -> Objective:
    """Resolve a registry name (+ l2) to an ``Objective`` instance.

    An already-constructed ``Objective`` passes through unchanged —
    except that asking for a *different* nonzero l2 at the same time is
    ambiguous and rejected.
    """
    if isinstance(objective, Objective):
        if l2 and objective.l2 != l2:
            raise ValueError(
                f"objective already carries l2={objective.l2}; conflicting l2={l2}"
            )
        return objective
    if objective not in OBJECTIVES:
        raise ValueError(
            f"objective={objective!r} not in registry {sorted(OBJECTIVES)}"
        )
    return OBJECTIVES[objective](l2=l2)
