"""Algorithm 2 — Federated SGD with Averaging (FedAvg / local SGD).

Row-partition (A, y) across p ranks; each rank runs τ sequential local
SGD iterations from the shared iterate; the local solutions are averaged
(one length-n Allreduce) every round. τ=1 degenerates to synchronous
mini-batch SGD on an effective batch of p·b; p=1 is sequential SGD.

Simulated-rank implementation: vmap the local solver over the stacked
team axis, then mean — *numerically identical* to the p-rank MPI/SPMD
execution (same per-rank sample sequences).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.problem import full_loss, sigmoid_residual
from repro.core.teams import TeamProblem, global_problem
from repro.sparse.ell import EllBlock, ell_matvec, ell_rmatvec


def _local_sgd(indices, values, n: int, x, k0, tau: int, b: int, eta: float):
    """τ local SGD steps on one team's rows, starting at step index k0."""
    m_local = indices.shape[0]

    def body(x, t):
        start = ((k0 + t) * b) % m_local
        idx = jax.lax.dynamic_slice_in_dim(indices, start, b, axis=0)
        val = jax.lax.dynamic_slice_in_dim(values, start, b, axis=0)
        batch = EllBlock(indices=idx, values=val, n=n)
        u = sigmoid_residual(ell_matvec(batch, x))
        return x + (eta / b) * ell_rmatvec(batch, u), None

    x, _ = jax.lax.scan(body, x, jnp.arange(tau))
    return x


@partial(jax.jit, static_argnames=("b", "tau", "rounds", "loss_every"))
def run_fedavg(
    tp: TeamProblem,
    x0: jnp.ndarray,
    b: int,
    eta: float,
    tau: int,
    rounds: int,
    loss_every: int = 0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``rounds`` outer iterations (K̃); each is τ local steps + average.

    Returns (x, losses) with the full global objective sampled every
    ``loss_every`` rounds.
    """
    if tp.rows_local % b:
        raise ValueError(f"local rows {tp.rows_local} must be divisible by b={b}")
    gp = global_problem(tp)
    local = jax.vmap(_local_sgd, in_axes=(0, 0, None, None, None, None, None, None))

    chunk = loss_every if loss_every else rounds
    n_chunks = max(rounds // chunk, 1)

    def one_round(x, r):
        xs = local(tp.indices, tp.values, tp.n, x, r * tau, tau, b, eta)
        return jnp.mean(xs, axis=0), None

    def outer(x, c):
        x, _ = jax.lax.scan(one_round, x, c * chunk + jnp.arange(chunk))
        return x, full_loss(gp, x)

    x, losses = jax.lax.scan(outer, x0, jnp.arange(n_chunks))
    if not loss_every:
        losses = jnp.zeros((0,), losses.dtype)
    return x, losses
