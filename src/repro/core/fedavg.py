"""Algorithm 2 — Federated SGD with Averaging (FedAvg / local SGD).

DEPRECATED module layout: ``run_fedavg`` is now a thin wrapper over the
unified engine (repro.core.engine) at the corner s = 1 (the bundle
degenerates to one mini-batch step, so no Gram work is done).

Row-partition (A, y) across p ranks; each rank runs τ sequential local
SGD iterations from the shared iterate; the local solutions are averaged
(one length-n Allreduce) every round. τ=1 degenerates to synchronous
mini-batch SGD on an effective batch of p·b; p=1 is sequential SGD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import ParallelSGDSchedule, run_parallel_sgd
from repro.core.objective import LOGISTIC
from repro.core.teams import TeamProblem
from repro.sparse.ell import EllBlock, ell_matvec, ell_rmatvec


def _local_sgd(indices, values, n: int, x, k0, tau: int, b: int, eta: float):
    """τ local SGD steps on one team's rows, starting at step index k0.

    Standalone reference for what the engine computes per team at the
    s = 1 corner (used by tests as the manual oracle)."""
    m_local = indices.shape[0]

    def body(x, t):
        start = ((k0 + t) * b) % m_local
        idx = jax.lax.dynamic_slice_in_dim(indices, start, b, axis=0)
        val = jax.lax.dynamic_slice_in_dim(values, start, b, axis=0)
        batch = EllBlock(indices=idx, values=val, n=n)
        u = LOGISTIC.residual(ell_matvec(batch, x))
        return x + (eta / b) * ell_rmatvec(batch, u), None

    x, _ = jax.lax.scan(body, x, jnp.arange(tau))
    return x


def run_fedavg(
    tp: TeamProblem,
    x0: jnp.ndarray,
    b: int,
    eta: float,
    tau: int,
    rounds: int,
    loss_every: int = 0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Engine corner (s=1): ``rounds`` outer iterations (K̃); each is τ
    local steps + average. Returns (x, losses) with the full global
    objective sampled every ``loss_every`` rounds."""
    if tp.rows_local % b:
        raise ValueError(f"local rows {tp.rows_local} must be divisible by b={b}")
    sched = ParallelSGDSchedule.fedavg(tp.p, b, eta, tau, rounds, loss_every=loss_every)
    return run_parallel_sgd(tp, x0, sched)
