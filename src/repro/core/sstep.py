"""Algorithm 3 — s-step (communication-avoiding) SGD.

DEPRECATED module layout: ``run_sstep_sgd`` is now a thin wrapper over
the unified engine (repro.core.engine) at the corner p_r = 1, τ = s.
``sstep_bundle`` remains as the standalone single-bundle helper.

Recurrence unrolling: a bundle of s consecutive mini-batch steps is
regrouped so that all matrix work happens up front —

    Y = [S_1; ...; S_s]·diag(y)·A          (sb × n)
    G = tril(Y Yᵀ)                         (sb × sb Gram, strictly-lower
                                            blocks correct the deferred
                                            updates)
    v = Y·x_sk                             (sb)

then the inner loop runs on s b-vectors only:

    z_j = v_j + (η/b) Σ_{l<j} G_{jl} u_l   (apply deferred updates)
    u_j = sigmoid_residual(z_j)
    x_{sk+s} = x_sk + (η/b) Yᵀ [u_1; ...; u_s]

This is an algebraic identity of Algorithm 1 (same sample sequence ⇒
identical iterates up to FP error) — validated in tests. In the 1D
distributed form the only communication is one Allreduce of (G, v) per s
steps; Yᵀu is local under column partitioning.

(G, v) routes through the scatter-free Pallas ELL-Gram kernel
(repro.kernels.ell_gram); the old densify path lives on only as the
parity oracle repro.kernels.ref.ell_gram_and_v_ref.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import (
    ParallelSGDSchedule,
    bundle_gram_v,
    inner_corrections,
    run_parallel_sgd,
    single_team,
)
from repro.core.problem import Problem
from repro.core.sgd import batch_rows
from repro.sparse.ell import EllBlock, ell_rmatvec


def gram_and_v(bundle_vals: jnp.ndarray, bundle_idx: jnp.ndarray, n: int, x: jnp.ndarray):
    """Return (G, v) for the ELL bundle rows — scatter-free.

    Kept for backwards compatibility (note the historical value-first
    argument order); new code should call
    repro.core.engine.bundle_gram_v directly."""
    return bundle_gram_v(bundle_idx, bundle_vals, x, n)


def sstep_bundle(
    ell: EllBlock,
    x: jnp.ndarray,
    k: jnp.ndarray,
    s: int,
    b: int,
    eta: float,
) -> jnp.ndarray:
    """One outer iteration of Algorithm 3 (s fused steps), starting at
    global step index k·s (cyclic sampling)."""
    bundle = batch_rows(ell, k, s * b)  # rows [k·sb, k·sb + sb)
    g, v = bundle_gram_v(bundle.indices, bundle.values, x, ell.n)
    u = inner_corrections(g, v, s, b, eta)
    return x + (eta / b) * ell_rmatvec(bundle, u).astype(x.dtype)


def run_sstep_sgd(
    problem: Problem,
    x0: jnp.ndarray,
    s: int,
    b: int,
    eta: float,
    K: int,
    loss_every: int = 0,
    gram: str = "pallas",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Engine corner (p_r=1, τ=s): K total SGD-equivalent iterations =
    K/s bundles. ``gram`` selects the bundle backend (engine.GRAM_METHODS)."""
    if K % s:
        raise ValueError(f"K={K} must be divisible by s={s}")
    if problem.ya.rows % (s * b):
        raise ValueError(f"padded m={problem.ya.rows} must be divisible by s·b={s * b}")
    sched = ParallelSGDSchedule.sstep(s, b, eta, K, loss_every=loss_every, gram=gram)
    return run_parallel_sgd(single_team(problem), x0, sched)
