"""Algorithm 3 — s-step (communication-avoiding) SGD.

Recurrence unrolling: a bundle of s consecutive mini-batch steps is
regrouped so that all matrix work happens up front —

    Y = [S_1; ...; S_s]·diag(y)·A          (sb × n)
    G = tril(Y Yᵀ)                         (sb × sb Gram, strictly-lower
                                            blocks correct the deferred
                                            updates)
    v = Y·x_sk                             (sb)

then the inner loop runs on s b-vectors only:

    z_j = v_j + (η/b) Σ_{l<j} G_{jl} u_l   (apply deferred updates)
    u_j = sigmoid_residual(z_j)
    x_{sk+s} = x_sk + (η/b) Yᵀ [u_1; ...; u_s]

This is an algebraic identity of Algorithm 1 (same sample sequence ⇒
identical iterates up to FP error) — validated in tests. In the 1D
distributed form the only communication is one Allreduce of (G, v) per s
steps; Yᵀu is local under column partitioning.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.problem import LogisticProblem, full_loss, sigmoid_residual
from repro.core.sgd import batch_rows
from repro.sparse.ell import EllBlock, ell_matvec, ell_rmatvec


def gram_and_v(bundle_vals: jnp.ndarray, bundle_idx: jnp.ndarray, n: int, x: jnp.ndarray):
    """Return (G, v) for the dense-ified bundle rows.

    The reference path densifies the sb ELL rows into (sb, n) — fine for
    tests; the production path uses the Pallas gram kernel on BSR tiles
    (repro.kernels). Here we avoid densifying by computing the Gram via
    the ELL overlap directly: scatter rows to dense is O(sb·n) memory, so
    instead use segment-sum on shared column ids.
    """
    sb, width = bundle_vals.shape
    # Dense scatter per row into n is avoided: G[i,j] = Σ_c Y[i,c]Y[j,c].
    # Build (sb, n) one-hot-free via scatter-add into a (sb, n) matrix
    # would be O(sb·n); for small n (column-partitioned shards) that's
    # acceptable and simple:
    dense = jnp.zeros((sb, n), bundle_vals.dtype)
    dense = dense.at[jnp.arange(sb)[:, None], bundle_idx].add(bundle_vals)
    g = jnp.tril(dense @ dense.T, k=-1)  # strictly lower: only l<j corrections
    v = dense @ x
    return g, v


def sstep_bundle(
    ell: EllBlock,
    x: jnp.ndarray,
    k: jnp.ndarray,
    s: int,
    b: int,
    eta: float,
) -> jnp.ndarray:
    """One outer iteration of Algorithm 3 (s fused steps), starting at
    global step index k·s (cyclic sampling)."""
    bundle = batch_rows(ell, k, s * b)  # rows [k·sb, k·sb + sb)
    g, v = gram_and_v(bundle.values, bundle.indices, ell.n, x)

    def inner(u_acc, j):
        # z_j = v_j + (η/b) Σ_{l<j} G[j·b:(j+1)b, :] u_acc   (u_acc zero
        # beyond filled entries, G strictly-lower ⇒ only l<j contribute)
        zj = jax.lax.dynamic_slice_in_dim(v, j * b, b) + (eta / b) * (
            jax.lax.dynamic_slice_in_dim(g, j * b, b, axis=0) @ u_acc
        )
        uj = sigmoid_residual(zj)
        u_acc = jax.lax.dynamic_update_slice_in_dim(u_acc, uj, j * b, axis=0)
        return u_acc, None

    u0 = jnp.zeros(s * b, v.dtype)
    u, _ = jax.lax.scan(inner, u0, jnp.arange(s))
    return x + (eta / b) * ell_rmatvec(bundle, u)


@partial(jax.jit, static_argnames=("s", "b", "K", "loss_every"))
def run_sstep_sgd(
    problem: LogisticProblem,
    x0: jnp.ndarray,
    s: int,
    b: int,
    eta: float,
    K: int,
    loss_every: int = 0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """K total SGD-equivalent iterations = K/s bundles."""
    ell = problem.ya
    if K % s:
        raise ValueError(f"K={K} must be divisible by s={s}")
    if ell.rows % (s * b):
        raise ValueError(f"padded m={ell.rows} must be divisible by s·b={s * b}")
    n_bundles = K // s
    chunk = max(loss_every // s, 1) if loss_every else n_bundles
    n_chunks = max(n_bundles // chunk, 1)

    def inner(x, k):
        return sstep_bundle(ell, x, k, s, b, eta), None

    def outer(x, c):
        x, _ = jax.lax.scan(inner, x, c * chunk + jnp.arange(chunk))
        return x, full_loss(problem, x)

    x, losses = jax.lax.scan(outer, x0, jnp.arange(n_chunks))
    if not loss_every:
        losses = jnp.zeros((0,), losses.dtype)
    return x, losses
