"""Row-team stacking: partition (A, y) into p row blocks with uniform
padded shapes and stack them along a leading axis.

The unified engine (repro.core.engine) maps its per-team inner loop
over this axis — giving *exact* SPMD semantics on one device.
All teams share one ELL width and one padded row count (SPMD uniformity;
this is where nnz imbalance κ becomes padded compute, DESIGN.md §5.3).
"""

from __future__ import annotations

import dataclasses

import jax

import jax.numpy as jnp
import numpy as np

from repro.core.objective import LOGISTIC, Objective, get_objective
from repro.core.problem import Problem
from repro.sparse.csr import CSRMatrix
from repro.sparse.ell import EllBlock
from repro.sparse.partition import partition_rows


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TeamProblem:
    """p stacked local problems. indices/values: (p, rows_local, width).
    ``objective`` (static) is the shared convex loss every team runs."""

    indices: jnp.ndarray
    values: jnp.ndarray
    rows_valid: jnp.ndarray  # (p, rows_local) bool
    p: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))  # global true samples
    n: int = dataclasses.field(metadata=dict(static=True))
    objective: Objective = dataclasses.field(
        default=LOGISTIC, metadata=dict(static=True)
    )

    @property
    def rows_local(self) -> int:
        return int(self.indices.shape[1])

    def team_ell(self, i: int) -> EllBlock:
        return EllBlock(indices=self.indices[i], values=self.values[i], n=self.n)


def stack_row_teams(
    a: CSRMatrix, y: np.ndarray, p: int, row_multiple: int = 1, dtype=jnp.float32,
    objective: str | Objective = LOGISTIC,
) -> TeamProblem:
    obj = get_objective(objective)
    ya = a.scale_rows(np.asarray(y, dtype=np.float64))
    rb = partition_rows(a.m, p)
    blocks = [ya.row_block(int(rb[i]), int(rb[i + 1])) for i in range(p)]
    width = max(max((int(blk.nnz_per_row.max()) if blk.m and blk.nnz else 1) for blk in blocks), 1)
    rows_local = max(int(rb[i + 1] - rb[i]) for i in range(p))
    rows_local = -(-rows_local // row_multiple) * row_multiple

    idx = np.zeros((p, rows_local, width), dtype=np.int32)
    val = np.zeros((p, rows_local, width), dtype=np.float64)
    valid = np.zeros((p, rows_local), dtype=bool)
    for i, blk in enumerate(blocks):
        for r in range(blk.m):
            lo, hi = int(blk.indptr[r]), int(blk.indptr[r + 1])
            k = hi - lo
            idx[i, r, :k] = blk.indices[lo:hi]
            val[i, r, :k] = blk.data[lo:hi]
        valid[i, : blk.m] = True
    return TeamProblem(
        indices=jnp.asarray(idx),
        values=jnp.asarray(val, dtype=dtype),
        rows_valid=jnp.asarray(valid),
        p=p,
        m=a.m,
        n=a.n,
        objective=obj,
    )


def global_problem(tp: TeamProblem) -> Problem:
    """Flatten the stacked teams back into one Problem (for the
    full-objective trace); the objective rides along."""
    flat_idx = tp.indices.reshape(-1, tp.indices.shape[-1])
    flat_val = tp.values.reshape(-1, tp.values.shape[-1])
    return Problem(
        ya=EllBlock(indices=flat_idx, values=flat_val, n=tp.n),
        m=tp.m,
        n=tp.n,
        rows_valid=tp.rows_valid.reshape(-1),
        objective=tp.objective,
    )
