"""HybridSGD — the paper's 2D-parallel SGD (§4.1).

DEPRECATED module layout: ``run_hybrid_sgd`` is now a thin wrapper over
the unified engine (repro.core.engine), which implements the general
(p_r, s, τ) point directly — see that module for the algorithm
description and the corner table.

Processors form a p = p_r × p_c mesh. Each of the p_r row teams runs
1D s-step SGD (Algorithm 3) on its local row block for τ inner
iterations (τ/s bundles, one row-team Allreduce of (G, v) per bundle
across its p_c column ranks); every τ iterations the weight vector is
averaged across row teams (one column Allreduce of n/p_c words per
rank). Constraint: s ≤ τ and τ ≡ 0 (mod s).

The *numerics* depend only on (p_r, s, b, τ): p_c changes where columns
live (communication), not what is computed — s-step is an algebraic
identity. repro.core.distributed executes the same schedule with
shard_map over a real 2D device mesh, sharing the engine's bundle
primitive, and tests assert the two agree.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import ParallelSGDSchedule, run_parallel_sgd
from repro.core.teams import TeamProblem


def run_hybrid_sgd(
    tp: TeamProblem,
    x0: jnp.ndarray,
    s: int,
    b: int,
    eta: float,
    tau: int,
    rounds: int,
    loss_every: int = 0,
    gram: str = "pallas",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``rounds`` outer rounds; each = τ inner s-step iterations per row
    team + one averaging step across the p_r teams. ``gram`` selects
    the bundle backend (engine.GRAM_METHODS)."""
    if tau % s:
        raise ValueError(f"tau={tau} must be divisible by s={s} (paper requires s ≤ τ)")
    if tp.rows_local % (s * b):
        raise ValueError(f"local rows {tp.rows_local} must be divisible by s·b={s * b}")
    sched = ParallelSGDSchedule.hybrid(
        tp.p, s, b, eta, tau, rounds, loss_every=loss_every, gram=gram
    )
    return run_parallel_sgd(tp, x0, sched)
