"""HybridSGD — the paper's 2D-parallel SGD (§4.1).

Processors form a p = p_r × p_c mesh. Each of the p_r row teams runs
1D s-step SGD (Algorithm 3) on its local row block for τ inner
iterations (τ/s bundles, one row-team Allreduce of (G, v) per bundle
across its p_c column ranks); every τ iterations the weight vector is
averaged across row teams (one column Allreduce of n/p_c words per
rank). Constraint: s ≤ τ and τ ≡ 0 (mod s).

Corners recovered exactly (tested):
  p_r = 1 (single team, averaging is identity)      → 1D s-step SGD
  p_r = p, s = 1                                    → FedAvg
  p_r = p, s = 1, τ = 1                             → synchronous MB-SGD

The *numerics* depend only on (p_r, s, b, τ): p_c changes where columns
live (communication), not what is computed — s-step is an algebraic
identity. This module implements the exact simulated-rank semantics on
one device (lax.map over row teams); repro.core.distributed implements
the same algorithm with shard_map over a real 2D device mesh, and tests
assert they agree.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.problem import full_loss, sigmoid_residual
from repro.core.teams import TeamProblem, global_problem


def _team_sstep_round(indices, values, n: int, x, round_idx, s: int, b: int, tau: int, eta: float):
    """τ inner iterations (= τ/s s-bundles) of Algorithm 3 on one team."""
    m_local = indices.shape[0]
    bundles = tau // s
    sb = s * b

    def bundle(x, t):
        k0 = round_idx * bundles + t
        start = (k0 * sb) % m_local
        idx = jax.lax.dynamic_slice_in_dim(indices, start, sb, axis=0)
        val = jax.lax.dynamic_slice_in_dim(values, start, sb, axis=0)
        # densify the bundle rows (sb × n) for Gram + v; production path
        # = Pallas BSR gram kernel (repro.kernels.gram)
        dense = jnp.zeros((sb, n), val.dtype).at[jnp.arange(sb)[:, None], idx].add(val)
        g = jnp.tril(dense @ dense.T, k=-1)
        v = dense @ x

        def inner(u_acc, j):
            zj = jax.lax.dynamic_slice_in_dim(v, j * b, b) + (eta / b) * (
                jax.lax.dynamic_slice_in_dim(g, j * b, b, axis=0) @ u_acc
            )
            uj = sigmoid_residual(zj)
            return jax.lax.dynamic_update_slice_in_dim(u_acc, uj, j * b, axis=0), None

        u, _ = jax.lax.scan(inner, jnp.zeros(sb, v.dtype), jnp.arange(s))
        return x + (eta / b) * (dense.T @ u), None

    x, _ = jax.lax.scan(bundle, x, jnp.arange(bundles))
    return x


@partial(jax.jit, static_argnames=("s", "b", "tau", "rounds", "loss_every"))
def run_hybrid_sgd(
    tp: TeamProblem,
    x0: jnp.ndarray,
    s: int,
    b: int,
    eta: float,
    tau: int,
    rounds: int,
    loss_every: int = 0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``rounds`` outer rounds; each = τ inner s-step iterations per row
    team + one averaging step across the p_r teams."""
    if tau % s:
        raise ValueError(f"tau={tau} must be divisible by s={s} (paper requires s ≤ τ)")
    if tp.rows_local % (s * b):
        raise ValueError(f"local rows {tp.rows_local} must be divisible by s·b={s * b}")
    gp = global_problem(tp)

    chunk = loss_every if loss_every else rounds
    n_chunks = max(rounds // chunk, 1)

    def one_round(x, r):
        def team(args):
            idx, val = args
            return _team_sstep_round(idx, val, tp.n, x, r, s, b, tau, eta)

        # lax.map (not vmap): teams run sequentially on one device, which
        # bounds peak memory at one (sb × n) densified bundle.
        xs = jax.lax.map(team, (tp.indices, tp.values))
        return jnp.mean(xs, axis=0), None

    def outer(x, c):
        x, _ = jax.lax.scan(one_round, x, c * chunk + jnp.arange(chunk))
        return x, full_loss(gp, x)

    x, losses = jax.lax.scan(outer, x0, jnp.arange(n_chunks))
    if not loss_every:
        losses = jnp.zeros((0,), losses.dtype)
    return x, losses
