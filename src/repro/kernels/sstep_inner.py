"""Pallas TPU kernel for the s-step inner correction loop (Alg 3,
lines 9-14).

After the Gram Allreduce, every rank runs s sequential corrections:

    z_j = v_j + (η/b) · G[j·b:(j+1)b, :] · u
    u_j = 1 / (1 + exp(z_j))        (u accumulates block by block)

The loop is latency-bound at b-vector granularity: s HBM round trips
for (G-row-panel, u) per bundle if expressed as XLA ops. The kernel
keeps G (sb × sb), v and the accumulating u in VMEM for the whole
bundle — one launch, zero intermediate HBM traffic.

VMEM: sb² + 2·sb f32 (sb = 512 → 1.05 MB, well inside budget).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _inner_kernel(
    g_ref, v_ref, u_ref, *, s: int, b: int, eta_over_b: float, compute_dtype=None
):
    u_ref[...] = jnp.zeros_like(u_ref)

    def step(j, _):
        # z_j = v_j + (η/b)·G_panel·u   (u zero beyond filled blocks;
        # G is strictly lower so in-block terms multiply zeros)
        panel = g_ref[pl.dslice(j * b, b), :]  # (b, sb)
        u = u_ref[:, 0]
        if compute_dtype is not None:
            panel = panel.astype(compute_dtype)
            u = u.astype(compute_dtype)
        zj = v_ref[pl.dslice(j * b, b), 0] + eta_over_b * (
            jnp.dot(panel, u, preferred_element_type=jnp.float32)
        )
        uj = jnp.where(zj >= 0, jnp.exp(-zj) / (1 + jnp.exp(-zj)), 1 / (1 + jnp.exp(zj)))
        u_ref[pl.dslice(j * b, b), 0] = uj.astype(u_ref.dtype)
        return 0

    jax.lax.fori_loop(0, s, step, 0)


def sstep_inner(
    g: jnp.ndarray,  # (sb, sb) strictly-lower Gram
    v: jnp.ndarray,  # (sb,)
    s: int,
    b: int,
    eta: float,
    *,
    precision: str = "fp32",
    interpret: bool = True,
) -> jnp.ndarray:
    """u (sb,) such that u_j = sigmoid_residual(v_j + (η/b) Σ_{l<j} G_{jl} u_l).

    ``precision="bf16"`` runs the G-panel·u MXU dot bf16-in /
    f32-accumulate; z, the residual, and u stay float32."""
    from repro.kernels.ell_gram import compute_dtype_for

    cd = compute_dtype_for(precision)
    sb = s * b
    assert g.shape == (sb, sb) and v.shape == (sb,)
    out = pl.pallas_call(
        functools.partial(
            _inner_kernel, s=s, b=b, eta_over_b=eta / b, compute_dtype=cd
        ),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((sb, sb), lambda i: (0, 0)),
            pl.BlockSpec((sb, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((sb, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((sb, 1), jnp.float32),
        interpret=interpret,
    )(g.astype(jnp.float32), v.astype(jnp.float32)[:, None])
    return out[:, 0]


def sstep_inner_ref(g, v, s: int, b: int, eta: float) -> jnp.ndarray:
    """Pure-jnp oracle — the same loop the core solver runs (at the
    logistic default; the VMEM kernel hardcodes the logistic residual)."""
    from repro.core.objective import LOGISTIC

    def inner(u_acc, j):
        zj = jax.lax.dynamic_slice_in_dim(v, j * b, b) + (eta / b) * (
            jax.lax.dynamic_slice_in_dim(g, j * b, b, axis=0) @ u_acc
        )
        uj = LOGISTIC.residual(zj)
        return jax.lax.dynamic_update_slice_in_dim(u_acc, uj, j * b, axis=0), None

    u, _ = jax.lax.scan(inner, jnp.zeros(s * b, v.dtype), jnp.arange(s))
    return u
