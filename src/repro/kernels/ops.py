"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True: this container is CPU-only, so kernels
execute their Python bodies (functionally identical to the TPU
lowering); on real TPU hardware pass interpret=False.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsr_matmul import bsr_matmat, bsr_matvec
from repro.kernels.gram import gram_and_v, gram_tril
from repro.sparse.bsr import BsrMatrix, bsr_from_csr
from repro.sparse.csr import CSRMatrix, csr_transpose


@partial(jax.jit, static_argnames=("interpret",))
def spmm(tiles, block_cols, x, interpret: bool = True):
    """Y = A @ X (block-sparse × dense)."""
    return bsr_matmat(tiles, block_cols, x, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def spmv(tiles, block_cols, x, interpret: bool = True):
    return bsr_matvec(tiles, block_cols, x, interpret=interpret)


@partial(jax.jit, static_argnames=("bk", "interpret"))
def sstep_gram(y, bk: int = 512, interpret: bool = True):
    """G = tril(YYᵀ, -1) — Algorithm 3's syrk hot spot."""
    return gram_tril(y, bk=bk, interpret=interpret)


@partial(jax.jit, static_argnames=("bk", "interpret"))
def sstep_gram_and_v(y, x, bk: int = 512, interpret: bool = True):
    """Fused (G, v) — one pass over the bundle panels."""
    return gram_and_v(y, x, bk=bk, interpret=interpret)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparseLinearOp:
    """A and Aᵀ as BSR tile sets, ready for the forward kernel.

    Transpose products run the forward kernel on BSR(Aᵀ) — the
    TPU-native answer to CSR's transpose-SpMV scatter (see
    bsr_matmul.py). Padded logical sizes are kept for truncation.
    """

    tiles: jnp.ndarray
    block_cols: jnp.ndarray
    t_tiles: jnp.ndarray
    t_block_cols: jnp.ndarray
    m: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    fwd_in: int = dataclasses.field(metadata=dict(static=True))  # padded n for A
    bwd_in: int = dataclasses.field(metadata=dict(static=True))  # padded m for Aᵀ

    def matvec(self, x: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
        x_pad = jnp.zeros(self.fwd_in, x.dtype).at[: self.n].set(x)
        return spmv(self.tiles, self.block_cols, x_pad, interpret=interpret)[: self.m]

    def rmatvec(self, u: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
        u_pad = jnp.zeros(self.bwd_in, u.dtype).at[: self.m].set(u)
        return spmv(self.t_tiles, self.t_block_cols, u_pad, interpret=interpret)[: self.n]

    def matmat(self, x: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
        x_pad = jnp.zeros((self.fwd_in, x.shape[1]), x.dtype).at[: self.n].set(x)
        return spmm(self.tiles, self.block_cols, x_pad, interpret=interpret)[: self.m]

    def rmatmat(self, u: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
        u_pad = jnp.zeros((self.bwd_in, u.shape[1]), u.dtype).at[: self.m].set(u)
        return spmm(self.t_tiles, self.t_block_cols, u_pad, interpret=interpret)[: self.n]


def sparse_linear_op(
    a: CSRMatrix, bm: int = 8, bn: int = 128, dtype=jnp.float32
) -> SparseLinearOp:
    fwd: BsrMatrix = bsr_from_csr(a, bm=bm, bn=bn, dtype=dtype)
    bwd: BsrMatrix = bsr_from_csr(csr_transpose(a), bm=bm, bn=bn, dtype=dtype)
    return SparseLinearOp(
        tiles=fwd.tiles,
        block_cols=fwd.block_cols,
        t_tiles=bwd.tiles,
        t_block_cols=bwd.block_cols,
        m=a.m,
        n=a.n,
        fwd_in=fwd.shape[1],
        bwd_in=bwd.shape[1],
    )
