"""Pure-jnp oracles for every Pallas kernel (allclose-tested)."""

from __future__ import annotations

import jax.numpy as jnp


def bsr_matmat_ref(tiles, block_cols, x) -> jnp.ndarray:
    """Y = A @ X via dense gather-einsum on the blocked layout."""
    n_brows, max_blocks, bm, bn = tiles.shape
    k = x.shape[1]
    x_blocked = x.reshape(-1, bn, k)
    gathered = jnp.take(x_blocked, block_cols, axis=0)  # (nbr, maxb, bn, k)
    y = jnp.einsum("rjab,rjbk->rak", tiles, gathered)
    return y.reshape(n_brows * bm, k)


def bsr_matvec_ref(tiles, block_cols, x) -> jnp.ndarray:
    return bsr_matmat_ref(tiles, block_cols, x[:, None])[:, 0]


def gram_tril_ref(y) -> jnp.ndarray:
    """G = tril(Y Yᵀ, -1), f32 accumulation (matches the kernel)."""
    return jnp.tril(jnp.dot(y, y.T, preferred_element_type=jnp.float32), k=-1)


def gram_and_v_ref(y, x) -> tuple[jnp.ndarray, jnp.ndarray]:
    return (
        jnp.tril(jnp.dot(y, y.T, preferred_element_type=jnp.float32), k=-1),
        jnp.dot(y, x, preferred_element_type=jnp.float32),
    )


def densify_bundle_ref(indices, values, n: int) -> jnp.ndarray:
    """Scatter the (sb, w) ELL bundle into a dense (sb, n) matrix.

    This is the retired inner-loop path of the pre-engine solvers, kept
    as the parity oracle for the scatter-free ELL Gram kernel (and as
    the dense baseline in benchmarks/bench_kernels.py)."""
    sb = values.shape[0]
    dense = jnp.zeros((sb, n), values.dtype)
    return dense.at[jnp.arange(sb)[:, None], indices].add(values)


def ell_gram_and_v_ref(indices, values, x, n: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(tril(YYᵀ,-1), Y·x) via the dense scatter — the bundle oracle."""
    dense = densify_bundle_ref(indices, values.astype(jnp.float32), n)
    return gram_and_v_ref(dense, x.astype(jnp.float32))
