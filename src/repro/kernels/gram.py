"""Pallas TPU kernel for the s-step Gram matrix  G = tril(Y Yᵀ, -1).

This is the MKL ``mkl_sparse_syrkd`` hot spot of Algorithm 3: Y is the
(sb × n_local) bundle of sampled rows; G's strictly-lower blocks correct
the deferred updates. sb is small (≤ a few hundred) while n_local is
large, so the kernel streams Y through VMEM in (sb × bk) column panels
and accumulates the (sb × sb) Gram block on the MXU — a classic
rank-k-update (syrk) tiling. The strict-lower mask is applied on the
final panel.

VMEM: sb·bk (panel) + sb·sb (accumulator) words; bk chosen so both fit
comfortably (default 512 lanes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(y_ref, g_ref, *, n_panels: int):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    panel = y_ref[...]  # (sb, bk)
    g_ref[...] += jnp.dot(panel, panel.T, preferred_element_type=g_ref.dtype)

    @pl.when(k == n_panels - 1)
    def _mask():
        sb = g_ref.shape[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (sb, sb), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (sb, sb), 1)
        g_ref[...] = jnp.where(row > col, g_ref[...], 0.0)


def gram_tril(y: jnp.ndarray, *, bk: int = 512, interpret: bool = True) -> jnp.ndarray:
    """G = tril(Y Yᵀ, -1) for Y: (sb, n). n is zero-padded to bk.

    Accumulates in float32 (MXU-faithful) regardless of input dtype."""
    sb, n = y.shape
    n_pad = -(-n // bk) * bk
    if n_pad != n:
        y = jnp.pad(y, ((0, 0), (0, n_pad - n)))
    n_panels = n_pad // bk
    import functools

    return pl.pallas_call(
        functools.partial(_gram_kernel, n_panels=n_panels),
        grid=(n_panels,),
        in_specs=[pl.BlockSpec((sb, bk), lambda k: (0, k))],
        out_specs=pl.BlockSpec((sb, sb), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((sb, sb), jnp.float32),
        interpret=interpret,
    )(y)


def _gram_and_v_kernel(y_ref, x_ref, g_ref, v_ref, *, n_panels: int):
    """Fused: G = tril(YYᵀ,-1) and v = Y·x in one pass over Y panels —
    halves HBM traffic for the bundle (the dominant stream)."""
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        v_ref[...] = jnp.zeros_like(v_ref)

    panel = y_ref[...]  # (sb, bk)
    xblk = x_ref[...]  # (bk, 1)
    g_ref[...] += jnp.dot(panel, panel.T, preferred_element_type=g_ref.dtype)
    v_ref[...] += jnp.dot(panel, xblk, preferred_element_type=v_ref.dtype)

    @pl.when(k == n_panels - 1)
    def _mask():
        sb = g_ref.shape[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (sb, sb), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (sb, sb), 1)
        g_ref[...] = jnp.where(row > col, g_ref[...], 0.0)


def gram_and_v(
    y: jnp.ndarray, x: jnp.ndarray, *, bk: int = 512, interpret: bool = True
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(tril(YYᵀ,-1), Y·x) fused. x: (n,)."""
    sb, n = y.shape
    n_pad = -(-n // bk) * bk
    if n_pad != n:
        y = jnp.pad(y, ((0, 0), (0, n_pad - n)))
        x = jnp.pad(x, (0, n_pad - n))
    n_panels = n_pad // bk
    import functools

    g, v = pl.pallas_call(
        functools.partial(_gram_and_v_kernel, n_panels=n_panels),
        grid=(n_panels,),
        in_specs=[
            pl.BlockSpec((sb, bk), lambda k: (0, k)),
            pl.BlockSpec((bk, 1), lambda k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((sb, sb), lambda k: (0, 0)),
            pl.BlockSpec((sb, 1), lambda k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sb, sb), jnp.float32),
            jax.ShapeDtypeStruct((sb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(y, x[:, None])
    return g, v[:, 0]
