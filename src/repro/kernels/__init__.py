"""Pallas TPU kernels for the paper's compute hot spots.

  ell_gram   — the engine's bundle primitive: fused tril(YYᵀ) + Y·x
               straight from ELL rows, scatter-free (the
               mkl_sparse_syrkd hot spot of Algorithm 3)
  sstep_inner — the s-step correction loop fused into one launch
               (G, v, u stay VMEM-resident across all s steps)

ref.py: pure-jnp oracles — including the retired (sb × n) densify
bundle path, kept only as the parity oracle. The pre-engine dense-panel
Gram (``gram.py``), the BSR matmul (``bsr_matmul.py``), and their
``ops.py`` wrappers were dead paths off the live bundle pipeline and
have been removed; ``repro.sparse.bsr`` keeps the BSR *layout* (and its
jnp reference matvec) for the format tests.
interpret=True on CPU, =False on real TPU.
"""

from repro.kernels.ell_gram import ell_gram_and_v, ell_gram_and_v_blocked
from repro.kernels.sstep_inner import sstep_inner

__all__ = [
    "ell_gram_and_v",
    "ell_gram_and_v_blocked",
    "sstep_inner",
]
