"""Pallas TPU kernels for the paper's compute hot spots.

  bsr_matmul — block-sparse matmul, scalar-prefetched block indices
               (the MKL-CSR SpMV, rethought for the MXU)
  ell_gram   — the engine's bundle primitive: fused tril(YYᵀ) + Y·x
               straight from ELL rows, scatter-free (the
               mkl_sparse_syrkd hot spot of Algorithm 3)
  gram       — the same syrk for an already-dense Y panel
  sstep_inner — the s-step correction loop fused into one launch
               (G, v, u stay VMEM-resident across all s steps)

ops.py: jit'd wrappers (SparseLinearOp bundles A and BSR(Aᵀ));
ref.py: pure-jnp oracles — including the retired (sb × n) densify
bundle path, kept only as the parity oracle.
interpret=True on CPU, =False on real TPU.
"""

from repro.kernels.ell_gram import ell_gram_and_v, ell_gram_and_v_blocked
from repro.kernels.ops import (
    SparseLinearOp,
    sparse_linear_op,
    spmm,
    spmv,
    sstep_gram,
    sstep_gram_and_v,
)
from repro.kernels.sstep_inner import sstep_inner

__all__ = [
    "SparseLinearOp",
    "ell_gram_and_v",
    "ell_gram_and_v_blocked",
    "sparse_linear_op",
    "spmm",
    "spmv",
    "sstep_gram",
    "sstep_gram_and_v",
    "sstep_inner",
]
