"""Block-sparse (BSR) matmul Pallas TPU kernel — the MKL-SpMV analogue.

TPU adaptation of the paper's sparse compute (DESIGN.md §2): instead of
CSR scalar gathers (no TPU analogue), A is re-blocked into dense
(bm × bn) tiles (repro.sparse.bsr) and each tile contracts on the MXU.
The tile's block-column index is *scalar-prefetched*
(pltpu.PrefetchScalarGridSpec) so the BlockSpec index_map can route the
right x/X block into VMEM ahead of the compute — the canonical Pallas
block-sparse pattern.

Grid: (n_block_rows, max_blocks_per_row). The output block row is
revisited along the minor grid axis j and accumulated in VMEM; padded
tiles are all-zero so they contribute nothing (no masking needed).

VMEM working set per step: bm·bn (tile) + bn·k (X block) + bm·k (Y
block) words — BlockSpec tiling bounds the footprint exactly the way
the paper's L_cap bounds n_local·w.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bsr_matmat_kernel(bc_ref, tiles_ref, x_ref, y_ref):
    """One (block_row r, slot j) step: Y[r] += T[r,j] @ X[bc[r,j]]."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    # (1, 1, bm, bn) tile × (1, bn, k) X block → accumulate (1, bm, k)
    tile = tiles_ref[0, 0]
    xblk = x_ref[0]
    y_ref[0, ...] += jnp.dot(tile, xblk, preferred_element_type=y_ref.dtype)


def bsr_matmat(
    tiles: jnp.ndarray,  # (n_brows, max_blocks, bm, bn)
    block_cols: jnp.ndarray,  # (n_brows, max_blocks) int32
    x: jnp.ndarray,  # (n_pad, k)
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Y = A @ X on padded shapes; returns (n_brows·bm, k)."""
    n_brows, max_blocks, bm, bn = tiles.shape
    n_pad, k = x.shape
    assert n_pad % bn == 0, (n_pad, bn)
    x_blocked = x.reshape(n_pad // bn, bn, k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_brows, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bn), lambda r, j, bc: (r, j, 0, 0)),
            pl.BlockSpec((1, bn, k), lambda r, j, bc: (bc[r, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, k), lambda r, j, bc: (r, 0, 0)),
    )
    out = pl.pallas_call(
        _bsr_matmat_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_brows, bm, k), x.dtype),
        interpret=interpret,
    )(block_cols, tiles, x_blocked)
    return out.reshape(n_brows * bm, k)


def bsr_matvec(tiles, block_cols, x, *, interpret: bool = True) -> jnp.ndarray:
    """y = A @ x via the matmat kernel with k=1 (TPU lane-padded)."""
    return bsr_matmat(tiles, block_cols, x[:, None], interpret=interpret)[:, 0]


# ---- transpose product: g = Aᵀ @ u (the SGD gradient) ----
#
# A scatter-accumulate kernel (output block routed by bc[r, j]) is
# unsafe in Pallas: an output block's VMEM buffer is undefined when
# revisited after the grid has moved away. The TPU-native answer is
# layout, not scatter: the host pre-builds BSR(Aᵀ) (a BSC view of A) and
# the *forward* kernel runs on it — every output block is then produced
# by consecutive grid steps. See repro.kernels.ops.SparseLinearOp.
