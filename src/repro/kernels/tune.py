"""Autotuned VMEM panel shapes for the bundle kernels.

The ELL-Gram kernel has two tiling knobs — the column-panel width
``bk`` and the row tile ``bm`` — whose best values depend on the
dataset's nnz profile (ELL width, local column count) and the device.
This module sweeps the candidate grid once per (profile, device kind),
scores candidates by **measured wall time cross-checked against the
analytic roofline** (``repro.launch.roofline.panel_roofline``: a
candidate that does not fit VMEM is infeasible; a measurement below the
attainable bound is a timer glitch and is discarded), and caches the
winner on disk.

Cache keying mirrors the engine's jit cache: the key is a content hash
of (profile, device kind, KERNEL_VERSION) — deterministic, so every
process that plans or builds the same spec on the same device computes
the same key, and bumping KERNEL_VERSION when the kernel math or tiling
changes invalidates every cached winner at once. One JSON file per key,
written atomically (tmp + rename), each carrying the full candidate
table and its roofline justification so a cache record is auditable.

The profile is derived from *registry statistics* (DatasetStats +
schedule + mesh), never from materialized arrays — ``plan()`` (pure,
device-free planning) and ``Session`` (the build) must compute the
identical key without touching data.

Measurement backend: on TPU the compiled Pallas kernel is timed; on CPU
(this container) Pallas runs in interpret mode, whose per-op Python
dispatch makes wall time meaningless — the blocked XLA twin
(``ell_gram_and_v_blocked``) is timed instead. It shares the panel
structure and math (it is what shard_map executes), so the relative
ranking across (bk, bm) is the quantity the cache stores.

The profile-driven gram-path choice (``select_gram_path``) also lives
here: when the ELL width is heavy-tailed (w ≫ s·b — the one-hot panel
expansion costs ~w/sb more FLOPs than densifying), the dense oracle
wins and the autotuner opts the build into it, logged once.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import logging
import os
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ell_gram import ell_gram_and_v, ell_gram_and_v_blocked
from repro.launch.roofline import panel_roofline

__all__ = [
    "KERNEL_VERSION",
    "PanelProfile",
    "cache_key",
    "default_cache_dir",
    "device_kind",
    "load_record",
    "lookup_panel",
    "resolve_panel",
    "select_gram_path",
    "store_record",
    "tune_panel",
]

log = logging.getLogger("repro.kernels.tune")

# Bump when ell_gram / sstep_inner math or tiling changes: the cache key
# folds this in, so every stale winner misses at once.
KERNEL_VERSION = 2

BK_CANDIDATES = (128, 256, 512, 1024)
BM_CANDIDATES = (None, 16, 32)

# Static fallback = the pre-autotune defaults (bitwise path).
FALLBACK_BK = 512
FALLBACK_BM = None


@dataclasses.dataclass(frozen=True)
class PanelProfile:
    """What the tuned shape depends on — and nothing else.

    rows      s·b, the bundle row count (the kernel's M dimension).
    width     ELL width hint — ⌈z̄⌉ from the dataset registry (the
              *mean* nnz/row: deterministic from stats, so plan() and
              the build agree; the max-width heavy-tail decision is
              separate, see ``select_gram_path``).
    n_local   per-shard column count ⌈n/p_c⌉ — the kernel's panel-walk
              extent.
    dense     registry dense flag (epsilon-style data: width = n).
    precision schedule precision ("fp32" | "bf16") — changes the MXU
              peak and the VMEM tile, so it is part of the key.
    """

    rows: int
    width: int
    n_local: int
    dense: bool = False
    precision: str = "fp32"

    @classmethod
    def from_stats(cls, stats, sched, p_c: int | None = None) -> "PanelProfile":
        """The deterministic profile of (DatasetStats, schedule, p_c).
        ``p_c`` defaults to the schedule's own (the simulated engine);
        pass the mesh's for shard_map."""
        p_c = sched.p_c if p_c is None else p_c
        return cls(
            rows=sched.s * sched.b,
            width=max(int(np.ceil(stats.zbar)), 1),
            n_local=-(-stats.n // p_c),
            dense=bool(getattr(stats, "dense", False)),
            precision=sched.precision,
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def device_kind() -> str:
    """The cache's device axis, e.g. ``cpu:cpu`` or ``tpu:TPU v5e``."""
    d = jax.devices()[0]
    return f"{d.platform}:{getattr(d, 'device_kind', d.platform)}"


def cache_key(
    profile: PanelProfile,
    device: str | None = None,
    kernel_version: int = KERNEL_VERSION,
) -> str:
    """Content hash of (profile, device kind, kernel version) — the jit
    cache's keying discipline applied to tuned shapes."""
    device = device_kind() if device is None else device
    payload = json.dumps(
        {"profile": profile.to_dict(), "device": device, "kernel_version": kernel_version},
        sort_keys=True,
    )
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def default_cache_dir() -> Path:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "tune"


def _record_path(key: str, cache_dir: Path | None = None) -> Path:
    return (default_cache_dir() if cache_dir is None else Path(cache_dir)) / f"{key}.json"


def load_record(key: str, cache_dir: Path | None = None) -> dict | None:
    p = _record_path(key, cache_dir)
    try:
        return json.loads(p.read_text())
    except (OSError, json.JSONDecodeError):
        return None


def store_record(record: dict, cache_dir: Path | None = None) -> Path:
    """Atomic write (tmp + rename): concurrent tuners race benignly —
    both compute the same winner for the same key."""
    p = _record_path(record["key"], cache_dir)
    p.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=p.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
        os.replace(tmp, p)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return p


def _synthesize(profile: PanelProfile, max_n: int, seed: int = 0):
    """A representative ELL bundle for timing: profile shapes, capped
    panel-walk extent (timing scales linearly in n — the ranking
    doesn't need the full shard)."""
    n = max(min(profile.n_local, max_n), 8)
    width = min(profile.width, n)
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, n, size=(profile.rows, width)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((profile.rows, width)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    return idx, val, x, n, width


def _time_candidate(idx, val, x, n, bk, bm, precision, repeats: int) -> float:
    """Median wall seconds of one jitted (G, v) bundle build."""
    on_tpu = jax.devices()[0].platform == "tpu"
    if on_tpu:
        fn = jax.jit(
            lambda i, v, z: ell_gram_and_v(
                i, v, z, n=n, bk=bk, bm=bm, precision=precision, interpret=False
            )
        )
    else:
        fn = jax.jit(
            lambda i, v, z: ell_gram_and_v_blocked(
                i, v, z, n=n, bk=bk, bm=bm, precision=precision
            )
        )
    jax.block_until_ready(fn(idx, val, x))  # compile outside the timer
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(idx, val, x))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def tune_panel(
    profile: PanelProfile,
    *,
    device: str | None = None,
    cache_dir: Path | None = None,
    force: bool = False,
    repeats: int = 3,
    max_n: int = 16384,
    bk_candidates: tuple = BK_CANDIDATES,
    bm_candidates: tuple = BM_CANDIDATES,
) -> dict:
    """Sweep the (bk, bm) grid for ``profile`` and cache the winner.

    Returns the cache record (reading the existing one unless ``force``):

        key, kernel_version, device, profile   — the cache identity
        bk, bm                                 — the winner
        measured_s, attainable_s, efficiency   — winner's score + bound
        candidates                             — the full audited table

    Candidate filtering: bk capped at the measured extent, bm capped at
    rows, VMEM-infeasible shapes dropped, and any measurement *below*
    its roofline bound discarded as a timer glitch (the cross-check).
    """
    device = device_kind() if device is None else device
    key = cache_key(profile, device)
    if not force:
        hit = load_record(key, cache_dir)
        if hit is not None:
            return hit

    idx, val, x, n, width = _synthesize(profile, max_n)
    rows = profile.rows
    bks = sorted({min(bk, -(-n // 8) * 8) for bk in bk_candidates})
    bms = sorted({bm for bm in bm_candidates if bm is None or bm < rows},
                 key=lambda v: -1 if v is None else v)
    table = []
    for bk in bks:
        for bm in bms:
            rl = panel_roofline(rows, width, n, bk, bm, profile.precision)
            if not rl.fits_vmem:
                table.append({"bk": bk, "bm": bm, "skipped": "vmem",
                              "vmem_bytes": rl.vmem_bytes})
                continue
            t = _time_candidate(idx, val, x, n, bk, bm, profile.precision, repeats)
            glitch = t < rl.attainable_s
            table.append({
                "bk": bk, "bm": bm, "measured_s": t,
                "attainable_s": rl.attainable_s, "dominant": rl.dominant,
                "vmem_bytes": rl.vmem_bytes,
                "skipped": "sub-roofline" if glitch else None,
            })
    feasible = [c for c in table if c.get("skipped") is None]
    if not feasible:  # every candidate filtered: static fallback, uncached
        return {
            "key": key, "kernel_version": KERNEL_VERSION, "device": device,
            "profile": profile.to_dict(), "bk": FALLBACK_BK, "bm": FALLBACK_BM,
            "measured_s": None, "attainable_s": None, "efficiency": None,
            "candidates": table, "fallback": True,
        }
    best = min(feasible, key=lambda c: c["measured_s"])
    record = {
        "key": key,
        "kernel_version": KERNEL_VERSION,
        "device": device,
        "profile": profile.to_dict(),
        "bk": best["bk"],
        "bm": best["bm"],
        "measured_s": best["measured_s"],
        "attainable_s": best["attainable_s"],
        "efficiency": best["attainable_s"] / best["measured_s"],
        "candidates": table,
    }
    store_record(record, cache_dir)
    return record


def lookup_panel(
    profile: PanelProfile,
    *,
    device: str | None = None,
    cache_dir: Path | None = None,
) -> dict | None:
    """Read-only cache probe — what ``plan()`` reports from (planning
    never tunes: it stays pure)."""
    return load_record(cache_key(profile, device), cache_dir)


def resolve_panel(
    profile: PanelProfile,
    *,
    device: str | None = None,
    cache_dir: Path | None = None,
    allow_tune: bool = True,
) -> tuple[int, int | None]:
    """The build-time answer for ``bk=None``: cached winner if present,
    a fresh sweep if allowed, the static (512, None) fallback otherwise."""
    rec = lookup_panel(profile, device=device, cache_dir=cache_dir)
    if rec is None and allow_tune:
        rec = tune_panel(profile, device=device, cache_dir=cache_dir)
    if rec is None:
        return FALLBACK_BK, FALLBACK_BM
    return int(rec["bk"]), None if rec["bm"] is None else int(rec["bm"])


# ---- profile-driven gram-path selection (heavy-tailed ELL widths) ----

_GRAM_CHOICES_LOGGED: set[tuple] = set()

# w/sb above this, the one-hot panel expansion (≈ w/sb × the dense
# densify cost) loses to the dense oracle.
HEAVY_TAIL_FACTOR = 4


def select_gram_path(width: int, rows: int, requested: str = "pallas") -> str:
    """Pick the (G, v) build for an ELL block of ``width`` at bundle
    size ``rows`` = s·b. Only the default "pallas" request is ever
    overridden (an explicit gram= choice is honored); a heavy-tailed
    width (w > 4·s·b) flips to the dense oracle. Logged once per
    (width, rows, verdict)."""
    if requested != "pallas":
        return requested
    choice = "dense" if width > HEAVY_TAIL_FACTOR * rows else "pallas"
    tag = (width, rows, choice)
    if tag not in _GRAM_CHOICES_LOGGED:
        _GRAM_CHOICES_LOGGED.add(tag)
        if choice != requested:
            log.info(
                "gram auto-select: ELL width %d is heavy-tailed for s·b=%d "
                "(> %d×): using the dense oracle for (G, v)",
                width, rows, HEAVY_TAIL_FACTOR,
            )
        else:
            log.info(
                "gram auto-select: ELL width %d fits s·b=%d: keeping the "
                "pallas panel kernel", width, rows,
            )
    return choice
