"""Pallas TPU kernel: fused s-bundle (G, v) straight from ELL rows.

This is the engine's bundle primitive (Algorithm 3 lines 5-8 — the
``mkl_sparse_syrkd`` + SpMV hot spot) without ever materializing the
dense (sb × n) bundle in HBM. The old core solvers scattered the bundle
into a dense matrix every inner iteration (O(sb·n) HBM traffic per
bundle); here the dense panel only ever exists as a (sb × bk) VMEM tile,
built on the fly from the ELL (indices, values) pair:

  for each column panel k of width bk:
      panel[r, c] = Σ_a val[r, a] · [idx[r, a] == k·bk + c]
      G += panel @ panelᵀ          (MXU rank-k update)
      v += panel @ x[k·bk : k·bk+bk]

The panel build is a compare-against-iota one-hot contraction — an MXU/
VPU-friendly formulation of scatter (Pallas TPU has no in-kernel
scatter). Cost per bundle is O(sb·w·n) for the expansion plus
O(sb²·n) for the syrk, vs O(sb·n) HBM *traffic* for the dense path —
on TPU the expansion is compute against VMEM-resident data, while the
dense path is a scatter into HBM plus a full re-stream. Arithmetic
caveat: the expansion term dominates the syrk when the ELL width w
exceeds sb, so heavy-tailed rows (w ≫ s·b, e.g. the url dataset)
favor a wider bundle or the dense oracle off-TPU — benchmarks
bench_kernels.py measures both sides.

The strict-lower mask (only l < j corrections are applied by the s-step
inner loop) lands on the final panel. Accumulation is float32
(MXU-faithful) regardless of input dtype.

Two tuning knobs, swept by ``repro.kernels.tune``:

* ``bk`` — column-panel width (the VMEM tile's second dimension);
* ``bm`` — optional row tile for the one-hot expansion: the (sb, w, bk)
  one-hot workspace is built ``bm`` rows at a time, shrinking the
  expansion working set from sb·w·bk to bm·w·bk words. ``bm=None``
  (default) is the original single-shot expansion; any ``bm`` is
  bitwise-identical to it (each row's contraction is independent).

Precision: ``precision="bf16"`` builds the panel in bfloat16 and runs
the MXU dots bf16-in / f32-accumulate (``preferred_element_type``);
G and v stay float32. ``precision="fp32"`` (default) traces exactly
the original kernel.

VMEM per step: sb·w (idx + val) + sb·bk (one-hot workspace) + sb·sb (G)
+ bk (x panel) words.

Oracle: repro.kernels.ref.ell_gram_and_v_ref (the retired densify path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prep_panels(values, x, n: int, bk: int):
    """Shared preamble for both backends: accumulation dtype + x padded
    to whole panels. f32 accumulation (MXU-faithful) for narrow dtypes;
    f64 stays f64 so the paper's FP64 Gram-conditioning runs keep their
    precision."""
    acc = jnp.float64 if values.dtype == jnp.float64 else jnp.float32
    n_pad = -(-n // bk) * bk
    x = x.astype(acc)
    if n_pad != n:
        x = jnp.pad(x, (0, n_pad - n))
    return acc, x, n_pad // bk


def _panel_rows(indices, values, k, bk: int, dtype) -> jnp.ndarray:
    """One-hot contraction for one row chunk: (rows, bk) in ``dtype``."""
    local = indices - k * bk  # (rows, w)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk), 2)
    onehot = (local[:, :, None] == lanes).astype(dtype)  # (rows, w, bk)
    return jax.lax.dot_general(
        values.astype(dtype)[:, None, :],  # (rows, 1, w)
        onehot,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=dtype,
    )[:, 0, :]  # (rows, bk)


def panel_from_ell(
    indices, values, k, bk: int, acc_dtype, compute_dtype=None, bm: int | None = None
) -> jnp.ndarray:
    """Expand the ELL bundle's column panel k into a dense (sb, bk) tile.

    Panel-local one-hot contraction: entries outside [k·bk, (k+1)·bk)
    match no lane and vanish; ELL pad entries (idx 0, val 0) contribute
    zero value. Shared by the Pallas kernel body and the pure-jnp
    blocked path (shard_map-safe).

    ``compute_dtype`` (e.g. bfloat16) overrides the expansion dtype —
    None keeps ``acc_dtype``, the original path. ``bm`` tiles the
    expansion ``bm`` rows at a time (bitwise-identical: rows are
    independent); None builds all rows in one shot."""
    dtype = acc_dtype if compute_dtype is None else compute_dtype
    sb = indices.shape[0]
    if bm is None or bm >= sb:
        return _panel_rows(indices, values, k, bk, dtype)
    return jnp.concatenate(
        [
            _panel_rows(indices[r : r + bm], values[r : r + bm], k, bk, dtype)
            for r in range(0, sb, bm)
        ],
        axis=0,
    )


def _ell_gram_kernel(
    idx_ref, val_ref, x_ref, g_ref, v_ref, *,
    n_panels: int, bk: int, compute_dtype=None, bm: int | None = None,
):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        v_ref[...] = jnp.zeros_like(v_ref)

    panel = panel_from_ell(
        idx_ref[...], val_ref[...], k, bk, g_ref.dtype, compute_dtype, bm
    )  # (sb, bk)
    xblk = x_ref[...]
    if compute_dtype is not None:
        xblk = xblk.astype(compute_dtype)
    g_ref[...] += jnp.dot(panel, panel.T, preferred_element_type=g_ref.dtype)
    v_ref[...] += jnp.dot(panel, xblk, preferred_element_type=v_ref.dtype)

    @pl.when(k == n_panels - 1)
    def _mask():
        sb = g_ref.shape[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (sb, sb), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (sb, sb), 1)
        g_ref[...] = jnp.where(row > col, g_ref[...], 0.0)


def compute_dtype_for(precision: str):
    """The panel/MXU compute dtype for a schedule ``precision`` knob:
    None (trace the original fp32 path) or jnp.bfloat16."""
    if precision == "fp32":
        return None
    if precision == "bf16":
        return jnp.bfloat16
    raise ValueError(f"precision must be 'fp32' or 'bf16', got {precision!r}")


def ell_gram_and_v(
    indices: jnp.ndarray,  # (sb, w) int32
    values: jnp.ndarray,  # (sb, w)
    x: jnp.ndarray,  # (n,)
    *,
    n: int,
    bk: int = 512,
    bm: int | None = None,
    precision: str = "fp32",
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(G, v) = (tril(Y Yᵀ, -1), Y·x) for the ELL bundle Y — scatter-free.

    ``n`` is the (local) column count; x is zero-padded to a multiple of
    ``bk`` so every grid step sees a full panel.
    """
    sb, w = values.shape
    acc, x, n_panels = _prep_panels(values, x, n, bk)
    cd = compute_dtype_for(precision)

    g, v = pl.pallas_call(
        functools.partial(
            _ell_gram_kernel, n_panels=n_panels, bk=bk, compute_dtype=cd, bm=bm
        ),
        grid=(n_panels,),
        in_specs=[
            pl.BlockSpec((sb, w), lambda k: (0, 0)),
            pl.BlockSpec((sb, w), lambda k: (0, 0)),
            pl.BlockSpec((bk, 1), lambda k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((sb, sb), lambda k: (0, 0)),
            pl.BlockSpec((sb, 1), lambda k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sb, sb), acc),
            jax.ShapeDtypeStruct((sb, 1), acc),
        ],
        interpret=interpret,
    )(indices, values.astype(acc), x[:, None])
    return g, v[:, 0]


def ell_gram_and_v_blocked(
    indices: jnp.ndarray,
    values: jnp.ndarray,
    x: jnp.ndarray,
    *,
    n: int,
    bk: int = 512,
    bm: int | None = None,
    precision: str = "fp32",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pure-jnp panel streaming — same scatter-free math as the Pallas
    kernel, expressed as a lax.scan over column panels.

    Used where a pallas_call cannot run (inside shard_map on the 2D
    device mesh); the VMEM-tile structure becomes an XLA loop whose
    working set is one (sb, bk) panel."""
    sb, w = values.shape
    acc, x, n_panels = _prep_panels(values, x, n, bk)
    cd = compute_dtype_for(precision)

    def panel_step(carry, k):
        g, v = carry
        panel = panel_from_ell(indices, values, k, bk, acc, cd, bm)
        xblk = jax.lax.dynamic_slice_in_dim(x, k * bk, bk)
        if cd is not None:
            xblk = xblk.astype(cd)
        return (
            g + jnp.dot(panel, panel.T, preferred_element_type=acc),
            v + jnp.dot(panel, xblk, preferred_element_type=acc),
        ), None

    (g, v), _ = jax.lax.scan(
        panel_step,
        (jnp.zeros((sb, sb), acc), jnp.zeros((sb,), acc)),
        jnp.arange(n_panels),
    )
    return jnp.tril(g, k=-1), v
