"""Host-side CSR matrices (numpy).

This is the ingest format: the paper stores A in three-array CSR and all
partitioners operate on column/row index structure. Device formats (ELL,
BSR) are derived from CSR blocks.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """Three-array CSR. ``indptr`` has length m+1; column indices sorted
    within each row is NOT required (partition permutations may unsort)."""

    indptr: np.ndarray  # (m+1,) int64
    indices: np.ndarray  # (nnz,) int32
    data: np.ndarray  # (nnz,) float
    shape: tuple[int, int]

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def nnz_per_row(self) -> np.ndarray:
        return np.diff(self.indptr)

    def nnz_per_col(self) -> np.ndarray:
        return np.bincount(self.indices, minlength=self.n)

    @property
    def zbar(self) -> float:
        """Mean nonzeros per row (the paper's z̄)."""
        return self.nnz / max(self.m, 1)

    def row_block(self, r0: int, r1: int) -> "CSRMatrix":
        """Rows [r0, r1) as a new CSR (row dimension r1-r0)."""
        lo, hi = int(self.indptr[r0]), int(self.indptr[r1])
        return CSRMatrix(
            indptr=(self.indptr[r0 : r1 + 1] - lo).astype(np.int64),
            indices=self.indices[lo:hi],
            data=self.data[lo:hi],
            shape=(r1 - r0, self.n),
        )

    def select_columns(self, cols: np.ndarray, relabel: bool = True) -> "CSRMatrix":
        """Keep only ``cols`` (any order). With ``relabel`` the kept
        columns are renumbered 0..len(cols)-1 in the order given — this
        is the column permutation a partitioner induces locally."""
        mask = np.zeros(self.n, dtype=bool)
        mask[cols] = True
        keep = mask[self.indices]
        new_indices = self.indices[keep]
        if relabel:
            remap = np.full(self.n, -1, dtype=np.int64)
            remap[cols] = np.arange(len(cols))
            new_indices = remap[new_indices].astype(np.int32)
            new_n = len(cols)
        else:
            new_n = self.n
        row_counts = np.add.reduceat(keep.astype(np.int64), self.indptr[:-1]) if self.nnz else np.zeros(self.m, np.int64)
        # reduceat misbehaves for empty rows; recompute robustly
        row_ids = np.repeat(np.arange(self.m), self.nnz_per_row)
        row_counts = np.bincount(row_ids[keep], minlength=self.m)
        indptr = np.zeros(self.m + 1, dtype=np.int64)
        np.cumsum(row_counts, out=indptr[1:])
        return CSRMatrix(indptr=indptr, indices=new_indices, data=self.data[keep], shape=(self.m, new_n))

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.data.dtype if self.nnz else np.float64)
        row_ids = np.repeat(np.arange(self.m), self.nnz_per_row)
        out[row_ids, self.indices] = self.data
        return out

    def scale_rows(self, y: np.ndarray) -> "CSRMatrix":
        """Return diag(y) @ A — the paper precomputes this once."""
        row_ids = np.repeat(np.arange(self.m), self.nnz_per_row)
        return dataclasses.replace(self, data=self.data * y[row_ids])


def csr_transpose(a: CSRMatrix) -> CSRMatrix:
    """Aᵀ as CSR (host-side; used to build BSR(Aᵀ) for TPU transpose
    products — see repro.kernels)."""
    row_ids = np.repeat(np.arange(a.m), a.nnz_per_row)
    order = np.argsort(a.indices, kind="stable")
    new_indices = row_ids[order].astype(np.int32)
    new_data = a.data[order]
    counts = np.bincount(a.indices, minlength=a.n)
    indptr = np.zeros(a.n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRMatrix(indptr=indptr, indices=new_indices, data=new_data, shape=(a.n, a.m))


def csr_from_dense(a: np.ndarray) -> CSRMatrix:
    m, n = a.shape
    indptr = np.zeros(m + 1, dtype=np.int64)
    idx_list, val_list = [], []
    for i in range(m):
        (cols,) = np.nonzero(a[i])
        idx_list.append(cols.astype(np.int32))
        val_list.append(a[i, cols])
        indptr[i + 1] = indptr[i] + len(cols)
    return CSRMatrix(
        indptr=indptr,
        indices=np.concatenate(idx_list) if idx_list else np.zeros(0, np.int32),
        data=np.concatenate(val_list) if val_list else np.zeros(0),
        shape=(m, n),
    )


def csr_matvec(a: CSRMatrix, x: np.ndarray) -> np.ndarray:
    """y = A @ x (host oracle)."""
    row_ids = np.repeat(np.arange(a.m), a.nnz_per_row)
    contrib = a.data * x[a.indices]
    return np.bincount(row_ids, weights=contrib, minlength=a.m).astype(x.dtype, copy=False)


def csr_rmatvec(a: CSRMatrix, u: np.ndarray) -> np.ndarray:
    """g = A.T @ u (host oracle)."""
    row_ids = np.repeat(np.arange(a.m), a.nnz_per_row)
    contrib = a.data * u[row_ids]
    return np.bincount(a.indices, weights=contrib, minlength=a.n).astype(u.dtype, copy=False)
