"""Column/row partitioners and irregularity statistics.

The paper frames partitioner choice as the two-objective constrained
problem  min_P κ(P)  s.t.  max_rank n_local(P)·w ≤ L_cap  (§6.5) and
implements three column partitioners (§7.3):

  rows    contiguous uniform n/p_c columns per rank — cache-friendly,
          nnz-imbalanced on skewed data;
  nnz     contiguous greedy — walk columns, advance rank when cumulative
          nnz reaches m·z̄/p_c — κ≈1 but may concentrate huge n_local;
  cyclic  round-robin c → c mod p_c — n_local exact AND κ≈1 in
          expectation, at the cost of a column permutation in the reader.

κ = max_rank(nnz)/mean_rank(nnz). On SPMD hardware every shard is padded
to the max, so κ multiplies compute directly (DESIGN.md §5.3).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.csr import CSRMatrix

PARTITIONERS = ("rows", "nnz", "cyclic")


@dataclasses.dataclass(frozen=True)
class ColumnPartition:
    """Assignment of the n columns to p_c ranks.

    ``order`` lists column ids grouped by rank (rank r owns
    order[starts[r]:starts[r+1]], renumbered locally in that order).
    """

    kind: str
    p: int
    order: np.ndarray  # (n,) int64 — permutation of column ids
    starts: np.ndarray  # (p+1,) int64

    def rank_cols(self, r: int) -> np.ndarray:
        return self.order[self.starts[r] : self.starts[r + 1]]

    @property
    def n_local(self) -> np.ndarray:
        return np.diff(self.starts)


def partition_columns(a: CSRMatrix, p: int, kind: str) -> ColumnPartition:
    n = a.n
    if kind == "rows":  # contiguous uniform
        bounds = np.linspace(0, n, p + 1).astype(np.int64)
        order = np.arange(n, dtype=np.int64)
        return ColumnPartition("rows", p, order, bounds)
    if kind == "nnz":  # contiguous greedy on cumulative nnz
        col_nnz = a.nnz_per_col()
        target = a.nnz / p
        csum = np.cumsum(col_nnz)
        starts = [0]
        for r in range(1, p):
            # first column index where cumulative nnz reaches r*target
            idx = int(np.searchsorted(csum, r * target, side="left")) + 1
            idx = max(idx, starts[-1])  # never move backwards
            idx = min(idx, n - (p - r))  # leave ≥1 col per remaining rank
            starts.append(idx)
        starts.append(n)
        order = np.arange(n, dtype=np.int64)
        return ColumnPartition("nnz", p, order, np.asarray(starts, np.int64))
    if kind == "cyclic":  # round robin
        order = np.concatenate([np.arange(r, n, p, dtype=np.int64) for r in range(p)])
        sizes = np.array([len(range(r, n, p)) for r in range(p)], np.int64)
        starts = np.zeros(p + 1, np.int64)
        np.cumsum(sizes, out=starts[1:])
        return ColumnPartition("cyclic", p, order, starts)
    raise ValueError(f"unknown partitioner {kind!r}; expected one of {PARTITIONERS}")


def partition_rows(m: int, p: int) -> np.ndarray:
    """Contiguous row bounds (p+1,) — all algorithms row-partition
    uniformly (the paper pads m to a multiple of s_max·b)."""
    return np.linspace(0, m, p + 1).astype(np.int64)


def partition_2d(
    a: CSRMatrix, p_r: int, p_c: int, kind: str
) -> tuple[list[list[CSRMatrix]], ColumnPartition, np.ndarray]:
    """Split A into p_r × p_c local CSR blocks.

    Returns (blocks[i][j], column partition, row bounds). Block (i, j)
    holds rows [rb[i], rb[i+1]) and the j-th rank's columns, locally
    renumbered in partition order.
    """
    cp = partition_columns(a, p_c, kind)
    rb = partition_rows(a.m, p_r)
    blocks: list[list[CSRMatrix]] = []
    for i in range(p_r):
        row_blk = a.row_block(int(rb[i]), int(rb[i + 1]))
        blocks.append([row_blk.select_columns(cp.rank_cols(j)) for j in range(p_c)])
    return blocks, cp, rb


@dataclasses.dataclass(frozen=True)
class PartitionStats:
    kind: str
    p: int
    kappa: float  # max/mean per-rank nnz
    nnz_per_rank: np.ndarray
    n_local: np.ndarray
    max_n_local: int
    weight_slab_bytes: int  # max_rank n_local · word
    fits_cache: bool


def partition_stats(
    a: CSRMatrix, cp: ColumnPartition, word_bytes: int = 8, l_cap_bytes: int = 1 << 20
) -> PartitionStats:
    col_nnz = a.nnz_per_col()
    nnz_per_rank = np.array(
        [int(col_nnz[cp.rank_cols(r)].sum()) for r in range(cp.p)], np.int64
    )
    mean = float(nnz_per_rank.mean()) if cp.p else 0.0
    kappa = float(nnz_per_rank.max() / mean) if mean > 0 else 1.0
    n_local = cp.n_local
    slab = int(n_local.max()) * word_bytes
    return PartitionStats(
        kind=cp.kind,
        p=cp.p,
        kappa=kappa,
        nnz_per_rank=nnz_per_rank,
        n_local=n_local,
        max_n_local=int(n_local.max()),
        weight_slab_bytes=slab,
        fits_cache=slab <= l_cap_bytes,
    )
