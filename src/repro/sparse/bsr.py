"""Block-sparse (BSR) tiles — the TPU-native sparse format.

TPUs have no efficient scalar gather; the MKL-CSR SpMV the paper uses
does not map to the MXU. The TPU-idiomatic adaptation (DESIGN.md §2) is
to re-block A into dense (bm × bn) tiles, keep only tiles containing
nonzeros, and drive a Pallas kernel whose block-column indices are
scalar-prefetched. Rows of tiles are padded to the max tile count per
block-row (ELL-of-tiles) so the grid is static.

The dense tiles land on the MXU; sparsity is exploited at tile
granularity. Tile shape defaults to (8, 128) — the VPU/MXU native lane
layout for f32.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.sparse.csr import CSRMatrix


@dataclasses.dataclass
class BsrMatrix:
    """ELL-of-tiles block-sparse matrix.

    tiles:      (n_block_rows, max_blocks, bm, bn) dense tile data
    block_cols: (n_block_rows, max_blocks) int32 — block-column index of
                each tile; padded entries point at block 0 with zero data.
    nblocks:    (n_block_rows,) int32 — valid tile count per block row.
    shape:      padded dense shape (rows = n_block_rows*bm, cols =
                n_block_cols*bn); logical_shape is the original (m, n).
    """

    tiles: jnp.ndarray
    block_cols: jnp.ndarray
    nblocks: jnp.ndarray
    shape: tuple[int, int]
    logical_shape: tuple[int, int]

    @property
    def bm(self) -> int:
        return int(self.tiles.shape[2])

    @property
    def bn(self) -> int:
        return int(self.tiles.shape[3])

    @property
    def n_block_rows(self) -> int:
        return int(self.tiles.shape[0])

    @property
    def max_blocks(self) -> int:
        return int(self.tiles.shape[1])

    @property
    def density(self) -> float:
        """Fraction of tiles stored vs a fully dense tiling."""
        total = self.n_block_rows * (self.shape[1] // self.bn)
        return float(np.sum(np.asarray(self.nblocks))) / max(total, 1)


def bsr_from_csr(a: CSRMatrix, bm: int = 8, bn: int = 128, dtype=jnp.float32) -> BsrMatrix:
    m_pad = -(-a.m // bm) * bm
    n_pad = -(-a.n // bn) * bn
    n_brows, n_bcols = m_pad // bm, n_pad // bn
    # bucket nonzeros by (block_row, block_col)
    row_ids = np.repeat(np.arange(a.m), a.nnz_per_row)
    br = row_ids // bm
    bc = a.indices // bn
    key = br.astype(np.int64) * n_bcols + bc
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    uniq, starts = np.unique(key_s, return_index=True)
    starts = np.append(starts, len(key_s))

    per_row_blocks: list[list[tuple[int, np.ndarray]]] = [[] for _ in range(n_brows)]
    for u_i, k in enumerate(uniq):
        blk_r, blk_c = int(k // n_bcols), int(k % n_bcols)
        sel = order[starts[u_i] : starts[u_i + 1]]
        tile = np.zeros((bm, bn), dtype=np.float64)
        tile[row_ids[sel] - blk_r * bm, a.indices[sel] - blk_c * bn] = a.data[sel]
        per_row_blocks[blk_r].append((blk_c, tile))

    max_blocks = max((len(b) for b in per_row_blocks), default=0) or 1
    tiles = np.zeros((n_brows, max_blocks, bm, bn), dtype=np.float64)
    block_cols = np.zeros((n_brows, max_blocks), dtype=np.int32)
    nblocks = np.zeros(n_brows, dtype=np.int32)
    for r, blks in enumerate(per_row_blocks):
        nblocks[r] = len(blks)
        for j, (c, tile) in enumerate(blks):
            tiles[r, j] = tile
            block_cols[r, j] = c
    return BsrMatrix(
        tiles=jnp.asarray(tiles, dtype=dtype),
        block_cols=jnp.asarray(block_cols),
        nblocks=jnp.asarray(nblocks),
        shape=(m_pad, n_pad),
        logical_shape=(a.m, a.n),
    )


def bsr_to_dense(bsr: BsrMatrix) -> np.ndarray:
    out = np.zeros(bsr.shape, dtype=np.asarray(bsr.tiles).dtype)
    tiles = np.asarray(bsr.tiles)
    bcols = np.asarray(bsr.block_cols)
    nb = np.asarray(bsr.nblocks)
    for r in range(bsr.n_block_rows):
        for j in range(int(nb[r])):
            c = int(bcols[r, j])
            out[r * bsr.bm : (r + 1) * bsr.bm, c * bsr.bn : (c + 1) * bsr.bn] += tiles[r, j]
    return out[: bsr.logical_shape[0], : bsr.logical_shape[1]]


def bsr_matvec_ref(bsr: BsrMatrix, x: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle: y = A @ x on the padded shape, truncated to m."""
    n_pad = bsr.shape[1]
    x_pad = jnp.zeros(n_pad, x.dtype).at[: x.shape[0]].set(x)
    x_blocks = x_pad.reshape(-1, bsr.bn)  # (n_bcols, bn)
    gathered = jnp.take(x_blocks, bsr.block_cols, axis=0)  # (nbr, maxb, bn)
    valid = (jnp.arange(bsr.max_blocks)[None, :] < bsr.nblocks[:, None]).astype(x.dtype)
    y_blocks = jnp.einsum("rjab,rjb->ra", bsr.tiles * valid[:, :, None, None], gathered)
    return y_blocks.reshape(-1)[: bsr.logical_shape[0]]
