"""Padded ELL device format and pure-jnp sparse matvec/rmatvec.

ELL pads every row to the same nonzero count so shapes are static —
required for jit/SPMD. Padding uses column 0 with value 0. The padded
width is the *global max* across SPMD shards so all ranks share one
shape; this is exactly where the paper's κ imbalance turns into padded
compute on TPU (DESIGN.md §5.3).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.csr import CSRMatrix


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EllBlock:
    """One local sparse block in padded-ELL layout.

    indices: (rows, width) int32 column ids (0 where padded)
    values:  (rows, width) float (0 where padded)
    n:       local column count (for rmatvec output length)
    """

    indices: jnp.ndarray
    values: jnp.ndarray
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def rows(self) -> int:
        return int(self.indices.shape[0])

    @property
    def width(self) -> int:
        return int(self.indices.shape[1])


def ell_from_csr(a: CSRMatrix, width: int | None = None, dtype=jnp.float32) -> EllBlock:
    counts = a.nnz_per_row
    w = int(counts.max()) if counts.size and width is None else int(width or 0)
    w = max(w, 1)
    idx = np.zeros((a.m, w), dtype=np.int32)
    val = np.zeros((a.m, w), dtype=np.float64)
    for i in range(a.m):
        lo, hi = int(a.indptr[i]), int(a.indptr[i + 1])
        k = hi - lo
        if k > w:
            raise ValueError(f"row {i} has {k} nnz > ELL width {w}")
        idx[i, :k] = a.indices[lo:hi]
        val[i, :k] = a.data[lo:hi]
    return EllBlock(indices=jnp.asarray(idx), values=jnp.asarray(val, dtype=dtype), n=a.n)


def ell_matvec(ell: EllBlock, x: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x, y: (rows,). Gather + row-sum; pads contribute 0."""
    gathered = jnp.take(x, ell.indices, axis=0)  # (rows, width)
    return jnp.sum(ell.values * gathered, axis=1)


def ell_matmat(ell: EllBlock, x: jnp.ndarray) -> jnp.ndarray:
    """Y = A @ X for X: (n, k) — used by the s-step bundle."""
    gathered = jnp.take(x, ell.indices, axis=0)  # (rows, width, k)
    return jnp.einsum("rw,rwk->rk", ell.values, gathered)


def ell_rmatvec(ell: EllBlock, u: jnp.ndarray) -> jnp.ndarray:
    """g = A.T @ u, g: (n,). Scatter-add of u-weighted values."""
    contrib = (ell.values * u[:, None]).reshape(-1)
    flat_idx = ell.indices.reshape(-1)
    return jnp.zeros(ell.n, dtype=contrib.dtype).at[flat_idx].add(contrib)


def ell_rmatmat(ell: EllBlock, u: jnp.ndarray) -> jnp.ndarray:
    """G = A.T @ U for U: (rows, k)."""
    contrib = ell.values[:, :, None] * u[:, None, :]  # (rows, width, k)
    flat_idx = ell.indices.reshape(-1)
    return (
        jnp.zeros((ell.n, u.shape[1]), dtype=contrib.dtype)
        .at[flat_idx]
        .add(contrib.reshape(-1, u.shape[1]))
    )


def ell_row_slice(ell: EllBlock, r0: int, r1: int) -> EllBlock:
    return EllBlock(indices=ell.indices[r0:r1], values=ell.values[r0:r1], n=ell.n)
