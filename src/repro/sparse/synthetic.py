"""Skew-controlled synthetic sparse datasets + LIBSVM stat analogues.

LIBSVM files (url, news20, rcv1, epsilon) are not available offline, so
we reproduce the paper's experiments on synthetic datasets matched to
each dataset's published statistics (m, n, z̄, column skew) — see
DESIGN.md §5.2. Column ids are drawn from p(c) ∝ (c+1)^(-alpha)
(alpha=0 uniform, alpha=1 Zipf), the same family as the paper's Figure 3
skew sweep. Full-size stats are registered for the cost model; the
matrices we *materialize* are the scaled "-sm" variants.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.csr import CSRMatrix


@dataclasses.dataclass(frozen=True)
class DatasetStats:
    """Published statistics used by the cost model (paper Table 6)."""

    name: str
    m: int
    n: int
    zbar: int
    skew_alpha: float  # column-skew exponent matched qualitatively
    dense: bool = False


# Paper Table 6 (+ the synthetic uniform matrix of Table 4 / Fig 7).
DATASET_STATS: dict[str, DatasetStats] = {
    "rcv1": DatasetStats("rcv1", 20_242, 47_236, 74, 0.6),
    "news20": DatasetStats("news20", 19_996, 1_355_191, 455, 0.9),
    "url": DatasetStats("url", 2_396_130, 3_231_961, 116, 1.0),
    "epsilon": DatasetStats("epsilon", 400_000, 2_000, 2_000, 0.0, dense=True),
    "synthetic_uniform": DatasetStats("synthetic_uniform", 2**21, 3_145_728, 12_582, 0.0),
}

# Scaled variants that we actually materialize on CPU. Scaling keeps the
# qualitative structure: n >> m for news20/url (high-dimensional), the
# column-skew exponent, and dense epsilon.
SM_STATS: dict[str, DatasetStats] = {
    "rcv1-sm": DatasetStats("rcv1-sm", 2_048, 4_736, 74, 0.6),
    "news20-sm": DatasetStats("news20-sm", 2_000, 66_560, 200, 0.9),
    "url-sm": DatasetStats("url-sm", 8_192, 131_072, 116, 1.0),
    "epsilon-sm": DatasetStats("epsilon-sm", 4_096, 512, 512, 0.0, dense=True),
    "uniform-sm": DatasetStats("uniform-sm", 4_096, 16_384, 64, 0.0),
}


def dataset_stats(name: str) -> DatasetStats:
    """Registered statistics for ``name`` — materializable -sm variants
    first, then the paper's full-size stat entries."""
    stats = SM_STATS.get(name) or DATASET_STATS.get(name)
    if stats is None:
        known = sorted(SM_STATS) + sorted(DATASET_STATS)
        raise KeyError(f"unknown dataset {name!r}; known: {known}")
    return stats


@dataclasses.dataclass
class SyntheticDataset:
    name: str
    A: CSRMatrix  # already includes NO label scaling; solvers apply diag(y)
    y: np.ndarray  # (m,) ±1
    x_true: np.ndarray  # (n,) generating weights
    stats: DatasetStats


def _column_probs(n: int, alpha: float, rng: np.random.Generator) -> np.ndarray:
    # p(c) ∝ (c+1)^(-α): heavy columns are *clustered at low ids*, the
    # structure real LIBSVM data exhibits (features sorted by frequency).
    # This is what makes contiguous partitioners κ-pathological (paper
    # Table 9: rows κ=33.8 on url) while cyclic stays near-optimal.
    del rng
    p = (np.arange(1, n + 1, dtype=np.float64)) ** (-alpha)
    return p / p.sum()


def make_skewed_csr(
    m: int, n: int, zbar: int, alpha: float, seed: int = 0, dense: bool = False
) -> CSRMatrix:
    rng = np.random.default_rng(seed)
    if dense:
        data = rng.standard_normal((m, n)) / np.sqrt(n)
        indptr = np.arange(m + 1, dtype=np.int64) * n
        indices = np.tile(np.arange(n, dtype=np.int32), m)
        return CSRMatrix(indptr=indptr, indices=indices, data=data.reshape(-1), shape=(m, n))
    probs = _column_probs(n, alpha, rng)
    # Per-row nnz ~ Poisson(zbar) clipped to [1, 4*zbar] — heavy-tailed
    # rows like real data.
    counts = np.clip(rng.poisson(zbar, size=m), 1, min(4 * zbar, n)).astype(np.int64)
    total = int(counts.sum())
    # Sample with replacement then dedupe per row (cheap, preserves skew).
    cols = rng.choice(n, size=total, p=probs).astype(np.int32)
    vals = rng.standard_normal(total) / np.sqrt(zbar)
    indptr = np.zeros(m + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    # dedupe within rows
    out_idx, out_val, out_ptr = [], [], [0]
    for i in range(m):
        lo, hi = indptr[i], indptr[i + 1]
        c, first = np.unique(cols[lo:hi], return_index=True)
        out_idx.append(c)
        out_val.append(vals[lo:hi][first])
        out_ptr.append(out_ptr[-1] + len(c))
    return CSRMatrix(
        indptr=np.asarray(out_ptr, np.int64),
        indices=np.concatenate(out_idx),
        data=np.concatenate(out_val),
        shape=(m, n),
    )


def make_dataset(name: str, seed: int = 0) -> SyntheticDataset:
    stats = dataset_stats(name)
    a = make_skewed_csr(stats.m, stats.n, stats.zbar, stats.skew_alpha, seed=seed, dense=stats.dense)
    rng = np.random.default_rng(seed + 1)
    # sparse ground truth for a learnable logistic problem
    x_true = np.zeros(stats.n)
    support = rng.choice(stats.n, size=max(stats.n // 100, 10), replace=False)
    x_true[support] = rng.standard_normal(len(support)) * 3.0
    from repro.sparse.csr import csr_matvec

    logits = csr_matvec(a, x_true)
    # normalize the generating margins to std ≈ 2.5 so the labels carry
    # real signal (unnormalized sparse margins were ≈0.2 std → 53%
    # predictable → every solver plateaued at log 2)
    scale = 2.5 / max(float(logits.std()), 1e-9)
    x_true *= scale
    logits *= scale
    p = 1.0 / (1.0 + np.exp(-logits))
    y = np.where(rng.random(stats.m) < p, 1.0, -1.0)
    return SyntheticDataset(name=name, A=a, y=y, x_true=x_true, stats=stats)
