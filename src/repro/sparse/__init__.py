"""Sparse-matrix substrate: host CSR, device ELL/BSR, partitioners, data.

The paper's workloads are sparse (A in R^{m x n}, CSR on the host). On
TPU we re-block into dense tiles (BSR) for the MXU or pad to ELL for the
pure-jnp path; both are produced from the host CSR here.
"""

from repro.sparse.csr import CSRMatrix, csr_from_dense, csr_matvec, csr_rmatvec
from repro.sparse.ell import EllBlock, ell_from_csr, ell_matvec, ell_rmatvec
from repro.sparse.bsr import BsrMatrix, bsr_from_csr, bsr_matvec_ref
from repro.sparse.partition import (
    ColumnPartition,
    partition_columns,
    partition_rows,
    partition_2d,
    partition_stats,
)
from repro.sparse.synthetic import (
    DATASET_STATS,
    SyntheticDataset,
    make_dataset,
    make_skewed_csr,
)

__all__ = [
    "CSRMatrix",
    "csr_from_dense",
    "csr_matvec",
    "csr_rmatvec",
    "EllBlock",
    "ell_from_csr",
    "ell_matvec",
    "ell_rmatvec",
    "BsrMatrix",
    "bsr_from_csr",
    "bsr_matvec_ref",
    "ColumnPartition",
    "partition_columns",
    "partition_rows",
    "partition_2d",
    "partition_stats",
    "DATASET_STATS",
    "SyntheticDataset",
    "make_dataset",
    "make_skewed_csr",
]
