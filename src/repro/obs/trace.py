"""Structured span tracing — the repo's observability seam.

The paper's empirical claims rest on a *time breakdown*: computation
vs. the two communication phases of Eq. 4, calibrated per machine in
§6.5. Before this module the repo could only measure whole rounds
(``CommLedger.round_seconds``) and whole runs (``RunReport``'s
compile/solve walls) — nothing could attribute wall time to a phase
*inside* a round, which is exactly what the overlap/asynchrony work
(exposed vs. total comm time) needs.

This is the tracing half of ``repro.obs``: a ``TraceRecorder`` collects
``Span``s — named, categorized, nested wall-clock intervals — from
instrumented sites across train/sweep/serve. The seam follows
``repro.core.faults`` exactly:

* a recorder is ``install``-ed for a scope (contextmanager + ContextVar;
  a module-level fallback makes it visible to worker threads, which
  do not inherit ContextVars — the serve plane's feed producer and
  prediction batcher record through it);
* instrumented code calls the module-level ``span(category, ...)``;
* with nothing installed, ``span`` returns one shared reusable no-op
  context — no allocation, no lock, one ContextVar read. Nothing is
  ever recorded from inside jit: spans are host-side wall intervals
  only, so compiled numerics are untouched and the default path is
  bitwise-identical (the same discipline as the faults seam).

Span categories are a closed set (``SPAN_CATEGORIES``); an unknown
category is a programming error and raises immediately. The mapping to
the paper: ``bundle_compute`` is Eq. 4's γ (compute) term,
``allreduce_gv`` the per-bundle (G, v) Allreduce (α/β over p_c),
``param_avg`` the per-τ weight averaging (α/β over p_r) — the three
phases §6.5 calibrates. Under a delay-D schedule ``allreduce_gv``
splits into ``allreduce_gv_issue`` (the host-side dispatch cost that
stays on the critical path) and ``allreduce_gv_await`` (the exposed
remainder after D bundle-computes of overlap) — Perfetto shows the
bubble closing as D grows. ``round``/``compile`` wrap the session chunk
loop; ``ckpt_save``/``ckpt_verify``/``swap`` the durability plane;
``ingest``/``predict_batch`` the serve plane.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from contextvars import ContextVar

__all__ = [
    "SPAN_CATEGORIES",
    "Span",
    "TraceRecorder",
    "active",
    "install",
    "span",
]

SPAN_CATEGORIES = (
    "round",
    "bundle_compute",
    "allreduce_gv",
    "allreduce_gv_issue",
    "allreduce_gv_await",
    "param_avg",
    "ckpt_save",
    "ckpt_verify",
    "swap",
    "ingest",
    "predict_batch",
    "compile",
)


@dataclasses.dataclass(frozen=True)
class Span:
    """One recorded wall-clock interval.

    category  one of ``SPAN_CATEGORIES``.
    name      instance label ("rounds[8+4]", "swap-12", ...).
    t0        start, seconds since the recorder's epoch (perf_counter
              clock — monotonic; the recorder also stamps a unix epoch
              so exports can place spans in absolute time).
    dur       duration in seconds.
    tid       recording thread id (spans from the feed producer and the
              prediction batcher land on their own tracks).
    depth     nesting depth within the recording thread (0 = top).
    args      small JSON-safe payload (round counts, paths, row counts).
    """

    category: str
    name: str
    t0: float
    dur: float
    tid: int
    depth: int
    args: dict = dataclasses.field(default_factory=dict)


class TraceRecorder:
    """Collects spans from every instrumented seam while installed.

    Thread-safe: instrumented sites run on the session thread, the
    stream feed's producer thread, and the prediction service's batcher
    thread; each appends under one lock and nests against its own
    per-thread depth stack.
    """

    def __init__(self):
        self.epoch_perf = time.perf_counter()
        self.epoch_unix = time.time()
        self.spans: list[Span] = []
        self._lock = threading.Lock()
        self._local = threading.local()

    # ---- recording ----

    def _depth(self) -> int:
        return getattr(self._local, "depth", 0)

    @contextlib.contextmanager
    def span(self, category: str, name: str | None = None, **args):
        """Record the with-block as one span. ``args`` must be
        JSON-safe (they land verbatim in the exported trace)."""
        if category not in SPAN_CATEGORIES:
            raise ValueError(f"category={category!r} not in {SPAN_CATEGORIES}")
        depth = self._depth()
        self._local.depth = depth + 1
        t0 = time.perf_counter() - self.epoch_perf
        try:
            yield self
        finally:
            dur = (time.perf_counter() - self.epoch_perf) - t0
            self._local.depth = depth
            self._append(Span(
                category=category,
                name=name if name is not None else category,
                t0=t0,
                dur=dur,
                tid=threading.get_ident(),
                depth=depth,
                args=args,
            ))

    def add_span(self, category: str, name: str, *, t0: float | None = None,
                 dur: float, **args) -> Span:
        """Record an externally-measured interval (phase probes, compile
        walls) post hoc. ``t0`` defaults to now-minus-``dur``."""
        if category not in SPAN_CATEGORIES:
            raise ValueError(f"category={category!r} not in {SPAN_CATEGORIES}")
        now = time.perf_counter() - self.epoch_perf
        s = Span(
            category=category,
            name=name,
            t0=(now - dur) if t0 is None else t0,
            dur=float(dur),
            tid=threading.get_ident(),
            depth=self._depth(),
            args=args,
        )
        self._append(s)
        return s

    def _append(self, s: Span) -> None:
        with self._lock:
            self.spans.append(s)

    # ---- inspection ----

    def by_category(self) -> dict[str, list[Span]]:
        out: dict[str, list[Span]] = {}
        with self._lock:
            spans = list(self.spans)
        for s in spans:
            out.setdefault(s.category, []).append(s)
        return out

    def total_seconds(self, category: str) -> float:
        return sum(s.dur for s in self.by_category().get(category, ()))

    def __len__(self) -> int:
        with self._lock:
            return len(self.spans)


# ---- the seam ----------------------------------------------------------
#
# ContextVar for the common single-threaded case, plus a module-level
# fallback: ContextVars do NOT propagate into threading.Thread, and the
# serve plane's producer/batcher threads are exactly where queue-depth
# and batch spans come from. install() sets both; active() prefers the
# ContextVar (correct nesting of scoped installs on one thread) and
# falls back to the global for threads started inside the scope.

_ACTIVE: ContextVar[TraceRecorder | None] = ContextVar("trace_recorder", default=None)
_GLOBAL: TraceRecorder | None = None

# one shared, reusable no-op context: the uninstalled fast path must not
# allocate per call (the round loop crosses it every sub-chunk).
_NULLCTX = contextlib.nullcontext()


def active() -> TraceRecorder | None:
    """The installed recorder, or None (the normal, untraced case)."""
    rec = _ACTIVE.get()
    if rec is not None:
        return rec
    return _GLOBAL


@contextlib.contextmanager
def install(recorder: TraceRecorder | None = None):
    """Install a recorder for the dynamic extent of the with-block and
    yield it (make one when not given). Worker threads started inside
    the scope see it too, via the module-level fallback."""
    global _GLOBAL
    rec = TraceRecorder() if recorder is None else recorder
    token = _ACTIVE.set(rec)
    prev_global = _GLOBAL
    _GLOBAL = rec
    try:
        yield rec
    finally:
        _ACTIVE.reset(token)
        _GLOBAL = prev_global


def span(category: str, name: str | None = None, **args):
    """Record a span at an instrumented site — the shared no-op context
    when no recorder is installed."""
    rec = active()
    if rec is None:
        return _NULLCTX
    return rec.span(category, name, **args)
