"""Trace export — Chrome trace-event JSON and a JSONL event log.

Two schema-versioned formats from one ``TraceRecorder``:

* ``write_chrome_trace`` — the Chrome trace-event "JSON Object Format":
  a top-level dict with ``traceEvents`` of ``ph: "X"`` complete events
  (ts/dur in microseconds, pid/tid tracks, span args attached). The
  file loads directly in Perfetto (ui.perfetto.dev) and
  chrome://tracing; each recording thread is its own named track, so a
  serve-plane trace shows the session, the feed producer, and the
  prediction batcher side by side.
* ``write_jsonl`` — one JSON object per line: a header line carrying
  the schema version and epochs, then one line per span in recording
  order. Greppable and streamable (the shape log scrapers want).

``summarize``/``category_table`` aggregate per category — total wall,
span count, wall share — which is also what the launch CLIs print as
the ``[trace]`` summary line.
"""

from __future__ import annotations

import json
import os
from pathlib import Path

from repro.obs.trace import SPAN_CATEGORIES, TraceRecorder

__all__ = [
    "TRACE_SCHEMA_VERSION",
    "category_table",
    "chrome_trace_dict",
    "load_trace",
    "summary_line",
    "summarize_text",
    "write_chrome_trace",
    "write_jsonl",
]

TRACE_SCHEMA_VERSION = 1


def chrome_trace_dict(rec: TraceRecorder, metrics: dict | None = None) -> dict:
    """The recorder as a Chrome trace-event JSON object (loads in
    Perfetto / chrome://tracing). ``metrics`` (a registry ``snapshot()``)
    rides along under ``otherData`` when given."""
    pid = os.getpid()
    tids = []
    events = [{
        "name": "process_name",
        "ph": "M",
        "pid": pid,
        "tid": 0,
        "args": {"name": "repro"},
    }]
    for s in rec.spans:
        if s.tid not in tids:
            tids.append(s.tid)
        events.append({
            "name": s.name,
            "cat": s.category,
            "ph": "X",
            "ts": s.t0 * 1e6,        # trace-event timestamps are µs
            "dur": s.dur * 1e6,
            "pid": pid,
            "tid": tids.index(s.tid),
            "args": dict(s.args),
        })
    for i, _tid in enumerate(tids):
        events.append({
            "name": "thread_name",
            "ph": "M",
            "pid": pid,
            "tid": i,
            "args": {"name": "session" if i == 0 else f"worker-{i}"},
        })
    other = {
        "schemaVersion": TRACE_SCHEMA_VERSION,
        "epochUnix": rec.epoch_unix,
        "categories": list(SPAN_CATEGORIES),
    }
    if metrics is not None:
        other["metrics"] = metrics
    return {
        "schemaVersion": TRACE_SCHEMA_VERSION,
        "displayTimeUnit": "ms",
        "traceEvents": events,
        "otherData": other,
    }


def write_chrome_trace(rec: TraceRecorder, path, metrics: dict | None = None) -> Path:
    path = Path(path)
    path.write_text(json.dumps(chrome_trace_dict(rec, metrics)))
    return path


def write_jsonl(rec: TraceRecorder, path) -> Path:
    """Header line (schema + epochs + span count), then one span per
    line in recording order."""
    path = Path(path)
    with path.open("w") as f:
        f.write(json.dumps({
            "schemaVersion": TRACE_SCHEMA_VERSION,
            "epochUnix": rec.epoch_unix,
            "spans": len(rec.spans),
        }) + "\n")
        for s in rec.spans:
            f.write(json.dumps({
                "cat": s.category,
                "name": s.name,
                "t0": s.t0,
                "dur": s.dur,
                "tid": s.tid,
                "depth": s.depth,
                "args": dict(s.args),
            }) + "\n")
    return path


def load_trace(path) -> dict:
    """Load either export back to one normalized shape:
    ``{"schemaVersion": int, "spans": [{cat, name, t0, dur}, ...]}``
    (seconds, like the recorder)."""
    path = Path(path)
    text = path.read_text()
    if path.suffix == ".jsonl" or "\n{" in text.strip():
        lines = [json.loads(ln) for ln in text.splitlines() if ln.strip()]
        header, spans = lines[0], lines[1:]
        return {"schemaVersion": header.get("schemaVersion"), "spans": spans}
    blob = json.loads(text)
    spans = [
        {
            "cat": ev.get("cat"),
            "name": ev.get("name"),
            "t0": ev.get("ts", 0.0) / 1e6,
            "dur": ev.get("dur", 0.0) / 1e6,
            "tid": ev.get("tid"),
            "args": ev.get("args", {}),
        }
        for ev in blob.get("traceEvents", ())
        if ev.get("ph") == "X"
    ]
    return {"schemaVersion": blob.get("schemaVersion"), "spans": spans}


# ---- aggregation -------------------------------------------------------


def category_table(spans) -> list[dict]:
    """Per-category rows — count, total wall seconds, wall share —
    sorted by wall descending. ``spans`` is ``load_trace()["spans"]``
    or a recorder's span list."""
    agg: dict[str, list[float]] = {}
    for s in spans:
        cat = s["cat"] if isinstance(s, dict) else s.category
        dur = s["dur"] if isinstance(s, dict) else s.dur
        row = agg.setdefault(cat, [0, 0.0])
        row[0] += 1
        row[1] += dur
    total = sum(v[1] for v in agg.values()) or 1.0
    return sorted(
        (
            {"category": c, "count": n, "seconds": sec, "share": sec / total}
            for c, (n, sec) in agg.items()
        ),
        key=lambda r: -r["seconds"],
    )


def summary_line(rec: TraceRecorder) -> str:
    """The greppable one-liner the launch CLIs print:
    ``[trace] N spans over S.SSSs; top: cat 61%, cat 20%, cat 10%``."""
    rows = category_table(rec.spans)
    total = sum(r["seconds"] for r in rows)
    top = ", ".join(f"{r['category']} {r['share'] * 100:.0f}%" for r in rows[:3])
    return f"[trace] {len(rec.spans)} spans over {total:.3f}s; top: {top or 'none'}"


def summarize_text(path) -> str:
    """The ``repro.launch.trace summarize`` table for one trace file."""
    blob = load_trace(path)
    rows = category_table(blob["spans"])
    out = [f"# trace {Path(path).name} (schema v{blob['schemaVersion']}, "
           f"{len(blob['spans'])} spans)"]
    out.append(f"{'category':<16} {'count':>6} {'seconds':>10} {'share':>7}")
    for r in rows:
        out.append(
            f"{r['category']:<16} {r['count']:>6} {r['seconds']:>10.4f} "
            f"{r['share'] * 100:>6.1f}%"
        )
    return "\n".join(out)
