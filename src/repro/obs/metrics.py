"""A unified metrics registry — typed counters, gauges, histograms.

Before this module the repo's telemetry lived in three ad-hoc silos:
``CommLedger`` (comm plane), the serve-only ``StageMetrics``, and chaos
forensics in log lines. This is the one process-wide home: every plane
registers typed instruments here, and snapshots/deltas give sweeps,
CLIs, and benchmarks a single labeled view.

Three instrument kinds, Prometheus-shaped:

  Counter    monotonically increasing count (points run, retries,
             predictions served). ``inc(n)``.
  Gauge      a level that goes up and down (queue depth, staleness,
             rounds/sec). ``set(v)``.
  Histogram  a running summary of observations — count/sum/min/max
             (per-module benchmark walls, batch sizes). ``observe(v)``.

Instruments are keyed by (kind, name, sorted labels); asking for the
same name with a different kind is a programming error and raises.
``registry()`` returns the process-default ``MetricsRegistry``
(tests use ``reset()`` or a private instance). Instruments are plain
Python on the host — nothing here touches jit or device buffers.
"""

from __future__ import annotations

import dataclasses
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "registry",
]


def _key(name: str, labels: dict[str, str]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


@dataclasses.dataclass
class Counter:
    """Monotonic count. ``inc`` by a non-negative amount."""

    name: str
    labels: dict = dataclasses.field(default_factory=dict)
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {n})")
        self.value += n

    def snapshot(self) -> dict:
        return {"kind": "counter", "value": self.value}


@dataclasses.dataclass
class Gauge:
    """A level — last value written wins."""

    name: str
    labels: dict = dataclasses.field(default_factory=dict)
    value: float | None = None

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"kind": "gauge", "value": self.value}


@dataclasses.dataclass
class Histogram:
    """Running summary of observations: count / sum / min / max."""

    name: str
    labels: dict = dataclasses.field(default_factory=dict)
    count: int = 0
    sum: float = 0.0
    min: float | None = None
    max: float | None = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    @property
    def mean(self) -> float | None:
        return self.sum / self.count if self.count else None

    def snapshot(self) -> dict:
        return {
            "kind": "histogram",
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "mean": self.mean,
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Process-wide home of typed instruments, keyed by name + labels.

    Thread-safe at the registration layer (instrument writes are plain
    float/int stores — atomic enough for telemetry under the GIL; this
    mirrors the big clients' approach, not a consistency guarantee).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, Counter | Gauge | Histogram] = {}
        self._kinds: dict[str, str] = {}  # name -> kind (conflict check)

    def _get(self, kind: str, name: str, labels: dict[str, str] | None):
        labels = dict(labels or {})
        key = _key(name, labels)
        with self._lock:
            prev_kind = self._kinds.get(name)
            if prev_kind is not None and prev_kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {prev_kind}, "
                    f"requested as {kind}"
                )
            self._kinds[name] = kind
            inst = self._instruments.get(key)
            if inst is None:
                inst = _KINDS[kind](name=name, labels=labels)
                self._instruments[key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    # ---- views ----

    def snapshot(self) -> dict[str, dict]:
        """All instruments as ``{"name{k=v}": {kind, value...}}`` —
        JSON-safe, stable keys (labels sorted)."""
        with self._lock:
            items = list(self._instruments.items())
        return {k: inst.snapshot() for k, inst in sorted(items)}

    def delta(self, prev: dict[str, dict]) -> dict[str, dict]:
        """What changed since a previous ``snapshot()``: counters and
        histograms report the increment, gauges their current level.
        Instruments absent from ``prev`` report their full value."""
        now = self.snapshot()
        out: dict[str, dict] = {}
        for key, snap in now.items():
            before = prev.get(key)
            if snap["kind"] == "gauge" or before is None:
                if before != snap:
                    out[key] = snap
                continue
            if snap["kind"] == "counter":
                d = snap["value"] - before.get("value", 0.0)
                if d:
                    out[key] = {"kind": "counter", "value": d}
            else:  # histogram
                d = snap["count"] - before.get("count", 0)
                if d:
                    out[key] = {
                        "kind": "histogram",
                        "count": d,
                        "sum": snap["sum"] - before.get("sum", 0.0),
                        "min": snap["min"],
                        "max": snap["max"],
                    }
        return out

    def reset(self) -> None:
        """Drop every instrument (tests; a fresh CLI run)."""
        with self._lock:
            self._instruments.clear()
            self._kinds.clear()


_DEFAULT = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-default registry every plane publishes into."""
    return _DEFAULT
