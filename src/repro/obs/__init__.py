"""repro.obs — the observability plane.

``trace``    Span/TraceRecorder seam (contextmanager + ContextVar,
             inert when uninstalled) with the closed span-category set.
``metrics``  process-wide registry of typed Counter/Gauge/Histogram
             instruments with labeled snapshots and deltas.
``export``   Chrome trace-event JSON (Perfetto-loadable) and JSONL
             event-log export, both schema-versioned.
"""

from repro.obs.metrics import MetricsRegistry, registry
from repro.obs.trace import SPAN_CATEGORIES, Span, TraceRecorder, active, install, span

__all__ = [
    "MetricsRegistry",
    "SPAN_CATEGORIES",
    "Span",
    "TraceRecorder",
    "active",
    "install",
    "registry",
    "span",
]
