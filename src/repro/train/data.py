"""Token data pipeline.

Offline container ⇒ no real corpora; the pipeline synthesizes a
deterministic, learnable token stream (a Zipf-distributed k-th order
Markov chain) with the same interface a file-backed loader would have:
``batches(batch, seq_len)`` yields (tokens, targets) int32 arrays.
A Markov stream has real structure (bigram statistics), so training
loss decreasing is meaningful, unlike i.i.d. noise.

The stream also conforms to the serving plane's ``StreamSource``
protocol (``micro_batches(start)`` — repro.serve.stream): batches carry
their stream index and replay deterministically, so the token pipeline
can ride the same ingest/feed machinery as the sparse-example streams
(its batches carry tokens, not sparse rows — consumers differ).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenMicroBatch:
    """One indexed (tokens, targets) pair — the token stream's
    ``StreamSource`` element (``index`` is the replay key)."""

    index: int
    tokens: np.ndarray  # (batch, seq_len) int32
    targets: np.ndarray  # (batch, seq_len) int32

    @property
    def rows(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class MarkovTextStream:
    vocab_size: int
    seed: int = 0
    branching: int = 32  # successors per token (Zipf-weighted)
    batch: int = 8  # micro_batches() shape (the batches() args, as fields)
    seq_len: int = 32

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        self.succ = rng.integers(0, v, size=(v, self.branching))
        w = 1.0 / np.arange(1, self.branching + 1)
        self.succ_p = w / w.sum()

    def batches(self, batch: int, seq_len: int, start_seed: int = 0):
        """Infinite iterator of (tokens, targets)."""
        rng = np.random.default_rng(self.seed + 1000 + start_seed)
        state = rng.integers(0, self.vocab_size, size=batch)
        while True:
            toks = np.empty((batch, seq_len + 1), dtype=np.int32)
            toks[:, 0] = state
            for t in range(seq_len):
                choice = rng.choice(self.branching, size=batch, p=self.succ_p)
                toks[:, t + 1] = self.succ[toks[:, t], choice]
            state = toks[:, -1]
            yield toks[:, :-1], toks[:, 1:]

    def micro_batches(self, start: int = 0) -> Iterator[TokenMicroBatch]:
        """``StreamSource`` conformance: indexed, deterministic batches
        of shape (``self.batch``, ``self.seq_len``).

        The chain carries state batch-to-batch, so batch k is a function
        of the whole prefix — replay-from-k is implemented by walking
        the chain from 0 and discarding (O(start); fine for the resume
        depths tests and demos use, unlike the sparse streams whose
        batch k is O(1) pure in k)."""
        it = self.batches(self.batch, self.seq_len)
        for _ in range(int(start)):
            next(it)
        k = int(start)
        for toks, targs in it:
            yield TokenMicroBatch(index=k, tokens=toks, targets=targs)
            k += 1


def bigram_entropy_floor(
    stream: MarkovTextStream, sample_states: int | None = 64
) -> float:
    """The stream's conditional entropy (nats) — the loss floor a
    perfect model reaches; used by tests to check learning headroom.

    The floor is averaged over the first ``min(vocab_size,
    sample_states)`` states rather than the whole vocabulary — every
    state's successor table is drawn from the same Zipf recipe, so a
    sample estimates the mean to well within test tolerances while
    keeping the call O(sample·branching). Pass ``sample_states=None``
    for the exact all-states average (O(vocab·branching)).
    """
    p = stream.succ_p
    n_states = (
        stream.vocab_size
        if sample_states is None
        else min(stream.vocab_size, int(sample_states))
    )
    if n_states < 1:
        raise ValueError(f"sample_states={sample_states} must be ≥ 1 (or None)")
    # successors may repeat; account per-state, averaged
    ent = 0.0
    for s in range(n_states):
        agg: dict[int, float] = {}
        for j, t in enumerate(stream.succ[s]):
            agg[int(t)] = agg.get(int(t), 0.0) + p[j]
        ent += -sum(q * np.log(q) for q in agg.values())
    return ent / n_states
