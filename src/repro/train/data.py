"""Token data pipeline.

Offline container ⇒ no real corpora; the pipeline synthesizes a
deterministic, learnable token stream (a Zipf-distributed k-th order
Markov chain) with the same interface a file-backed loader would have:
``batches(batch, seq_len)`` yields (tokens, targets) int32 arrays.
A Markov stream has real structure (bigram statistics), so training
loss decreasing is meaningful, unlike i.i.d. noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class MarkovTextStream:
    vocab_size: int
    seed: int = 0
    branching: int = 32  # successors per token (Zipf-weighted)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        self.succ = rng.integers(0, v, size=(v, self.branching))
        w = 1.0 / np.arange(1, self.branching + 1)
        self.succ_p = w / w.sum()

    def batches(self, batch: int, seq_len: int, start_seed: int = 0):
        """Infinite iterator of (tokens, targets)."""
        rng = np.random.default_rng(self.seed + 1000 + start_seed)
        state = rng.integers(0, self.vocab_size, size=batch)
        while True:
            toks = np.empty((batch, seq_len + 1), dtype=np.int32)
            toks[:, 0] = state
            for t in range(seq_len):
                choice = rng.choice(self.branching, size=batch, p=self.succ_p)
                toks[:, t + 1] = self.succ[toks[:, t], choice]
            state = toks[:, -1]
            yield toks[:, :-1], toks[:, 1:]


def bigram_entropy_floor(stream: MarkovTextStream) -> float:
    """The stream's conditional entropy (nats) — the loss floor a
    perfect model reaches; used by tests to check learning headroom."""
    p = stream.succ_p
    # successors may repeat; account per-state, averaged
    ent = 0.0
    for s in range(min(stream.vocab_size, 64)):  # sample of states
        agg: dict[int, float] = {}
        for j, t in enumerate(stream.succ[s]):
            agg[int(t)] = agg.get(int(t), 0.0) + p[j]
        ent += -sum(q * np.log(q) for q in agg.values())
    return ent / min(stream.vocab_size, 64)
