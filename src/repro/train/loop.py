"""Training loop driver (used by examples/ and launch/train.py).

Wires: config → params → hybrid-2D train step (the paper's technique:
τ local steps per pod, then a parameter-averaging sync) → data stream →
metrics → checkpoints.

The sync cadence comes from the engine's ParallelSGDSchedule — the
transformer workload and the logistic-regression workload share one
schedule object (τ means the same thing in both; see
docs/paper_map.md).
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.engine import ParallelSGDSchedule
from repro.models.config import ArchConfig
from repro.models.init import init_params
from repro.models.transformer import lm_loss
from repro.optim.hybrid2d import make_hybrid_train_step, make_sync_step, stack_for_pods
from repro.optim.sgd import Optimizer, adamw
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.data import MarkovTextStream


@dataclasses.dataclass
class TrainReport:
    losses: list[float]
    steps: int
    tokens_per_s: float


def train(
    cfg: ArchConfig,
    steps: int = 100,
    batch: int = 8,
    seq_len: int = 128,
    tau: int = 10,
    mesh=None,
    opt: Optimizer | None = None,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 0,
    log_every: int = 10,
    seed: int = 0,
    dtype=jnp.float32,
    schedule: ParallelSGDSchedule | None = None,
) -> TrainReport:
    """Train cfg on the synthetic Markov stream. With a multi-pod mesh
    this runs the full hybrid-2D schedule (pod-local steps + τ-sync).

    ``schedule`` is the engine's knob object; this loop consumes its τ
    (pod-sync cadence) and validates p_r against the mesh. s maps to
    gradient-accumulation microsteps in launch.steps.make_train_step,
    not here; b is the ``batch`` argument."""
    opt = opt or adamw(3e-4)
    params = init_params(cfg, jax.random.PRNGKey(seed), dtype=dtype)
    opt_state = opt.init(params)

    n_pods = 1
    if mesh is not None and "pod" in mesh.axis_names:
        n_pods = dict(zip(mesh.axis_names, mesh.axis_sizes))["pod"]
    if schedule is not None:
        if schedule.p_r not in (1, n_pods):
            raise ValueError(
                f"schedule.p_r={schedule.p_r} but the mesh has {n_pods} pods"
            )
        tau = schedule.tau

    def loss_fn(p, tokens, targets):
        return lm_loss(cfg, p, tokens, targets)

    if mesh is not None:
        train_step = make_hybrid_train_step(mesh, loss_fn, opt)
        sync_step = make_sync_step(mesh)
        if n_pods > 1:
            params = stack_for_pods(params, n_pods)
            opt_state = stack_for_pods(opt_state, n_pods)
        state = (params, opt_state)
    else:

        @jax.jit
        def train_step(state, batch_):
            p, s = state
            loss, g = jax.value_and_grad(loss_fn)(p, *batch_)
            p, s = opt.update(g, s, p)
            return (p, s), loss

        sync_step = lambda p: p
        state = (params, opt_state)

    stream = MarkovTextStream(cfg.vocab_size, seed=seed)
    it = stream.batches(batch, seq_len)

    start, step0 = None, 0
    if checkpoint_dir:
        restored, step0 = restore_checkpoint(Path(checkpoint_dir) / "ckpt", state)
        if restored is not None:
            state = jax.tree.map(jnp.asarray, restored)

    losses: list[float] = []
    t0 = time.time()
    for step in range(step0, steps):
        tokens, targets = next(it)
        state, loss = train_step(state, (jnp.asarray(tokens), jnp.asarray(targets)))
        if n_pods > 1 and tau and (step + 1) % tau == 0:
            p, s = state
            state = (sync_step(p), s)
        if (step + 1) % log_every == 0 or step == steps - 1:
            losses.append(float(loss))
        if checkpoint_dir and checkpoint_every and (step + 1) % checkpoint_every == 0:
            save_checkpoint(Path(checkpoint_dir) / "ckpt", state, step + 1)
    if start is None:
        elapsed = max(time.time() - t0, 1e-9)
    tokens_per_s = (steps - step0) * batch * seq_len / elapsed
    return TrainReport(losses=losses, steps=steps, tokens_per_s=tokens_per_s)
