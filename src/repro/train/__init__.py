"""Training substrate: data pipeline, checkpointing, loop driver."""

from repro.train.data import MarkovTextStream, TokenMicroBatch, bigram_entropy_floor
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.loop import TrainReport, train

__all__ = [
    "MarkovTextStream",
    "TokenMicroBatch",
    "bigram_entropy_floor",
    "restore_checkpoint",
    "save_checkpoint",
    "TrainReport",
    "train",
]
