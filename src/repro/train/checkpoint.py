"""Checkpointing: flat-npz save/restore of pytrees + session state.

No external deps (no orbax). Two layers:

* pytree checkpoints (``save_checkpoint`` / ``restore_checkpoint``) —
  the NN training loop's format: the tree is flattened with '/'-joined
  key paths into a single .npz plus a small JSON manifest for the
  treedef.
* session checkpoints (``save_session_checkpoint`` /
  ``load_session_checkpoint``) — the ``repro.api.Session`` lifecycle's
  format: the solver carry (weights, loss trace) in an .npz plus a JSON
  manifest holding the full spec dict, its content hash, and the round
  counter. The hash keys the checkpoint: restoring under a spec whose
  ``content_hash()`` differs is a hard ``SpecMismatchError`` — a
  checkpoint is only ever resumed into the exact experiment that wrote
  it.

Everything is atomic via write-to-temp + rename.
"""

from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path

import jax
import numpy as np


class SpecMismatchError(ValueError):
    """A session checkpoint was opened under a different spec."""


def _write_atomic(path: Path, npz_payload: dict, manifest: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp.npz")
    np.savez(tmp, **npz_payload)
    tmp_manifest = path.with_suffix(".tmp.json")
    tmp_manifest.write_text(json.dumps(manifest))
    os.replace(tmp, path.with_suffix(".npz"))
    os.replace(tmp_manifest, path.with_suffix(".json"))


# ---------------- pytree checkpoints (NN training loop) ----------------


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str | os.PathLike, tree, step: int) -> None:
    flat = _flatten(tree)
    _write_atomic(Path(path), flat, {"step": step, "keys": sorted(flat)})


def restore_checkpoint(path: str | os.PathLike, tree_like):
    """Restore into the structure of ``tree_like``; returns (tree, step)
    or (None, 0) if absent."""
    path = Path(path)
    npz, manifest = path.with_suffix(".npz"), path.with_suffix(".json")
    if not npz.exists() or not manifest.exists():
        return None, 0
    data = np.load(npz)
    meta = json.loads(manifest.read_text())
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    new_leaves = []
    for path_elems, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"checkpoint shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        new_leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta["step"]


# ---------------- session checkpoints (repro.api.Session) ----------------

_SESSION_FORMAT = "repro-session-v1"


@dataclasses.dataclass
class SessionCheckpoint:
    """One saved ``Session`` carry — everything needed to fast-forward a
    freshly built session to the interrupted round."""

    spec_dict: dict
    spec_hash: str
    rounds_done: int
    x: np.ndarray
    losses: np.ndarray
    wall_time_s: float
    compile_time_s: float


def save_session_checkpoint(
    path: str | os.PathLike,
    *,
    spec_dict: dict,
    spec_hash: str,
    rounds_done: int,
    x: np.ndarray,
    losses: np.ndarray,
    wall_time_s: float,
    compile_time_s: float,
) -> None:
    manifest = {
        "format": _SESSION_FORMAT,
        "spec": spec_dict,
        "spec_hash": spec_hash,
        "rounds_done": int(rounds_done),
        "wall_time_s": float(wall_time_s),
        "compile_time_s": float(compile_time_s),
    }
    payload = {
        "x": np.asarray(x),
        "losses": np.asarray(losses, np.float32),
    }
    _write_atomic(Path(path), payload, manifest)


def load_session_checkpoint(
    path: str | os.PathLike, expect_spec_hash: str | None = None
) -> SessionCheckpoint:
    """Load a session checkpoint; with ``expect_spec_hash``, refuse
    (``SpecMismatchError``) if the checkpoint was written under a
    different spec."""
    path = Path(path)
    npz, manifest = path.with_suffix(".npz"), path.with_suffix(".json")
    if not npz.exists() or not manifest.exists():
        raise FileNotFoundError(f"no session checkpoint at {path}(.npz/.json)")
    meta = json.loads(manifest.read_text())
    if meta.get("format") != _SESSION_FORMAT:
        raise ValueError(
            f"{path}: not a session checkpoint (format={meta.get('format')!r})"
        )
    if expect_spec_hash is not None and meta["spec_hash"] != expect_spec_hash:
        raise SpecMismatchError(
            f"{path}: checkpoint was written under spec hash {meta['spec_hash']} "
            f"but the session's spec hashes to {expect_spec_hash} — a checkpoint "
            f"only resumes into the exact spec that wrote it"
        )
    data = np.load(npz)
    return SessionCheckpoint(
        spec_dict=meta["spec"],
        spec_hash=meta["spec_hash"],
        rounds_done=int(meta["rounds_done"]),
        x=data["x"],
        losses=data["losses"],
        wall_time_s=float(meta["wall_time_s"]),
        compile_time_s=float(meta["compile_time_s"]),
    )
