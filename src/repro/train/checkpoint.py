"""Checkpointing: flat-npz save/restore of pytrees + session state.

No external deps (no orbax). Two layers:

* pytree checkpoints (``save_checkpoint`` / ``restore_checkpoint``) —
  the NN training loop's format: the tree is flattened with '/'-joined
  key paths into a single .npz plus a small JSON manifest for the
  treedef.
* session checkpoints (``save_session_checkpoint`` /
  ``load_session_checkpoint``) — the ``repro.api.Session`` lifecycle's
  format: the solver carry (weights, loss trace) in an .npz plus a JSON
  manifest holding the full spec dict, its content hash, and the round
  counter. The hash keys the checkpoint: restoring under a spec whose
  ``content_hash()`` differs is a hard ``SpecMismatchError`` — a
  checkpoint is only ever resumed into the exact experiment that wrote
  it (elastic resume is an explicit, separate door:
  ``Session.restore_elastic``).

Durability contract (the chaos tests in tests/chaos/ enforce it):

* writes are atomic — both files land via write-to-temp + rename, and
  a failure anywhere in the write phase (including an injected fault in
  the ``repro.core.faults`` "commit" window) leaves the destination
  untouched and no temp files behind;
* the manifest carries a sha256 of the payload and of itself, so a
  truncated/torn .npz, a flipped byte, or a crash between the two
  renames is *detected* on load — every corruption path raises a typed
  ``CheckpointCorruptError`` naming the offending file, never a raw
  zipfile/JSON traceback (checkpoints written before the hashes existed
  still load; they just skip the integrity check).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path

import jax
import numpy as np

from repro.core import faults
from repro.obs import trace as obs_trace


class SpecMismatchError(ValueError):
    """A session checkpoint was opened under a different spec."""


class CheckpointCorruptError(ValueError):
    """A checkpoint on disk is unreadable or inconsistent — truncated
    payload, garbled/missing manifest, failed integrity hash, or the
    leftovers of an interrupted save."""


def _sha256_file(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _manifest_digest(manifest: dict) -> str:
    body = {k: v for k, v in manifest.items() if k != "manifest_sha256"}
    payload = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()


def _write_atomic(path: Path, npz_payload: dict, manifest: dict) -> None:
    """Commit (payload, manifest) under ``path`` (.npz/.json pair).

    Temps first, then two renames. The window between the renames is
    irreducible with two files, but never silent: the manifest's
    ``npz_sha256`` won't match a payload from a different save, so a
    crash there reads back as ``CheckpointCorruptError``, not as a
    plausible-but-wrong checkpoint. Any failure before the first rename
    (the ``faults`` "commit" site sits there) leaves the previous
    checkpoint intact and no temp files."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp_npz = path.with_suffix(".tmp.npz")
    tmp_json = path.with_suffix(".tmp.json")
    try:
        np.savez(tmp_npz, **npz_payload)
        manifest = dict(manifest)
        manifest["npz_sha256"] = _sha256_file(tmp_npz)
        manifest["manifest_sha256"] = _manifest_digest(manifest)
        tmp_json.write_text(json.dumps(manifest))
        faults.poke("commit", at=int(manifest.get("rounds_done", 0)), path=tmp_npz)
        os.replace(tmp_npz, path.with_suffix(".npz"))
        os.replace(tmp_json, path.with_suffix(".json"))
    except BaseException:
        tmp_npz.unlink(missing_ok=True)
        tmp_json.unlink(missing_ok=True)
        raise


def _read_manifest(manifest_path: Path, npz_path: Path) -> dict:
    """Parse + integrity-check a checkpoint manifest; verify the payload
    hash when the manifest carries one."""
    try:
        meta = json.loads(manifest_path.read_text())
    except (json.JSONDecodeError, UnicodeDecodeError, ValueError) as e:
        raise CheckpointCorruptError(
            f"{manifest_path}: garbled checkpoint manifest ({e})"
        ) from e
    if not isinstance(meta, dict):
        raise CheckpointCorruptError(
            f"{manifest_path}: checkpoint manifest is not an object"
        )
    stored = meta.get("manifest_sha256")
    if stored is not None and _manifest_digest(meta) != stored:
        raise CheckpointCorruptError(
            f"{manifest_path}: manifest integrity hash mismatch — the manifest "
            f"was modified after it was written"
        )
    expected = meta.get("npz_sha256")
    if expected is not None:
        actual = _sha256_file(npz_path)
        if actual != expected:
            raise CheckpointCorruptError(
                f"{npz_path}: payload integrity hash mismatch (truncated or torn "
                f"write, or a manifest from a different save)"
            )
    return meta


def _load_npz(npz_path: Path):
    try:
        return np.load(npz_path)
    except Exception as e:  # zipfile/pickle/OS errors — never surfaced raw
        raise CheckpointCorruptError(
            f"{npz_path}: unreadable checkpoint payload ({e})"
        ) from e


def _require_pair(path: Path) -> tuple[Path, Path]:
    """Resolve the (.npz, .json) pair; distinguish 'never written'
    (FileNotFoundError) from 'a save was interrupted here'
    (CheckpointCorruptError: half a pair, or only .tmp.* leftovers)."""
    path = Path(path)
    npz, manifest = path.with_suffix(".npz"), path.with_suffix(".json")
    if npz.exists() and manifest.exists():
        return npz, manifest
    stale = [p for p in (path.with_suffix(".tmp.npz"), path.with_suffix(".tmp.json"))
             if p.exists()]
    partial = [p for p in (npz, manifest) if p.exists()]
    if partial or stale:
        found = ", ".join(str(p) for p in partial + stale)
        raise CheckpointCorruptError(
            f"{path}: interrupted save — found {found} but no complete "
            f"checkpoint pair"
        )
    raise FileNotFoundError(f"no session checkpoint at {path}(.npz/.json)")


def _first_spec_diff(ck: dict, ours: dict, prefix: str = "") -> str | None:
    """First differing field between two spec dicts, depth-first in key
    order — the human-readable half of a SpecMismatchError."""
    for key in sorted(set(ck) | set(ours)):
        a, b = ck.get(key, "<absent>"), ours.get(key, "<absent>")
        if isinstance(a, dict) and isinstance(b, dict):
            sub = _first_spec_diff(a, b, prefix=f"{prefix}{key}.")
            if sub is not None:
                return sub
        elif a != b:
            return f"{prefix}{key}: checkpoint has {a!r}, session has {b!r}"
    return None


# ---------------- pytree checkpoints (NN training loop) ----------------


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str | os.PathLike, tree, step: int) -> None:
    flat = _flatten(tree)
    _write_atomic(Path(path), flat, {"step": step, "keys": sorted(flat)})


def restore_checkpoint(path: str | os.PathLike, tree_like):
    """Restore into the structure of ``tree_like``; returns (tree, step)
    or (None, 0) if absent. Corruption (truncated npz, garbled manifest)
    raises ``CheckpointCorruptError``, never a raw traceback."""
    path = Path(path)
    npz, manifest = path.with_suffix(".npz"), path.with_suffix(".json")
    if not npz.exists() or not manifest.exists():
        return None, 0
    meta = _read_manifest(manifest, npz)
    data = _load_npz(npz)
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    new_leaves = []
    for path_elems, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems)
        try:
            arr = data[key]
        except KeyError as e:
            raise CheckpointCorruptError(
                f"{npz}: checkpoint payload is missing key {key!r}"
            ) from e
        if arr.shape != leaf.shape:
            raise ValueError(f"checkpoint shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        new_leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta["step"]


# ---------------- session checkpoints (repro.api.Session) ----------------

_SESSION_FORMAT = "repro-session-v1"


@dataclasses.dataclass
class SessionCheckpoint:
    """One saved ``Session`` carry — everything needed to fast-forward a
    freshly built session to the interrupted round."""

    spec_dict: dict
    spec_hash: str
    rounds_done: int
    x: np.ndarray
    losses: np.ndarray
    wall_time_s: float
    compile_time_s: float


def save_session_checkpoint(
    path: str | os.PathLike,
    *,
    spec_dict: dict,
    spec_hash: str,
    rounds_done: int,
    x: np.ndarray,
    losses: np.ndarray,
    wall_time_s: float,
    compile_time_s: float,
) -> None:
    manifest = {
        "format": _SESSION_FORMAT,
        "spec": spec_dict,
        "spec_hash": spec_hash,
        "rounds_done": int(rounds_done),
        "wall_time_s": float(wall_time_s),
        "compile_time_s": float(compile_time_s),
    }
    payload = {
        "x": np.asarray(x),
        "losses": np.asarray(losses, np.float32),
    }
    path = Path(path)
    with obs_trace.span("ckpt_save", name=path.name, rounds_done=int(rounds_done)):
        _write_atomic(path, payload, manifest)
    # chaos seam: a "save"-site ckpt_truncate tears the durable payload
    # here — the integrity hash must catch it on the next restore.
    faults.poke("save", at=int(rounds_done), path=path.with_suffix(".npz"))


def load_session_checkpoint(
    path: str | os.PathLike,
    expect_spec_hash: str | None = None,
    expect_spec_dict: dict | None = None,
) -> SessionCheckpoint:
    """Load a session checkpoint; with ``expect_spec_hash``, refuse
    (``SpecMismatchError``) if the checkpoint was written under a
    different spec. ``expect_spec_dict`` (the expecting spec's
    ``to_dict()``) upgrades that error from bare hashes to the first
    differing spec field."""
    path = Path(path)
    with obs_trace.span("ckpt_verify", name=path.name):
        npz, manifest = _require_pair(path)
        meta = _read_manifest(manifest, npz)
    if meta.get("format") != _SESSION_FORMAT:
        raise CheckpointCorruptError(
            f"{path}: not a session checkpoint (format={meta.get('format')!r})"
        )
    if expect_spec_hash is not None and meta.get("spec_hash") != expect_spec_hash:
        detail = ""
        if expect_spec_dict is not None and isinstance(meta.get("spec"), dict):
            diff = _first_spec_diff(meta["spec"], expect_spec_dict)
            detail = (
                f"; first differing field — {diff}"
                if diff is not None
                else "; spec fields agree — the hash inputs drifted"
            )
        raise SpecMismatchError(
            f"{path}: checkpoint was written under spec hash "
            f"{meta.get('spec_hash')} but the session's spec hashes to "
            f"{expect_spec_hash}{detail} — a checkpoint only resumes into the "
            f"exact spec that wrote it (use Session.restore_elastic to re-shape "
            f"a run deliberately)"
        )
    data = _load_npz(npz)
    try:
        x, losses = data["x"], data["losses"]
        return SessionCheckpoint(
            spec_dict=meta["spec"],
            spec_hash=meta["spec_hash"],
            rounds_done=int(meta["rounds_done"]),
            x=x,
            losses=losses,
            wall_time_s=float(meta["wall_time_s"]),
            compile_time_s=float(meta["compile_time_s"]),
        )
    except KeyError as e:
        raise CheckpointCorruptError(
            f"{path}: checkpoint is missing field {e.args[0]!r}"
        ) from e


def load_model_weights(path: str | os.PathLike) -> tuple[np.ndarray, dict]:
    """Swap-safe read of a session checkpoint's *weights only* — the
    serving plane's hot-swap door (``repro.serve.ModelStore``).

    Integrity is verified exactly like a full restore (manifest
    self-hash + npz sha256), so a torn or truncated checkpoint raises
    ``CheckpointCorruptError`` *before* any weight byte is trusted — a
    swap either installs a fully verified model or changes nothing. No
    Session is rebuilt: the returned manifest dict carries the spec,
    its hash, and ``rounds_done`` for staleness accounting."""
    path = Path(path)
    with obs_trace.span("ckpt_verify", name=path.name):
        npz, manifest = _require_pair(path)
        meta = _read_manifest(manifest, npz)
    if meta.get("format") != _SESSION_FORMAT:
        raise CheckpointCorruptError(
            f"{path}: not a session checkpoint (format={meta.get('format')!r})"
        )
    data = _load_npz(npz)
    try:
        x = np.asarray(data["x"])
    except KeyError as e:
        raise CheckpointCorruptError(
            f"{path}: checkpoint is missing field 'x'"
        ) from e
    return x, meta


def discard_session_checkpoint(path: str | os.PathLike) -> None:
    """Remove a session checkpoint (durable pair + any stale temps) —
    what retry logic does with a checkpoint that failed to load."""
    path = Path(path)
    for suffix in (".npz", ".json", ".tmp.npz", ".tmp.json"):
        path.with_suffix(suffix).unlink(missing_ok=True)
