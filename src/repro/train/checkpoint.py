"""Checkpointing: flat-npz save/restore of arbitrary pytrees.

No external deps (no orbax): the tree is flattened with '/'-joined key
paths into a single .npz plus a small JSON manifest for the treedef.
Atomic via write-to-temp + rename.
"""

from __future__ import annotations

import json
import os
from pathlib import Path

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str | os.PathLike, tree, step: int) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp.npz")
    flat = _flatten(tree)
    np.savez(tmp, **flat)
    manifest = {"step": step, "keys": sorted(flat)}
    tmp_manifest = path.with_suffix(".tmp.json")
    tmp_manifest.write_text(json.dumps(manifest))
    os.replace(tmp, path.with_suffix(".npz"))
    os.replace(tmp_manifest, path.with_suffix(".json"))


def restore_checkpoint(path: str | os.PathLike, tree_like):
    """Restore into the structure of ``tree_like``; returns (tree, step)
    or (None, 0) if absent."""
    path = Path(path)
    npz, manifest = path.with_suffix(".npz"), path.with_suffix(".json")
    if not npz.exists() or not manifest.exists():
        return None, 0
    data = np.load(npz)
    meta = json.loads(manifest.read_text())
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    new_leaves = []
    for path_elems, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"checkpoint shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        new_leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta["step"]
