"""PredictionService — batched predict() with request micro-batching.

Requests (from any thread, or from the HTTP front below) enqueue their
rows; one batcher thread drains the queue, coalescing everything that
arrives within ``max_wait_s`` of the first pending request (up to
``max_batch_rows``) into a *single* ``ModelStore.predict`` over one
pinned model snapshot. Heavy concurrent traffic therefore amortizes to
one matvec batch per tick, and every row in a coalesced batch is served
by the same model version — a hot swap lands between batches, never
inside one.

Two fronts, one batcher:

* in-process — ``service.predict(indices, values)`` (what the
  controller, tests, and benchmarks use; no sockets);
* HTTP — ``serve_http(service, port=0)``: a stdlib
  ``ThreadingHTTPServer`` with ``POST /predict``, ``GET /healthz``,
  ``GET /stats`` (no external deps).
"""

from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

__all__ = ["PredictResult", "PredictionService", "serve_http"]


@dataclasses.dataclass(frozen=True)
class PredictResult:
    """One request's answer: margins ``x·a`` per row, hard labels
    (sign, 0 → +1), and the model version that computed them."""

    margins: np.ndarray
    labels: np.ndarray
    model_version: int


@dataclasses.dataclass
class _Pending:
    indices: np.ndarray
    values: np.ndarray
    done: threading.Event
    result: PredictResult | None = None
    error: BaseException | None = None


class PredictionService:
    """The request micro-batcher over a ``ModelStore``.

    max_batch_rows  coalesce at most this many rows into one predict.
    max_wait_s      after the first pending request arrives, wait up to
                    this long for more before computing (the batching
                    window; latency floor under light load).
    """

    def __init__(self, store, max_batch_rows: int = 256, max_wait_s: float = 0.002):
        if max_batch_rows < 1:
            raise ValueError(f"max_batch_rows={max_batch_rows} must be ≥ 1")
        self.store = store
        self.max_batch_rows = int(max_batch_rows)
        self.max_wait_s = float(max_wait_s)
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._started_at = 0.0
        # counters (read by stats(); single-writer from the batcher)
        self.rows_served = 0
        self.batches = 0
        self.errors = 0

    # ---- lifecycle ----

    def start(self) -> "PredictionService":
        if self._thread is not None:
            raise RuntimeError("PredictionService already started")
        self._started_at = time.monotonic()
        self._thread = threading.Thread(
            target=self._batch_loop, name="predict-batcher", daemon=True
        )
        self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    def __enter__(self) -> "PredictionService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ---- the request door ----

    def predict(
        self, indices, values, timeout: float | None = 10.0
    ) -> PredictResult:
        """Enqueue (B, width) ELL rows and wait for the coalesced
        answer. Thread-safe; rows from concurrent callers share one
        model application."""
        if self._thread is None:
            raise RuntimeError("PredictionService not started — use it as a context manager")
        indices = np.atleast_2d(np.asarray(indices, np.int32))
        values = np.atleast_2d(np.asarray(values, np.float32))
        if indices.shape != values.shape:
            raise ValueError(f"indices {indices.shape} != values {values.shape}")
        pending = _Pending(indices=indices, values=values, done=threading.Event())
        self._q.put(pending)
        if not pending.done.wait(timeout):
            raise TimeoutError(f"prediction not answered within {timeout}s")
        if pending.error is not None:
            raise pending.error
        return pending.result

    # ---- the batcher ----

    def _batch_loop(self) -> None:
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            rows = first.indices.shape[0]
            deadline = time.monotonic() + self.max_wait_s
            while rows < self.max_batch_rows:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=remaining)
                except queue.Empty:
                    break
                batch.append(nxt)
                rows += nxt.indices.shape[0]
            self._answer(batch)

    def _answer(self, batch: list[_Pending]) -> None:
        # runs on the batcher thread: spans land via the trace seam's
        # module-level fallback; counters feed the serve gauges too.
        reg = obs_metrics.registry()
        total_rows = sum(p.indices.shape[0] for p in batch)
        try:
            with obs_trace.span("predict_batch", name=f"batch[{self.batches}]",
                                rows=int(total_rows), requests=len(batch)):
                width = max(p.indices.shape[1] for p in batch)
                idx = np.zeros((total_rows, width), np.int32)
                val = np.zeros_like(idx, dtype=np.float32)
                r = 0
                for p in batch:
                    b, w = p.indices.shape
                    idx[r : r + b, :w] = p.indices
                    val[r : r + b, :w] = p.values
                    r += b
                margins, version = self.store.predict(idx, val)
                labels = np.where(margins >= 0.0, 1.0, -1.0).astype(np.float32)
            r = 0
            for p in batch:
                b = p.indices.shape[0]
                p.result = PredictResult(
                    margins=margins[r : r + b],
                    labels=labels[r : r + b],
                    model_version=version,
                )
                r += b
            self.rows_served += r
            self.batches += 1
            reg.counter("serve.rows_served_total").inc(r)
            reg.counter("serve.batches_total").inc()
            reg.histogram("serve.batch_rows").observe(r)
        except BaseException as e:
            self.errors += 1
            reg.counter("serve.errors_total").inc()
            for p in batch:
                p.error = e
        finally:
            for p in batch:
                p.done.set()

    # ---- per-stage metrics ----

    def stats(self) -> dict:
        elapsed = max(time.monotonic() - self._started_at, 1e-9)
        return {
            "rows_served": self.rows_served,
            "batches": self.batches,
            "errors": self.errors,
            "mean_batch_rows": self.rows_served / max(self.batches, 1),
            "predictions_per_sec": self.rows_served / elapsed,
            "model_version": self.store.version,
        }


# ---------------- stdlib HTTP front ----------------


def _rows_to_arrays(rows: list[dict]) -> tuple[np.ndarray, np.ndarray]:
    """JSON rows [{"idx": [...], "val": [...]}, ...] → padded ELL."""
    if not rows:
        raise ValueError("empty rows")
    width = max(max(len(r.get("idx", [])), 1) for r in rows)
    idx = np.zeros((len(rows), width), np.int32)
    val = np.zeros((len(rows), width), np.float32)
    for i, r in enumerate(rows):
        ri, rv = r.get("idx", []), r.get("val", [])
        if len(ri) != len(rv):
            raise ValueError(f"row {i}: idx/val length mismatch")
        idx[i, : len(ri)] = ri
        val[i, : len(rv)] = rv
    return idx, val


def serve_http(
    service: PredictionService, host: str = "127.0.0.1", port: int = 0
) -> tuple[ThreadingHTTPServer, threading.Thread]:
    """Expose a started ``PredictionService`` over HTTP. Returns the
    server (``server.server_address`` carries the bound port — pass
    ``port=0`` for an ephemeral one) and its daemon thread; call
    ``server.shutdown()`` to stop."""

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *args):  # quiet by default
            pass

        def _send(self, code: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            if self.path == "/healthz":
                self._send(200, {"ok": True, "model_version": service.store.version})
            elif self.path == "/stats":
                self._send(
                    200, {"service": service.stats(), "store": service.store.stats()}
                )
            else:
                self._send(404, {"error": f"unknown path {self.path}"})

        def do_POST(self):
            if self.path != "/predict":
                self._send(404, {"error": f"unknown path {self.path}"})
                return
            try:
                length = int(self.headers.get("Content-Length", 0))
                payload = json.loads(self.rfile.read(length) or b"{}")
                idx, val = _rows_to_arrays(payload.get("rows", []))
                res = service.predict(idx, val)
                self._send(
                    200,
                    {
                        "labels": res.labels.tolist(),
                        "margins": res.margins.tolist(),
                        "model_version": res.model_version,
                    },
                )
            except (ValueError, KeyError, json.JSONDecodeError) as e:
                self._send(400, {"error": str(e)})
            except RuntimeError as e:  # e.g. empty store
                self._send(503, {"error": str(e)})

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(
        target=server.serve_forever, name="predict-http", daemon=True
    )
    thread.start()
    return server, thread
