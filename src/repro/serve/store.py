"""ModelStore — the serving side's model holder, hot-swappable.

The prediction service reads models from here; the training side
publishes into it. The two never share mutable state: a published model
is an immutable ``ModelSnapshot`` (read-only weight buffer), and a swap
is one atomic reference assignment under a lock — a reader either sees
the whole previous model or the whole next one, never a mix.

The hot-swap door is ``swap_from_checkpoint``: weights come from a PR 6
integrity-hashed session checkpoint via
``repro.train.checkpoint.load_model_weights``, which verifies the
manifest self-hash and payload sha256 *before* anything is installed.
A corrupt/torn checkpoint raises and leaves the current model serving —
ingest and prediction never pause for a failed swap.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.train.checkpoint import load_model_weights

__all__ = ["ModelSnapshot", "ModelStore"]


@dataclasses.dataclass(frozen=True)
class ModelSnapshot:
    """One immutable served model.

    x            (n,) float32 weights — the buffer is frozen read-only.
    version      monotonically increasing store version.
    rounds_done  training rounds behind this model (staleness unit).
    spec_hash    content hash of the spec that trained it ("" if
                 published directly from weights).
    loaded_at    ``time.monotonic()`` at install (staleness in seconds).
    """

    x: np.ndarray
    version: int
    rounds_done: int = 0
    spec_hash: str = ""
    loaded_at: float = 0.0

    @property
    def n(self) -> int:
        return int(self.x.shape[0])

    def predict(self, indices: np.ndarray, values: np.ndarray) -> np.ndarray:
        """Batched margins for (B, width) ELL rows: Σ_w x[idx]·val.
        Padded slots (value 0) contribute nothing; ids must be < n."""
        indices = np.asarray(indices)
        values = np.asarray(values, np.float32)
        return np.einsum("rw,rw->r", self.x[indices], values)


class ModelStore:
    """Thread-safe holder of the current ``ModelSnapshot``.

    ``snapshot()`` hands out the current immutable model (readers pin it
    for their whole batch — a concurrent swap never tears a batch);
    ``publish``/``swap_from_checkpoint`` install the next one
    atomically. ``swaps`` counts successful installs,
    ``failed_swaps`` the rejected (corrupt) ones.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._snapshot: ModelSnapshot | None = None
        self.swaps = 0
        self.failed_swaps = 0

    # ---- read side ----

    def snapshot(self) -> ModelSnapshot:
        snap = self._snapshot  # atomic ref read
        if snap is None:
            raise RuntimeError("ModelStore is empty — publish or swap a model first")
        return snap

    @property
    def version(self) -> int:
        snap = self._snapshot
        return snap.version if snap is not None else 0

    def predict(self, indices: np.ndarray, values: np.ndarray) -> tuple[np.ndarray, int]:
        """Margins + the version that served them (one snapshot pin for
        the whole batch — never a torn model mid-batch)."""
        snap = self.snapshot()
        return snap.predict(indices, values), snap.version

    # ---- write side ----

    def publish(
        self, x: np.ndarray, rounds_done: int = 0, spec_hash: str = ""
    ) -> ModelSnapshot:
        """Install weights directly (initial model, tests). The buffer
        is copied and frozen — later writes by the publisher can't
        mutate a served model."""
        buf = np.array(x, np.float32, copy=True)
        buf.flags.writeable = False
        with self._lock:
            snap = ModelSnapshot(
                x=buf,
                version=self.version + 1,
                rounds_done=int(rounds_done),
                spec_hash=spec_hash,
                loaded_at=time.monotonic(),
            )
            self._snapshot = snap
            self.swaps += 1
        return snap

    def swap_from_checkpoint(self, path) -> ModelSnapshot:
        """Hot-swap from an integrity-hashed session checkpoint.
        Verification (manifest self-hash + payload sha256) happens
        before install; on ``CheckpointCorruptError`` the current model
        keeps serving untouched."""
        reg = obs_metrics.registry()
        try:
            with obs_trace.span("swap", name=str(getattr(path, "name", path))):
                x, meta = load_model_weights(path)
        except BaseException:
            self.failed_swaps += 1
            reg.counter("serve.failed_swaps_total").inc()
            raise
        reg.counter("serve.swaps_total").inc()
        return self.publish(
            x,
            rounds_done=int(meta.get("rounds_done", 0)),
            spec_hash=str(meta.get("spec_hash", "")),
        )

    def stats(self) -> dict:
        snap = self._snapshot
        return {
            "version": self.version,
            "swaps": self.swaps,
            "failed_swaps": self.failed_swaps,
            "rounds_done": snap.rounds_done if snap is not None else 0,
            "model_age_s": (
                time.monotonic() - snap.loaded_at if snap is not None else None
            ),
        }
