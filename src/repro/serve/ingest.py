"""Micro-batch → engine row-shards: the host-side glue between the
streaming data plane and the two executors.

A micro-batch arrives as one (rows, width) ELL block with global column
ids. One schedule round consumes ``p_r · τ · b`` rows (τ/s bundles of
s·b rows per team), so the batch reshapes into the executors' layouts:

* simulated — a per-round ``TeamProblem`` ``(p_r, τ·b, width)``: the
  engine's cyclic bundle slicing ``(k₀·s·b) mod m_local`` with
  ``m_local = τ·b`` walks the fresh rows exactly once per round, for
  *any* round index — streaming reuses the offline round body verbatim.
* shard_map — ``(p_r, p_c, τ·b, width)`` blocks with column ids locally
  renumbered per the session's ``ColumnPartition`` (same renumbering
  ``build_2d_problem`` applies to the resident dataset), padded to the
  shared ``width`` so the jitted step compiles once and is reused for
  every batch.

Shapes are fixed by the first batch; the session enforces them, so the
jit caches stay warm for the life of the stream.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.teams import TeamProblem
from repro.sparse.partition import ColumnPartition

__all__ = ["ColumnLocalizer", "stream_team_problem", "stream_shard_arrays"]


def stream_team_problem(batch, p_r: int, n: int, objective) -> TeamProblem:
    """One micro-batch as a p_r-team problem (simulated backend).

    Rows split contiguously across teams (row block i → team i), labels
    folded in (diag(y)·A), every row valid. ``m`` is the batch's true
    row count — only the loss probe reads it, and streaming sessions
    probe the resident holdout problem instead."""
    rows = batch.rows
    if rows % p_r:
        raise ValueError(f"batch rows={rows} not divisible by p_r={p_r}")
    rows_local = rows // p_r
    idx = batch.indices.reshape(p_r, rows_local, batch.width)
    val = batch.ya_values().reshape(p_r, rows_local, batch.width)
    return TeamProblem(
        indices=jnp.asarray(idx),
        values=jnp.asarray(val, jnp.float32),
        rows_valid=jnp.ones((p_r, rows_local), bool),
        p=p_r,
        m=rows,
        n=n,
        objective=objective,
    )


@dataclasses.dataclass
class ColumnLocalizer:
    """Global → (shard, local id) maps for one ``ColumnPartition``,
    built once per session and applied per micro-batch (vectorized
    lookups — no per-batch repartitioning)."""

    owner: np.ndarray  # (n,) int32 — shard owning each global column
    local: np.ndarray  # (n,) int32 — column's id inside its shard
    p_c: int

    @classmethod
    def from_partition(cls, cp: ColumnPartition) -> "ColumnLocalizer":
        n = int(cp.order.shape[0])
        owner = np.empty(n, np.int32)
        local = np.empty(n, np.int32)
        for j in range(cp.p):
            cols = cp.rank_cols(j)
            owner[cols] = j
            local[cols] = np.arange(len(cols), dtype=np.int32)
        return cls(owner=owner, local=local, p_c=cp.p)


def stream_shard_arrays(
    batch, loc: ColumnLocalizer, p_r: int, width: int
) -> tuple[np.ndarray, np.ndarray]:
    """One micro-batch as (indices, values) of shape
    ``(p_r, p_c, rows_local, width)`` with shard-local column ids —
    the operand layout ``make_hybrid_step`` maps over the mesh.

    ``width`` is the fixed per-shard ELL width (the batch width is an
    upper bound on any shard's per-row count, so reusing it keeps one
    static shape for every batch); overflow is impossible by
    construction, padding is id 0 + value 0.
    """
    rows = batch.rows
    if rows % p_r:
        raise ValueError(f"batch rows={rows} not divisible by p_r={p_r}")
    rows_local = rows // p_r
    p_c = loc.p_c
    owner = loc.owner[batch.indices]  # (rows, width)
    local = loc.local[batch.indices]
    ya = batch.ya_values()
    # padded slots (value 0) must stay inert on every shard: route them
    # to shard 0 / id 0 explicitly so a pad never lands a nonzero id.
    pad = batch.values == 0.0
    owner = np.where(pad, 0, owner)
    local = np.where(pad, 0, local)

    idx = np.zeros((p_r, p_c, rows_local, width), np.int32)
    val = np.zeros((p_r, p_c, rows_local, width), np.float32)
    for r in range(rows):
        ti, tr = divmod(r, rows_local)
        for j in range(p_c):
            sel = owner[r] == j
            sel &= ~pad[r]
            cnt = int(sel.sum())
            if cnt:
                idx[ti, j, tr, :cnt] = local[r][sel]
                val[ti, j, tr, :cnt] = ya[r][sel]
    return idx, val
