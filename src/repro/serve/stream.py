"""The streaming data plane: deterministic, replayable micro-batches.

The engine is already round-incremental (PR 3) — streaming training is
purely a missing *input* plane. One round of the (p_r, p_c, s, τ)
schedule consumes exactly ``p_r · τ · b`` sample rows, so a live stream
plugs in by micro-batching arrivals into fixed-shape ELL row-shards of
that size and handing each batch to ``Session.step_stream`` as one
round.

Determinism contract (what makes streaming fault-tolerant): micro-batch
``k`` is a pure function of ``(source config, seed, k)`` — never of
thread timing, queue depth, or how many batches were already drawn.
``micro_batches(start=k)`` therefore *replays* the identical suffix, so
a session resumed from a round-``k`` autosave re-attaches at batch ``k``
and continues the exact sequence: no duplicated and no dropped
micro-batch, enforced structurally (``MicroBatch.index`` must equal the
session's round counter — ``StreamDesyncError`` otherwise).

Sources:

* ``DriftStream``  — synthetic labeled examples from a hidden weight
  vector that flips at ``drift_at`` (concept shift); the time-to-adapt
  benchmark's generator.
* ``ReplayStream`` — cycles a registered synthetic dataset's rows; the
  bridge that feeds the *offline* matrices through the online path.
* ``repro.train.data.MarkovTextStream`` — the token stream conforms to
  the same protocol (its batches carry tokens, not sparse rows).

``StreamFeed`` is the ingest half of the serving plane: a producer
thread pulls a source into a bounded queue, so training backpressure
(queue full) and ingest lag are observable per-stage metrics instead of
hidden in iterator pull order.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Protocol, runtime_checkable

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

__all__ = [
    "MicroBatch",
    "StreamSource",
    "StreamDesyncError",
    "DriftStream",
    "ReplayStream",
    "StreamFeed",
    "make_stream_source",
]


class StreamDesyncError(RuntimeError):
    """A consumer received a micro-batch whose ``index`` does not match
    its position — a duplicated, dropped, or reordered batch. Raised
    instead of silently training on the wrong data."""


@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """One fixed-shape micro-batch of labeled sparse examples.

    index    position in the stream (the replay key; equals the round
             that will consume it).
    indices  (rows, width) int32 global feature ids (ELL layout; id 0 +
             value 0 where padded — duplicates are legal, contributions
             sum).
    values   (rows, width) float32 feature values (labels NOT folded —
             ``ya_values`` gives the diag(y)·A form the solver wants).
    y        (rows,) float32 labels in {−1, +1}.
    """

    index: int
    indices: np.ndarray
    values: np.ndarray
    y: np.ndarray

    @property
    def rows(self) -> int:
        return int(self.indices.shape[0])

    @property
    def width(self) -> int:
        return int(self.indices.shape[1])

    def ya_values(self) -> np.ndarray:
        """Label-folded values: row i scaled by y_i (diag(y)·A), the
        layout both executors train on."""
        return (self.values * self.y[:, None]).astype(np.float32)


@runtime_checkable
class StreamSource(Protocol):
    """Anything that yields a deterministic, replayable batch sequence.

    ``micro_batches(start)`` must yield batch ``start``, ``start+1``, …
    with each batch a pure function of the source's configuration and
    its index — two iterators from equal sources are elementwise
    identical, regardless of interleaving.
    """

    def micro_batches(self, start: int = 0) -> Iterator:
        ...


@dataclasses.dataclass(frozen=True)
class DriftStream:
    """Synthetic labeled stream with one concept shift.

    Examples are sparse rows with exactly ``width`` active features
    (ids Zipf-skewed like the offline synthetic datasets when
    ``alpha > 0``); labels are sampled from a logistic model on a hidden
    weight vector ``w`` that *flips sign* at batch ``drift_at``
    (``drift_mode="flip"`` — every learned margin inverts, the hardest
    useful shift) or is redrawn independently (``"rotate"``).

    Batch ``k`` derives every array from ``default_rng([seed, k])`` —
    pure in (config, seed, k), so replay-from-k is exact.
    """

    n: int
    rows: int
    width: int = 16
    seed: int = 0
    drift_at: int = 0  # batch index of the shift; 0 = never drifts
    drift_mode: str = "flip"
    alpha: float = 0.6  # column-skew exponent (0 = uniform)
    margin_scale: float = 2.5

    def __post_init__(self):
        if self.n < 1 or self.rows < 1 or self.width < 1:
            raise ValueError(
                f"DriftStream needs n, rows, width ≥ 1, got "
                f"n={self.n} rows={self.rows} width={self.width}"
            )
        if self.drift_mode not in ("flip", "rotate"):
            raise ValueError(f"drift_mode={self.drift_mode!r} not in ('flip', 'rotate')")

    def truth(self, batch_index: int) -> np.ndarray:
        """The hidden concept at ``batch_index`` (pre/post drift)."""
        w0 = self._base_truth(0)
        if not self.drift_at or batch_index < self.drift_at:
            return w0
        return -w0 if self.drift_mode == "flip" else self._base_truth(1)

    def _col_p(self) -> np.ndarray | None:
        if not self.alpha:
            return None
        p = np.arange(1, self.n + 1, dtype=np.float64) ** (-self.alpha)
        return p / p.sum()

    def _base_truth(self, which: int) -> np.ndarray:
        rng = np.random.default_rng([self.seed, 0x7A57E, which])
        w = np.zeros(self.n, np.float64)
        # support drawn from the *feature frequency* distribution, so
        # typical rows actually touch signal-carrying features (a
        # uniform support on a Zipf-skewed stream leaves most rows with
        # zero margin — unlearnable coin flips).
        size = min(max(self.n // 50, 8), self.n)
        support = rng.choice(self.n, size=size, replace=False, p=self._col_p())
        w[support] = rng.standard_normal(len(support)) * 3.0
        return w

    def batch(self, k: int) -> MicroBatch:
        """Micro-batch ``k`` — pure in (self, k)."""
        rng = np.random.default_rng([self.seed, int(k)])
        p = self._col_p()
        if p is not None:
            idx = rng.choice(self.n, size=(self.rows, self.width), p=p)
        else:
            idx = rng.integers(0, self.n, size=(self.rows, self.width))
        idx = idx.astype(np.int32)
        val = (rng.standard_normal((self.rows, self.width)) / np.sqrt(self.width)).astype(
            np.float32
        )
        w = self.truth(k)
        margins = np.einsum("rw,rw->r", val.astype(np.float64), w[idx])
        std = max(float(np.abs(margins).mean()), 1e-9)
        logits = self.margin_scale * margins / std
        prob = 1.0 / (1.0 + np.exp(-logits))
        y = np.where(rng.random(self.rows) < prob, 1.0, -1.0).astype(np.float32)
        return MicroBatch(index=int(k), indices=idx, values=val, y=y)

    def micro_batches(self, start: int = 0) -> Iterator[MicroBatch]:
        k = int(start)
        while True:
            yield self.batch(k)
            k += 1


@dataclasses.dataclass(frozen=True)
class ReplayStream:
    """Cycle a registered synthetic dataset's rows as micro-batches —
    the offline matrices fed through the online path (batch k = rows
    ``[k·rows, (k+1)·rows)`` of diag-less A, cyclically; deterministic
    trivially, since the dataset is deterministic in (name, seed))."""

    dataset: str
    rows: int
    seed: int = 0
    width: int | None = None  # None → the dataset's max nnz/row

    def _materialize(self):
        # lazy so the serving plane can be imported without jax/dataset
        # machinery; the dataset cache is shared with the offline path.
        from repro.api.run import _cached_dataset

        return _cached_dataset(self.dataset, seed=self.seed)

    def batch(self, k: int) -> MicroBatch:
        ds = self._materialize()
        a, y = ds.A, ds.y
        w = self.width or max(int(a.nnz_per_row.max()), 1)
        idx = np.zeros((self.rows, w), np.int32)
        val = np.zeros((self.rows, w), np.float32)
        yy = np.empty(self.rows, np.float32)
        for r in range(self.rows):
            src = (k * self.rows + r) % a.m
            lo, hi = int(a.indptr[src]), int(a.indptr[src + 1])
            cnt = min(hi - lo, w)
            idx[r, :cnt] = a.indices[lo : lo + cnt]
            val[r, :cnt] = a.data[lo : lo + cnt]
            yy[r] = y[src]
        return MicroBatch(index=int(k), indices=idx, values=val, y=yy)

    def micro_batches(self, start: int = 0) -> Iterator[MicroBatch]:
        k = int(start)
        while True:
            yield self.batch(k)
            k += 1


class StreamFeed:
    """Bounded-queue ingest: a producer thread pulls a ``StreamSource``
    into a ``queue.Queue(capacity)``; the trainer consumes with
    ``get()``. Determinism is the *source's* job (batch k is pure in k),
    so the queue adds observability — ingest lag, depth, backpressure —
    without touching the replay contract.

    Use as a context manager, or call ``start()`` / ``close()``.
    """

    def __init__(self, source: StreamSource, start: int = 0, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be ≥ 1")
        self.source = source
        self.start_index = int(start)
        self.capacity = int(capacity)
        self._q: queue.Queue = queue.Queue(maxsize=capacity)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self.produced = 0
        self.consumed = 0

    # ---- lifecycle ----

    def start(self) -> "StreamFeed":
        if self._thread is not None:
            raise RuntimeError("StreamFeed already started")
        self._thread = threading.Thread(
            target=self._produce, name="stream-feed", daemon=True
        )
        self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        # unblock a producer stuck on a full queue
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    def __enter__(self) -> "StreamFeed":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ---- the two ends ----

    def _produce(self) -> None:
        # runs on the producer thread: the trace seam's module-level
        # fallback makes an install()-ed recorder visible here, and the
        # queue-depth gauge is the serving plane's backpressure signal.
        depth = obs_metrics.registry().gauge("stream.queue_depth")
        try:
            it = self.source.micro_batches(self.start_index)
            while not self._stop.is_set():
                with obs_trace.span("ingest", name="produce",
                                    index=self.start_index + self.produced):
                    batch = next(it)
                while not self._stop.is_set():
                    try:
                        self._q.put(batch, timeout=0.05)
                        self.produced += 1
                        depth.set(self._q.qsize())
                        break
                    except queue.Full:
                        continue  # backpressure: trainer is behind
        except StopIteration:
            return  # a finite source ran dry — a clean end of stream
        except BaseException as e:  # surfaced to the consumer on get()
            self._error = e

    def get(self, timeout: float | None = 30.0) -> MicroBatch:
        """Next micro-batch (blocks up to ``timeout``); re-raises a
        producer-side error here, on the consumer thread."""
        try:
            batch = self._q.get(timeout=timeout)
        except queue.Empty:
            if self._error is not None:
                raise RuntimeError("stream producer failed") from self._error
            raise TimeoutError(
                f"no micro-batch arrived within {timeout}s (queue empty, "
                f"produced={self.produced})"
            ) from None
        self.consumed += 1
        obs_metrics.registry().gauge("stream.queue_depth").set(self._q.qsize())
        return batch

    # ---- per-stage metrics ----

    @property
    def queue_depth(self) -> int:
        return self._q.qsize()

    @property
    def ingest_lag(self) -> int:
        """Batches produced but not yet consumed (bounded by capacity)."""
        return self.produced - self.consumed

    def stats(self) -> dict:
        return {
            "produced": self.produced,
            "consumed": self.consumed,
            "queue_depth": self.queue_depth,
            "ingest_lag": self.ingest_lag,
            "capacity": self.capacity,
        }


def make_stream_source(spec) -> StreamSource:
    """Build the spec's declared stream source (``spec.stream``): the
    feature dimension comes from the spec's dataset registry entry, the
    rows-per-round from the schedule (one round's consumption)."""
    from repro.sparse.synthetic import dataset_stats

    st = spec.stream
    if not st.enabled:
        raise ValueError(
            "spec has no stream attached (stream.source='') — set "
            "stream=StreamSpec(source='drift'|'replay')"
        )
    rows = spec.stream_rows_per_round()
    if st.source == "drift":
        return DriftStream(
            n=dataset_stats(spec.dataset).n,
            rows=rows,
            width=st.width,
            seed=st.seed,
            drift_at=st.drift_at,
        )
    if st.source == "replay":
        return ReplayStream(dataset=spec.dataset, rows=rows, seed=spec.seed)
    raise ValueError(f"unknown stream source {st.source!r}")
