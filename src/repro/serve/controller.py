"""OnlineController — serve and train on one process, one Session.

The interleave loop of the serving plane: a ``StreamFeed`` ingests
micro-batches, ``Session.step_stream`` trains one round per batch, and
on freshness boundaries the controller publishes the current weights to
the ``ModelStore`` the prediction service reads from. Ingest never
pauses for a swap — the swap path is checkpoint-shaped
(``session.save`` → ``store.swap_from_checkpoint``), so every served
model went through the integrity-hashed durable format and a torn or
corrupt model can never install.

Freshness policy (when the served model refreshes):

* ``swap_every`` — every k training rounds (the steady-state cadence;
  defaults to the spec's ``stream.swap_every``);
* ``swap_at_loss`` — additionally as soon as a sampled holdout loss
  crosses this target (publish the recovered model immediately after a
  drift instead of waiting out the cadence);
* a final swap when the run ends, so the store never lags the trainer
  at rest.

``metrics()`` reports the per-stage health the ISSUE asks for: ingest
lag and queue depth (stream), rounds/sec (train), predictions/sec
(serve), and staleness (rounds the served model trails the trainer).
"""

from __future__ import annotations

import dataclasses
import tempfile
import time
from pathlib import Path

__all__ = ["StageMetrics", "OnlineController"]

from repro.obs import metrics as obs_metrics
from repro.serve.stream import StreamFeed


@dataclasses.dataclass(frozen=True)
class StageMetrics:
    """One snapshot of the three stages (ingest / train / serve).

    Based on the ``repro.obs`` metrics registry: every field is also a
    registry gauge (``serve.stage.<field>``), published whenever the
    controller takes a snapshot, so the serving stages share the one
    process-wide telemetry home with train/sweep. ``to_dict()`` keys are
    unchanged (``bench_serve`` and the serve CLI read them)."""

    rounds_done: int
    rounds_per_sec: float
    last_loss: float | None
    ingest_lag: int
    queue_depth: int
    predictions_per_sec: float | None
    predictions_served: int | None
    staleness_rounds: int
    model_version: int
    swaps: int
    failed_swaps: int

    def publish(self, registry: obs_metrics.MetricsRegistry | None = None) -> None:
        """Mirror every (non-None) field into ``serve.stage.*`` gauges."""
        reg = obs_metrics.registry() if registry is None else registry
        for field, value in dataclasses.asdict(self).items():
            if value is not None:
                reg.gauge(f"serve.stage.{field}").set(value)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class OnlineController:
    """Drive one ``Session`` from a stream while a ``ModelStore`` (and
    optionally a started ``PredictionService``) serves beside it.

    session     the (streaming-spec) Session to train.
    source      a ``StreamSource``; wrapped in a ``StreamFeed`` anchored
                at ``session.rounds_done`` (resume-safe by construction).
    store       the ``ModelStore`` predictions read from; seeded with
                the session's current weights so serving starts at
                round 0 (version 1 = the unswapped initial model).
    service     optional ``PredictionService`` (only read for metrics —
                the controller never blocks on the serve side).
    swap_every  override the spec's ``stream.swap_every`` cadence.
    swap_dir    where swap checkpoints land (a tempdir when omitted).
    swap_at_loss  also swap immediately when a sampled loss ≤ this.
    """

    def __init__(
        self,
        session,
        source,
        store,
        service=None,
        swap_every: int | None = None,
        swap_dir=None,
        swap_at_loss: float | None = None,
    ):
        self.session = session
        self.store = store
        self.service = service
        st = session.spec.stream
        self.swap_every = st.swap_every if swap_every is None else int(swap_every)
        self.swap_at_loss = swap_at_loss
        self.swap_dir = Path(
            tempfile.mkdtemp(prefix="repro-swap-") if swap_dir is None else swap_dir
        )
        self.source = source
        self.feed = StreamFeed(
            source, start=session.rounds_done, capacity=st.queue_capacity
        )
        self.events: list = []
        self.swap_rounds: list[int] = []
        self._train_seconds = 0.0
        self._rounds_run = 0
        self._feed_started = False
        # serve from round 0: the initial weights are a valid (if
        # untrained) model, and a target-loss swap may never fire.
        self.store.publish(
            session.current_x(),
            rounds_done=session.rounds_done,
            spec_hash=session.input_spec.content_hash(),
        )

    # ---- the interleave loop ----

    def _swap(self) -> None:
        path = self.swap_dir / f"swap-{self.session.rounds_done}"
        self.session.save(path)
        self.store.swap_from_checkpoint(path)
        self.swap_rounds.append(self.session.rounds_done)

    def _ensure_feed(self) -> None:
        if self._feed_started:
            return
        if self.feed._thread is not None:
            # a closed feed's producer is gone — re-anchor a fresh one
            # at the current round (sources replay, so the sequence
            # continues exactly where the previous feed left off).
            self.feed = StreamFeed(
                self.source,
                start=self.session.rounds_done,
                capacity=self.session.spec.stream.queue_capacity,
            )
        self.feed.start()
        self._feed_started = True

    def step(self):
        """One stream round + the freshness policy. Returns the
        session's ``RoundEvent`` (callers interleave probes/logging
        between steps; ``run`` is the no-frills loop over this)."""
        self._ensure_feed()
        t0 = time.perf_counter()
        ev = self.session.step_stream(self.feed, 1)
        self._train_seconds += time.perf_counter() - t0
        self.events.append(ev)
        self._rounds_run += 1
        if self.swap_every and self.session.rounds_done % self.swap_every == 0:
            self._swap()
        elif (
            self.swap_at_loss is not None
            and ev.loss is not None
            and ev.loss <= self.swap_at_loss
            and self.store.snapshot().rounds_done < self.session.rounds_done
        ):
            self._swap()
        return ev

    def finish(self) -> StageMetrics:
        """Final swap (the store never lags the trainer at rest) + feed
        shutdown. Idempotent; returns the end-of-run metrics."""
        if self.store.snapshot().rounds_done < self.session.rounds_done:
            self._swap()
        if self._feed_started:
            self.feed.close()
            self._feed_started = False
        return self.metrics()

    def run(self, rounds: int | None = None) -> StageMetrics:
        """Train up to ``rounds`` stream rounds (default: the session's
        remaining budget), hot-swapping per the freshness policy, and
        finish with a final swap. Returns the end-of-run metrics."""
        remaining = self.session.total_rounds - self.session.rounds_done
        rounds = remaining if rounds is None else min(int(rounds), remaining)
        done = 0
        while done < rounds and not self.session.done:
            ev = self.step()
            done += 1
            if ev.stop:
                break
        return self.finish()

    # ---- per-stage metrics ----

    def metrics(self) -> StageMetrics:
        svc = self.service.stats() if self.service is not None else None
        snap = self.store.snapshot()
        m = StageMetrics(
            rounds_done=self.session.rounds_done,
            rounds_per_sec=(
                self._rounds_run / self._train_seconds if self._train_seconds else 0.0
            ),
            last_loss=self.session.losses[-1] if self.session.losses else None,
            ingest_lag=self.feed.ingest_lag,
            queue_depth=self.feed.queue_depth,
            predictions_per_sec=svc["predictions_per_sec"] if svc else None,
            predictions_served=svc["rows_served"] if svc else None,
            staleness_rounds=self.session.rounds_done - snap.rounds_done,
            model_version=snap.version,
            swaps=self.store.swaps,
            failed_swaps=self.store.failed_swaps,
        )
        m.publish()
        return m
