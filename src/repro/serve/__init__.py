"""repro.serve — the online serving plane.

The offline stack (spec → Session → rounds over a resident dataset)
gains its live half here:

* ``repro.serve.stream``      — the streaming data plane: a
  ``StreamSource`` protocol (deterministic, replayable micro-batches),
  a drifting synthetic generator for concept-shift benchmarks, and the
  bounded-queue ``StreamFeed`` that decouples ingest from training.
* ``repro.serve.store``       — ``ModelStore``: the serving-side model
  holder; hot-swaps weights from integrity-hashed session checkpoints
  without ever exposing a torn model.
* ``repro.serve.server``      — ``PredictionService``: batched
  ``predict()`` with request micro-batching, plus a stdlib-HTTP
  front (``serve_http``) for out-of-process clients.
* ``repro.serve.controller``  — ``OnlineController``: interleaves
  serve and train on one ``Session`` (train-on-arrival, freshness
  policy for hot swaps, per-stage metrics).

Entry point: ``python -m repro.launch.serve --spec spec.json``.
"""

from repro.serve.stream import (
    DriftStream,
    MicroBatch,
    ReplayStream,
    StreamDesyncError,
    StreamFeed,
    StreamSource,
    make_stream_source,
)
from repro.serve.store import ModelSnapshot, ModelStore
from repro.serve.server import PredictionService, PredictResult, serve_http
from repro.serve.controller import OnlineController, StageMetrics

__all__ = [
    "DriftStream",
    "MicroBatch",
    "ReplayStream",
    "StreamDesyncError",
    "StreamFeed",
    "StreamSource",
    "make_stream_source",
    "ModelSnapshot",
    "ModelStore",
    "PredictionService",
    "PredictResult",
    "serve_http",
    "OnlineController",
    "StageMetrics",
]
