"""Single point of contact with version-dependent JAX APIs.

The repo targets two JAX generations:

  * jax >= 0.6 — ``jax.shard_map(..., axis_names=..., check_vma=...)``,
    explicit meshes (``jax.make_mesh(..., axis_types=...)``,
    ``jax.sharding.set_mesh`` / ``get_abstract_mesh``).
  * jax 0.4.x — ``jax.experimental.shard_map.shard_map(..., auto=...,
    check_rep=...)``, legacy ``with mesh:`` contexts, no axis types.

Everything else in the codebase imports the mesh/shard_map surface from
here, never from ``jax`` directly, so the solver engine and the NN
trainer run unmodified on both generations.
"""

from __future__ import annotations

import contextlib
import threading

import jax

HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")
HAS_EXPLICIT_MESH = hasattr(jax.sharding, "set_mesh")
HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

# Trace-time stack of manual axis-name sets (legacy JAX only): the
# enclosing shard_map's manual axes cannot appear in a sharding
# constraint, and old meshes carry no axis_types to recover them from.
_local = threading.local()


def _manual_stack() -> list[frozenset[str]]:
    if not hasattr(_local, "stack"):
        _local.stack = []
    return _local.stack


def manual_axes(mesh=None) -> frozenset[str]:
    """Axis names currently Manual: from mesh.axis_types on new JAX,
    from the compat shard_map trace stack on old JAX."""
    if HAS_AXIS_TYPES and mesh is not None and hasattr(mesh, "axis_types"):
        return frozenset(
            name
            for name, ty in zip(mesh.axis_names, mesh.axis_types)
            if ty == jax.sharding.AxisType.Manual
        )
    acc: frozenset[str] = frozenset()
    for s in _manual_stack():
        acc = acc | s
    return acc


def shard_map(f, mesh, in_specs, out_specs, axis_names=None, check=False):
    """Version-portable shard_map.

    ``axis_names``: the *manual* axes (None = all mesh axes manual).
    ``check``: replication/VMA checking (off by default — the hybrid
    schedules intentionally let per-team params drift).
    """
    if HAS_JAX_SHARD_MAP:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check)
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map

    manual = frozenset(axis_names) if axis_names is not None else frozenset(mesh.axis_names)
    auto = frozenset(mesh.axis_names) - manual

    def traced(*args, **kw):
        _manual_stack().append(manual)
        try:
            return f(*args, **kw)
        finally:
            _manual_stack().pop()

    return _shard_map(
        traced, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check, auto=auto
    )


def axis_size(name) -> int:
    """Static size of a named mesh axis inside shard_map (jax.lax
    .axis_size where available, the tracing axis env otherwise)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    from jax._src import core as _core

    return int(_core.axis_frame(name))  # 0.4.x: returns the size


# ---------------------------------------------------------------------------
# mesh construction / ambient mesh
# ---------------------------------------------------------------------------


def abstract_mesh(shape, axes):
    """jax.sharding.AbstractMesh across both constructor generations
    ((sizes, names) on new JAX, ((name, size), ...) pairs on 0.4.x)."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_mesh(shape, axes, devices=None):
    """jax.make_mesh with Auto axis types where supported."""
    if HAS_AXIS_TYPES:
        return jax.make_mesh(
            shape, axes, devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(shape, axes, devices=devices)


class _AmbientMesh:
    """Fallback global for jax.sharding.set_mesh/get_abstract_mesh."""

    def __init__(self):
        self.mesh = None


_ambient = _AmbientMesh()


class _EmptyMesh:
    empty = True
    axis_names: tuple = ()
    axis_sizes: tuple = ()


def get_abstract_mesh():
    """The ambient mesh (an object with .empty/.axis_names/.axis_sizes)."""
    if HAS_EXPLICIT_MESH:
        return jax.sharding.get_abstract_mesh()
    if _ambient.mesh is not None:
        return _ambient.mesh
    return _EmptyMesh()


class _SetMeshHandle:
    """Mimics jax.sharding.set_mesh: applies immediately, optionally
    usable as a context manager to restore the previous mesh."""

    def __init__(self, mesh, prev):
        self._mesh = mesh
        self._prev = prev
        self._ctx = None
        if mesh is not None:
            self._ctx = mesh.__enter__()  # legacy `with mesh:` context

    def __enter__(self):
        return self._mesh

    def __exit__(self, *exc):
        if self._ctx is not None:
            args = exc if len(exc) == 3 else (None, None, None)
            self._mesh.__exit__(*args)
            self._ctx = None
        _ambient.mesh = self._prev
        return False


def set_mesh(mesh):
    """Set the ambient mesh (jax.sharding.set_mesh where available)."""
    if HAS_EXPLICIT_MESH:
        return jax.sharding.set_mesh(mesh)
    prev = _ambient.mesh
    _ambient.mesh = mesh
    return _SetMeshHandle(mesh, prev)


@contextlib.contextmanager
def use_mesh(mesh):
    """Scoped ambient mesh — always restores on exit."""
    handle = set_mesh(mesh)
    try:
        yield mesh
    finally:
        if not HAS_EXPLICIT_MESH:
            handle.__exit__(None, None, None)
        elif hasattr(handle, "__exit__"):
            handle.__exit__(None, None, None)
