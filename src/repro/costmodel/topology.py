"""Topology-respecting mesh rule (paper Eq. 7).

    p_c* = max(⌈n·w / L_cap⌉, min(R, p)),   p_r* = p / p_c*

Keep the *frequent* row-team (Gram) Allreduce inside the fast
communication domain (node ↦ pod): the measured β(q) is a step function
at the domain boundary q = R, so sliding p_c up to R monotonically cuts
the sync-BW term while staying on fast transport. The cache term raises
p_c above R only when the per-rank weight slab n·w/p_c would spill
L_cap at p_c = R. Only two machine constants (R, L_cap) are needed —
no α-β-γ calibration.
"""

from __future__ import annotations

import math

from repro.costmodel.machines import Machine


def topology_rule(p: int, n: int, machine: Machine) -> tuple[int, int]:
    """Return (p_r*, p_c*). p must be a power of two (meshes here are);
    p_c* is rounded up to the nearest power-of-two divisor of p."""
    if p & (p - 1):
        raise ValueError(f"p={p} must be a power of two")
    w = machine.word_bytes
    cache_term = math.ceil(n * w / machine.l_cap)
    p_c = max(cache_term, min(machine.ranks_per_domain, p))
    # round UP to a power-of-two divisor of p (≤ p)
    p_c = min(1 << math.ceil(math.log2(max(p_c, 1))), p)
    return p // p_c, p_c


def cache_term_binding(n: int, machine: Machine) -> bool:
    """True when the cache term (not R) sets p_c* (paper: non-binding on
    every LIBSVM dataset since n·w ≤ R·L_cap)."""
    return n * machine.word_bytes > machine.ranks_per_domain * machine.l_cap
