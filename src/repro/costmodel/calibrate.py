"""§6.5 closing the loop: fit machine constants from measured ledgers.

The static ``machines.py`` presets are the paper's Table 7 — measured
once, on their hardware. A *timed* run (``repro.core.comm``'s timed
collectives: the driver blocks per round and appends wall seconds to
the ``CommLedger``) carries everything needed to refit the Hockney
constants for the machine actually underneath:

    per-round wall  ≈  α·phases + β·bytes + γ·flops

where phases (2⌈log₂ span⌉ per collective call), bytes, and flops per
round are known exactly from the ledger's captured rates and the
dataset statistics. ``calibrate`` solves the least-squares system over
a set of measured points (ideally a sweep over schedules, so the three
columns are linearly independent), clamps negative coefficients to
zero, and returns a ``Calibration`` whose ``machine()`` re-targets any
preset — which ``repro.api.plan(spec, calibration=...)`` then uses to
rank configurations with machine-fitted constants instead of presets
(``repro.launch.sweep --calibrate report.json --plan-only`` end to
end).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.costmodel.machines import Machine

__all__ = ["CalPoint", "Calibration", "calibrate"]


@dataclasses.dataclass(frozen=True)
class CalPoint:
    """One measured operating point: the per-round regressors (from the
    comm ledger + dataset stats) and the measured per-round seconds
    (median over the timed rounds). ``label`` is carried for fit
    diagnostics only."""

    phases_per_round: float
    bytes_per_round: float
    flops_per_round: float
    seconds_per_round: float
    label: str = ""

    def __post_init__(self):
        if self.seconds_per_round <= 0 or not math.isfinite(self.seconds_per_round):
            raise ValueError(
                f"seconds_per_round={self.seconds_per_round} must be finite and > 0"
            )


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Fitted Hockney constants (zero = term not identifiable from the
    points given, e.g. a single-rank run has no comm columns).

    alpha    seconds per Allreduce phase.
    beta     seconds per byte on the wire.
    gamma    seconds per flop.
    rel_rms  relative RMS residual of the fit (‖Ax−t‖/‖t‖).
    points   how many measured points entered the fit.
    """

    alpha: float
    beta: float
    gamma: float
    rel_rms: float
    points: int

    def machine(self, base: Machine) -> Machine:
        """Re-target ``base`` with the fitted constants: flat (rank- and
        tier-independent) α/β/γ tables — the calibration measures one
        machine at one scale, so the fitted values apply at every span.
        Terms that did not fit (coefficient 0) keep the preset tables.
        """
        repl: dict = {"name": f"{base.name}+calibrated"}
        if self.alpha > 0:
            repl["alpha_intra"] = {1: self.alpha}
            repl["alpha_inter"] = {1: self.alpha}
        if self.beta > 0:
            repl["beta_intra"] = {1: self.beta}
            repl["beta_inter"] = {1: self.beta}
        if self.gamma > 0:
            # Machine stores γ as s/B tiers; γ_flop = γ_B·w/flops_per_word,
            # so invert to one flat tier reproducing the fitted s/flop.
            gamma_bytes = self.gamma * base.flops_per_word / base.word_bytes
            repl["gamma_tiers"] = ((1 << 62, gamma_bytes),)
        return dataclasses.replace(base, **repl)

    def summary(self) -> str:
        return (
            f"calibration over {self.points} point(s): α={self.alpha:.3g} s/phase, "
            f"β={self.beta:.3g} s/B, γ={self.gamma:.3g} s/flop "
            f"(rel. RMS {self.rel_rms:.2f})"
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Calibration":
        return cls(**d)


def calibrate(points: Sequence[CalPoint]) -> Calibration:
    """Least-squares fit of (α, β, γ) to the measured points.

    Columns that are identically zero across every point (e.g. no
    collective spanned >1 rank) are excluded and fit to 0; negative
    coefficients are clamped to zero and the remaining columns refit —
    a two-pass non-negativity good enough for ranking (the validated
    property of the refined model is ranking fidelity, §6.5)."""
    points = list(points)
    if not points:
        raise ValueError("calibrate needs at least one measured point")
    a = np.array(
        [[p.phases_per_round, p.bytes_per_round, p.flops_per_round] for p in points],
        dtype=np.float64,
    )
    t = np.array([p.seconds_per_round for p in points], dtype=np.float64)

    active = [j for j in range(3) if np.any(a[:, j] != 0.0)]
    coef = np.zeros(3)
    for _ in range(3):  # drop-negative refit passes
        if not active:
            break
        sol, *_ = np.linalg.lstsq(a[:, active], t, rcond=None)
        coef[:] = 0.0
        coef[active] = sol
        neg = [j for j in active if coef[j] < 0.0]
        if not neg:
            break
        coef[neg] = 0.0
        active = [j for j in active if j not in neg]
    resid = a @ coef - t
    denom = float(np.linalg.norm(t))
    rel = float(np.linalg.norm(resid) / denom) if denom else 0.0
    return Calibration(
        alpha=float(coef[0]),
        beta=float(coef[1]),
        gamma=float(coef[2]),
        rel_rms=rel,
        points=len(points),
    )
