"""α-β-γ cost model, refinements, topology rule, and selection API."""

from repro.costmodel.machines import MACHINES, PERLMUTTER, TPU_V5E, Machine
from repro.costmodel.hockney import (
    CommVolume,
    CostBreakdown,
    HybridConfig,
    fedavg_epoch_cost,
    hybrid_epoch_cost,
    mbsgd_epoch_cost,
    per_sample_costs,
    schedule_comm_volume,
    sstep_epoch_cost,
)
from repro.costmodel.calibrate import CalPoint, Calibration, calibrate
from repro.costmodel.optimum import (
    Regime,
    b_star,
    bandwidth_balance,
    classify_regime,
    grid_search_config,
    joint_sb_star,
    s_star,
)
from repro.costmodel.topology import cache_term_binding, topology_rule
from repro.costmodel.refine import (
    IterBreakdown,
    PartitionerProfile,
    predict_fedavg_iter,
    predict_hybrid_iter,
    rank_partitioners,
)

__all__ = [
    "MACHINES",
    "PERLMUTTER",
    "TPU_V5E",
    "Machine",
    "CalPoint",
    "Calibration",
    "calibrate",
    "CommVolume",
    "schedule_comm_volume",
    "CostBreakdown",
    "HybridConfig",
    "fedavg_epoch_cost",
    "hybrid_epoch_cost",
    "mbsgd_epoch_cost",
    "per_sample_costs",
    "sstep_epoch_cost",
    "Regime",
    "b_star",
    "bandwidth_balance",
    "classify_regime",
    "grid_search_config",
    "joint_sb_star",
    "s_star",
    "cache_term_binding",
    "topology_rule",
    "IterBreakdown",
    "PartitionerProfile",
    "predict_fedavg_iter",
    "predict_hybrid_iter",
    "rank_partitioners",
]
