"""Empirical refinements of the leading-order model (paper §6.5).

  * cache-aware compute: γ evaluated at the per-rank weight-slab working
    set (max n_local·w) — cache spill (nnz-greedy on url) lands in a
    slower tier;
  * rank-aware β: each Allreduce uses β(q) for its span (in Machine);
  * load imbalance: κ multiplies the sparse-compute term;
  * sync-skew: T ≈ (κ-1)·T_compute,avg charged to the row-team
    Allreduce — wait-for-slowest, not payload cost (paper Table 10);
  * per-call column-proportional floor: MKL sparse_syrkd's inspector and
    the transpose-SpMV scatter scale with n_local, not flops. The TPU
    analogue is index streaming + kernel launch; coefficient is a
    calibration knob (0 disables).

The refined predictor's validated property is *ranking fidelity* across
partitioners and meshes (paper: correct on all 9 dataset×partitioner
cells), not absolute seconds.
"""

from __future__ import annotations

import dataclasses
import math

from repro.costmodel.machines import Machine


@dataclasses.dataclass(frozen=True)
class PartitionerProfile:
    """What the refined model needs from a (dataset, partitioner, p_c)
    combination. Obtainable from repro.sparse.partition.partition_stats
    or taken from the paper's measured Table 9."""

    name: str
    kappa: float
    max_n_local: int


@dataclasses.dataclass(frozen=True)
class IterBreakdown:
    """Per-inner-iteration seconds (cf. paper Table 10 phases)."""

    compute: float  # SpMV + Gram + correction flops on the avg rank
    sync_skew: float  # (κ-1)·compute — waits inside the row Allreduce
    row_comm: float  # Gram/residual Allreduce payload+latency (per iter)
    col_comm: float  # weight-averaging Allreduce (amortized over τ)
    weights: float  # τ-amortized weight-vector access
    per_call: float  # column-proportional per-call floor

    @property
    def total(self) -> float:
        return self.compute + self.sync_skew + self.row_comm + self.col_comm + self.weights + self.per_call


def predict_hybrid_iter(
    n: int,
    zbar: float,
    prof: PartitionerProfile,
    p_r: int,
    p_c: int,
    s: int,
    b: int,
    tau: int,
    machine: Machine,
    percall_col_coeff: float = 4.0e-10,
) -> IterBreakdown:
    """Refined per-inner-iteration prediction for HybridSGD."""
    w = machine.word_bytes
    slab = prof.max_n_local * w  # per-rank weight working set
    gamma = machine.gamma_flop(slab)

    # average-rank compute per iteration: b rows, z̄/p_c nnz each after
    # column split, with the s-step extra 2sb correction flops
    compute = b * (6 * zbar / p_c + 2 * s * b) * gamma
    sync_skew = max(prof.kappa - 1.0, 0.0) * compute

    # row-team Allreduce, amortized per iteration: one (G, v) per bundle
    gram_words = (s - 1) * b * b / 2 + b  # tril Gram blocks + residual
    row_comm = machine.allreduce_time(p_c, int(gram_words)) / s if p_c > 1 else 0.0

    # column Allreduce of the n_local weight slab every τ iterations
    col_comm = machine.allreduce_time(p_r, prof.max_n_local) / tau if p_r > 1 else 0.0

    # cache-aware weight access: first touch at DRAM tier, the remaining
    # τ-1 inner iterations at the slab's cache tier (§6.5)
    gamma_dram = machine.gamma_tiers[-1][1]
    weights = slab * (gamma_dram + (tau - 1) * machine.gamma_bytes(slab)) / tau

    per_call = percall_col_coeff * prof.max_n_local
    return IterBreakdown(
        compute=compute,
        sync_skew=sync_skew,
        row_comm=row_comm,
        col_comm=col_comm,
        weights=weights,
        per_call=per_call,
    )


def predict_fedavg_iter(
    n: int,
    zbar: float,
    b: int,
    tau: int,
    p: int,
    machine: Machine,
    kappa: float = 1.0,
) -> float:
    """Refined per-inner-iteration prediction for FedAvg (1D-row)."""
    w = machine.word_bytes
    slab = n * w  # FedAvg keeps the full weight vector per rank
    gamma = machine.gamma_flop(slab)
    compute = b * 4 * zbar * gamma * kappa
    gamma_dram = machine.gamma_tiers[-1][1]
    weights = slab * (gamma_dram + (tau - 1) * machine.gamma_bytes(slab)) / tau
    col_comm = machine.allreduce_time(p, n) / tau if p > 1 else 0.0
    return compute + weights + col_comm


def rank_partitioners(
    n: int,
    zbar: float,
    profiles: list[PartitionerProfile],
    p_r: int,
    p_c: int,
    s: int,
    b: int,
    tau: int,
    machine: Machine,
    percall_col_coeff: float = 4.0e-10,
) -> list[tuple[str, IterBreakdown]]:
    """Order partitioners by predicted per-iteration time (ascending) —
    the selection decision the model drives (§6.5 Validation)."""
    preds = [
        (
            prof.name,
            predict_hybrid_iter(
                n, zbar, prof, p_r, p_c, s, b, tau, machine, percall_col_coeff
            ),
        )
        for prof in profiles
    ]
    return sorted(preds, key=lambda kv: kv[1].total)
