"""Closed-form optima and regime analysis (paper §6.3-6.4).

s* (Eq. 5) and b* (Eq. 6) minimize the convex A·x + B/x + C collection
of Eq. (4) terms; one fixed-point sweep couples them. The bandwidth
balance (s-1)s·b²·τ·p_c ≈ 2n separates the Gram-BW and sync-BW regimes
(Table 5).
"""

from __future__ import annotations

import dataclasses
import math

from repro.costmodel.hockney import CostBreakdown, HybridConfig, hybrid_epoch_cost, _log2
from repro.costmodel.machines import Machine


def s_star(b: int, tau: int, p_r: int, p_c: int, n: int, machine: Machine) -> float:
    """Eq. (5): s* = sqrt(B_s / A_s)."""
    p = p_r * p_c
    w = machine.word_bytes
    gamma = machine.gamma_flop(n * w / p_c)
    beta_row = machine.beta(p_c)
    beta_col = machine.beta(p_r)
    alpha_row, alpha_col = machine.alpha(p_c), machine.alpha(p_r)
    l_tilde_alpha = alpha_row * tau * _log2(p_c) + alpha_col * _log2(p_r)
    a_s = (2 * gamma / p + w * beta_row / 2) * b
    b_s = 2 * l_tilde_alpha / (b * tau) + n * w * beta_col / (b * tau * p_c)
    return math.sqrt(b_s / a_s) if a_s > 0 else float("inf")


def b_star(s: int, tau: int, p_r: int, p_c: int, n: int, machine: Machine) -> float:
    """Eq. (6)."""
    p = p_r * p_c
    w = machine.word_bytes
    gamma = machine.gamma_flop(n * w / p_c)
    beta_row = machine.beta(p_c)
    beta_col = machine.beta(p_r)
    alpha_row, alpha_col = machine.alpha(p_c), machine.alpha(p_r)
    l_tilde_alpha = alpha_row * tau * _log2(p_c) + alpha_col * _log2(p_r)
    num = 2 * l_tilde_alpha / tau + n * w * beta_col / (tau * p_c)
    den = (2 * gamma * s / p + (s - 1) * w * beta_row / 2) * s
    return math.sqrt(num / den) if den > 0 else float("inf")


def joint_sb_star(
    tau: int, p_r: int, p_c: int, n: int, machine: Machine, s0: int = 4, b0: int = 32
) -> tuple[float, float]:
    """One fixed-point iteration on (Eq. 5, Eq. 6), as the paper does."""
    s1 = s_star(b0, tau, p_r, p_c, n, machine)
    b1 = b_star(max(int(round(s1)), 1), tau, p_r, p_c, n, machine)
    return s1, b1


def bandwidth_balance(s: int, b: int, tau: int, p_c: int, n: int) -> float:
    """(s-1)·s·b²·τ·p_c / 2n — >1 means Gram-BW dominates, <1 sync-BW."""
    return (s - 1) * s * b * b * tau * p_c / (2 * n)


@dataclasses.dataclass(frozen=True)
class Regime:
    name: str  # compute | latency | gram_bw | sync_bw
    breakdown: CostBreakdown
    balance: float  # bandwidth balance ratio
    action: str


_ACTIONS = {
    "compute": "increase p; s, b secondary",
    "latency": "maximize s·b·τ; prefer large s, b",
    "gram_bw": "decrease s or b; FedAvg limit",
    "sync_bw": "increase τ or p_c",
}


def classify_regime(
    m: int, n: int, zbar: float, cfg: HybridConfig, machine: Machine
) -> Regime:
    """Table 5: the dominant Eq. (4) term names the operating regime."""
    cb = hybrid_epoch_cost(m, n, zbar, cfg, machine)
    name = cb.dominant
    return Regime(
        name=name,
        breakdown=cb,
        balance=bandwidth_balance(cfg.s, cfg.b, cfg.tau, cfg.p_c, n),
        action=_ACTIONS[name],
    )


def grid_search_config(
    m: int,
    n: int,
    zbar: float,
    p_r: int,
    p_c: int,
    machine: Machine,
    s_grid=(1, 2, 4, 8, 16, 32),
    b_grid=(8, 16, 32, 64, 128),
    tau_grid=(1, 5, 10, 20, 50),
) -> tuple[HybridConfig, CostBreakdown]:
    """Rank candidate (s, b, τ) at a fixed mesh by Eq. (4) — the model's
    selection-tool role (§6): ranking, not absolute runtime."""
    best = None
    for s in s_grid:
        for b in b_grid:
            for tau in tau_grid:
                if tau % s and tau >= s:
                    continue
                if tau < s:
                    continue
                cfg = HybridConfig(p_r=p_r, p_c=p_c, s=s, b=b, tau=tau)
                cb = hybrid_epoch_cost(m, n, zbar, cfg, machine)
                if best is None or cb.total < best[1].total:
                    best = (cfg, cb)
    assert best is not None
    return best
