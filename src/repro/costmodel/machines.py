"""Machine models: measured Perlmutter CPU (paper Table 7) and TPU v5e.

All cost-model formulas take a ``Machine`` so the paper's measured
constants reproduce its tables bit-for-bit, and the same formalism
retargets to the TPU pod geometry (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class Machine:
    """α(q): s per Allreduce *phase* over q ranks; β(q): s/B rank-aware
    Allreduce bandwidth; γ(W): s/B memory-access cost at working-set W
    bytes. ``ranks_per_domain`` is the paper's R (per-node rank count ↦
    per-pod device count on TPU); ``l_cap`` the per-core fast-memory
    capacity (L2 ↦ VMEM slab budget)."""

    name: str
    ranks_per_domain: int  # R
    l_cap: int  # bytes
    word_bytes: int
    flops_per_word: float  # γ_flop = flops_per_word⁻¹… see gamma_flop()
    peak_flops: float  # per rank (s⁻¹) — used for roofline-style checks
    alpha_intra: dict[int, float]  # ranks -> s
    alpha_inter: dict[int, float]
    beta_intra: dict[int, float]  # ranks -> s/B
    beta_inter: dict[int, float]
    gamma_tiers: tuple[tuple[int, float], ...]  # (max W bytes, s/B)

    # ---- parameter lookups (rank-aware β, cache-aware γ: §6.5) ----

    def _interp(self, table: dict[int, float], q: int) -> float:
        ks = sorted(table)
        if q <= ks[0]:
            return table[ks[0]]
        if q >= ks[-1]:
            return table[ks[-1]]
        # log-log interpolation between measured points
        lo = max(k for k in ks if k <= q)
        hi = min(k for k in ks if k >= q)
        if lo == hi:
            return table[lo]
        t = (math.log2(q) - math.log2(lo)) / (math.log2(hi) - math.log2(lo))
        return math.exp((1 - t) * math.log(table[lo]) + t * math.log(table[hi]))

    def alpha(self, q: int) -> float:
        """Per-phase latency of an Allreduce over q ranks."""
        if q <= 1:
            return 0.0
        if q <= self.ranks_per_domain:
            return self._interp(self.alpha_intra, q)
        return self._interp(self.alpha_inter, q)

    def beta(self, q: int) -> float:
        """Rank-aware Allreduce s/B over q ranks (§6.5): step at the
        domain boundary (node ↦ pod)."""
        if q <= 1:
            return self.beta_intra[min(self.beta_intra)]
        if q <= self.ranks_per_domain:
            return self._interp(self.beta_intra, q)
        return self._interp(self.beta_inter, q)

    def gamma_bytes(self, working_set: float) -> float:
        """Cache-aware γ(W) in s/B (§6.5)."""
        for cap, g in self.gamma_tiers:
            if working_set <= cap:
                return g
        return self.gamma_tiers[-1][1]

    def gamma_flop(self, working_set: float) -> float:
        """s/flop at working-set W: γ_B(W) · bytes-moved-per-flop."""
        return self.gamma_bytes(working_set) * self.word_bytes / self.flops_per_word

    def allreduce_time(self, q: int, words: int) -> float:
        """Hockney: 2⌈log₂ q⌉ α + W β (reduce-scatter + all-gather)."""
        if q <= 1:
            return 0.0
        return 2 * math.ceil(math.log2(q)) * self.alpha(q) + words * self.word_bytes * self.beta(q)


# Paper Table 7 — measured on Perlmutter CPU (2×EPYC 7763, Slingshot-11,
# 64 ranks/node). α is the total 8-byte Allreduce time.
PERLMUTTER = Machine(
    name="perlmutter-cpu",
    ranks_per_domain=64,
    l_cap=1 << 20,  # 1 MB L2/core
    word_bytes=8,  # FP64 (paper §7)
    flops_per_word=1.0,
    peak_flops=39.2e9,  # 2.45 GHz × 16 flops/cycle AVX2 FMA (per core)
    alpha_intra={8: 3.41e-6, 32: 3.39e-6, 64: 4.22e-6},
    alpha_inter={
        64: 3.64e-6, 128: 8.36e-6, 256: 12.56e-6, 512: 14.46e-6,
        1024: 23.23e-6, 2048: 43.22e-6, 4096: 92.71e-6, 8192: 57.13e-6,
        16384: 84.92e-6,
    },
    beta_intra={1: 5.34e-11, 8: 5.90e-10, 32: 1.50e-9, 64: 2.67e-9},
    beta_inter={
        64: 2.66e-9, 128: 3.14e-9, 256: 3.33e-9, 512: 3.73e-9,
        1024: 4.14e-9, 2048: 5.15e-9, 4096: 5.37e-9, 8192: 6.10e-9,
        16384: 6.65e-9,
    },
    gamma_tiers=(
        (16 << 10, 4.0e-12),  # L1
        (1 << 20, 1.25e-11),  # L2
        (32 << 20, 1.5e-11),  # L3
        (1 << 62, 2.6e-11),  # DRAM
    ),
)

# TPU v5e pod (DESIGN.md §2). Domain = one pod (256 chips, ICI);
# crossing the pod boundary (DCI) mirrors the paper's node-boundary β
# step (~an order of magnitude).   β_ICI: ring all-reduce moves 2(q-1)/q
# ≈ 2 bytes/byte over 50 GB/s links → ~4e-11 s/B effective; DCI ~10×.
# γ tiers: VMEM-resident vs HBM-streamed (819 GB/s).
TPU_V5E = Machine(
    name="tpu-v5e",
    ranks_per_domain=256,  # chips per pod
    l_cap=64 << 20,  # usable VMEM slab budget (half of 128 MiB)
    word_bytes=2,  # bf16
    flops_per_word=2.0,
    peak_flops=197e12,
    alpha_intra={2: 1e-6, 256: 1e-6},
    alpha_inter={512: 5e-6, 4096: 10e-6},
    beta_intra={1: 1.0 / 819e9, 2: 4.0e-11, 256: 4.0e-11},
    beta_inter={512: 4.0e-10, 4096: 6.0e-10},
    gamma_tiers=(
        (64 << 20, 1.0 / (3 * 819e9)),  # VMEM-resident (≈3× HBM bw proxy)
        (1 << 62, 1.0 / 819e9),  # HBM
    ),
)

MACHINES = {m.name: m for m in (PERLMUTTER, TPU_V5E)}
