"""Closed-form α-β-γ cost model (paper §6, Tables 2-3, Eq. 4).

Eq. (4) per-epoch wall time of HybridSGD on a p_r × p_c mesh:

  T = (m/p)(6z̄ + 2sb)γ                                 [compute]
    + m · 2α(τ·log p_c + log p_r)/(sbτ)                  [latency]
    + m · (s-1)b·w·β/2                                   [Gram BW]
    + m · n·w·β/(sbτ·p_c)                                [sync BW]

The 1D baselines are exact limits: (p_r=1, p_c=p, τ→∞) → 1D s-step SGD;
(p_r=p, p_c=1, s=1) → FedAvg; additionally τ=1 → MB-SGD.

β is rank-aware (§6.5): the row-team (Gram) Allreduce spans p_c ranks,
the column (weight-sync) Allreduce spans p_r ranks.
"""

from __future__ import annotations

import dataclasses
import math

from repro.costmodel.machines import Machine


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """One point of the (p_r, p_c, s, b, τ) design space."""

    p_r: int
    p_c: int
    s: int
    b: int
    tau: int

    @property
    def p(self) -> int:
        return self.p_r * self.p_c


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Per-epoch seconds, decomposed as in Eq. (4).

    ``overlap_saved`` is the Gram-phase communication hidden behind
    compute by a delay-D schedule (0 for the synchronous D=0 form):
    per bundle the critical path pays max(comm, compute) instead of
    their sum, so the epoch saves min(gram_comm, D · compute). The
    decomposed terms keep their synchronous Eq. (4) values — ``total``
    subtracts the overlap, so dominant-term analysis still sees what
    the run pays on the wire."""

    compute: float
    latency: float
    gram_bw: float
    sync_bw: float
    overlap_saved: float = 0.0

    @property
    def total(self) -> float:
        return (
            self.compute + self.latency + self.gram_bw + self.sync_bw
            - self.overlap_saved
        )

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute,
            "latency": self.latency,
            "gram_bw": self.gram_bw,
            "sync_bw": self.sync_bw,
        }
        return max(terms, key=terms.get)


def _log2(q: int) -> float:
    return math.log2(q) if q > 1 else 0.0


def hybrid_epoch_cost(
    m: int,
    n: int,
    zbar: float,
    cfg: HybridConfig,
    machine: Machine,
    gamma: float | None = None,
    beta_row: float | None = None,
    beta_col: float | None = None,
    delay: int = 0,
    gram_word_bytes: int | None = None,
) -> CostBreakdown:
    """Eq. (4). γ defaults to the cache-aware value at the per-rank
    weight-slab working set (n·w/p_c); β defaults to the rank-aware
    values for each Allreduce's span.

    ``delay`` prices the DaSGD overlap pipeline: at D ≥ 1 each per-
    bundle (G, v) Allreduce (the row-team latency + Gram-bandwidth
    phases) has D bundle-computes to hide behind, so the critical path
    pays max(gram_comm, D·compute) in place of gram_comm + D·compute —
    equivalently ``overlap_saved = min(gram_comm, D·compute)`` per
    epoch. The synchronous column sync is never overlapped.

    ``gram_word_bytes`` prices the (G, v) wire format separately from
    the machine word (default: equal): a ``precision="bf16"`` schedule
    ships 2-byte Gram words, halving the β·bytes Gram term while the
    Table 2–3 *word* counts — and the sync term, whose weights stay
    fp32 — are untouched."""
    w = machine.word_bytes
    gw = w if gram_word_bytes is None else gram_word_bytes
    if gamma is None:
        gamma = machine.gamma_flop(n * w / cfg.p_c)
    if beta_row is None:  # row-team (Gram) Allreduce spans p_c ranks
        beta_row = machine.beta(cfg.p_c)
    if beta_col is None:  # column (weight) Allreduce spans p_r ranks
        beta_col = machine.beta(cfg.p_r)
    s, b, tau, p_r, p_c, p = cfg.s, cfg.b, cfg.tau, cfg.p_r, cfg.p_c, cfg.p

    compute = (m / p) * (6 * zbar + 2 * s * b) * gamma
    alpha_row = machine.alpha(p_c)
    alpha_col = machine.alpha(p_r)
    lat_row = m * 2 * alpha_row * _log2(p_c) / (s * b)
    lat_col = m * 2 * alpha_col * _log2(p_r) / (s * b * tau)
    latency = lat_row + lat_col
    gram_bw = m * ((s - 1) * b / 2) * gw * beta_row
    sync_bw = m * n * w * beta_col / (s * b * tau * p_c)
    overlap_saved = 0.0
    if delay >= 1 and p_c > 1:
        overlap_saved = min(lat_row + gram_bw, delay * compute)
    return CostBreakdown(
        compute=compute, latency=latency, gram_bw=gram_bw, sync_bw=sync_bw,
        overlap_saved=overlap_saved,
    )


def recommend_delay(
    m: int, n: int, zbar: float, cfg: HybridConfig, machine: Machine
) -> int:
    """The smallest staleness D whose overlap window covers the Gram-
    phase communication: ⌈gram_comm / compute⌉ per bundle (both scale
    with the same m/(sbτ) call count, so the epoch ratio is the bundle
    ratio), clamped to the schedule's legal range [1, τ/s]. Returns 0
    when p_c = 1 — no row-team Allreduce exists, so staleness buys
    nothing and D=0 keeps the exact synchronous iterates."""
    if cfg.p_c <= 1:
        return 0
    cb = hybrid_epoch_cost(m, n, zbar, cfg, machine)
    lat_row = m * 2 * machine.alpha(cfg.p_c) * _log2(cfg.p_c) / (cfg.s * cfg.b)
    gram_comm = lat_row + cb.gram_bw
    if cb.compute <= 0.0:
        return 1
    d = math.ceil(gram_comm / cb.compute)
    return max(1, min(d, cfg.tau // cfg.s))


def sstep_epoch_cost(m: int, n: int, zbar: float, s: int, b: int, p: int, machine: Machine) -> CostBreakdown:
    """1D s-step SGD limit (p_r=1, p_c=p, τ→∞): column Allreduce
    vanishes."""
    cfg = HybridConfig(p_r=1, p_c=p, s=s, b=b, tau=1)
    cb = hybrid_epoch_cost(m, n, zbar, cfg, machine)
    # remove the column-sync contributions (τ→∞ limit)
    lat = m * 2 * machine.alpha(p) * _log2(p) / (s * b)
    return CostBreakdown(compute=cb.compute, latency=lat, gram_bw=cb.gram_bw, sync_bw=0.0)


def fedavg_epoch_cost(m: int, n: int, zbar: float, b: int, tau: int, p: int, machine: Machine) -> CostBreakdown:
    """FedAvg limit (p_r=p, p_c=1, s=1): row (Gram) Allreduce vanishes."""
    w = machine.word_bytes
    gamma = machine.gamma_flop(n * w)
    compute = (m / p) * (6 * zbar + 2 * b) * gamma
    latency = m * 2 * machine.alpha(p) * _log2(p) / (b * tau)
    sync_bw = m * n * w * machine.beta(p) / (b * tau)
    return CostBreakdown(compute=compute, latency=latency, gram_bw=0.0, sync_bw=sync_bw)


def mbsgd_epoch_cost(m: int, n: int, zbar: float, b: int, p: int, machine: Machine) -> CostBreakdown:
    """Synchronous mini-batch SGD = FedAvg with τ=1."""
    return fedavg_epoch_cost(m, n, zbar, b, 1, p, machine)


# ---- Tables 2–3: communicated words per rank (closed form) ----


@dataclasses.dataclass(frozen=True)
class CommVolume:
    """Closed-form per-rank communication of a schedule, in words and
    calls — the quantity the ``repro.core.comm`` ledger counts and the
    β/α terms of Eq. 4 charge for.

    gram_*   the row-team (G, v) Allreduce over the p_c column shards:
             one call per s-bundle, s²b² + sb words on the wire (the
             dense (sb, sb) Gram block + residual; ``gram_words_min``
             is Table 3's strictly-lower-triangular information content
             s(s-1)b²/2 + sb — the wire payload's lower bound).
    sync_*   the column weight Allreduce over the p_r row teams: one
             call per round, the ⌈n/p_c⌉-word balanced weight shard.

    A collective spanning a single rank moves nothing: its calls and
    words are zero here, matching the ledger's counted totals.
    """

    gram_calls: int
    gram_words: float
    gram_words_min: float
    gram_span: int
    sync_calls: int
    sync_words: float
    sync_span: int

    @property
    def total_words(self) -> float:
        return self.gram_words + self.sync_words

    def words_dict(self) -> dict[str, float]:
        """The modeled-volume dict reports carry ({gram,sync,total})."""
        return {
            "gram_words": self.gram_words,
            "sync_words": self.sync_words,
            "total_words": self.total_words,
        }


def schedule_comm_volume(
    n: int, p_r: int, p_c: int, s: int, b: int, tau: int, rounds: int = 1
) -> CommVolume:
    """Tables 2–3 as word counts: per-rank communication of ``rounds``
    outer rounds of the (p_r, p_c, s, b, τ) schedule.

    The four named corners are limits of this one form:
      MB-SGD   (p_r=1, s=1, τ=1)   gram only (when p_c > 1)
      s-step   (p_r=1, τ=s)        gram only (one bundle per round)
      FedAvg   (s=1, p_c=1)        sync only
      Hybrid   general             both
    """
    bundles = rounds * (tau // s)
    sb = s * b
    gram_active = p_c > 1
    sync_active = p_r > 1
    gram_calls = bundles if gram_active else 0
    gram_words = float(bundles * (sb * sb + sb)) if gram_active else 0.0
    gram_words_min = (
        float(bundles * (s * (s - 1) * b * b // 2 + sb)) if gram_active else 0.0
    )
    sync_calls = rounds if sync_active else 0
    sync_words = float(rounds * math.ceil(n / p_c)) if sync_active else 0.0
    return CommVolume(
        gram_calls=gram_calls,
        gram_words=gram_words,
        gram_words_min=gram_words_min,
        gram_span=p_c,
        sync_calls=sync_calls,
        sync_words=sync_words,
        sync_span=p_r,
    )


# ---- Table 3: per-sample costs (amortized over the comm period) ----


def per_sample_costs(
    solver: str,
    m: int,
    n: int,
    zbar: float,
    p: int,
    s: int,
    b: int,
    tau: int,
    machine: Machine,
    p_r: int = 1,
    p_c: int = 1,
) -> dict[str, float]:
    """Latency / bandwidth / compute per sample (paper Table 3), in
    seconds. ``solver`` ∈ {sgd, mbsgd, fedavg, sstep1d, hybrid}."""
    w = machine.word_bytes
    a = machine.alpha(p)
    bt = machine.beta(p)
    g = machine.gamma_flop(n * w / max(p_c, 1))
    L2 = _log2
    if solver == "sgd":
        return {"latency": 2 * L2(p) * a, "bandwidth": w * bt, "compute": 4 * zbar * g}
    if solver == "mbsgd":
        return {
            "latency": 2 * L2(p) * a / b,
            "bandwidth": w * bt,
            "compute": (4 * zbar + 2 * n / b) * g,
        }
    if solver == "fedavg":
        return {
            "latency": 2 * L2(p) * a / (tau * b),
            "bandwidth": n * w * bt / (tau * b),
            "compute": (4 * zbar + 2 * n / b) * g,
        }
    if solver == "sstep1d":
        return {
            "latency": 2 * L2(p) * a / (s * b),
            "bandwidth": (s - 1) * b * w * bt / 2,
            "compute": (6 * zbar + 2 * s * b) * g,
        }
    if solver == "hybrid":
        a_row, a_col = machine.alpha(p_c), machine.alpha(p_r)
        b_row, b_col = machine.beta(p_c), machine.beta(p_r)
        return {
            "latency": 2 * (a_row * tau * L2(p_c) + a_col * L2(p_r)) / (s * b * tau),
            "bandwidth": ((s - 1) * b / 2) * w * b_row + n * w * b_col / (s * b * tau * p_c),
            "compute": (6 * zbar + 2 * s * b) * g,
        }
    raise ValueError(f"unknown solver {solver!r}")
