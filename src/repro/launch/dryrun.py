import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) this lowers + compiles
the real step function against ShapeDtypeStruct stand-ins on 512
placeholder host devices, proving the distribution config is coherent:
sharding mismatches, compile-time OOM, and unsupported collectives all
fail here.

Per combination it records:
  * memory_analysis of the FULL-depth scanned compile (fits-per-device
    proof),
  * cost_analysis + HLO collective bytes of depth-1/2 unrolled variants
    extrapolated to full depth (roofline terms — see roofline.py for
    why unrolled: XLA counts a while body once).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
      --shape train_4k --mesh single                              # one combo
  ... --skip-roofline                                             # memory only
Results accumulate in results/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import REGISTRY
from repro.launch import roofline as rl
from repro.launch.input_specs import (
    SHAPES,
    cache_shape,
    cache_shardings,
    params_shape,
    params_shardings,
    resolve_config,
    shape_applicable,
    token_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    make_pod_sync_step,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _mesh_label(mesh) -> str:
    return "x".join(str(s) for s in mesh.axis_sizes) + ":" + ",".join(mesh.axis_names)


def lower_step(cfg, shape, mesh, *, unroll: bool, opt=None, single_microbatch: bool = False):
    """Lower the appropriate step for (cfg, shape) on mesh.

    ``single_microbatch``: collapse the gradient-accumulation scan to
    M=1 so cost_analysis counts the whole batch (roofline lowerings;
    XLA counts a while body once — see roofline.py)."""
    structs, shardings = token_specs(cfg, shape, mesh)
    pshape = params_shape(cfg)
    pshard = params_shardings(cfg, mesh, pshape)

    if shape.kind == "train":
        from repro.launch.steps import data_parallel_size
        from repro.models.init import param_pspecs

        mps = max(shape.global_batch // data_parallel_size(mesh), 1) if single_microbatch else 1
        # ≥100B params: bf16 gradient accumulation (§Perf-3)
        gdt = jnp.bfloat16 if cfg.param_count() > 100e9 else jnp.float32
        step = make_train_step(cfg, mesh, opt=opt, unroll=unroll, microbatch_per_shard=mps,
                               param_specs=param_pspecs(cfg, pshape, mesh), grad_dtype=gdt)
        args = [pshape, jax.eval_shape(lambda: ())]  # sgd state is ()
        in_shardings = [pshard, ()]
        for name in ("tokens", "targets", "prefix_emb"):
            if name in structs:
                args.append(structs[name])
                in_shardings.append(shardings[name])
        fn = jax.jit(
            step,
            in_shardings=tuple(in_shardings),
            out_shardings=(pshard, (), None),
            donate_argnums=(0,),
        )
        return fn.lower(*args)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, unroll=unroll)
        args = [pshape, structs["tokens"]]
        in_shardings = [pshard, shardings["tokens"]]
        if "prefix_emb" in structs:
            args.append(structs["prefix_emb"])
            in_shardings.append(shardings["prefix_emb"])
        fn = jax.jit(step, in_shardings=tuple(in_shardings))
        return fn.lower(*args)
    # decode
    step = make_serve_step(cfg, unroll=unroll)
    cshape = cache_shape(cfg, shape)
    cshard = cache_shardings(cfg, shape, mesh, cshape)
    fn = jax.jit(
        step,
        in_shardings=(pshard, cshard, shardings["tokens"]),
        out_shardings=(None, cshard),
        donate_argnums=(1,),
    )
    return fn.lower(pshape, cshape, structs["tokens"])


def _cost_and_collectives(cfg, shape, mesh, n_periods: int):
    small = dataclasses.replace(cfg, n_layers=len(cfg.period) * n_periods)
    lowered = lower_step(small, shape, mesh, unroll=True, single_microbatch=True)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    coll = rl.parse_collectives(compiled.as_text())
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)), coll


def run_combo(arch: str, shape_name: str, mesh, *, skip_roofline: bool = False) -> dict:
    shape = SHAPES[shape_name]
    cfg = resolve_config(arch, shape)
    label = _mesh_label(mesh)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": label, "config": cfg.name}

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    t0 = time.time()
    with compat.use_mesh(mesh):
        # 1) full-depth scanned compile — memory proof
        lowered = lower_step(cfg, shape, mesh, unroll=False)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
            ),
        }
        rec["fits_16gb_hbm"] = rec["memory"]["peak_bytes"] < 16e9
        rec["compile_s_full"] = round(time.time() - t0, 1)

        if not skip_roofline:
            # 2) depth-1/2 unrolled compiles — roofline extrapolation
            f1, b1, c1 = _cost_and_collectives(cfg, shape, mesh, 1)
            f2, b2, c2 = _cost_and_collectives(cfg, shape, mesh, 2)
            flops = rl.extrapolate_depth(f1, f2, cfg.n_periods)
            hbm = rl.extrapolate_depth(b1, b2, cfg.n_periods)
            coll_bytes = rl.extrapolate_depth(
                float(c1.total_bytes), float(c2.total_bytes), cfg.n_periods
            )
            breakdown = {
                k: int(rl.extrapolate_depth(c1.bytes_by_kind.get(k, 0), c2.bytes_by_kind.get(k, 0), cfg.n_periods))
                for k in set(c1.bytes_by_kind) | set(c2.bytes_by_kind)
            }
            n_dev = mesh.size
            terms = rl.RooflineTerms(
                arch=arch,
                shape=shape_name,
                mesh=label,
                flops=flops,
                hbm_bytes=hbm,
                collective_bytes=coll_bytes,
                collective_breakdown=breakdown,
                model_flops=rl.model_flops_per_step(cfg, shape, shape.kind) / n_dev,
            )
            rec["roofline"] = terms.row()
            rec["roofline"]["collectives_in_while"] = c1.in_while_body or c2.in_while_body

    rec["status"] = "ok"
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES), help="one shape")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(REGISTRY)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mlabel, mesh in meshes:
                tag = f"{arch}__{shape_name}__{mlabel}"
                out = RESULTS_DIR / f"{tag}.json"
                try:
                    # roofline terms are single-pod deliverables; multi-pod
                    # proves the pod axis lowers (memory only)
                    rec = run_combo(
                        arch, shape_name, mesh,
                        skip_roofline=args.skip_roofline or mlabel == "multi",
                    )
                except Exception as e:  # a failure here is a bug in our sharding
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mlabel,
                        "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures.append(tag)
                out.write_text(json.dumps(rec, indent=2, default=str))
                status = rec["status"]
                extra = ""
                if status == "ok" and "memory" in rec:
                    extra = f" peak={rec['memory']['peak_bytes'] / 1e9:.2f}GB"
                    if "roofline" in rec:
                        r = rec["roofline"]
                        extra += (
                            f" compute={r['compute_s'] * 1e3:.2f}ms"
                            f" memory={r['memory_s'] * 1e3:.2f}ms"
                            f" collective={r['collective_s'] * 1e3:.2f}ms"
                            f" dominant={r['dominant']}"
                        )
                print(f"[{status:7s}] {tag}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} combinations failed: {failures}")
    print("ALL DRY-RUN COMBINATIONS LOWERED AND COMPILED.")


if __name__ == "__main__":
    main()
