"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape).

The four assigned input shapes:

  train_4k      seq 4,096   global_batch 256   → train_step
  prefill_32k   seq 32,768  global_batch 32    → prefill_step
  decode_32k    seq 32,768  global_batch 128   → serve_step (1 token,
                                                  KV cache of seq_len)
  long_500k     seq 524,288 global_batch 1     → serve_step; only for
                 sub-quadratic archs (SSM / hybrid / SWA overlay)

Nothing here allocates: inputs are ShapeDtypeStructs (weak-type-correct,
shardable) and parameter/cache trees come from jax.eval_shape.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, with_sliding_window
from repro.models.config import ArchConfig
from repro.models.init import init_params, param_pspecs
from repro.models.transformer import init_cache


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# prefix positions supplied by the stub frontend (DESIGN.md §4)
VISION_PREFIX_TRAIN = 576  # one 24×24 tile
VISION_PREFIX_PREFILL = 2880  # anyres: 5 tiles × 576


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped). long_500k needs sub-quadratic decode."""
    if shape.name == "long_500k":
        if cfg.name.startswith("mistral-nemo"):
            return True, "runs with SWA-4096 overlay"
        if cfg.subquadratic:
            return True, ""
        return False, "pure full-attention arch: 500k decode cache/attn is not sub-quadratic"
    return True, ""


# regime-aware mesh-role selection (the paper's Eq.-7 insight applied
# to the NN zoo — EXPERIMENTS.md §Perf-1): small dense models cannot
# use a 16-way TP axis (gemma: 8 heads), so the "model" axis folds into
# batch/FSDP ("dp" profile) whenever the step's batch can fill it
# (train) or its compute is negligible (decode). Prefill's small batch
# cannot fill the mesh → TP stays.
DP_PROFILE_ARCHS = {"gemma-2b", "qwen2.5-3b", "musicgen-medium"}


def select_profile(arch: str, shape: ShapeSpec) -> str:
    if arch in DP_PROFILE_ARCHS and shape.kind in ("train", "decode"):
        return "dp"
    return "tp"


def _stationary_experts_ok(cfg: ArchConfig) -> bool:
    """Weight-stationary serving only when the per-rank resident expert
    bytes stay small (jamba's 43 GB/rank would regress — §Perf-4)."""
    if cfg.moe is None:
        return False
    from repro.models.init import padded_experts

    e = cfg.moe
    per_rank = max(padded_experts(e.n_experts) // 16, 1)
    moe_layers = sum(1 for sp in cfg.period if sp.ff == "moe") * cfg.n_periods
    resident = per_rank * 3 * cfg.d_model * e.d_ff_expert * 2 * moe_layers
    return resident < 4e9


def resolve_config(arch: str, shape: ShapeSpec) -> ArchConfig:
    cfg = get_config(arch)
    if shape.name == "long_500k" and cfg.name.startswith("mistral-nemo"):
        cfg = with_sliding_window(cfg, 4096)
    return dataclasses.replace(
        cfg,
        max_seq_len=max(cfg.max_seq_len, shape.seq_len),
        sharding_profile=select_profile(arch, shape),
        expert_weight_stationary=shape.kind == "decode" and _stationary_experts_ok(cfg),
    )


def batch_axes(mesh, profile: str = "tp") -> tuple[str, ...]:
    names = ("pod", "data", "model") if profile == "dp" else ("pod", "data")
    return tuple(a for a in names if a in mesh.axis_names)


def _bspec(mesh, batch: int, *rest, profile: str = "tp") -> P:
    """Batch sharded over the profile's batch axes, greedily dropping
    trailing axes until the batch divides."""
    axes = batch_axes(mesh, profile)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    while axes:
        total = 1
        for a in axes:
            total *= sizes[a]
        if batch % total == 0:
            break
        axes = axes[:-1]
    first = axes or None
    if first and len(first) == 1:
        first = first[0]
    return P(first, *rest)


def token_specs(cfg: ArchConfig, shape: ShapeSpec, mesh):
    """(ShapeDtypeStructs, shardings) for the data inputs of the step."""
    B = shape.global_batch
    prof = cfg.sharding_profile
    structs: dict = {}
    shardings: dict = {}
    if shape.kind == "train":
        s_text = shape.seq_len
        if cfg.frontend == "vision":
            s_text = shape.seq_len - VISION_PREFIX_TRAIN
            structs["prefix_emb"] = jax.ShapeDtypeStruct((B, VISION_PREFIX_TRAIN, cfg.d_model), jnp.bfloat16)
            shardings["prefix_emb"] = NamedSharding(mesh, _bspec(mesh, B, None, None, profile=prof))
        structs["tokens"] = jax.ShapeDtypeStruct((B, s_text), jnp.int32)
        structs["targets"] = jax.ShapeDtypeStruct((B, s_text), jnp.int32)
        shardings["tokens"] = NamedSharding(mesh, _bspec(mesh, B, profile=prof))
        shardings["targets"] = NamedSharding(mesh, _bspec(mesh, B, profile=prof))
    elif shape.kind == "prefill":
        s_text = shape.seq_len
        if cfg.frontend == "vision":
            s_text = shape.seq_len - VISION_PREFIX_PREFILL
            structs["prefix_emb"] = jax.ShapeDtypeStruct((B, VISION_PREFIX_PREFILL, cfg.d_model), jnp.bfloat16)
            shardings["prefix_emb"] = NamedSharding(mesh, _bspec(mesh, B, None, None, profile=prof))
        structs["tokens"] = jax.ShapeDtypeStruct((B, s_text), jnp.int32)
        shardings["tokens"] = NamedSharding(mesh, _bspec(mesh, B, profile=prof))
    else:  # decode
        structs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        shardings["tokens"] = NamedSharding(mesh, _bspec(mesh, B, profile=prof))
    return structs, shardings


def params_shape(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))


def params_shardings(cfg: ArchConfig, mesh, pshape=None):
    pshape = pshape or params_shape(cfg)
    specs = param_pspecs(cfg, pshape, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def cache_shape(cfg: ArchConfig, shape: ShapeSpec, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, dtype)
    )


def cache_pspec_for_leaf(path_names: tuple[str, ...], leaf, mesh, batch: int) -> P:
    """Decode-cache sharding: batch over (pod, data); the long cache
    dim (KV seq) over "model" — sequence-parallel cache reads (kv heads
    are rarely divisible by 16, the seq dim always is here). Mamba
    states shard d_inner over "model"."""
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    name = path_names[-1]
    if name == "pos":
        return P()
    # leading dim is n_periods (stacked), then the block-cache dims
    shape = leaf.shape
    spec: list = [None] * len(shape)
    baxes = batch_axes(mesh)
    btotal = 1
    for a in baxes:
        btotal *= sizes[a]
    if batch % btotal == 0 and baxes:
        spec[1] = baxes[0] if len(baxes) == 1 else baxes
    model = sizes.get("model", 1)
    if name in ("k", "v", "ckv", "kr"):
        if shape[2] % model == 0:  # cache seq dim
            spec[2] = "model"
    elif name == "ssm":
        if shape[2] % model == 0:  # d_inner
            spec[2] = "model"
    elif name == "conv":
        if shape[3] % model == 0:  # d_inner (B, c, d_in) + period dim
            spec[3] = "model"
    return P(*spec)


def cache_shardings(cfg: ArchConfig, shape: ShapeSpec, mesh, cshape=None):
    cshape = cshape or cache_shape(cfg, shape)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, path + (str(i),)) for i, v in enumerate(tree))
        return NamedSharding(mesh, cache_pspec_for_leaf(path, tree, mesh, shape.global_batch))

    return walk(cshape)
