"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs real training (allocates!) on whatever devices exist — on this
CPU container use a reduced config; on a TPU slice pass --full. The
hybrid-2D schedule (pod-local steps, τ-deferred sync) engages when the
mesh has a "pod" axis.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--full", action="store_true", help="full config (needs real accelerators)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--mesh", default=None, help='e.g. "2x2:data,model" or "2x2x2:pod,data,model"')
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)

    mesh = None
    if args.mesh:
        shape_s, axes_s = args.mesh.split(":")
        shape = tuple(int(x) for x in shape_s.split("x"))
        axes = tuple(axes_s.split(","))
        from repro import compat

        mesh = compat.make_mesh(shape, axes)

    if mesh is not None:
        from repro import compat

        compat.set_mesh(mesh)
    report = train(
        cfg,
        steps=args.steps,
        batch=args.batch,
        seq_len=args.seq_len,
        tau=args.tau,
        mesh=mesh,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=50 if args.checkpoint_dir else 0,
    )
    print(f"arch={cfg.name} steps={report.steps} tokens/s={report.tokens_per_s:.0f}")
    print("losses:", " ".join(f"{l:.4f}" for l in report.losses))


if __name__ == "__main__":
    main()
