"""Generate the §Roofline markdown table from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report > results/roofline_table.md
"""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}µs"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def main() -> None:
    recs = [json.loads(f.read_text()) for f in sorted(RESULTS.glob("*.json"))]
    singles = {
        (r["arch"], r["shape"]): r for r in recs if r["mesh"].startswith("16x16")
    }
    multis = {
        (r["arch"], r["shape"]): r for r in recs if r["mesh"].startswith("2x16x16")
    }

    print("| arch | shape | compute | memory | collective | dominant | useful | peak GB (1-pod) | multi-pod |")
    print("|------|-------|--------:|-------:|-----------:|----------|-------:|----------------:|-----------|")
    archs = sorted({a for a, _ in singles})
    n_ok = n_skip = 0
    for arch in archs:
        for shape in SHAPE_ORDER:
            r = singles.get((arch, shape))
            if r is None:
                continue
            m = multis.get((arch, shape), {})
            mstat = m.get("status", "—")
            if mstat == "ok":
                mpk = m.get("memory", {}).get("peak_bytes", 0) / 1e9
                mcell = f"ok ({mpk:.1f} GB)"
            elif mstat == "skipped":
                mcell = "skip"
            else:
                mcell = mstat
            if r["status"] == "skipped":
                n_skip += 1
                print(f"| {arch} | {shape} | — | — | — | SKIP: {r['reason'][:44]} | — | — | {mcell} |")
                continue
            if r["status"] != "ok":
                print(f"| {arch} | {shape} | FAILED | | | | | | {mcell} |")
                continue
            n_ok += 1
            pk = r["memory"]["peak_bytes"] / 1e9
            ro = r.get("roofline")
            if ro is None:
                print(f"| {arch} | {shape} | | | | (memory only) | | {pk:.2f} | {mcell} |")
                continue
            print(
                f"| {arch} | {shape} | {fmt_s(ro['compute_s'])} | {fmt_s(ro['memory_s'])} "
                f"| {fmt_s(ro['collective_s'])} | **{ro['dominant']}** "
                f"| {ro['useful_ratio']:.2f} | {pk:.2f} | {mcell} |"
            )
    print(f"\n{n_ok} combinations lowered+compiled with roofline terms; {n_skip} skipped (sub-quadratic rule).")

    # dominant-term census + hillclimb candidates
    rows = [r["roofline"] | {"peak": r["memory"]["peak_bytes"]} for r in singles.values()
            if r.get("status") == "ok" and "roofline" in r]
    if rows:
        doms = {}
        for ro in rows:
            doms[ro["dominant"]] = doms.get(ro["dominant"], 0) + 1
        print(f"\nDominant-term census: {doms}")
        worst_useful = min(rows, key=lambda ro: ro["useful_ratio"] if ro["useful_ratio"] > 0 else 9)
        most_coll = max(rows, key=lambda ro: ro["collective_s"] / max(ro["compute_s"], 1e-12))
        print(f"Worst useful-flops ratio: {worst_useful['arch']}/{worst_useful['shape']} "
              f"({worst_useful['useful_ratio']:.2f})")
        print(f"Most collective-bound: {most_coll['arch']}/{most_coll['shape']} "
              f"(coll/compute = {most_coll['collective_s'] / max(most_coll['compute_s'], 1e-12):.1f})")


if __name__ == "__main__":
    main()
