"""Production mesh builders.

Single-pod: (16, 16) = ("data", "model") — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips.

Functions, not module constants, so importing never touches jax device
state. The axis semantics implement the paper's mesh (DESIGN.md §2):
"model" is the frequent/exact axis (p_c, intra-pod ICI), "pod" is the
τ-deferred FedAvg axis (p_r, crossing the slow DCI boundary).
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (used by tests and the perf sweeps)."""
    return compat.make_mesh(shape, axes)


def device_count_needed(*, multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256
