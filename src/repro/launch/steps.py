"""Step functions the launcher lowers: train_step (microbatched SGD,
hybrid-2D aware), prefill_step, serve_step.

train_step does M gradient-accumulation microbatches (M chosen so each
microbatch puts one sequence on each (pod × data) shard — this bounds
the logits buffer, the decisive activation on 100k+-vocab archs) and
one optimizer update. On a multi-pod mesh the step is wrapped in the
hybrid-2D pod-local form (repro.optim.hybrid2d); the τ-deferred pod
sync is a separate lowerable fn (sync_step).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.transformer import decode_step, forward, lm_loss
from repro.optim.sgd import Optimizer, sgd


def data_parallel_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def make_train_step(cfg: ArchConfig, mesh, opt: Optimizer | None = None,
                    microbatch_per_shard: int = 1, unroll: bool = False,
                    param_specs=None, grad_dtype=jnp.float32):
    """Returns train_step(params, opt_state, tokens, targets[, prefix])
    → (params, opt_state, loss).

    ``param_specs``: PartitionSpec tree for params; when given, the
    gradient accumulator is constrained to the same layout (without it
    XLA was measured to replicate MoE expert grads — 12.9 GB/dev on
    jamba, EXPERIMENTS.md §Perf P-gacc).
    ``grad_dtype``: accumulator dtype; bf16 halves the dominant
    gradient buffers on ≥100B-param models (§Perf-3) at an accepted
    precision cost for plain-SGD training."""
    opt = opt or sgd(3e-3)
    dp = data_parallel_size(mesh)

    def constrain(tree):
        if param_specs is None:
            return tree
        return jax.tree.map(
            lambda t, spec: jax.lax.with_sharding_constraint(t, spec),
            tree,
            param_specs,
            is_leaf=lambda x: x is None,
        )

    def loss_fn(params, tokens, targets, prefix_emb=None):
        return lm_loss(cfg, params, tokens, targets, prefix_emb=prefix_emb,
                       remat=True, unroll=unroll)

    def train_step(params, opt_state, tokens, targets, prefix_emb=None):
        B = tokens.shape[0]
        mb = dp * microbatch_per_shard
        M = max(B // mb, 1)

        def micro(carry, xs):
            g_acc, l_acc = carry
            if prefix_emb is None:
                tok, tgt = xs
                loss, g = jax.value_and_grad(loss_fn)(params, tok, tgt)
            else:
                tok, tgt, pre = xs
                loss, g = jax.value_and_grad(loss_fn)(params, tok, tgt, pre)
            g_acc = constrain(jax.tree.map(jnp.add, g_acc, g))
            return (g_acc, l_acc + loss), None

        def split(x):
            return x.reshape(M, x.shape[0] // M, *x.shape[1:])

        xs = (split(tokens), split(targets))
        if prefix_emb is not None:
            xs = xs + (split(prefix_emb),)
        def acc_dtype(p):
            # f32-stored params (A_log, router) keep f32 accumulators
            return grad_dtype if p.dtype == jnp.bfloat16 else jnp.float32

        g0 = constrain(jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype(p)), params))
        (g, loss), _ = jax.lax.scan(micro, (g0, jnp.float32(0)), xs)
        g = jax.tree.map(lambda x: x / M, g)
        new_params, new_state = opt.update(g, opt_state, params)
        return new_params, new_state, loss / M

    return train_step


def make_prefill_step(cfg: ArchConfig, unroll: bool = False):
    """prefill_step(params, tokens[, prefix]) → last-position logits.
    (A production server would also return the populated KV cache; the
    compute and memory profile is dominated by the forward pass either
    way.)"""

    def prefill_step(params, tokens, prefix_emb=None):
        return forward(cfg, params, tokens, prefix_emb, last_only=True, unroll=unroll)

    return prefill_step


def make_serve_step(cfg: ArchConfig, unroll: bool = False):
    """serve_step(params, cache, tokens) → (logits, cache): ONE new
    token against a seq_len-deep cache."""

    def serve_step(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens, unroll=unroll)

    return serve_step


def make_pod_sync_step(mesh):
    """The paper's τ-deferred column Allreduce at pod scale: average
    params across the "pod" axis. Identity on single-pod meshes."""
    if "pod" not in mesh.axis_names:
        return lambda params: params

    def sync(params):
        # params replicated per pod drift during τ local steps; the sync
        # is a pmean expressed as a resharding-free global mean when
        # params carry no pod dim — here we mark it with an explicit
        # collective via shard_map over the pod axis.
        from repro.compat import shard_map

        smap = shard_map(
            lambda p: jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), p),
            mesh=mesh,
            in_specs=P(),
            out_specs=P(),
            axis_names={"pod"},
        )
        return smap(params)

    return sync
