"""Trace-file inspector: ``python -m repro.launch.trace``.

Reads the artifacts the ``--trace`` flags emit (``repro.launch.sweep``
/ ``repro.launch.serve``) — either the Chrome trace-event JSON or the
``.jsonl`` event log — and prints the per-category wall-share table:

    PYTHONPATH=src python -m repro.launch.trace summarize out.json

For the interactive view, load the ``.json`` file directly in Perfetto
(https://ui.perfetto.dev) or chrome://tracing — this CLI is the
grep-able terminal complement.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.obs import export as obs_export


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.trace", description="inspect repro.obs trace files"
    )
    sub = ap.add_subparsers(dest="command", required=True)
    sm = sub.add_parser(
        "summarize", help="per-category span count / wall seconds / share table"
    )
    sm.add_argument("path", type=Path, help="trace .json (Chrome) or .jsonl file")
    args = ap.parse_args(argv)

    if not args.path.exists():
        print(f"error: {args.path} does not exist", file=sys.stderr)
        return 2
    try:
        print(obs_export.summarize_text(args.path))
    except (ValueError, KeyError) as e:
        print(f"error: {args.path} is not a repro.obs trace: {e}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
