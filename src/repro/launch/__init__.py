"""Launch layer: meshes, dry-run, roofline, train/serve drivers.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — import it only in
a dedicated process (the __main__ entry), never from library code.
"""

from repro.launch.mesh import device_count_needed, make_mesh, make_production_mesh

__all__ = ["device_count_needed", "make_mesh", "make_production_mesh"]
