"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Per (arch × shape × mesh) we derive, from the per-device SPMD module:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s      (197e12 bf16)
  memory term     = HLO_bytes_per_device / HBM_bw           (819e9 B/s)
  collective term = collective_bytes_per_device / link_bw   (~50e9 B/s)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes;
``compiled.as_text()`` parsed here for collective operand bytes (they
are NOT in cost_analysis). XLA's cost analysis counts a while-loop body
ONCE, so the launcher lowers depth-1 and depth-2 *unrolled* variants
and linearly extrapolates to full depth (exact for layer-linear
models); the full scanned compile is used for memory_analysis only.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); the ratio
MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is useful
(catches remat recompute and padding waste — with remat-everything the
expected train ratio is ≈ 6/8 = 0.75 of the no-remat value).
"""

from __future__ import annotations

import dataclasses
import re

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link (effective, see DESIGN.md)
VMEM_BYTES = 16 * 2**20  # per-core VMEM budget the panel tiler fits in

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %x = bf16[16,128]{1,0} all-gather(...)   or tuple shapes
_OP_RE = re.compile(
    r"=\s*(?P<shape>\(?[a-z0-9]+\[[0-9,]*\][^\s]*\)?[^=]*?)\s*"
    r"(?P<kind>all-gather|all-reduce-start|all-reduce|reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(",
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z][a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        bytes_per = _DTYPE_BYTES.get(m.group("dt"))
        if bytes_per is None:
            continue
        dims = m.group("dims")
        count = 1
        if dims:
            for d in dims.split(","):
                count *= int(d)
        total += count * bytes_per
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]
    in_while_body: bool  # True if any collective sits inside a while

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-buffer bytes of every collective in the module.

    For all-gather/all-reduce the output size equals the full (gathered/
    reduced) payload each device holds; for reduce-scatter the *input*
    is the payload — we approximate with output × group_size ≈ input by
    just using output bytes uniformly (consistent across configs, and
    the ranking/regime use is insensitive to the ≤2× convention).
    """
    bytes_by_kind: dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    count_by_kind: dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    in_while = False
    current_comp_is_body = False
    body_names: set[str] = set()
    for m in re.finditer(r"body=%?([\w.\-]+)", hlo_text):
        body_names.add(m.group(1))

    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith(("%", "ENTRY")) and stripped.endswith("{"):
            comp_name = stripped.split(" ")[0].lstrip("%").split(".(")[0]
            comp_name = comp_name.split("(")[0].rstrip()
            current_comp_is_body = any(comp_name.startswith(b) or b.startswith(comp_name) for b in body_names)
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group("kind").replace("-start", "")
        nbytes = _shape_bytes(m.group("shape"))
        # all-reduce-start returns (operand, result) tuples in some
        # lowerings — halve to avoid double counting the pair
        if "-start" in m.group(0) and m.group("shape").startswith("("):
            nbytes //= 2
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + nbytes
        count_by_kind[kind] = count_by_kind.get(kind, 0) + 1
        if current_comp_is_body:
            in_while = True
    return CollectiveStats(bytes_by_kind, count_by_kind, in_while)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops: float  # per device, full depth
    hbm_bytes: float
    collective_bytes: float
    collective_breakdown: dict[str, int]
    model_flops: float  # 6·N_active·D (global) / device
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def bound_s(self) -> float:
        """Roofline lower bound on step time (max of the three terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_per_dev": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "collectives": self.collective_breakdown,
        }


# ---- analytic panel roofline (repro.kernels.tune's justification) ----
#
# The ELL-Gram kernel walks ⌈n/bk⌉ column panels; per panel it expands
# the (sb, w) ELL block into a (sb, bk) dense panel (one-hot contraction,
# 2·sb·w·bk FLOPs), accumulates G += P·Pᵀ (2·sb²·bk) and v += P·x_blk
# (2·sb·bk). The ELL block itself is re-streamed from HBM once per panel
# (it is VMEM-resident *within* a grid step, not across steps) — that
# re-read is the bk tradeoff the tuner prices: larger panels cut the
# ⌈n/bk⌉ re-reads but grow the (bm, bk) VMEM tile.


def panel_vmem_bytes(
    rows: int, width: int, bk: int, bm: int | None = None, compute_bytes: int = 4
) -> int:
    """VMEM working set of one ell_gram grid step: the (bm, bk) expanded
    panel tile at compute precision plus the resident ELL block
    (indices + values), G, v, and x panel (all f32/i32)."""
    bm = rows if bm is None or bm > rows else bm
    panel = bm * bk * compute_bytes
    resident = rows * width * (4 + 4) + rows * rows * 4 + rows * 4 + bk * 4
    return panel + resident


def panel_flops(rows: int, width: int, n: int, bk: int) -> float:
    """Total FLOPs of one (G, v) bundle build at panel width bk."""
    n_panels = -(-n // bk)
    per_panel = 2 * rows * width * bk + 2 * rows * rows * bk + 2 * rows * bk
    return float(n_panels * per_panel)


def panel_hbm_bytes(
    rows: int, width: int, n: int, bk: int, compute_bytes: int = 4
) -> float:
    """HBM traffic of one bundle build: the ELL block re-streamed once
    per panel, x streamed once, G and v written once."""
    n_panels = -(-n // bk)
    ell = n_panels * rows * width * (4 + 4)  # int32 indices + f32 values
    x = n_panels * bk * 4
    out = rows * rows * 4 + rows * 4
    return float(ell + x + out)


@dataclasses.dataclass(frozen=True)
class PanelRoofline:
    """Attainable-time bound for one (rows, width, n, bk, bm) panel
    configuration — what the autotuner cross-checks measured wall time
    against (a measurement below the bound is a timer glitch; far above
    it, headroom the next candidate may claim)."""

    rows: int
    width: int
    n: int
    bk: int
    bm: int | None
    flops: float
    hbm_bytes: float
    vmem_bytes: int
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW

    @property
    def compute_s(self) -> float:
        return self.flops / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def attainable_s(self) -> float:
        """Roofline lower bound on the bundle build (max of the terms)."""
        return max(self.compute_s, self.memory_s)

    @property
    def dominant(self) -> str:
        return "compute" if self.compute_s >= self.memory_s else "memory"

    @property
    def fits_vmem(self) -> bool:
        return self.vmem_bytes <= VMEM_BYTES


def panel_roofline(
    rows: int,
    width: int,
    n: int,
    bk: int,
    bm: int | None = None,
    precision: str = "fp32",
) -> PanelRoofline:
    """The attainable-FLOP/s justification for one tuner candidate.

    ``precision`` prices the MXU: bf16 panels run at the full PEAK_FLOPS
    (the constant is the bf16 peak) with 2-byte panel tiles; fp32 halves
    the peak and doubles the tile."""
    cb = 2 if precision == "bf16" else 4
    peak = PEAK_FLOPS if precision == "bf16" else PEAK_FLOPS / 2
    return PanelRoofline(
        rows=rows,
        width=width,
        n=n,
        bk=bk,
        bm=bm,
        flops=panel_flops(rows, width, n, bk),
        hbm_bytes=panel_hbm_bytes(rows, width, n, bk, cb),
        vmem_bytes=panel_vmem_bytes(rows, width, bk, bm, cb),
        peak_flops=peak,
    )


def extrapolate_depth(v1: float, v2: float, n_periods: int) -> float:
    """cost(P) = base + P·per_period, measured at P=1 and P=2."""
    per = max(v2 - v1, 0.0)
    base = max(v1 - per, 0.0)
    return base + n_periods * per


def model_flops_per_step(cfg, shape, kind: str) -> float:
    """6·N_active·D global model FLOPs for the step (3 matmul passes
    fwd+bwd for train; 2·N·D for inference forward)."""
    n_active = cfg.active_param_count() - cfg.vocab_size * cfg.d_model * (
        0 if cfg.tie_embeddings else 1
    )  # lm_head counted once below; embedding lookup is a gather
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # decode: 1 token/seq
    return 2.0 * n_active * tokens
