"""Declarative experiment launcher: ``python -m repro.launch.sweep``.

Drives the repro.api front door from JSON spec files — the config-file
twin of ``repro.launch.train``'s flag-style CLI:

    PYTHONPATH=src python -m repro.launch.sweep --spec spec.json
    PYTHONPATH=src python -m repro.launch.sweep --spec sweep.json --out results.json
    PYTHONPATH=src python -m repro.launch.sweep --spec spec.json --plan-only
    PYTHONPATH=src python -m repro.launch.sweep --spec sweep.json --resume ckpt/ --table
    PYTHONPATH=src python -m repro.launch.sweep --spec spec.json --objective squared_hinge --l2 1e-3

The spec file holds one ``ExperimentSpec`` dict or a list of them (a
sweep). Each spec is cost-model planned (Eq. 4 breakdown + regime;
Eq. 5–6 autotune when the spec asks) and then run on its declared
backend through ``repro.api.sweep`` — one process, shared dataset
cache across points.

``--plan-only`` stops after planning, which needs no devices and no
dataset materialization (the CI smoke path). ``--resume DIR`` persists
each finished point's report under DIR keyed by spec content hash:
interrupt the sweep anywhere (Ctrl-C, preemption, ``--max-points``)
and re-invoke with the same ``--resume`` to continue — finished points
are rehydrated, never re-run. ``--table`` prints the paper-style
time-to-loss table (§7.5) over the collected reports.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

from repro.api import ExperimentSpec, plan, sweep
from repro.core.objective import OBJECTIVES


def load_specs(path: Path) -> list[ExperimentSpec]:
    """One spec dict or a list of them → ExperimentSpecs (validated)."""
    raw = json.loads(path.read_text())
    if isinstance(raw, dict):
        raw = [raw]
    if not isinstance(raw, list):
        raise ValueError(f"{path}: expected a spec object or a list of them")
    return [ExperimentSpec.from_dict(d) for d in raw]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.launch.sweep", description="plan/run ExperimentSpecs from JSON"
    )
    ap.add_argument("--spec", required=True, type=Path, help="spec JSON (object or list)")
    ap.add_argument("--plan-only", action="store_true",
                    help="cost-model only — no build, no devices, no training")
    ap.add_argument("--out", type=Path, default=None,
                    help="write reports (JSON list) here")
    ap.add_argument("--resume", type=Path, default=None, metavar="DIR",
                    help="persist finished points here (keyed by spec content "
                         "hash) and skip them on re-invocation")
    ap.add_argument("--max-points", type=int, default=None, metavar="N",
                    help="run at most N unfinished points this invocation "
                         "(continue later with --resume)")
    ap.add_argument("--table", action="store_true",
                    help="print the paper-style time-to-loss table (§7.5)")
    ap.add_argument("--target-loss", type=float, default=None,
                    help="fallback target for --table points without a "
                         "stop.target_loss of their own")
    ap.add_argument("--objective", default=None, choices=sorted(OBJECTIVES),
                    help="override every loaded spec's convex objective "
                         "(repro.core.objective registry)")
    ap.add_argument("--l2", type=float, default=None, metavar="LAMBDA",
                    help="override every loaded spec's L2 coefficient")
    args = ap.parse_args(argv)

    specs = load_specs(args.spec)
    override = {}
    if args.objective is not None:
        override["objective"] = args.objective
    if args.l2 is not None:
        override["l2"] = args.l2
    if override:
        # replace() re-validates through __post_init__; the override
        # also moves each spec's content hash, so --resume dirs never
        # mix objectives.
        specs = [dataclasses.replace(s, **override) for s in specs]
    records = []
    for spec in specs:
        pl = plan(spec)
        print(f"[plan ] {pl.summary()}", flush=True)
        records.append({"spec": pl.spec.to_dict(),
                        "predicted_total_s": pl.cost.total, "regime": pl.regime})
    if args.plan_only:
        _finish(args, records, f"{len(records)} spec(s) planned")
        return

    result = sweep(specs, resume_dir=args.resume, max_points=args.max_points)
    for rep, was_resumed in zip(result.reports, result.resumed):
        tag = "skip " if was_resumed else "run  "
        print(f"[{tag}] {rep.summary()}", flush=True)
    for h in result.skipped:
        print(f"[defer] point {h} not reached (--max-points); re-invoke with "
              f"--resume to finish", flush=True)
    if args.table and result.reports:
        print(result.time_to_loss_table(target=args.target_loss))
    _finish(args, result.to_dict()["reports"], result.summary())


def _finish(args, records, summary: str) -> None:
    if args.out:
        args.out.write_text(json.dumps(records, indent=2))
        print(f"[done ] {summary} → {args.out}")
    else:
        print(f"[done ] {summary}")


if __name__ == "__main__":
    main()
