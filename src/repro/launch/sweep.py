"""Declarative experiment launcher: ``python -m repro.launch.sweep``.

Drives the repro.api front door from JSON spec files — the config-file
twin of ``repro.launch.train``'s flag-style CLI:

    PYTHONPATH=src python -m repro.launch.sweep --spec spec.json
    PYTHONPATH=src python -m repro.launch.sweep --spec sweep.json --out results.json
    PYTHONPATH=src python -m repro.launch.sweep --spec spec.json --plan-only
    PYTHONPATH=src python -m repro.launch.sweep --spec sweep.json --resume ckpt/ --table
    PYTHONPATH=src python -m repro.launch.sweep --spec spec.json --objective squared_hinge --l2 1e-3
    PYTHONPATH=src python -m repro.launch.sweep --spec sweep.json --timed --out measured.json
    PYTHONPATH=src python -m repro.launch.sweep --spec sweep.json --calibrate measured.json --plan-only

The spec file holds one ``ExperimentSpec`` dict or a list of them (a
sweep). Each spec is cost-model planned (Eq. 4 breakdown + regime;
Eq. 5–6 autotune when the spec asks) and then run on its declared
backend through ``repro.api.sweep`` — one process, shared dataset
cache across points.

``--plan-only`` stops after planning, which needs no devices and no
dataset materialization (the CI smoke path). ``--resume DIR`` persists
each finished point's report under DIR keyed by spec content hash:
interrupt the sweep anywhere (Ctrl-C, preemption, ``--max-points``)
and re-invoke with the same ``--resume`` to continue — finished points
are rehydrated, never re-run. A point that keeps failing is retried per
its spec's ``FaultPolicy`` and then quarantined (``[quar ]`` line; the
record lands in the ``--out`` dump) while the rest of the sweep
completes. ``--table`` prints the paper-style time-to-loss table (§7.5)
over the collected reports.

The communication loop closes here too: ``--timed`` runs every spec
with the timed collectives (per-round wall seconds land in each
report's CommLedger — persist with ``--out``), and ``--calibrate
report.json`` fits Hockney constants from such a prior run
(repro.costmodel.calibrate) and re-plans against the fitted machine,
printing the re-ranked prediction table. ``--calibrate`` requires
``--plan-only``: calibration re-ranks predictions, it never changes
what runs.

``--trace out.json`` records the whole run through the ``repro.obs``
span seam and writes a Perfetto-loadable Chrome trace (plus a
``out.jsonl`` event log), printing a greppable ``[trace]`` summary
line — the observability twin of ``--timed``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

from repro.api import ExperimentSpec, RunReport, calibrate, plan, sweep
from repro.core.objective import OBJECTIVES
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def load_specs(path: Path) -> list[ExperimentSpec]:
    """One spec dict or a list of them → ExperimentSpecs (validated)."""
    raw = json.loads(path.read_text())
    if isinstance(raw, dict):
        raw = [raw]
    if not isinstance(raw, list):
        raise ValueError(f"{path}: expected a spec object or a list of them")
    return [ExperimentSpec.from_dict(d) for d in raw]


def _report_dicts(raw) -> list[dict]:
    """Report dicts from any shape this CLI emits: one report, a list
    of them (--out), or a SweepReport dump ({"reports": [...]})."""
    if isinstance(raw, dict):
        if "reports" in raw:
            return list(raw["reports"])
        return [raw]
    if isinstance(raw, list):
        return list(raw)
    raise ValueError("expected a report object, a list of them, or a sweep dump")


def load_calibration(path: Path):
    """Fit machine constants from a prior run's persisted report(s):
    every report with a timed CommLedger becomes one calibration point
    (``RunReport.calibration_point``)."""
    points = []
    for d in _report_dicts(json.loads(path.read_text())):
        if "spec" not in d or "backend" not in d:
            continue  # plan-only records are not reports
        pt = RunReport.from_dict(d).calibration_point()
        if pt is not None:
            points.append(pt)
    if not points:
        raise SystemExit(
            f"--calibrate {path}: no timed ledgers found — produce one with "
            f"`repro.launch.sweep --spec ... --timed --out {path}`"
        )
    return calibrate(points)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.launch.sweep", description="plan/run ExperimentSpecs from JSON"
    )
    ap.add_argument("--spec", required=True, type=Path, help="spec JSON (object or list)")
    ap.add_argument("--plan-only", action="store_true",
                    help="cost-model only — no build, no devices, no training")
    ap.add_argument("--out", type=Path, default=None,
                    help="write results here (plan-only: a JSON list of plan "
                         "records; run: the full SweepReport dump, quarantine "
                         "records included)")
    ap.add_argument("--resume", type=Path, default=None, metavar="DIR",
                    help="persist finished points here (keyed by spec content "
                         "hash) and skip them on re-invocation")
    ap.add_argument("--max-points", type=int, default=None, metavar="N",
                    help="run at most N unfinished points this invocation "
                         "(continue later with --resume)")
    ap.add_argument("--table", action="store_true",
                    help="print the paper-style time-to-loss table (§7.5)")
    ap.add_argument("--target-loss", type=float, default=None,
                    help="fallback target for --table points without a "
                         "stop.target_loss of their own")
    ap.add_argument("--objective", default=None, choices=sorted(OBJECTIVES),
                    help="override every loaded spec's convex objective "
                         "(repro.core.objective registry)")
    ap.add_argument("--l2", type=float, default=None, metavar="LAMBDA",
                    help="override every loaded spec's L2 coefficient")
    ap.add_argument("--delay", type=int, default=None, metavar="D",
                    help="override every loaded spec's schedule.delay: the "
                         "DaSGD staleness D — (G, v) Allreduces issued at "
                         "bundle k are consumed at bundle k+D, overlapping "
                         "the collective with D bundles of Gram compute "
                         "(0 = synchronous; changes the iterates at D ≥ 1)")
    ap.add_argument("--timed", action="store_true",
                    help="run every spec with the timed collectives "
                         "(per-round wall into the report's CommLedger — "
                         "the --calibrate input)")
    ap.add_argument("--calibrate", type=Path, default=None, metavar="REPORT",
                    help="fit Hockney constants (α/β/γ) from a prior run's "
                         "report JSON (a --timed --out file) and plan "
                         "against the fitted machine instead of the preset "
                         "(requires --plan-only: calibration re-ranks "
                         "predictions, it does not change what runs)")
    ap.add_argument("--trace", type=Path, default=None, metavar="OUT.json",
                    help="record the run through the repro.obs tracing seam "
                         "and write a Chrome trace-event JSON here (loads in "
                         "Perfetto / chrome://tracing; a .jsonl event log "
                         "lands beside it)")
    args = ap.parse_args(argv)
    if args.calibrate is not None and not args.plan_only:
        # without this, the printed calibrated plans (incl. autotuned
        # schedules) would diverge from what the sweep then executes —
        # the run path plans with the preset machine.
        ap.error("--calibrate requires --plan-only")
    if args.trace is not None and args.plan_only:
        ap.error("--trace records a run — drop --plan-only")

    specs = load_specs(args.spec)
    override = {}
    if args.objective is not None:
        override["objective"] = args.objective
    if args.l2 is not None:
        override["l2"] = args.l2
    if args.timed:
        override["comm_timing"] = True
    if override:
        # replace() re-validates through __post_init__; the override
        # also moves each spec's content hash, so --resume dirs never
        # mix objectives (or timed with untimed runs).
        specs = [dataclasses.replace(s, **override) for s in specs]
    if args.delay is not None:
        # schedule-level override (same hash-moving property: a D ≥ 1
        # run never collides with a synchronous resume dir).
        specs = [
            dataclasses.replace(
                s, schedule=dataclasses.replace(s.schedule, delay=args.delay)
            )
            for s in specs
        ]

    calibration = None
    if args.calibrate is not None:
        calibration = load_calibration(args.calibrate)
        print(f"[cal  ] {calibration.summary()}", flush=True)

    records = []
    planned = []
    preset = [plan(s) for s in specs] if calibration is not None else None
    for i, spec in enumerate(specs):
        pl = plan(spec, calibration=calibration)
        planned.append(pl)
        print(f"[plan ] {pl.summary()}", flush=True)
        rec = {"spec": pl.spec.to_dict(),
               "predicted_total_s": pl.cost.total, "regime": pl.regime}
        if calibration is not None:
            rec["preset_total_s"] = preset[i].cost.total
            rec["calibration"] = calibration.to_dict()
        records.append(rec)
    if calibration is not None and len(planned) > 1:
        _print_reranked(planned, preset)
    if args.plan_only:
        _finish(args, records, f"{len(records)} spec(s) planned")
        return

    if args.trace is not None:
        with obs_trace.install() as rec:
            result = sweep(specs, resume_dir=args.resume, max_points=args.max_points)
        obs_export.write_chrome_trace(
            rec, args.trace, metrics=obs_metrics.registry().snapshot()
        )
        obs_export.write_jsonl(rec, args.trace.with_suffix(".jsonl"))
        print(obs_export.summary_line(rec), flush=True)
    else:
        result = sweep(specs, resume_dir=args.resume, max_points=args.max_points)
    for rep, was_resumed in zip(result.reports, result.resumed):
        tag = "skip " if was_resumed else "run  "
        print(f"[{tag}] {rep.summary()}", flush=True)
    for q in result.quarantined:
        print(f"[quar ] {q.name} ({q.spec_hash}) quarantined after "
              f"{q.attempts} attempt(s) at round {q.rounds_done}: {q.error}",
              flush=True)
    for h in result.skipped:
        print(f"[defer] point {h} not reached (--max-points); re-invoke with "
              f"--resume to finish", flush=True)
    if args.table and result.reports:
        print(result.time_to_loss_table(target=args.target_loss))
    # the full SweepReport dict (reports + quarantine records) is the
    # artifact CI uploads; _report_dicts/--calibrate accept this shape.
    _finish(args, result.to_dict(), result.summary())


def _print_reranked(planned, preset) -> None:
    """The calibrated ranking next to the preset one: which config the
    model now says to run, and whether the fitted constants moved it."""
    order_cal = sorted(range(len(planned)), key=lambda i: planned[i].cost.total)
    order_pre = sorted(range(len(preset)), key=lambda i: preset[i].cost.total)
    print(f"{'rank':>4s} {'point':24s} {'calibrated s/ep':>15s} "
          f"{'preset s/ep':>12s} {'preset rank':>11s}")
    for rank, i in enumerate(order_cal, 1):
        name = (planned[i].spec.name or planned[i].spec.dataset)[:24]
        moved = "" if order_pre[rank - 1] == i else "  ↕"
        print(f"{rank:>4d} {name:24s} {planned[i].cost.total:>15.4g} "
              f"{preset[i].cost.total:>12.4g} {order_pre.index(i) + 1:>11d}{moved}")


def _finish(args, records, summary: str) -> None:
    if args.out:
        args.out.write_text(json.dumps(records, indent=2))
        print(f"[done ] {summary} → {args.out}")
    else:
        print(f"[done ] {summary}")


if __name__ == "__main__":
    main()
