"""Declarative experiment launcher: ``python -m repro.launch.sweep``.

Drives the repro.api front door from JSON spec files — the config-file
twin of ``repro.launch.train``'s flag-style CLI:

    PYTHONPATH=src python -m repro.launch.sweep --spec spec.json
    PYTHONPATH=src python -m repro.launch.sweep --spec sweep.json --out results.json
    PYTHONPATH=src python -m repro.launch.sweep --spec spec.json --plan-only

The spec file holds one ``ExperimentSpec`` dict or a list of them (a
sweep). Each spec is cost-model planned (Eq. 4 breakdown + regime;
Eq. 5–6 autotune when the spec asks) and then run on its declared
backend — ``--plan-only`` stops after planning, which needs no devices
and no dataset materialization (the CI smoke path).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.api import ExperimentSpec, plan, run


def load_specs(path: Path) -> list[ExperimentSpec]:
    """One spec dict or a list of them → ExperimentSpecs (validated)."""
    raw = json.loads(path.read_text())
    if isinstance(raw, dict):
        raw = [raw]
    if not isinstance(raw, list):
        raise ValueError(f"{path}: expected a spec object or a list of them")
    return [ExperimentSpec.from_dict(d) for d in raw]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.launch.sweep", description="plan/run ExperimentSpecs from JSON"
    )
    ap.add_argument("--spec", required=True, type=Path, help="spec JSON (object or list)")
    ap.add_argument("--plan-only", action="store_true",
                    help="cost-model only — no build, no devices, no training")
    ap.add_argument("--out", type=Path, default=None,
                    help="write reports (JSON list) here")
    args = ap.parse_args(argv)

    specs = load_specs(args.spec)
    records = []
    for spec in specs:
        pl = plan(spec)
        print(f"[plan ] {pl.summary()}", flush=True)
        if args.plan_only:
            records.append({"spec": pl.spec.to_dict(), "predicted_total_s": pl.cost.total,
                            "regime": pl.regime})
            continue
        report = run(spec)
        print(f"[run  ] {report.summary()}", flush=True)
        records.append(report.to_dict())

    if args.out:
        args.out.write_text(json.dumps(records, indent=2))
        print(f"[done ] {len(records)} record(s) → {args.out}")
    else:
        print(f"[done ] {len(records)} spec(s) processed")


if __name__ == "__main__":
    main()
