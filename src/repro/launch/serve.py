"""Serving launcher: batched prefill + decode on real devices.

``python -m repro.launch.serve --arch gemma-2b --prompt-len 64 --gen 32``
uses the reduced config on CPU; --full targets real accelerators.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models.init import init_params
from repro.models.transformer import decode_step, forward, init_cache


def serve_batch(cfg, params, prompts: jnp.ndarray, gen: int, max_len: int):
    """Greedy-decode ``gen`` tokens for a batch of prompts."""
    B, S = prompts.shape
    cache = init_cache(cfg, batch=B, max_len=max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    # prefill by stepping (simple reference server; production prefill
    # would batch-process the prompt — see launch/steps.make_prefill_step)
    tok = prompts[:, :1]
    for i in range(S):
        logits, cache = step(params, cache, prompts[:, i : i + 1])
    out = [jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)]
    for _ in range(gen - 1):
        logits, cache = step(params, cache, out[-1])
        out.append(jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32))
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = serve_batch(cfg, params, prompts, args.gen, args.prompt_len + args.gen + 1)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
