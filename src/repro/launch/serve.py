"""Serving-plane launcher: stream, train, and serve in one process.

``python -m repro.launch.serve --spec examples/specs/serve_drift.json``
builds the spec's ``Session``, attaches its declared stream source
(``spec.stream``), starts the batched prediction service (plus the
stdlib HTTP front when ``--port`` is given), and runs the
``OnlineController`` interleave loop: one training round per
micro-batch, hot-swapping the served model per the freshness policy,
probing held-out accuracy against the stream's current concept as it
goes. The probe lines make drift recovery visible:

    [probe] round=12 acc=0.91 model_version=4 ...
    [swap ] round=16 version=5 ...

The transformer text-serving demo that used to live here predated the
paper pipeline and was removed; for transformer step benchmarks
(``--arch``-style configs) use ``python -m repro.launch.steps``.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.api import ExperimentSpec, Session
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import (
    DriftStream,
    ModelStore,
    OnlineController,
    PredictionService,
    make_stream_source,
    serve_http,
)


def probe_accuracy(service: PredictionService, source, batch_index: int) -> float:
    """Held-out accuracy against the stream's *current* concept: draw a
    fresh micro-batch (an index the trainer never consumes) and compare
    the service's labels to the generator's."""
    batch = source.batch(batch_index)
    res = service.predict(batch.indices, batch.values)
    return float(np.mean(res.labels == batch.y))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="stream → train → hot-swap → predict, one process"
    )
    ap.add_argument("--spec", required=True, help="ExperimentSpec JSON (with stream)")
    ap.add_argument("--rounds", type=int, default=None, help="stream rounds to train")
    ap.add_argument("--port", type=int, default=None,
                    help="also serve HTTP on this port (0 = ephemeral)")
    ap.add_argument("--swap-every", type=int, default=None,
                    help="override the spec's freshness cadence")
    ap.add_argument("--probe-every", type=int, default=4,
                    help="probe served accuracy every N rounds (0 = off)")
    ap.add_argument("--swap-dir", default=None, help="where swap checkpoints land")
    ap.add_argument("--out", default=None, help="write final metrics JSON here")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record the run through the repro.obs tracing seam "
                         "and write a Chrome trace-event JSON here (loads in "
                         "Perfetto; a .jsonl event log lands beside it)")
    args = ap.parse_args(argv)

    spec = ExperimentSpec.from_json(Path(args.spec).read_text())
    if not spec.stream.enabled:
        ap.error("spec has no stream attached (stream.source='')")
    source = make_stream_source(spec)

    session = Session(spec)
    store = ModelStore()
    http_server = None
    # the recorder installs as the module-global fallback too, so spans
    # from the feed producer and predict-batcher threads land in it.
    recorder = obs_trace.TraceRecorder() if args.trace else None
    with contextlib.ExitStack() as stack:
        if recorder is not None:
            stack.enter_context(obs_trace.install(recorder))
        service = stack.enter_context(PredictionService(store))
        if args.port is not None:
            http_server, _ = serve_http(service, port=args.port)
            host, port = http_server.server_address[:2]
            print(f"[serve] http://{host}:{port}  (POST /predict, GET /healthz /stats)")

        ctrl = OnlineController(
            session, source, store, service=service,
            swap_every=args.swap_every, swap_dir=args.swap_dir,
        )
        rounds = args.rounds if args.rounds is not None else session.total_rounds
        print(
            f"[start] dataset={spec.dataset} stream={spec.stream.source} "
            f"rows/round={spec.stream_rows_per_round()} rounds={rounds} "
            f"swap_every={ctrl.swap_every}"
        )

        # drive round-by-round so probes and swap lines interleave live
        t0 = time.perf_counter()
        done = 0
        probing = args.probe_every > 0 and isinstance(source, DriftStream)
        while done < rounds and not session.done:
            before = store.swaps
            ev = ctrl.step()
            done += 1
            if store.swaps > before:
                print(f"[swap ] round={session.rounds_done} version={store.version}")
            if probing and session.rounds_done % args.probe_every == 0:
                acc = probe_accuracy(service, source, session.rounds_done)
                loss = session.losses[-1] if session.losses else float("nan")
                print(
                    f"[probe] round={session.rounds_done} acc={acc:.3f} "
                    f"holdout_loss={loss:.4f} model_version={store.version}"
                )
            if ev.stop:
                break

        m = ctrl.finish()
        elapsed = time.perf_counter() - t0
        print(
            f"[done ] rounds={m.rounds_done} swaps={m.swaps} "
            f"failed_swaps={m.failed_swaps} staleness={m.staleness_rounds} "
            f"rounds/s={m.rounds_per_sec:.2f} "
            f"predictions={m.predictions_served} wall={elapsed:.1f}s"
        )
        if args.out:
            payload = {"metrics": m.to_dict(), "feed": ctrl.feed.stats(),
                       "service": service.stats(), "store": store.stats()}
            Path(args.out).write_text(json.dumps(payload, indent=2))
            print(f"[out  ] {args.out}")
        if http_server is not None:
            http_server.shutdown()
    if recorder is not None:
        out = Path(args.trace)
        obs_export.write_chrome_trace(
            recorder, out, metrics=obs_metrics.registry().snapshot()
        )
        obs_export.write_jsonl(recorder, out.with_suffix(".jsonl"))
        print(obs_export.summary_line(recorder), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
