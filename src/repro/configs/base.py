"""Config helpers: reduced smoke variants + SWA overlay."""

from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, LayerSpec, MLAConfig, MambaConfig, MoEConfig


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant of the same family: ≤2 layers (one period for
    heterogeneous periods, truncated to 2 specs), d_model ≤ 512,
    ≤4 experts — runs a forward/train step on CPU in seconds."""
    period = cfg.period if len(cfg.period) <= 2 else cfg.period[:2]
    # keep at least one of each mixer present in the original period
    mixers = {s.mixer for s in cfg.period}
    if len(mixers) > 1 and {s.mixer for s in period} != mixers:
        attn = next(s for s in cfg.period if s.mixer == "attn")
        mamba = next(s for s in cfg.period if s.mixer == "mamba")
        period = (attn, mamba)
    n_layers = len(period)
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    head_dim = 64 if cfg.head_dim else 0
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=min(cfg.moe.d_ff_expert, 128),
            n_shared=min(cfg.moe.n_shared, 1),
        )
    mla = MLAConfig(kv_lora_rank=64, qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32) if cfg.mla else None
    mamba = MambaConfig(d_state=cfg.mamba.d_state, d_conv=cfg.mamba.d_conv, expand=2) if cfg.mamba else None
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        period=period,
        moe=moe,
        mla=mla,
        mamba=mamba,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        max_seq_len=256,
    )


def with_sliding_window(cfg: ArchConfig, window: int) -> ArchConfig:
    """Overlay: convert all full-attention layers to sliding-window —
    the sub-quadratic variant used for long_500k on dense archs
    (DESIGN.md §4: mistral-nemo)."""
    period = tuple(
        dataclasses.replace(s, attn="swa") if s.mixer == "attn" and s.attn == "full" else s
        for s in cfg.period
    )
    return dataclasses.replace(
        cfg, name=cfg.name + f"-swa{window}", period=period, sliding_window=window
    )
