"""Gemma-2B [arXiv:2403.08295] — GeGLU, head_dim=256, MQA (kv=1),
256k vocab, tied embeddings."""

from repro.models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="gemma-2b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=256_000,
    period=(LayerSpec(),),
    mlp_act="gelu",
    tie_embeddings=True,
)
