"""Jamba-1.5-Large 398B [arXiv:2403.19887] — hybrid Mamba+attention at
a 7:1 ratio, MoE (16 experts, top-2) on every other layer.

Period of 8: position 0 is the attention layer, 1-7 Mamba; odd
positions carry MoE FFNs, even positions dense FFNs.
"""

from repro.models.config import ArchConfig, LayerSpec, MambaConfig, MoEConfig

_PERIOD = tuple(
    LayerSpec(
        mixer="attn" if i == 0 else "mamba",
        attn="full",
        ff="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    period=_PERIOD,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24_576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)
