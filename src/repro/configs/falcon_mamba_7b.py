"""Falcon-Mamba-7B [arXiv:2410.05355] — pure Mamba-1 (attention-free),
64 layers, d_state=16, d_inner=2·d_model. No FFN (the Mamba block is
the whole layer)."""

from repro.models.config import ArchConfig, LayerSpec, MambaConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65_024,
    period=(LayerSpec(mixer="mamba", ff="none"),),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)
