"""MusicGen-medium [arXiv:2306.05284] — decoder-only transformer over
EnCodec audio tokens (vocab 2048).

Frontend stub (DESIGN.md §4): the EnCodec tokenizer is out of scope —
input_specs feeds token ids directly. Deviations: single codebook
stream (the real model interleaves 4 codebooks with a delay pattern)
and no text-conditioning cross-attention.
"""

from repro.models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    period=(LayerSpec(),),
    mlp_act="gelu",
    frontend="audio",
)
