"""DeepSeek-V2-Lite 16B [arXiv:2405.04434] — MoE with multi-head latent
attention (MLA, kv_lora=512), 2 shared + 64 routed experts, top-6.

Deviations noted in DESIGN.md: (a) the real model's first layer uses a
dense FFN; here every layer is MoE (uniform period keeps the scan
square); (b) the assignment lists both "64e" (structured field) and
"160 routed" (bracket note — that is the full V2, not Lite); we use 64,
which reproduces the 16B total-parameter count.
"""

from repro.models.config import ArchConfig, LayerSpec, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    period=(LayerSpec(mixer="attn", attn="mla", ff="moe"),),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    mlp_act="silu",
)
