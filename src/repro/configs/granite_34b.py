"""Granite-34B-code [arXiv:2405.04324] — 88-layer dense MQA (kv=1)."""

from repro.models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-34b",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24_576,
    vocab_size=49_152,
    period=(LayerSpec(),),
)
