"""Qwen2.5-3B [hf:Qwen/Qwen2.5-0.5B family] — dense GQA (kv=2) with QKV
bias."""

from repro.models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11_008,
    vocab_size=151_936,
    period=(LayerSpec(),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
