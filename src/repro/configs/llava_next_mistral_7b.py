"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b]
— VLM: the assigned scope is the language decoder; the SigLIP/CLIP
vision tower is a STUB. input_specs supplies precomputed anyres patch
embeddings (up to 5 tiles × 576 patches = 2880 prefix positions) which
pass through a trainable linear projector.
"""

from repro.models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    period=(LayerSpec(),),
    rope_theta=1_000_000.0,
    frontend="vision",
)
