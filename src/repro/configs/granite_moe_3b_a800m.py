"""Granite-MoE 3B-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base] —
40 experts, top-8, expert FFN width 512, tied embeddings.

(The assignment lists both "MoE 40e" and "32 experts"; we follow the
structured field: 40 experts — noted in DESIGN.md.)
"""

from repro.models.config import ArchConfig, LayerSpec, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    period=(LayerSpec(ff="moe"),),
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
    tie_embeddings=True,
)
