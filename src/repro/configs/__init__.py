"""Assigned-architecture registry: ``get_config(name)`` /
``REGISTRY``. Every entry cites its source in the module docstring."""

from repro.configs.base import reduced, with_sliding_window
from repro.configs.deepseek_v2_lite_16b import CONFIG as deepseek_v2_lite_16b
from repro.configs.musicgen_medium import CONFIG as musicgen_medium
from repro.configs.qwen2_5_3b import CONFIG as qwen2_5_3b
from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.jamba_1_5_large_398b import CONFIG as jamba_1_5_large_398b
from repro.configs.granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from repro.configs.llava_next_mistral_7b import CONFIG as llava_next_mistral_7b
from repro.configs.gemma_2b import CONFIG as gemma_2b
from repro.configs.falcon_mamba_7b import CONFIG as falcon_mamba_7b
from repro.configs.mistral_nemo_12b import CONFIG as mistral_nemo_12b

REGISTRY = {
    c.name: c
    for c in (
        deepseek_v2_lite_16b,
        musicgen_medium,
        qwen2_5_3b,
        granite_34b,
        jamba_1_5_large_398b,
        granite_moe_3b_a800m,
        llava_next_mistral_7b,
        gemma_2b,
        falcon_mamba_7b,
        mistral_nemo_12b,
    )
}


def get_config(name: str):
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = ["REGISTRY", "get_config", "reduced", "with_sliding_window"]
