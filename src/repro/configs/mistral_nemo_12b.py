"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense GQA
(kv=8), head_dim=128, 128k context.

long_500k qualification (DESIGN.md §4): the real model is full
attention; we provide a sliding-window (SWA-4096) variant via
configs.base.with_sliding_window for the 500k-decode shape, and run all
other shapes full-attention.
"""

from repro.models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    period=(LayerSpec(),),
    rope_theta=1_000_000.0,
)
