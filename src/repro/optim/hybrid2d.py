"""Hybrid 2D training — the paper's HybridSGD mesh semantics applied to
NN training (DESIGN.md §2 "Generalization to NN training").

Axis mapping (the paper → this trainer):

  row teams p_r   → the "pod" mesh axis: each pod is a FedAvg group.
                    Parameters carry a leading n_pods dim sharded
                    P("pod", ...); each pod trains on its local batch
                    shard with NO cross-pod communication for τ steps.
  column axis p_c → the "model" (+ FSDP "data") axes: exact sharded
                    compute inside the pod; gradient/TP collectives stay
                    on fast intra-pod ICI — the topology rule (Eq. 7).
  τ sync          → sync_step(): parameter mean over the pod dim — one
                    n/p_c-sized payload per rank over the slow DCI,
                    amortized 1/τ, exactly the paper's column Allreduce.

The s-step Gram identity is exact only for the convex core; here the
row-team inner solver is plain local SGD (the FedAvg limit), which is
the honest NN analogue (noted in DESIGN.md §4).

Implementation: shard_map with axis_names={"pod"} — the pod axis is
manual (so per-pod params can drift; replication checking off) while
"data" and "model" stay auto-sharded (GSPMD inserts the intra-pod
collectives). On a single-pod mesh this degenerates to standard 2D
data×model training (n_pods = 1).

The schedule knobs are the engine's ParallelSGDSchedule
(repro.core.engine) — the same (p_r, p_c, s, τ) object drives the
convex solver family and this trainer, with p_r ↦ n_pods and τ ↦ the
pod-sync period.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.engine import ParallelSGDSchedule
from repro.optim.sgd import Optimizer


def _pod_axis(mesh) -> tuple[str | None, int]:
    if "pod" in mesh.axis_names:
        i = mesh.axis_names.index("pod")
        return "pod", mesh.axis_sizes[i] if hasattr(mesh, "axis_sizes") else tuple(mesh.shape.values())[i]
    return None, 1


def stack_for_pods(params: Any, n_pods: int) -> Any:
    """Give every pod its own replica: leading n_pods dim, P('pod', ...)."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (n_pods,) + p.shape), params)


def make_hybrid_train_step(
    mesh,
    loss_fn: Callable[..., jnp.ndarray],  # loss_fn(params, *batch) -> scalar
    opt: Optimizer,
):
    """Returns train_step((params_stacked, opt_state_stacked), *batch)
    → ((params, opt_state), loss). Batch leading dim is global-batch,
    sharded over ("pod", "data")."""
    pod_name, n_pods = _pod_axis(mesh)

    def local_step(params, opt_state, batch):
        # inside shard_map over "pod": params have their leading pod dim
        # sliced to 1 — squeeze, step locally, restore. The batch leaves
        # arrive with dim0 already cut to this pod's share.
        params = jax.tree.map(lambda p: p[0], params)
        opt_state = jax.tree.map(lambda s: s[0], opt_state)
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        new_params, new_state = opt.update(grads, opt_state, params)
        expand = lambda t: jax.tree.map(lambda x: x[None], t)
        return expand(new_params), expand(new_state), loss[None]

    if pod_name is None:
        # single pod: ordinary jit step (GSPMD handles data/model axes)
        def train_step(state, batch):
            params, opt_state = state
            loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
            new_params, new_state = opt.update(grads, opt_state, params)
            return (new_params, new_state), loss

        return jax.jit(train_step, donate_argnums=(0,))

    smapped = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P("pod"), P("pod"), P("pod")),
        out_specs=(P("pod"), P("pod"), P("pod")),
        axis_names={"pod"},
    )

    def train_step(state, batch):
        params, opt_state = state
        new_params, new_state, losses = smapped(params, opt_state, batch)
        return (new_params, new_state), jnp.mean(losses)

    return jax.jit(train_step, donate_argnums=(0,))


def make_sync_step(mesh):
    """The τ-deferred column Allreduce: average each parameter across
    its pod replicas (one cross-DCI collective per τ local steps)."""
    pod_name, n_pods = _pod_axis(mesh)
    if pod_name is None:
        return jax.jit(lambda params: params)

    def sync(params):
        return jax.tree.map(
            lambda p: jnp.broadcast_to(jnp.mean(p, axis=0, keepdims=True), p.shape), params
        )

    return jax.jit(sync, donate_argnums=(0,))


def HybridSchedule(tau: int = 10, s: int = 1) -> ParallelSGDSchedule:
    """Deprecated constructor preserving the old (tau, s) signature.

    The NN trainer now shares the engine's schedule object: p_r ↦
    n_pods, b ↦ per-pod batch, s ↦ gradient-accumulation microsteps
    (the inexact NN analogue of the s-step bundle), τ ↦ the pod-sync
    period. New code should build ParallelSGDSchedule directly."""
    return ParallelSGDSchedule(s=s, tau=tau)
