"""Optimizers + the paper's hybrid 2D trainer for NN training."""

from repro.optim.sgd import Optimizer, adamw, momentum, sgd
from repro.optim.hybrid2d import (
    HybridSchedule,
    make_hybrid_train_step,
    make_sync_step,
    stack_for_pods,
)

__all__ = [
    "Optimizer",
    "adamw",
    "momentum",
    "sgd",
    "HybridSchedule",
    "make_hybrid_train_step",
    "make_sync_step",
    "stack_for_pods",
]
