"""Optimizers (pure pytree transforms — no external deps).

The paper is an SGD paper: plain SGD and momentum-SGD are the defaults
(and keep dry-run memory at 1-2× params). AdamW is provided for the LM
examples.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params) -> (new_params, new_state)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params):
        vel = jax.tree.map(lambda v, g: beta * v + g.astype(v.dtype), state, grads)
        new = jax.tree.map(lambda p, v: p - lr * v, params, vel)
        return new, vel

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, wd: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["nu"], grads)
        bc1 = 1 - b1**t.astype(jnp.float32)
        bc2 = 1 - b2**t.astype(jnp.float32)

        def step(p, m, v):
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return (p - lr * (upd + wd * p.astype(jnp.float32))).astype(p.dtype)

        new = jax.tree.map(step, params, mu, nu)
        return new, {"mu": mu, "nu": nu, "t": t}

    return Optimizer(init, update)
