"""repro.api — one front door for the whole (p_r, p_c, s, τ) family.

    spec  = ExperimentSpec(dataset="rcv1-sm",
                           schedule=ParallelSGDSchedule.hybrid(...),
                           mesh=MeshSpec(p_r=4, p_c=2, backend="simulated"))
    plan  = repro.api.plan(spec)     # Eq. 4 cost + regime (+ Eq. 5–6 autotune)
    report = repro.api.run(spec)     # build → dispatch → RunReport

The same spec runs on either backend ("simulated" engine oracle or the
"shard_map" 2D device mesh) and returns the same ``RunReport``; specs
JSON round-trip for reproducible configs (``python -m
repro.launch.sweep --spec spec.json``). See docs/api.md.
"""

from repro.api.spec import BACKENDS, ExperimentSpec, MeshSpec, dataset_stats
from repro.api.plan import Plan, plan
from repro.api.report import RunReport, modeled_comm_words
from repro.api.run import ProblemBundle, build_problem, run

__all__ = [
    "BACKENDS",
    "ExperimentSpec",
    "MeshSpec",
    "dataset_stats",
    "Plan",
    "plan",
    "RunReport",
    "modeled_comm_words",
    "ProblemBundle",
    "build_problem",
    "run",
]
