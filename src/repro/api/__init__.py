"""repro.api — one front door for the whole (p_r, p_c, s, τ) family.

    spec  = ExperimentSpec(dataset="rcv1-sm",
                           schedule=ParallelSGDSchedule.hybrid(...),
                           mesh=MeshSpec(p_r=4, p_c=2, backend="simulated"))
    plan  = repro.api.plan(spec)     # Eq. 4 cost + regime (+ Eq. 5–6 autotune)
    report = repro.api.run(spec)     # build → session loop → RunReport

The execution lifecycle is round-incremental underneath: ``Session``
exposes it (step_rounds / save / restore / report), ``run`` is a thin
loop over it honoring the spec's ``StopPolicy`` (target_loss /
max_seconds / max_rounds), and ``sweep`` drives many specs with a
shared dataset cache and interrupt/resume. The same spec runs on either
backend ("simulated" engine oracle or the "shard_map" 2D device mesh)
and returns the same ``RunReport``; the convex loss is a spec field
(``objective`` + ``l2``, repro.core.objective — logistic default,
squared-hinge SVM, least squares); specs JSON round-trip for
reproducible configs (``python -m repro.launch.sweep --spec
spec.json``). See docs/api.md.
"""

from repro.api.spec import (
    BACKENDS,
    ExperimentSpec,
    FaultPolicy,
    MeshSpec,
    StopPolicy,
    StreamSpec,
    dataset_stats,
)
from repro.api.plan import Plan, plan, replan_mesh
from repro.api.report import RunReport, modeled_comm_words
from repro.api.run import ProblemBundle, build_problem, run, run_decaying_tau
from repro.api.session import RoundEvent, Session, autosave_base
from repro.api.sweep import QuarantineRecord, SweepReport, sweep
from repro.core.comm import CommLedger
from repro.costmodel.calibrate import CalPoint, Calibration, calibrate

__all__ = [
    "BACKENDS",
    "ExperimentSpec",
    "FaultPolicy",
    "MeshSpec",
    "StopPolicy",
    "StreamSpec",
    "dataset_stats",
    "Plan",
    "plan",
    "replan_mesh",
    "RunReport",
    "modeled_comm_words",
    "CommLedger",
    "CalPoint",
    "Calibration",
    "calibrate",
    "ProblemBundle",
    "build_problem",
    "run",
    "run_decaying_tau",
    "RoundEvent",
    "Session",
    "autosave_base",
    "QuarantineRecord",
    "SweepReport",
    "sweep",
]
