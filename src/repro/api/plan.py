"""plan(spec) — the cost-model stage of the front door.

Runs the paper's closed-form α-β-γ machinery (Eq. 4 via
``repro.costmodel.hockney.hybrid_epoch_cost``; regime classification
per Table 5) on the spec's registered dataset statistics, and — when
``spec.autotune`` — rewrites the schedule's (s, b) to the Eq. 5–6
optima before anything is built or run. ``run`` calls ``plan`` first,
so every run carries its predicted cost breakdown in the report.
"""

from __future__ import annotations

import dataclasses
import math

from repro.costmodel.calibrate import Calibration
from repro.costmodel.hockney import (
    CostBreakdown,
    HybridConfig,
    hybrid_epoch_cost,
    recommend_delay,
)
from repro.costmodel.machines import MACHINES, Machine
from repro.costmodel.optimum import classify_regime, joint_sb_star
from repro.api.spec import ExperimentSpec, dataset_stats


@dataclasses.dataclass(frozen=True)
class Plan:
    """The planned experiment: the (possibly retuned) spec plus the
    model's predictions for it.

    spec      the spec that ``run`` will execute — if autotune rewrote
              (s, b), this is the rewritten spec (``autotuned`` True).
    cost      Eq. 4 per-epoch CostBreakdown at the spec's operating
              point on ``spec.machine``.
    regime    dominant cost term (Table 5): compute | latency |
              gram_bw | sync_bw.
    balance   bandwidth-balance ratio (s-1)·s·b²·τ·p_c / 2n.
    s_star, b_star   raw Eq. 5–6 optima (before integer snapping);
              None when autotune is off.
    recommended_delay   the model's suggested DaSGD staleness D — the
              smallest D whose overlap window covers the Gram-phase
              comm (0 when the mesh has no column shards to reduce
              over). Advisory: ``plan`` never rewrites the schedule's
              ``delay`` (staleness changes the iterates, so opting in
              is the user's call — unlike the loss-neutral (s, b)
              autotune).
    """

    spec: ExperimentSpec
    cost: CostBreakdown
    regime: str
    balance: float
    autotuned: bool = False
    s_star: float | None = None
    b_star: float | None = None
    calibrated: bool = False
    recommended_delay: int = 0
    # (bk, bm) from the kernel tuner's disk cache when schedule.bk=None
    # opted in and a cached winner exists; None = tune (or fall back to
    # the static 512) at build time. plan() only *reads* the cache —
    # planning stays pure.
    tuned_panel: tuple | None = None

    def summary(self) -> str:
        sched, mesh = self.spec.schedule, self.spec.mesh
        tag = f" [autotuned s*={self.s_star:.2f} b*={self.b_star:.2f}]" if self.autotuned else ""
        if sched.delay or self.recommended_delay:
            tag += (
                f" [delay D={sched.delay}, hides {self.cost.overlap_saved:.3g} s/epoch; "
                f"model recommends D={self.recommended_delay}]"
            )
        if sched.bk is None:
            if self.tuned_panel is not None:
                bk, bm = self.tuned_panel
                tag += f" [panel bk=auto→{bk} bm={bm} (tuner cache)]"
            else:
                tag += " [panel bk=auto (tuned at build)]"
        if sched.precision != "fp32":
            tag += f" [precision={sched.precision}: 2-byte Gram wire words]"
        machine = self.spec.machine + ("+calibrated" if self.calibrated else "")
        return (
            f"{self.spec.name or self.spec.dataset}: mesh {mesh.p_r}×{mesh.p_c} "
            f"({mesh.backend}), s={sched.s} b={sched.b} τ={sched.tau} → predicted "
            f"{self.cost.total:.3g} s/epoch on {machine} "
            f"(dominant: {self.regime}, balance {self.balance:.2f}){tag}"
        )


def _autotune_schedule(spec: ExperimentSpec, machine: Machine) -> tuple[ExperimentSpec, float, float]:
    """Rewrite (s, b) to the Eq. 5–6 joint optimum, snapped to a valid
    schedule (s ≥ 1, s | τ, b ≥ 1)."""
    sched, mesh = spec.schedule, spec.mesh
    st = dataset_stats(spec.dataset)
    s_raw, b_raw = joint_sb_star(
        sched.tau, mesh.p_r, mesh.p_c, st.n, machine, s0=sched.s, b0=sched.b
    )
    s_new = sched.s if not math.isfinite(s_raw) else max(1, min(int(round(s_raw)), sched.tau))
    while sched.tau % s_new:  # snap down to a divisor of τ (s | τ)
        s_new -= 1
    b_new = sched.b if not math.isfinite(b_raw) else max(1, int(round(b_raw)))
    new_sched = dataclasses.replace(sched, s=s_new, b=b_new)
    return dataclasses.replace(spec, schedule=new_sched), s_raw, b_raw


def replan_mesh(
    spec: ExperimentSpec,
    devices: int,
    calibration: Calibration | None = None,
    backend: str | None = None,
) -> Plan:
    """Elastic re-planning: the mesh changed size (a preemption lost
    workers, or capacity arrived) — price every (p_r, p_c) factorization
    of ``devices`` under the (optionally §6.5-calibrated) Eq. 4 model
    and return the cheapest point's Plan.

    The winning geometry is written into both the mesh and the schedule
    (``schedule.p_r`` follows ``mesh.p_r``: row teams are a numerical
    knob, so an elastic resume at a different p_r continues the
    *optimization*, not the bitwise trajectory — the Session layer
    guarantees bitwise resumption only at an unchanged mesh). Pure
    planning: nothing is built or run — ``Session.restore_elastic``
    does the rebuild/remap."""
    devices = int(devices)
    if devices < 1:
        raise ValueError(f"replan_mesh needs ≥ 1 device, got {devices}")
    best: Plan | None = None
    for p_r in range(1, devices + 1):
        if devices % p_r:
            continue
        p_c = devices // p_r
        cand = dataclasses.replace(
            spec,
            schedule=dataclasses.replace(spec.schedule, p_r=p_r, p_c=p_c),
            mesh=dataclasses.replace(
                spec.mesh, p_r=p_r, p_c=p_c,
                backend=backend if backend is not None else spec.mesh.backend,
            ),
        )
        pl = plan(cand, calibration=calibration)
        if best is None or pl.cost.total < best.cost.total:
            best = pl
    return best


def plan(spec: ExperimentSpec, calibration: Calibration | None = None) -> Plan:
    """Cost-model the spec (and auto-tune it when asked). Pure planning:
    nothing is built, placed, or run — safe as a CI dry-run.

    ``calibration`` (repro.costmodel.calibrate — fitted from a timed
    run's CommLedger) re-targets the spec's machine with measured α/β/γ
    before anything is predicted, so planned sweeps rank configurations
    with machine-fitted constants instead of the static presets; the
    Eq. 5–6 autotune then also optimizes against the fitted machine."""
    machine = MACHINES[spec.machine]
    if calibration is not None:
        machine = calibration.machine(machine)
    s_raw = b_raw = None
    autotuned = False
    if spec.autotune:
        spec, s_raw, b_raw = _autotune_schedule(spec, machine)
        autotuned = True
    st = dataset_stats(spec.dataset)
    sched, mesh = spec.schedule, spec.mesh
    cfg = HybridConfig(p_r=mesh.p_r, p_c=mesh.p_c, s=sched.s, b=sched.b, tau=sched.tau)
    cost = hybrid_epoch_cost(
        st.m, st.n, st.zbar, cfg, machine, delay=sched.delay,
        # bf16 schedules ship 2-byte Gram words: the β·bytes Gram term
        # halves, the fp32 weight sync is unchanged (Tables 2–3 word
        # counts are precision-invariant — only the byte pricing moves).
        gram_word_bytes=2 if sched.precision == "bf16" else None,
    )
    regime = classify_regime(st.m, st.n, st.zbar, cfg, machine)
    tuned_panel = None
    if sched.bk is None:
        # read-only probe of the kernel tuner's cache (never tunes here)
        from repro.kernels.tune import PanelProfile, lookup_panel

        rec = lookup_panel(PanelProfile.from_stats(st, sched, mesh.p_c))
        if rec is not None:
            tuned_panel = (rec["bk"], rec["bm"])
    return Plan(
        spec=spec,
        cost=cost,
        regime=regime.name,
        balance=regime.balance,
        autotuned=autotuned,
        s_star=s_raw,
        b_star=b_raw,
        calibrated=calibration is not None,
        recommended_delay=recommend_delay(st.m, st.n, st.zbar, cfg, machine),
        tuned_panel=tuned_panel,
    )
