"""The declarative experiment spec — the front door's input language.

An ``ExperimentSpec`` is everything needed to reproduce one run of the
(p_r, p_c, s, τ) family: the dataset (by registered name + seed), the
``ParallelSGDSchedule`` (the same knob object the engine executes), the
``MeshSpec`` (geometry + which execution backend realizes it), and the
``Machine`` (by name) the cost model plans against.

Specs JSON round-trip (``to_dict``/``from_dict``/``to_json``/
``from_json``) so a run is reproducible from a config file:

    spec = ExperimentSpec.from_json(Path("spec.json").read_text())
    report = repro.api.run(spec)

Geometry lives in one place: ``MeshSpec`` is authoritative for
(p_r, p_c). The schedule's ``p_r`` must agree (it is a numerical knob —
row teams change the iterates); the schedule's ``p_c`` is
communication-only and is canonicalized from the mesh.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math

from repro.core.engine import ParallelSGDSchedule
from repro.core.objective import OBJECTIVES
from repro.costmodel.machines import MACHINES
from repro.sparse.partition import PARTITIONERS
from repro.sparse.synthetic import dataset_stats

BACKENDS = ("simulated", "shard_map")


@dataclasses.dataclass(frozen=True)
class StopPolicy:
    """When to stop *before* the schedule's round budget runs out.

    The schedule's ``rounds`` is the hard budget (the compiled loop
    shape); the policy ends the run early at round granularity — the
    paper's §7.5 time-to-loss protocol made first-class instead of
    being post-hoc arithmetic on a finished trace.

    target_loss  stop once a sampled full objective ≤ this (needs
                 ``schedule.loss_every > 0`` — the objective is only
                 observable on sampling boundaries).
    max_seconds  stop once cumulative solver wall time crosses this
                 (checked between chunks; the running chunk finishes).
    max_rounds   stop after this many rounds even if the schedule asks
                 for more (resume-friendly: restore, raise, continue).
    """

    target_loss: float | None = None
    max_seconds: float | None = None
    max_rounds: int | None = None

    def __post_init__(self):
        if self.max_seconds is not None and self.max_seconds < 0:
            raise ValueError(f"max_seconds={self.max_seconds} must be ≥ 0")
        if self.max_rounds is not None and self.max_rounds < 1:
            raise ValueError(f"max_rounds={self.max_rounds} must be ≥ 1")

    @property
    def trivial(self) -> bool:
        """True when no knob is set (run the full schedule)."""
        return (
            self.target_loss is None
            and self.max_seconds is None
            and self.max_rounds is None
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "StopPolicy":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """How a run survives failures — the knobs of the fault-tolerance
    plane (autosave cadence, retry budget, backoff), declared on the
    spec so a sweep point carries its own recovery contract.

    autosave_every  checkpoint the session every this many rounds
                    (0 = off). The *where* is runtime state, not spec
                    content: ``Session(spec, autosave_dir=...)`` or the
                    sweep's ``resume_dir`` supply the directory.
    max_retries     how many times a failed sweep point is retried
                    (each retry resumes from the point's last autosave
                    when one exists) before it is quarantined — i.e.
                    quarantine-after-N with N = 1 + max_retries failed
                    attempts.
    backoff_s       sleep before retry k: ``backoff_s · 2^(k-1)``
                    (0 = retry immediately).
    """

    autosave_every: int = 0
    max_retries: int = 2
    backoff_s: float = 0.0

    def __post_init__(self):
        if self.autosave_every < 0:
            raise ValueError(f"autosave_every={self.autosave_every} must be ≥ 0")
        if self.max_retries < 0:
            raise ValueError(f"max_retries={self.max_retries} must be ≥ 0")
        if not math.isfinite(self.backoff_s) or self.backoff_s < 0:
            raise ValueError(f"backoff_s={self.backoff_s} must be finite and ≥ 0")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPolicy":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """The spec's declared online data plane (the serving plane's
    input). One schedule round consumes exactly ``p_r · τ · b`` sample
    rows, so a stream plugs in by micro-batching arrivals into
    fixed-shape row blocks of that size (``Session.step_stream``).

    source          "" = no stream (pure offline run — the default, and
                    invisible on the wire so default hashes are
                    unchanged); "drift" = synthetic labeled stream with
                    one concept shift (``repro.serve.DriftStream``);
                    "replay" = cycle the spec's dataset rows through the
                    online path (``repro.serve.ReplayStream``).
    rows_per_round  micro-batch size. 0 (default) derives it from the
                    schedule (p_r·τ·b); a nonzero value must equal that
                    product — one batch is one round by construction.
    width           active features per streamed example ("drift" only).
    seed            stream seed (independent of the dataset seed).
    drift_at        batch index of the concept shift (0 = never).
    queue_capacity  ingest queue bound (backpressure point).
    swap_every      serving freshness policy: hot-swap the served model
                    every this many rounds (0 = only the final swap).
    """

    source: str = ""
    rows_per_round: int = 0
    width: int = 16
    seed: int = 0
    drift_at: int = 0
    queue_capacity: int = 8
    swap_every: int = 4

    def __post_init__(self):
        if self.source not in ("", "drift", "replay"):
            raise ValueError(
                f"stream.source={self.source!r} not in ('', 'drift', 'replay')"
            )
        if self.rows_per_round < 0:
            raise ValueError(f"rows_per_round={self.rows_per_round} must be ≥ 0")
        if self.width < 1:
            raise ValueError(f"stream.width={self.width} must be ≥ 1")
        if self.queue_capacity < 1:
            raise ValueError(f"queue_capacity={self.queue_capacity} must be ≥ 1")
        if self.swap_every < 0:
            raise ValueError(f"swap_every={self.swap_every} must be ≥ 0")

    @property
    def enabled(self) -> bool:
        return bool(self.source)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "StreamSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Where the computation runs.

    p_r × p_c   the paper's 2D processor mesh (row teams × column
                shards).
    backend     "simulated" — exact rank semantics on one device via
                the unified engine (repro.core.engine); "shard_map" —
                real device mesh execution (repro.core.distributed;
                needs p_r·p_c addressable devices).
    partitioner column partitioner for the shard_map layout (§6.5);
                ignored by the simulated backend (p_c is
                communication-only and never changes the numerics).
    """

    p_r: int = 1
    p_c: int = 1
    backend: str = "simulated"
    partitioner: str = "cyclic"

    def __post_init__(self):
        if self.p_r < 1 or self.p_c < 1:
            raise ValueError(f"mesh must be ≥ 1×1, got {self.p_r}×{self.p_c}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend={self.backend!r} not in {BACKENDS}")
        if self.partitioner not in PARTITIONERS:
            raise ValueError(
                f"partitioner={self.partitioner!r} not in {tuple(PARTITIONERS)}"
            )

    @property
    def p(self) -> int:
        return self.p_r * self.p_c

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MeshSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One declarative experiment: spec → plan → run → report.

    dataset      registered dataset name (repro.sparse.synthetic); the
                 -sm variants materialize on one host.
    schedule     the (s, b, τ, η, rounds, loss_every, gram) knobs —
                 the exact object both backends execute.
    objective    registered convex loss (repro.core.objective):
                 "logistic" (default) | "squared_hinge" |
                 "least_squares". Flows into the problem build on both
                 backends; the default reproduces pre-objective traces
                 bitwise.
    l2           ridge coefficient λ ≥ 0 (0 = unregularized; exact on
                 s > 1 via the decay-aware correction recurrence).
    mesh         geometry + backend (authoritative for p_r, p_c).
    machine      cost-model machine name (repro.costmodel.MACHINES)
                 used by ``plan``.
    seed         dataset generation seed.
    autotune     let ``plan`` rewrite (s, b) via the closed-form optima
                 (Eq. 5–6) before running.
    row_multiple rows are padded to this multiple (None → s·b, the
                 paper's cyclic-sampling requirement). Pin it when
                 comparing schedules with different s·b so they see the
                 identical sample sequence.
    stop         round-granular early-stop policy (``StopPolicy``);
                 default: run the schedule's full round budget.
    comm_timing  run with the *timed* collectives (repro.core.comm):
                 each round blocks on completion and its wall seconds
                 land in the report's CommLedger — the §6.5 calibration
                 input (repro.costmodel.calibrate). Serializes per-round
                 dispatch, so leave False for throughput runs.
    faults       fault-tolerance policy (``FaultPolicy``): autosave
                 cadence + sweep retry/quarantine budget. The default
                 (no autosave, 2 retries) serializes to nothing, so
                 default hashes are unchanged.
    stream       online data plane (``StreamSpec``): which stream
                 source feeds ``Session.step_stream`` and the serving
                 freshness policy. The default (no stream) serializes
                 to nothing — offline specs, hashes, and checkpoints
                 are untouched.
    name         optional label for reports/sweeps.
    """

    dataset: str
    schedule: ParallelSGDSchedule
    mesh: MeshSpec = dataclasses.field(default_factory=MeshSpec)
    machine: str = "perlmutter-cpu"
    seed: int = 0
    autotune: bool = False
    row_multiple: int | None = None
    stop: StopPolicy = dataclasses.field(default_factory=StopPolicy)
    objective: str = "logistic"
    l2: float = 0.0
    comm_timing: bool = False
    faults: FaultPolicy = dataclasses.field(default_factory=FaultPolicy)
    stream: StreamSpec = dataclasses.field(default_factory=StreamSpec)
    name: str = ""

    def __post_init__(self):
        dataset_stats(self.dataset)  # raises on unknown name
        if self.machine not in MACHINES:
            raise ValueError(f"machine={self.machine!r} not in {sorted(MACHINES)}")
        if self.objective not in OBJECTIVES:
            raise ValueError(
                f"objective={self.objective!r} not in {sorted(OBJECTIVES)}"
            )
        if not math.isfinite(self.l2) or self.l2 < 0.0:
            raise ValueError(f"l2={self.l2} must be finite and ≥ 0")
        if self.stop.target_loss is not None and not self.schedule.loss_every:
            raise ValueError(
                "stop.target_loss needs schedule.loss_every > 0: the objective is "
                "only observable on loss-sampling boundaries"
            )
        if self.schedule.p_r != self.mesh.p_r:
            raise ValueError(
                f"schedule.p_r={self.schedule.p_r} != mesh.p_r={self.mesh.p_r}: row "
                f"teams are a numerical knob and must agree"
            )
        if self.schedule.p_c not in (1, self.mesh.p_c):
            raise ValueError(
                f"schedule.p_c={self.schedule.p_c} != mesh.p_c={self.mesh.p_c}"
            )
        if self.schedule.p_c != self.mesh.p_c:
            # p_c is communication-only: canonicalize from the mesh so
            # one object describes the full run.
            object.__setattr__(
                self, "schedule", dataclasses.replace(self.schedule, p_c=self.mesh.p_c)
            )
        if self.stream.enabled and self.stream.rows_per_round:
            want = self.schedule.p_r * self.schedule.tau * self.schedule.b
            if self.stream.rows_per_round != want:
                raise ValueError(
                    f"stream.rows_per_round={self.stream.rows_per_round} != "
                    f"p_r·τ·b={want}: one micro-batch is one schedule round "
                    f"by construction (leave it 0 to derive it)"
                )

    def stream_rows_per_round(self) -> int:
        """Rows one schedule round consumes — the micro-batch size the
        stream plane must produce (p_r·τ·b unless pinned explicitly)."""
        return self.stream.rows_per_round or (
            self.schedule.p_r * self.schedule.tau * self.schedule.b
        )

    # ---- JSON round-tripping ----

    def to_dict(self) -> dict:
        d = {
            "name": self.name,
            "dataset": self.dataset,
            "seed": self.seed,
            "machine": self.machine,
            "autotune": self.autotune,
            "row_multiple": self.row_multiple,
            "schedule": dataclasses.asdict(self.schedule),
            "mesh": self.mesh.to_dict(),
            "stop": self.stop.to_dict(),
        }
        # schedule.delay is emitted only when nonzero: a delay-0 spec
        # serializes (and content-hashes) exactly as it did before the
        # overlap knob existed, so pre-overlap checkpoints and sweep
        # resume dirs stay valid.
        if not self.schedule.delay:
            d["schedule"].pop("delay", None)
        # bm/precision likewise: the untiled fp32 default serializes
        # (and content-hashes) exactly as it did before the autotune +
        # precision knobs existed. bk stays on the wire (it predates
        # this layer); bk=None — the opt-in autotune sentinel — moves
        # the hash, which is correct: a tuned run is a different run.
        if self.schedule.bm is None:
            d["schedule"].pop("bm", None)
        if self.schedule.precision == "fp32":
            d["schedule"].pop("precision", None)
        # objective/l2 are emitted only when non-default: a
        # default-logistic spec serializes (and content-hashes) exactly
        # as it did before the objective layer existed, so pre-existing
        # checkpoints and sweep resume dirs stay valid — the default
        # run is bitwise-identical, and its hash says so.
        if self.objective != "logistic":
            d["objective"] = self.objective
        if self.l2:
            d["l2"] = self.l2
        # comm_timing likewise: emitted only when on, so default specs
        # (and their content hashes / resume dirs) are byte-identical to
        # every pre-ledger release.
        if self.comm_timing:
            d["comm_timing"] = True
        # faults likewise: a default policy is invisible on the wire —
        # pre-fault-tolerance JSON and hashes stay valid.
        if self.faults != FaultPolicy():
            d["faults"] = self.faults.to_dict()
        # stream likewise: offline specs serialize (and hash) exactly as
        # they did before the serving plane existed.
        if self.stream != StreamSpec():
            d["stream"] = self.stream.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        schedule = ParallelSGDSchedule(**d.pop("schedule"))
        mesh = MeshSpec.from_dict(d.pop("mesh", {}))
        stop = StopPolicy.from_dict(d.pop("stop", {}))
        fault_policy = FaultPolicy.from_dict(d.pop("faults", {}))
        stream = StreamSpec.from_dict(d.pop("stream", {}))
        return cls(
            schedule=schedule,
            mesh=mesh,
            stop=stop,
            faults=fault_policy,
            stream=stream,
            **d,
        )

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    def content_hash(self) -> str:
        """Stable hash of the full spec content (every field, including
        ``name``). This keys session checkpoints and sweep resume
        records: a checkpoint written under one spec can only be resumed
        under a spec with the identical hash — anything else is a hard
        error, never a silent renumber."""
        payload = json.dumps(self.to_dict(), sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(payload.encode()).hexdigest()[:16]
