"""run(spec) — build once, dispatch to a backend, report uniformly.

``build_problem`` subsumes the three hand-rolled construction paths the
launchers used to carry (``single_team`` / ``stack_row_teams`` /
``build_2d_problem``) behind one call keyed off the spec; ``run`` then
dispatches the same ``ParallelSGDSchedule`` to either executor:

  backend="simulated"  repro.core.engine.run_parallel_sgd — exact
                       simulated-rank semantics on one device (the
                       oracle; p_c is communication-only there).
  backend="shard_map"  repro.core.distributed.run_hybrid_distributed —
                       the production 2D device-mesh execution (needs
                       p_r·p_c addressable devices, e.g. via
                       XLA_FLAGS=--xla_force_host_platform_device_count).

Both return the same ``RunReport`` (weights, loss trace with engine
``loss_every`` semantics, wall time, modeled comm volume), so switching
hardware is a one-field change in the spec — tested for parity in
tests/test_distributed_subprocess.py.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.api.plan import Plan, plan
from repro.api.report import RunReport, modeled_comm_words
from repro.api.spec import ExperimentSpec
from repro.core.distributed import (
    Hybrid2DProblem,
    build_2d_problem,
    run_hybrid_distributed,
)
from repro.sparse.partition import ColumnPartition
from repro.core.engine import run_parallel_sgd
from repro.core.problem import LogisticProblem, full_loss, make_problem
from repro.core.teams import TeamProblem, stack_row_teams
from repro.sparse.synthetic import SyntheticDataset, make_dataset


@dataclasses.dataclass
class ProblemBundle:
    """Everything ``run`` needs, built once from the spec.

    Exactly one of (team, prob2d) is populated, per the backend; the
    global problem is always present (loss traces + final objective).
    """

    spec: ExperimentSpec
    dataset: SyntheticDataset
    global_problem: LogisticProblem
    row_multiple: int
    team: TeamProblem | None = None
    prob2d: Hybrid2DProblem | None = None
    cp: ColumnPartition | None = None


# Dataset materialization is deterministic in (name, seed) and is the
# dominant build cost for repeated run(spec) calls (benchmark repeats,
# sweeps over schedules on one dataset) — memoize it. Treat the cached
# dataset as read-only.
_cached_dataset = functools.lru_cache(maxsize=8)(make_dataset)


def build_problem(spec: ExperimentSpec) -> ProblemBundle:
    """Materialize the dataset and partition it for the spec's backend.
    Row padding is ``spec.row_multiple`` (default s·b) on both paths so
    simulated and distributed sample sequences agree."""
    sched, mesh = spec.schedule, spec.mesh
    ds = _cached_dataset(spec.dataset, seed=spec.seed)
    rm = spec.row_multiple or sched.s * sched.b
    gp = make_problem(ds.A, ds.y, row_multiple=rm)
    bundle = ProblemBundle(spec=spec, dataset=ds, global_problem=gp, row_multiple=rm)
    if mesh.backend == "simulated":
        bundle.team = stack_row_teams(ds.A, ds.y, mesh.p_r, row_multiple=rm)
    else:
        bundle.prob2d, bundle.cp = build_2d_problem(
            ds.A, ds.y, mesh.p_r, mesh.p_c, mesh.partitioner, row_multiple=rm
        )
    return bundle


def _make_device_mesh(p_r: int, p_c: int):
    need = p_r * p_c
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"backend='shard_map' needs {need} devices for a {p_r}×{p_c} mesh but "
            f"only {len(devices)} are visible — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} (CPU) or use "
            f"backend='simulated'"
        )
    return compat.make_mesh((p_r, p_c), ("rows", "cols"), devices=devices[:need])


def run(spec: ExperimentSpec, x0: np.ndarray | None = None) -> RunReport:
    """The front door: plan (auto-tuning if asked), build, execute,
    report. ``wall_time_s`` covers the solver only (first call includes
    jit compilation; repeat with the same spec shape for steady-state)."""
    pl: Plan = plan(spec)
    spec = pl.spec
    sched, mesh = spec.schedule, spec.mesh
    bundle = build_problem(spec)
    n = bundle.dataset.A.n
    x0 = np.zeros(n, np.float32) if x0 is None else np.asarray(x0, np.float32)

    if mesh.backend == "simulated":
        t0 = time.perf_counter()
        x_j, losses_j = run_parallel_sgd(bundle.team, jnp.asarray(x0), sched)
        x = np.asarray(x_j)  # blocks until the computation is done
        losses = np.asarray(losses_j)
        wall = time.perf_counter() - t0
    else:
        mesh_dev = _make_device_mesh(mesh.p_r, mesh.p_c)
        # the schedule's default "pallas" bundle backend maps to the
        # identical-math "blocked" path inside shard_map (see
        # make_hybrid_step) — pass through verbatim.
        t0 = time.perf_counter()
        x, losses = run_hybrid_distributed(
            mesh_dev, bundle.prob2d, bundle.cp, x0, sched,
            loss_problem=bundle.global_problem,
        )
        wall = time.perf_counter() - t0

    final_loss = float(full_loss(bundle.global_problem, jnp.asarray(x)))
    return RunReport(
        spec=spec,
        plan=pl,
        backend=mesh.backend,
        x=np.asarray(x),
        losses=np.asarray(losses, np.float32),
        final_loss=final_loss,
        wall_time_s=wall,
        comm_words=modeled_comm_words(spec),
    )
