"""run(spec) — build once, execute through a Session, report uniformly.

``build_problem`` subsumes the three hand-rolled construction paths the
launchers used to carry (``single_team`` / ``stack_row_teams`` /
``build_2d_problem``) behind one call keyed off the spec; ``run`` is a
thin loop over the round-incremental ``repro.api.Session``, which
dispatches the same ``ParallelSGDSchedule`` to either executor:

  backend="simulated"  repro.core.engine.run_engine_chunk — exact
                       simulated-rank semantics on one device (the
                       oracle; p_c is communication-only there).
  backend="shard_map"  repro.core.distributed.HybridDriver —
                       the production 2D device-mesh execution (needs
                       p_r·p_c addressable devices, e.g. via
                       XLA_FLAGS=--xla_force_host_platform_device_count).

Both return the same ``RunReport`` (weights, loss trace with engine
``loss_every`` semantics, wall time split into compile/solve, modeled
comm volume), so switching hardware is a one-field change in the spec —
tested for parity in tests/test_distributed_subprocess.py. Chunked
session execution is bitwise-identical to the monolithic single-scan
engine path (tests/test_session.py).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np

from repro import compat
from repro.api.report import RunReport
from repro.api.spec import ExperimentSpec
from repro.core.distributed import Hybrid2DProblem, build_2d_problem
from repro.sparse.partition import ColumnPartition
from repro.core.objective import get_objective
from repro.core.problem import Problem, make_problem
from repro.core.teams import TeamProblem, stack_row_teams
from repro.sparse.synthetic import SyntheticDataset, make_dataset


@dataclasses.dataclass
class ProblemBundle:
    """Everything ``run`` needs, built once from the spec.

    Exactly one of (team, prob2d) is populated, per the backend; the
    global problem is always present (loss traces + final objective).
    """

    spec: ExperimentSpec
    dataset: SyntheticDataset
    global_problem: Problem
    row_multiple: int
    team: TeamProblem | None = None
    prob2d: Hybrid2DProblem | None = None
    cp: ColumnPartition | None = None


# Dataset materialization is deterministic in (name, seed) and is the
# dominant build cost for repeated run(spec) calls (benchmark repeats,
# sweeps over schedules on one dataset) — memoize it. The cached
# dataset is *enforced* read-only: every consumer sees the same numpy
# buffers, so an in-place write anywhere would silently corrupt every
# later run on the same (name, seed). Frozen flags turn that aliasing
# hazard into an immediate ValueError at the write site.


@functools.lru_cache(maxsize=8)
def _cached_dataset(name: str, seed: int = 0) -> SyntheticDataset:
    ds = make_dataset(name, seed=seed)
    for arr in (ds.A.indptr, ds.A.indices, ds.A.data, ds.y, ds.x_true):
        arr.flags.writeable = False
    return ds


def build_problem(spec: ExperimentSpec) -> ProblemBundle:
    """Materialize the dataset and partition it for the spec's backend.
    Row padding is ``spec.row_multiple`` (default s·b) on both paths so
    simulated and distributed sample sequences agree; the spec's
    objective (+ l2) rides on every problem object, so both executors
    and the loss probes read the same convex loss."""
    sched, mesh = spec.schedule, spec.mesh
    ds = _cached_dataset(spec.dataset, seed=spec.seed)
    rm = spec.row_multiple or sched.s * sched.b
    obj = get_objective(spec.objective, l2=spec.l2)
    gp = make_problem(ds.A, ds.y, row_multiple=rm, objective=obj)
    bundle = ProblemBundle(spec=spec, dataset=ds, global_problem=gp, row_multiple=rm)
    if mesh.backend == "simulated":
        bundle.team = stack_row_teams(
            ds.A, ds.y, mesh.p_r, row_multiple=rm, objective=obj
        )
    else:
        bundle.prob2d, bundle.cp = build_2d_problem(
            ds.A, ds.y, mesh.p_r, mesh.p_c, mesh.partitioner, row_multiple=rm,
            objective=obj,
        )
    return bundle


def _make_device_mesh(p_r: int, p_c: int):
    need = p_r * p_c
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"backend='shard_map' needs {need} devices for a {p_r}×{p_c} mesh but "
            f"only {len(devices)} are visible — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} (CPU) or use "
            f"backend='simulated'"
        )
    return compat.make_mesh((p_r, p_c), ("rows", "cols"), devices=devices[:need])


def run(spec: ExperimentSpec, x0: np.ndarray | None = None) -> RunReport:
    """The front door: plan (auto-tuning if asked), build, execute,
    report — now a thin loop over the round-incremental ``Session``
    (``Session(spec, x0).run()``), honoring the spec's ``StopPolicy``.
    ``wall_time_s`` covers the solver only and splits into
    ``compile_time_s`` (first chunk, includes jit) + ``solve_time_s``."""
    from repro.api.session import Session

    return Session(spec, x0=x0).run()


def run_decaying_tau(
    spec: ExperimentSpec,
    x0: np.ndarray | None = None,
    stages: int = 3,
    growth: int = 2,
) -> list[RunReport]:
    """The decaying-communication-frequency schedule of *Local SGD to
    One-Shot Averaging* (arXiv:2106.04759), as a compensation knob for
    delayed averaging: run ``stages`` consecutive segments of the spec,
    multiplying τ by ``growth`` each stage — synchronize often while
    the iterates move fast, then progressively less as they settle.
    The spec's round budget is split across the stages (earlier stages
    get the remainder) and the weights chain stage to stage, so the
    list of per-stage reports is one continuous optimization; the last
    report holds the final iterate. A ``delay`` on the schedule rides
    along unchanged — growing τ only widens its legal range (D ≤ τ/s).
    """
    if stages < 1:
        raise ValueError(f"stages={stages} must be ≥ 1")
    if growth < 1:
        raise ValueError(f"growth={growth} must be ≥ 1")
    sched = spec.schedule
    total = sched.rounds
    per = [total // stages + (1 if i < total % stages else 0) for i in range(stages)]
    if per[-1] < 1:
        raise ValueError(
            f"rounds={total} cannot cover {stages} stages with ≥ 1 round each"
        )
    base = spec.name or spec.dataset
    reports: list[RunReport] = []
    x = x0
    for k, r in enumerate(per):
        st = dataclasses.replace(
            spec,
            name=f"{base}/stage{k}-tau{sched.tau * growth**k}",
            schedule=dataclasses.replace(
                sched, tau=sched.tau * growth**k, rounds=r
            ),
        )
        rep = run(st, x0=x)
        reports.append(rep)
        x = rep.x
    return reports
