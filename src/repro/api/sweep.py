"""sweep(specs) — the processor-scale sweep as one resumable call.

The paper's headline artifacts are sweeps over the (p_r, p_c, s, τ)
family — Table 11 / Figure 6 time-to-loss rows, Figure 5 mesh sweeps.
This module makes that a first-class operation instead of a for-loop
around ``run()``:

* points run sequentially in one process, so the dataset/problem cache
  (``repro.api.run._cached_dataset``) is shared across every point on
  the same (dataset, seed) — the dominant build cost is paid once;
* with ``resume_dir``, every finished point persists its report as
  ``<spec content hash>.report.json``; re-invoking the same sweep after
  an interruption rehydrates finished points from disk and only runs
  the rest (the CLI's ``--resume``);
* a *failing* point no longer kills the sweep: each point is retried
  per its spec's ``FaultPolicy`` (``max_retries`` with exponential
  ``backoff_s``; every retry resumes from the point's last autosave in
  ``resume_dir`` when the policy autosaves), and a point that exhausts
  its retries is **quarantined** — recorded in
  ``SweepReport.quarantined`` (hash, attempts, error, rounds of
  progress) while the remaining points complete;
* the result knows how to print the paper-style time-to-loss table
  (§7.5 protocol: seconds/rounds to the first crossing of a target).

``max_points`` bounds how many *unfinished* points one invocation runs
— the building block for budgeted/interruptible sweeps and the CI
resume smoke test.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Iterable, Sequence

import numpy as np

from repro.api.report import RunReport
from repro.api.spec import ExperimentSpec
from repro.core import faults
from repro.obs import metrics as obs_metrics
from repro.train.checkpoint import (
    CheckpointCorruptError,
    SpecMismatchError,
    discard_session_checkpoint,
)

__all__ = ["QuarantineRecord", "SweepReport", "sweep"]


@dataclasses.dataclass
class QuarantineRecord:
    """One sweep point that exhausted its retry budget.

    spec_hash    the point's content hash (the resume-dir key).
    name         the spec's label (or dataset) for human output.
    attempts     how many times it was tried (1 + max_retries).
    error        repr of the last failure.
    rounds_done  progress at the final failure (what an autosave holds —
                 a later re-invocation resumes there, it is not lost).
    """

    spec_hash: str
    name: str
    attempts: int
    error: str
    rounds_done: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "QuarantineRecord":
        return cls(**d)


@dataclasses.dataclass
class SweepReport:
    """All points of one sweep, finished or rehydrated.

    reports      one ``RunReport`` per *completed* spec, in spec order
                 (rehydrated reports have ``x=None`` — weights live in
                 checkpoints).
    resumed      per completed point: True when the report was loaded
                 from ``resume_dir`` instead of being run here.
    attempts     per completed point: how many tries it took (1 = clean;
                 0 = rehydrated, never run in this invocation).
    skipped      specs beyond ``max_points`` that this invocation did
                 not reach (their hashes; rerun with ``resume_dir``).
    quarantined  points that exhausted their retry budget — the sweep
                 completed *around* them (``QuarantineRecord`` each).
    """

    reports: list[RunReport]
    resumed: list[bool]
    skipped: list[str] = dataclasses.field(default_factory=list)
    quarantined: list[QuarantineRecord] = dataclasses.field(default_factory=list)
    attempts: list[int] = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        ran = sum(1 for r in self.resumed if not r)
        quar = (
            f", {len(self.quarantined)} quarantined" if self.quarantined else ""
        )
        return (
            f"sweep: {len(self.reports)} point(s) ({ran} run, "
            f"{len(self.reports) - ran} resumed, {len(self.skipped)} skipped"
            f"{quar})"
        )

    def time_to_loss_table(self, target: float | None = None) -> str:
        """The paper-style table: per point, wall seconds and rounds to
        the first crossing of the target loss.

        The target is per-point ``spec.stop.target_loss`` when set
        (runs that stopped on it report their measured wall directly);
        ``target`` is the fallback for points without one, applied
        post-hoc to their loss trace via ``RunReport.time_to_target``.
        """
        rows = [
            f"{'point':24s} {'backend':9s} {'mesh':7s} {'s':>3s} {'b':>4s} "
            f"{'τ':>4s} {'target':>8s} {'sec-to-target':>13s} {'rounds':>6s} "
            f"{'loss':>8s} hit"
        ]
        for rep in self.reports:
            spec = rep.spec
            tgt = spec.stop.target_loss if spec.stop.target_loss is not None else target
            if tgt is not None and rep.stop_reason != "target_loss" and not len(rep.losses):
                tgt = None  # no trace to cross (loss_every=0) — report the full run
            if tgt is None:
                sec, rounds, loss, hit = rep.wall_time_s, len(rep.losses), rep.final_loss, False
                tgt_s = "-"
            elif rep.stop_reason == "target_loss":
                # the run *stopped* at the crossing — the wall time is
                # the measured time-to-target, not a scaled estimate
                sec, rounds, loss, hit = (
                    rep.wall_time_s, rep.rounds_completed, float(rep.losses[-1]), True,
                )
                tgt_s = f"{tgt:.4f}"
            else:
                sec, rounds, loss, hit = rep.time_to_target(tgt)
                tgt_s = f"{tgt:.4f}"
            sched = spec.schedule
            rows.append(
                f"{(spec.name or spec.dataset)[:24]:24s} {rep.backend:9s} "
                f"{spec.mesh.p_r}×{spec.mesh.p_c:<5d} {sched.s:>3d} {sched.b:>4d} "
                f"{sched.tau:>4d} {tgt_s:>8s} {sec:>13.4f} {rounds:>6d} "
                f"{loss:>8.4f} {'yes' if hit else 'no'}"
            )
        for q in self.quarantined:
            rows.append(
                f"{q.name[:24]:24s} QUARANTINED after {q.attempts} attempt(s) "
                f"at round {q.rounds_done}: {q.error}"
            )
        return "\n".join(rows)

    def to_dict(self) -> dict:
        return {
            "reports": [r.to_dict() for r in self.reports],
            "resumed": list(self.resumed),
            "attempts": list(self.attempts),
            "skipped": list(self.skipped),
            "quarantined": [q.to_dict() for q in self.quarantined],
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def _record_path(resume_dir: Path, spec: ExperimentSpec) -> Path:
    return resume_dir / f"{spec.content_hash()}.report.json"


def _open_session(spec, autosave_dir: Path | None, x0):
    """A session for one sweep attempt: resume from the point's
    autosave when a loadable one exists; a torn or foreign autosave is
    discarded (the integrity layer flags it), never trusted."""
    from repro.api.session import Session, autosave_base

    if autosave_dir is not None:
        base = autosave_base(autosave_dir, spec)
        try:
            return Session.restore(base, spec=spec, autosave_dir=autosave_dir)
        except FileNotFoundError:
            pass
        except (CheckpointCorruptError, SpecMismatchError):
            discard_session_checkpoint(base)
    return Session(spec, x0=x0, autosave_dir=autosave_dir)


def _run_point(spec, index: int, autosave_dir: Path | None, x0):
    """Run one sweep point under its FaultPolicy: retry with backoff,
    resuming from autosave; returns (report | None, attempts, error) —
    report None means the point is quarantined."""
    policy = spec.faults
    attempts = 0
    rounds_done = 0
    reg = obs_metrics.registry()
    while True:
        attempts += 1
        if attempts > 1:
            reg.counter("sweep.retries_total").inc()
        sess = None
        try:
            faults.poke("point", at=index)
            sess = _open_session(spec, autosave_dir, x0)
            report = sess.run()
            return report, attempts, None
        except (KeyboardInterrupt, SystemExit):
            raise  # the *user* interrupting a sweep is not a point fault
        except Exception as err:
            if sess is not None:
                rounds_done = max(rounds_done, sess.rounds_done)
            if attempts > policy.max_retries:
                return None, attempts, (err, rounds_done)
            if policy.backoff_s:
                time.sleep(policy.backoff_s * 2 ** (attempts - 1))


def sweep(
    specs: Sequence[ExperimentSpec] | Iterable[ExperimentSpec],
    resume_dir: str | Path | None = None,
    max_points: int | None = None,
    x0: np.ndarray | None = None,
) -> SweepReport:
    """Run every spec (sequentially, shared dataset cache) and collect
    the reports.

    With ``resume_dir``, finished points are persisted there keyed by
    spec content hash and never re-run — interrupt the sweep anywhere
    and re-invoke to continue; autosaves (``FaultPolicy.autosave_every``)
    land there too, so a retried or re-invoked point resumes mid-run
    instead of from round 0. A point that keeps failing is quarantined
    after its retry budget (``FaultPolicy.max_retries``) and the sweep
    completes the remaining points. ``max_points`` caps how many
    unfinished points this invocation executes (the rest are reported in
    ``skipped``).
    """
    from repro.api.session import autosave_base

    specs = list(specs)
    resume_dir = Path(resume_dir) if resume_dir is not None else None
    if resume_dir is not None:
        resume_dir.mkdir(parents=True, exist_ok=True)

    reports: list[RunReport] = []
    resumed: list[bool] = []
    attempts_log: list[int] = []
    skipped: list[str] = []
    quarantined: list[QuarantineRecord] = []
    reg = obs_metrics.registry()
    ran = 0
    for index, spec in enumerate(specs):
        if resume_dir is not None:
            rec = _record_path(resume_dir, spec)
            if rec.exists():
                reports.append(RunReport.from_json(rec.read_text()))
                resumed.append(True)
                attempts_log.append(0)
                reg.counter("sweep.points_resumed_total").inc()
                continue
        if max_points is not None and ran >= max_points:
            skipped.append(spec.content_hash())
            reg.counter("sweep.points_skipped_total").inc()
            continue
        reg.counter("sweep.points_total").inc()
        report, attempts, failure = _run_point(spec, index, resume_dir, x0)
        ran += 1
        if report is None:
            err, rounds_done = failure
            reg.counter("sweep.quarantined_total").inc()
            quarantined.append(
                QuarantineRecord(
                    spec_hash=spec.content_hash(),
                    name=spec.name or spec.dataset,
                    attempts=attempts,
                    error=repr(err),
                    rounds_done=int(rounds_done),
                )
            )
            continue
        if resume_dir is not None:
            rec = _record_path(resume_dir, spec)
            tmp = rec.with_suffix(".tmp")
            tmp.write_text(report.to_json())
            tmp.replace(rec)
            # the point is durably finished — its autosave is spent
            discard_session_checkpoint(autosave_base(resume_dir, spec))
        reports.append(report)
        resumed.append(False)
        attempts_log.append(attempts)
    return SweepReport(
        reports=reports,
        resumed=resumed,
        skipped=skipped,
        quarantined=quarantined,
        attempts=attempts_log,
    )
