"""Session — the round-incremental execution lifecycle.

``run(spec)`` used to be one opaque block: build, scan every round,
report. A ``Session`` opens that loop up at round granularity without
changing a single iterate:

    sess = Session(spec)                 # plan + build once
    while not sess.done:
        ev = sess.step_rounds(4)         # advance 4 rounds
        print(ev.rounds_done, ev.loss)   # weights-so-far, loss sample
        sess.save("ckpt/run1")           # resumable at any boundary
    report = sess.report()

    sess2 = Session.restore("ckpt/run1") # later / elsewhere
    report2 = sess2.run()                # finish under the StopPolicy

Both backends are chunkable underneath: the simulated engine advances
through ``repro.core.engine.run_engine_chunk`` (one jitted executable
shared across chunks and sessions — the carry is just the weight
vector, the round offset is traced) and the shard_map backend through
``repro.core.distributed.HybridDriver`` (device-resident donated
carry). Chunked execution reproduces the monolithic single-scan path
bitwise — both scan the same per-round body over the same global round
indices — which is what makes save/restore and early stopping safe to
use in time-to-loss experiments (tests/test_session.py enforces it).

``run()`` is a thin loop over ``step_rounds`` that honors the spec's
``StopPolicy`` (``target_loss`` / ``max_seconds`` / ``max_rounds``) —
the paper's §7.5 time-to-loss protocol as a first-class stop condition
instead of post-hoc arithmetic on a finished trace.
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import Plan, plan, replan_mesh
from repro.api.report import RunReport, modeled_comm_words
from repro.api.spec import ExperimentSpec, MeshSpec
from repro.core import faults
from repro.core.comm import MESH, TIMED, CommLedger, time_dispatch, time_phase
from repro.core.engine import engine_comm_ledger, engine_loss, run_engine_chunk
from repro.core.distributed import HybridDriver
from repro.core.problem import problem_loss
from repro.core.teams import global_problem
from repro.obs import trace as obs_trace
from repro.train.checkpoint import (
    SessionCheckpoint,
    load_session_checkpoint,
    save_session_checkpoint,
)

__all__ = ["RoundEvent", "Session", "autosave_base"]


def autosave_base(directory: str | Path, spec: ExperimentSpec) -> Path:
    """Where a session autosaves inside ``directory`` — keyed by the
    spec's content hash (dot-free stem: the checkpoint layer appends
    .npz/.json via with_suffix)."""
    return Path(directory) / f"autosave-{spec.content_hash()}"


@dataclasses.dataclass
class RoundEvent:
    """What one ``step_rounds`` call observed.

    rounds_done     total rounds completed so far (cumulative).
    x               weights after those rounds (global (n,) on host).
    loss            the most recent full-objective sample taken during
                    this step, or None if no sampling boundary was
                    crossed (``schedule.loss_every`` semantics).
    wall_time_s     cumulative solver wall time.
    compile_time_s  wall accrued to first chunks (jit compile + one
                    chunk, summed across restores — each process
                    recompiles) — the split ``RunReport`` carries.
    comm_words      cumulative modeled per-rank comm volume for the
                    rounds completed (Table 3 payloads).
    ledger          snapshot of the run's CommLedger at this boundary:
                    the *counted* collectives (and, timed runs, the
                    measured per-round seconds) for the rounds done.
    stop            StopPolicy verdict at this boundary: None, or one of
                    "target_loss" / "max_seconds" / "max_rounds" /
                    "rounds" (schedule budget exhausted).
    """

    rounds_done: int
    x: np.ndarray
    loss: float | None
    wall_time_s: float
    compile_time_s: float
    comm_words: dict[str, float]
    ledger: CommLedger | None = None
    stop: str | None = None


class Session:
    """An open, resumable run of one ``ExperimentSpec``.

    Construction plans the spec (autotune included — ``self.spec`` is
    the spec as executed) and builds the problem once; every
    ``step_rounds`` call after that advances the same device-resident
    carry. The session is the single source of truth for run state:
    rounds done, loss trace, wall/compile time — ``report()`` is a pure
    read of it.
    """

    def __init__(
        self,
        spec: ExperimentSpec,
        x0: np.ndarray | None = None,
        autosave_dir: str | Path | None = None,
    ):
        # imported here: repro.api.run imports Session for its thin
        # run() wrapper, so the build machinery import must be lazy.
        from repro.api.run import build_problem, _make_device_mesh

        self.autosave_dir = Path(autosave_dir) if autosave_dir is not None else None
        self.input_spec = spec          # pre-plan (what checkpoints key on)
        self._plan: Plan = plan(spec)
        self.spec = self._plan.spec     # post-autotune (what executes)
        autotuned_panels = self.spec.schedule.bk is None
        if autotuned_panels:
            # bk=None opted into the kernel autotuner: resolve to the
            # cached (or freshly tuned) panel shape before anything
            # compiles. Checkpoints still key on input_spec, so the
            # tuned value never moves a content hash.
            from repro.api.spec import dataset_stats
            from repro.kernels import tune

            profile = tune.PanelProfile.from_stats(
                dataset_stats(self.spec.dataset),
                self.spec.schedule,
                self.spec.mesh.p_c,
            )
            bk, bm = tune.resolve_panel(profile)
            sched = dataclasses.replace(
                self.spec.schedule,
                bk=bk,
                bm=self.spec.schedule.bm if self.spec.schedule.bm is not None else bm,
            )
            self.spec = dataclasses.replace(self.spec, schedule=sched)
        self.bundle = build_problem(self.spec)
        if autotuned_panels:
            # autotune opt-in also owns the gram-path choice: a
            # heavy-tailed ELL width (w ≫ s·b) flips the bundle build
            # to the dense oracle (logged once in tune).
            from repro.kernels import tune

            sched = self.spec.schedule
            built = self.bundle.team if self.bundle.team is not None else self.bundle.prob2d
            width = int(built.indices.shape[-1])
            gram = tune.select_gram_path(width, sched.s * sched.b, sched.gram)
            if gram != sched.gram:
                self.spec = dataclasses.replace(
                    self.spec, schedule=dataclasses.replace(sched, gram=gram)
                )
        n = self.bundle.dataset.A.n
        x0 = np.zeros(n, np.float32) if x0 is None else np.asarray(x0, np.float32)

        self.rounds_done = 0
        self.losses: list[float] = []
        self.wall_time_s = 0.0
        self.compile_time_s = 0.0
        self.stop_reason: str | None = None
        # the next chunk's wall is accrued to compile_time_s (set again
        # on restore: a fresh process recompiles, and that wall must not
        # masquerade as steady-state solve time)
        self._first_chunk_pending = True

        if self.spec.mesh.backend == "simulated":
            self._driver = None
            self._x = jnp.asarray(x0)
            self._gp = global_problem(self.bundle.team)
            # the counted-comm ledger: the round body's collectives,
            # captured abstractly from the problem actually built
            self.ledger = engine_comm_ledger(
                self.spec.schedule, n, tp=self.bundle.team
            )
        else:
            mesh = _make_device_mesh(self.spec.mesh.p_r, self.spec.mesh.p_c)
            self._driver = HybridDriver(
                mesh,
                self.bundle.prob2d,
                self.bundle.cp,
                x0,
                self.spec.schedule,
                loss_problem=self.bundle.global_problem,
                comm=TIMED if self.spec.comm_timing else MESH,
            )
            self._x = None
            self._gp = None
            self.ledger = self._driver.ledger  # driver commits rounds

    # ---- state probes ----

    @property
    def total_rounds(self) -> int:
        """The schedule's round budget (the StopPolicy may end sooner)."""
        return self.spec.schedule.rounds

    @property
    def done(self) -> bool:
        return self.rounds_done >= self.total_rounds or self.stop_reason is not None

    def current_x(self) -> np.ndarray:
        """Current global weights (host copy; blocks on pending work)."""
        if self._driver is not None:
            return self._driver.gather()
        return np.asarray(self._x)

    # ---- the incremental core ----

    def _advance(self, k: int) -> None:
        """Run k rounds on the backend carry (no loss sampling)."""
        if self._driver is not None:
            self._driver.advance(k)  # commits (and, timed, measures) rounds
        elif self.spec.comm_timing:
            # timed collectives on the simulated engine: advance one
            # round at a time, blocking per round, so the ledger gets a
            # per-round wall — the iterate sequence is unchanged (chunked
            # execution is bitwise-identical at any chunk size).
            for i in range(int(k)):
                t0 = time.perf_counter()
                self._x = run_engine_chunk(
                    self.bundle.team, self._x, self.rounds_done + i, 1,
                    self.spec.schedule,
                )
                jax.block_until_ready(self._x)
                self.ledger.add_round_seconds(time.perf_counter() - t0)
            self.ledger.add_rounds(k)
        else:
            self._x = run_engine_chunk(
                self.bundle.team, self._x, self.rounds_done, k, self.spec.schedule
            )
            self.ledger.add_rounds(k)
        self.rounds_done += k

    def _sync(self) -> None:
        """Block on the backend carry without a host copy."""
        if self._driver is not None:
            self._driver.sync()
        else:
            jax.block_until_ready(self._x)

    def _traced_advance(self, sub: int, first: bool, stream_batch=None) -> None:
        """One sub-chunk through the tracing seam: untraced, exactly the
        bare advance (the bitwise-identical default path); traced, the
        same advance wrapped in a host-side span, blocking at the span
        edge so the recorded wall covers the dispatched work (observer
        effect on timing only — the compiled numerics are untouched)."""
        rec = obs_trace.active()
        if rec is None:
            if stream_batch is not None:
                self._advance_stream(stream_batch)
            else:
                self._advance(sub)
            return
        with rec.span(
            "compile" if first else "round",
            name=f"rounds[{self.rounds_done}+{sub}]",
            start_round=self.rounds_done,
            rounds=sub,
        ):
            if stream_batch is not None:
                self._advance_stream(stream_batch)
            else:
                self._advance(sub)
            self._sync()

    def _measure_phases(self) -> None:
        """Populate ``ledger.phase_seconds`` (→ ``exposed_comm_s``) once
        per timed run: the §6.5 phase split, measured by separate jitted
        probes over the round's real payload shapes — the training step
        itself is never split or re-traced. Runs outside the wall/compile
        accounting windows; each probed phase also lands as a trace span
        when a recorder is installed."""
        from repro.core.engine import engine_phase_probes

        if self._driver is not None:
            probes = self._driver.phase_probes()
        else:
            probes = engine_phase_probes(self.bundle.team, self.spec.schedule)
        rec = obs_trace.active()
        delay = self.spec.schedule.delay
        phases = {}
        for name, (fn, args, calls) in probes.items():
            per_call = time_phase(fn, *args)
            phases[name] = per_call * calls
            if rec is None:
                continue
            if name == "allreduce_gv" and delay >= 1:
                # delay-D split: the issue half is the async dispatch
                # cost (measured — what the critical path pays while
                # the reduction is in flight); the await half is the
                # exposed remainder after D bundle-computes of overlap
                # (the ledger's closed form, so trace and ledger agree).
                issue_call = time_dispatch(fn, *args)
                issue = min(issue_call, per_call) * calls
                compute = phases.get("bundle_compute", 0.0)
                await_s = max(phases[name] - issue - delay * compute, 0.0)
                rec.add_span("allreduce_gv_issue", f"probe:{name}:issue",
                             dur=issue, per_call_s=issue_call,
                             calls_per_round=calls)
                rec.add_span("allreduce_gv_await", f"probe:{name}:await",
                             dur=await_s, delay=delay,
                             calls_per_round=calls)
            else:
                rec.add_span(name, f"probe:{name}", dur=phases[name],
                             per_call_s=per_call, calls_per_round=calls)
        self.ledger.set_phase_seconds(phases)

    def _sample_loss(self) -> float:
        if self._driver is not None:
            return self._driver.loss()
        return float(engine_loss(self._gp, self._x))

    def step_rounds(self, k: int | None = None) -> RoundEvent:
        """Advance up to ``k`` rounds (default: to the next loss-sampling
        boundary, or all remaining rounds when ``loss_every`` is 0) and
        return what happened.

        Internally the advance is split at every ``loss_every`` boundary
        so the full objective is sampled exactly where the monolithic
        scan sampled it — arbitrary ``k`` never changes the trace, only
        how often control returns to the caller. The StopPolicy is
        evaluated at every boundary, so a step spanning several may end
        early (``RoundEvent.stop`` says why).
        """
        if self.done:
            raise RuntimeError(
                f"session is finished ({self.stop_reason or 'rounds'} at round "
                f"{self.rounds_done}); nothing to step"
            )
        sched = self.spec.schedule
        budget = self.total_rounds
        if self.spec.stop.max_rounds is not None:
            budget = min(budget, self.spec.stop.max_rounds)
        remaining = budget - self.rounds_done
        if k is None:
            k = (
                sched.loss_every - self.rounds_done % sched.loss_every
                if sched.loss_every
                else remaining
            )
        k = min(int(k), remaining)
        if k < 1:
            raise ValueError(f"step_rounds needs k ≥ 1, got {k}")

        loss = None
        synced = False
        autosave_every = self.input_spec.faults.autosave_every
        autosaving = self.autosave_dir is not None and autosave_every > 0
        t0 = time.perf_counter()
        while k > 0 and self.stop_reason is None:
            if sched.loss_every:
                sub = min(k, sched.loss_every - self.rounds_done % sched.loss_every)
            else:
                sub = k
            if autosaving:
                # split at autosave boundaries too, so a cadence finer
                # than loss_every still checkpoints on time (chunk size
                # never changes the iterates).
                sub = min(sub, autosave_every - self.rounds_done % autosave_every)
            if faults.active() is not None:
                # under an installed fault plan every round is a
                # boundary, so planned events fire exactly at their
                # round index on either backend.
                sub = 1
            first = self._first_chunk_pending
            tc = time.perf_counter()
            self._traced_advance(sub, first)
            sampled = None
            if sched.loss_every and self.rounds_done % sched.loss_every == 0:
                sampled = self._sample_loss()  # blocks (device → float)
                self.losses.append(sampled)
                loss, synced = sampled, True
            else:
                synced = False
            if first:
                if sampled is None:
                    self.current_x()  # block: compile wall must be real
                    synced = True
                self.compile_time_s += time.perf_counter() - tc
                self._first_chunk_pending = False
            k -= sub
            # the policy is checked at every boundary, not once per
            # call: a target crossed mid-step stops the step there.
            self._check_stop(
                sampled, wall=self.wall_time_s + (time.perf_counter() - t0)
            )
            if autosaving and self.rounds_done % autosave_every == 0:
                # preemption-safe: the carry is durable at this boundary
                # *before* the seam below may kill/stall/fail the worker.
                self.save(self.autosave_path)
            faults.poke("round", at=self.rounds_done)
        if not synced:
            self.current_x()  # block: wall covers all dispatched work
        self.wall_time_s += time.perf_counter() - t0
        if self.spec.comm_timing and not self.ledger.phase_seconds:
            # after the wall accrual so probe time never masquerades as
            # solve/compile time.
            self._measure_phases()

        return RoundEvent(
            rounds_done=self.rounds_done,
            x=self.current_x(),  # post-sync: a copy, not a timed stall
            loss=loss,
            wall_time_s=self.wall_time_s,
            compile_time_s=self.compile_time_s,
            comm_words=modeled_comm_words(self.spec, rounds=self.rounds_done),
            ledger=self.ledger.snapshot(),
            stop=self.stop_reason,
        )

    # ---- the streaming door ----

    def _next_stream_batch(self, source):
        """One micro-batch from ``source`` — a ``StreamFeed`` (bounded
        ingest queue; preferred) or a bare ``StreamSource`` (iterated
        lazily from the current round, re-anchored if swapped)."""
        if hasattr(source, "get"):  # StreamFeed
            return source.get()
        if getattr(self, "_stream_src", None) is not source:
            self._stream_src = source
            self._stream_iter = source.micro_batches(self.rounds_done)
        return next(self._stream_iter)

    def _advance_stream(self, batch) -> None:
        """Run ONE round over a fresh micro-batch (no loss sampling).

        The batch replaces the resident data for exactly this round:
        with ``m_local = τ·b`` rows per team, the engine's cyclic bundle
        slicing walks the fresh rows exactly once at any round index, so
        streaming reuses the offline round body (and its jit cache —
        fixed batch shapes compile once) verbatim.
        """
        from repro.serve.ingest import (
            ColumnLocalizer,
            stream_shard_arrays,
            stream_team_problem,
        )

        want = self.spec.stream_rows_per_round()
        if batch.rows != want:
            raise ValueError(
                f"micro-batch has {batch.rows} rows; one round of this schedule "
                f"consumes p_r·τ·b = {want}"
            )
        if self._driver is not None:
            if getattr(self, "_localizer", None) is None:
                self._localizer = ColumnLocalizer.from_partition(self.bundle.cp)
            idx, val = stream_shard_arrays(
                batch, self._localizer, self.spec.schedule.p_r, batch.width
            )
            self._driver.advance_stream(idx, val)  # commits the round
        else:
            tp = stream_team_problem(
                batch,
                self.spec.schedule.p_r,
                self.bundle.dataset.A.n,
                self.bundle.team.objective,
            )
            if self.spec.comm_timing:
                t0 = time.perf_counter()
                self._x = run_engine_chunk(
                    tp, self._x, self.rounds_done, 1, self.spec.schedule
                )
                jax.block_until_ready(self._x)
                self.ledger.add_round_seconds(time.perf_counter() - t0)
            else:
                self._x = run_engine_chunk(
                    tp, self._x, self.rounds_done, 1, self.spec.schedule
                )
            self.ledger.add_rounds(1)
        self.rounds_done += 1

    def step_stream(self, source, k: int | None = None) -> RoundEvent:
        """Advance up to ``k`` rounds (default: to the next loss-sampling
        boundary, or all remaining budget), each round consuming one
        fresh micro-batch from ``source``, and return what happened.

        The streaming twin of ``step_rounds`` — same loss-sampling
        boundaries (the full objective is probed on the spec's resident
        dataset, which serves as the stream session's holdout — so
        ``stop.target_loss`` keeps working), same autosave cadence, same
        StopPolicy and fault seam. What changes is the data: round r
        trains on micro-batch r instead of the resident rows.

        Exactly-once is structural: ``MicroBatch.index`` must equal the
        session's round counter (``StreamDesyncError`` otherwise), and a
        session restored from a round-r autosave re-attaches at batch r
        — sources replay deterministically, so resume continues the
        identical sequence with no duplicated or dropped batch.
        """
        from repro.serve.stream import StreamDesyncError

        if self.done:
            raise RuntimeError(
                f"session is finished ({self.stop_reason or 'rounds'} at round "
                f"{self.rounds_done}); nothing to step"
            )
        sched = self.spec.schedule
        budget = self.total_rounds
        if self.spec.stop.max_rounds is not None:
            budget = min(budget, self.spec.stop.max_rounds)
        remaining = budget - self.rounds_done
        if k is None:
            k = (
                sched.loss_every - self.rounds_done % sched.loss_every
                if sched.loss_every
                else remaining
            )
        k = min(int(k), remaining)
        if k < 1:
            raise ValueError(f"step_stream needs k ≥ 1, got {k}")

        loss = None
        synced = False
        autosave_every = self.input_spec.faults.autosave_every
        autosaving = self.autosave_dir is not None and autosave_every > 0
        t0 = time.perf_counter()
        while k > 0 and self.stop_reason is None:
            # the span measures consumer-side stall: how long the
            # trainer waited on the feed for this round's batch.
            with obs_trace.span("ingest", name=f"batch[{self.rounds_done}]",
                                index=self.rounds_done):
                batch = self._next_stream_batch(source)
            if batch.index != self.rounds_done:
                raise StreamDesyncError(
                    f"micro-batch index {batch.index} != session round "
                    f"{self.rounds_done}: a batch was duplicated, dropped, or "
                    f"reordered (resume must re-attach the source at "
                    f"start={self.rounds_done})"
                )
            first = self._first_chunk_pending
            tc = time.perf_counter()
            self._traced_advance(1, first, stream_batch=batch)
            sampled = None
            if sched.loss_every and self.rounds_done % sched.loss_every == 0:
                sampled = self._sample_loss()  # blocks (device → float)
                self.losses.append(sampled)
                loss, synced = sampled, True
            else:
                synced = False
            if first:
                if sampled is None:
                    self.current_x()  # block: compile wall must be real
                    synced = True
                self.compile_time_s += time.perf_counter() - tc
                self._first_chunk_pending = False
            k -= 1
            self._check_stop(
                sampled, wall=self.wall_time_s + (time.perf_counter() - t0)
            )
            if autosaving and self.rounds_done % autosave_every == 0:
                # the carry AND the stream position (rounds_done) are
                # durable here — resume re-attaches at this batch index.
                self.save(self.autosave_path)
            faults.poke("round", at=self.rounds_done)
        if not synced:
            self.current_x()  # block: wall covers all dispatched work
        self.wall_time_s += time.perf_counter() - t0
        if self.spec.comm_timing and not self.ledger.phase_seconds:
            self._measure_phases()

        return RoundEvent(
            rounds_done=self.rounds_done,
            x=self.current_x(),  # post-sync: a copy, not a timed stall
            loss=loss,
            wall_time_s=self.wall_time_s,
            compile_time_s=self.compile_time_s,
            comm_words=modeled_comm_words(self.spec, rounds=self.rounds_done),
            ledger=self.ledger.snapshot(),
            stop=self.stop_reason,
        )

    def _check_stop(self, loss: float | None, wall: float | None = None) -> None:
        # target_loss is checked first: a crossing on the final budgeted
        # round is still a hit (the §7.5 verdict the benchmarks persist),
        # not a budget exhaustion.
        stop = self.spec.stop
        wall = self.wall_time_s if wall is None else wall
        if (
            stop.target_loss is not None
            and loss is not None
            and loss <= stop.target_loss
        ):
            self.stop_reason = "target_loss"
        elif self.rounds_done >= self.total_rounds:
            self.stop_reason = "rounds"
        elif stop.max_rounds is not None and self.rounds_done >= stop.max_rounds:
            self.stop_reason = "max_rounds"
        elif stop.max_seconds is not None and wall >= stop.max_seconds:
            self.stop_reason = "max_seconds"

    def run(self) -> RunReport:
        """Drive the session to its stop condition and report — the
        whole old ``run(spec)``, now a loop anything can interleave
        with."""
        while not self.done:
            self.step_rounds()
        return self.report()

    def report(self) -> RunReport:
        """The uniform ``RunReport`` for the rounds completed so far."""
        x = self.current_x()
        final_loss = float(problem_loss(self.bundle.global_problem, jnp.asarray(x)))
        return RunReport(
            spec=self.spec,
            plan=self._plan,
            backend=self.spec.mesh.backend,
            x=x,
            losses=np.asarray(self.losses, np.float32),
            final_loss=final_loss,
            wall_time_s=self.wall_time_s,
            comm_words=modeled_comm_words(self.spec, rounds=self.rounds_done),
            compile_time_s=self.compile_time_s,
            solve_time_s=max(self.wall_time_s - self.compile_time_s, 0.0),
            rounds_completed=self.rounds_done,
            stop_reason=self.stop_reason,
            ledger=self.ledger.snapshot(),
        )

    # ---- checkpoint / resume ----

    @property
    def autosave_path(self) -> Path:
        """Where this session autosaves (``autosave_dir`` keyed by the
        input spec's content hash); raises when no dir was given."""
        if self.autosave_dir is None:
            raise ValueError(
                "session has no autosave_dir — pass Session(spec, autosave_dir=...)"
            )
        return autosave_base(self.autosave_dir, self.input_spec)

    def save(self, path) -> None:
        """Checkpoint the session carry at the current round boundary
        (atomic; keyed by the input spec's content hash)."""
        save_session_checkpoint(
            path,
            spec_dict=self.input_spec.to_dict(),
            spec_hash=self.input_spec.content_hash(),
            rounds_done=self.rounds_done,
            x=self.current_x(),
            losses=np.asarray(self.losses, np.float32),
            wall_time_s=self.wall_time_s,
            compile_time_s=self.compile_time_s,
        )

    @classmethod
    def restore(
        cls,
        path,
        spec: ExperimentSpec | None = None,
        autosave_dir: str | Path | None = None,
    ) -> "Session":
        """Reopen a saved session and fast-forward to its round.

        With ``spec`` given, its ``content_hash()`` must equal the hash
        the checkpoint was written under (``SpecMismatchError``
        otherwise — the message names both hashes and the first
        differing spec field) — resuming under a different experiment is
        always a hard error. With ``spec`` omitted, the spec is rebuilt
        from the checkpoint itself.

        The restored session continues the identical round sequence:
        the round counter is part of the carry, so rounds r, r+1, …
        sample exactly what the uninterrupted run would have.
        """
        ck = load_session_checkpoint(
            path,
            expect_spec_hash=spec.content_hash() if spec is not None else None,
            expect_spec_dict=spec.to_dict() if spec is not None else None,
        )
        restored_spec = (
            spec if spec is not None else ExperimentSpec.from_dict(ck.spec_dict)
        )
        sess = cls(restored_spec, x0=ck.x, autosave_dir=autosave_dir)
        return cls._fast_forward(sess, ck)

    @classmethod
    def restore_elastic(
        cls,
        path,
        devices: int | None = None,
        mesh: MeshSpec | None = None,
        calibration=None,
        autosave_dir: str | Path | None = None,
    ) -> "Session":
        """Reopen a saved session on a *different* mesh — the elastic
        door for shrink/grow after a preemption.

        Exactly one of ``devices`` / ``mesh`` picks the new geometry:
        with ``devices``, ``replan_mesh`` prices every (p_r, p_c)
        factorization under the (optionally §6.5-``calibration``-fitted)
        cost model and the cheapest wins; with ``mesh``, that geometry
        is used as given. The checkpoint's weights are re-scattered onto
        the new layout (the ELL shards are rebuilt for the new
        partition when the session constructs its problem), the loss
        trace and round counter carry over, and the run continues from
        the last round boundary.

        At an *unchanged* mesh this is exactly ``restore`` (bitwise-
        identical continuation). At a changed p_c the numerics are
        unchanged by construction (p_c is communication-only); a changed
        p_r re-teams the rows, so the resumed trajectory is a different
        — equally valid — member of the (p_r, p_c, s, τ) family that
        converges to the same objective, not a bitwise replay.
        """
        if (devices is None) == (mesh is None):
            raise ValueError("restore_elastic needs exactly one of devices= / mesh=")
        ck = load_session_checkpoint(path)  # deliberately un-keyed: elastic
        old_spec = ExperimentSpec.from_dict(ck.spec_dict)
        if mesh is None:
            new_spec = replan_mesh(old_spec, devices, calibration=calibration).spec
        else:
            new_spec = dataclasses.replace(
                old_spec,
                schedule=dataclasses.replace(
                    old_spec.schedule, p_r=mesh.p_r, p_c=mesh.p_c
                ),
                mesh=mesh,
            )
        sess = cls(new_spec, x0=ck.x, autosave_dir=autosave_dir)
        return cls._fast_forward(sess, ck)

    @staticmethod
    def _fast_forward(sess: "Session", ck: SessionCheckpoint) -> "Session":
        """Advance a freshly built session's counters to the checkpoint:
        round counter (part of the carry — the sample sequence
        continues exactly), loss-trace prefix, and accumulated wall.
        The counted-comm side of the ledger fast-forwards too (the run,
        as opposed to this process, has communicated ck.rounds_done
        rounds' worth); measured per-round seconds stay per-process — a
        fresh process recompiles and re-times."""
        sess.rounds_done = ck.rounds_done
        if sess._driver is not None:
            sess._driver.rounds_done = ck.rounds_done
        sess.ledger.rounds = ck.rounds_done
        sess.losses = [float(v) for v in ck.losses]
        sess.wall_time_s = ck.wall_time_s
        sess.compile_time_s = ck.compile_time_s
        sess._first_chunk_pending = True  # this process must recompile
        sess._check_stop(sess.losses[-1] if sess.losses else None)
        return sess
