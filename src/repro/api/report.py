"""RunReport — the unified result object every backend returns.

One report shape regardless of how the spec executed (simulated engine
or shard_map device mesh): final weights, loss trace with the engine's
``loss_every`` semantics, measured solver wall time, the plan's
predicted cost breakdown, and the run's communication three ways —
**modeled** (Table 2–3 closed forms, ``costmodel.schedule_comm_volume``),
**counted** (the ``repro.core.comm`` ledger: what the round bodies
actually issued), and **measured** (timed runs: host wall seconds per
round in the same ledger).
"""

from __future__ import annotations

import dataclasses
import json
import statistics

import numpy as np

from repro.api.plan import Plan
from repro.api.spec import ExperimentSpec
from repro.core.comm import CommLedger
from repro.costmodel.hockney import schedule_comm_volume


def modeled_comm_words(spec: ExperimentSpec, rounds: int | None = None) -> dict[str, float]:
    """Per-rank communicated words implied by the schedule — the
    Table 2–3 closed form (``costmodel.schedule_comm_volume``): one
    (s²b² + sb)-word row-team Allreduce per bundle when columns are
    sharded, one ⌈n/p_c⌉-word column Allreduce per round when there is
    more than one row team.

    ``rounds`` overrides the schedule's round budget — the Session uses
    it to report the volume of the rounds actually completed (early
    stop, mid-run events)."""
    from repro.api.spec import dataset_stats

    sched, mesh = spec.schedule, spec.mesh
    st_n = dataset_stats(spec.dataset).n
    r = sched.rounds if rounds is None else int(rounds)
    return schedule_comm_volume(
        st_n, mesh.p_r, mesh.p_c, sched.s, sched.b, sched.tau, rounds=r
    ).words_dict()


@dataclasses.dataclass
class RunReport:
    """What ``run(spec)`` returns, for any backend.

    ``wall_time_s`` splits as ``compile_time_s + solve_time_s``:
    the first session chunk (jit compile + one chunk of rounds) versus
    the steady-state remainder — compare solve times across specs
    without the one-off compilation noise.
    """

    spec: ExperimentSpec          # the spec as executed (post-autotune)
    plan: Plan                    # predicted cost at that operating point
    backend: str                  # which executor ran it
    x: np.ndarray | None          # final weights (n,); None when the
                                  # report was rehydrated from JSON
    losses: np.ndarray            # full objective every loss_every rounds
    final_loss: float             # full objective at the final iterate
    wall_time_s: float            # measured solver wall (excl. build)
    comm_words: dict[str, float]  # modeled per-rank comm volume
    compile_time_s: float = 0.0   # first chunk (includes jit compile)
    solve_time_s: float = 0.0     # steady state (wall − first chunk)
    rounds_completed: int | None = None  # rounds actually run (None: full budget)
    stop_reason: str | None = None  # StopPolicy verdict ("rounds" = budget)
    ledger: CommLedger | None = None  # counted (+ measured, when timed)
                                  # communication; None on reports
                                  # rehydrated from pre-ledger JSON

    def time_to_target(self, target: float) -> tuple[float, int, float, bool]:
        """(seconds, rounds, loss, hit) to reach ``target`` on this
        run's per-round loss trace: the wall time scaled by the first
        crossing round (the paper's §7.5 protocol). When the trace never
        crosses, returns the full wall/rounds/final loss with hit=False."""
        losses = np.asarray(self.losses)
        if not len(losses):
            raise ValueError("time_to_target needs a loss trace (schedule loss_every > 0)")
        rounds = len(losses)
        hit = np.nonzero(losses <= target)[0]
        if len(hit):
            r = int(hit[0]) + 1
            return self.wall_time_s * r / rounds, r, float(losses[hit[0]]), True
        return self.wall_time_s, rounds, float(losses[-1]), False

    def summary(self) -> str:
        sched = self.spec.schedule
        obj = ""
        if self.spec.objective != "logistic" or self.spec.l2:
            obj = f" obj={self.spec.objective}" + (
                f"+l2={self.spec.l2:g}" if self.spec.l2 else ""
            )
        trace = f", trace[{len(self.losses)}]" if len(self.losses) else ""
        stopped = (
            f" (stopped: {self.stop_reason} @ round {self.rounds_completed})"
            if self.stop_reason not in (None, "rounds")
            else ""
        )
        comm = f"modeled comm {self.comm_words['total_words']:.3g} words/rank"
        if self.ledger is not None:
            comm += f", counted {self.ledger.counted_words()['total_words']:.3g}"
            if self.ledger.seconds_per_round is not None:
                comm += f", measured {self.ledger.seconds_per_round:.3g} s/round"
            if self.ledger.exposed_comm_s is not None:
                comm += (
                    f", exposed {self.ledger.exposed_comm_s:.3g}"
                    f"/{self.ledger.total_comm_s:.3g} s"
                    f" (overlap-eff {self.ledger.overlap_efficiency:.2f}"
                )
                comm += (
                    f", delay D={self.ledger.delay})" if self.ledger.delay else ")"
                )
        return (
            f"{self.spec.name or self.spec.dataset} [{self.backend}]{obj} "
            f"s={sched.s} b={sched.b} τ={sched.tau} p_r×p_c="
            f"{self.spec.mesh.p_r}×{self.spec.mesh.p_c}: loss {self.final_loss:.4f} "
            f"in {self.wall_time_s:.2f}s{trace}{stopped}; {comm}"
        )

    def to_dict(self) -> dict:
        """JSON-serializable record (weights elided — they belong in a
        checkpoint, not a report). Round-trips through ``from_dict``;
        the ledger key is emitted only when a ledger exists, so default
        records stay readable by (and byte-compatible with) pre-ledger
        tooling."""
        d = {
            "spec": self.spec.to_dict(),
            "backend": self.backend,
            "final_loss": self.final_loss,
            "wall_time_s": self.wall_time_s,
            "compile_time_s": self.compile_time_s,
            "solve_time_s": self.solve_time_s,
            "rounds_completed": self.rounds_completed,
            "stop_reason": self.stop_reason,
            "losses": [float(v) for v in np.asarray(self.losses)],
            "comm_words": self.comm_words,
            "predicted": {
                "compute": self.plan.cost.compute,
                "latency": self.plan.cost.latency,
                "gram_bw": self.plan.cost.gram_bw,
                "sync_bw": self.plan.cost.sync_bw,
                "total": self.plan.cost.total,
                "regime": self.plan.regime,
            },
        }
        if self.ledger is not None:
            d["comm_ledger"] = self.ledger.to_dict()
        return d

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "RunReport":
        """Rehydrate a persisted report (sweep resume). The plan is
        recomputed from the spec (pure and deterministic); the weights
        are not stored in reports, so ``x`` is None. Pre-ledger JSON
        (no ``comm_ledger`` key) loads with ``ledger=None``."""
        from repro.api.plan import plan as plan_fn

        spec = ExperimentSpec.from_dict(d["spec"])
        led = d.get("comm_ledger")
        return cls(
            spec=spec,
            plan=plan_fn(spec),
            backend=d["backend"],
            x=None,
            losses=np.asarray(d["losses"], np.float32),
            final_loss=float(d["final_loss"]),
            wall_time_s=float(d["wall_time_s"]),
            comm_words=dict(d["comm_words"]),
            compile_time_s=float(d.get("compile_time_s", 0.0)),
            solve_time_s=float(d.get("solve_time_s", 0.0)),
            rounds_completed=d.get("rounds_completed"),
            stop_reason=d.get("stop_reason"),
            ledger=CommLedger.from_dict(led) if led is not None else None,
        )

    def calibration_point(self):
        """This run as a §6.5 calibration point (``costmodel.CalPoint``)
        — or None when the run was not timed (no measured rounds in the
        ledger). Regressors come from the ledger's captured rates and
        the dataset statistics; the response is the median measured
        round wall."""
        from repro.costmodel.calibrate import CalPoint
        from repro.costmodel.machines import MACHINES
        from repro.api.spec import dataset_stats

        if self.ledger is None or not self.ledger.round_seconds:
            return None
        machine = MACHINES[self.spec.machine]
        st = dataset_stats(self.spec.dataset)
        sched, mesh = self.spec.schedule, self.spec.mesh
        # per-rank flops per round: τ inner iterations of b rows at
        # 6z̄/p_c nnz-work + 2sb correction flops each (refine.py's
        # per-iteration compute term × τ)
        flops = sched.tau * sched.b * (6 * st.zbar / mesh.p_c + 2 * sched.s * sched.b)
        return CalPoint(
            phases_per_round=float(self.ledger.phases_per_round()),
            bytes_per_round=self.ledger.bytes_per_round(machine.word_bytes),
            flops_per_round=float(flops),
            seconds_per_round=statistics.median(self.ledger.round_seconds),
            label=self.spec.name or self.spec.dataset,
        )

    @classmethod
    def from_json(cls, text: str) -> "RunReport":
        return cls.from_dict(json.loads(text))
