"""RunReport — the unified result object every backend returns.

One report shape regardless of how the spec executed (simulated engine
or shard_map device mesh): final weights, loss trace with the engine's
``loss_every`` semantics, measured solver wall time, the plan's
predicted cost breakdown, and the modeled communication volume of the
run (Table 3 payloads × the schedule's round structure).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.api.plan import Plan
from repro.api.spec import ExperimentSpec


def modeled_comm_words(spec: ExperimentSpec) -> dict[str, float]:
    """Per-rank communicated words implied by the schedule (Table 3):
    one (s²b² + sb)-word row-team Allreduce per bundle when columns are
    sharded, one ~n/p_c-word column Allreduce per round when there is
    more than one row team."""
    from repro.api.spec import dataset_stats

    sched, mesh = spec.schedule, spec.mesh
    st_n = dataset_stats(spec.dataset).n
    bundles = sched.rounds * (sched.tau // sched.s)
    sb = sched.s * sched.b
    gram = float(bundles * (sb * sb + sb)) if mesh.p_c > 1 else 0.0
    sync = float(sched.rounds * math.ceil(st_n / mesh.p_c)) if mesh.p_r > 1 else 0.0
    return {"gram_words": gram, "sync_words": sync, "total_words": gram + sync}


@dataclasses.dataclass
class RunReport:
    """What ``run(spec)`` returns, for any backend."""

    spec: ExperimentSpec          # the spec as executed (post-autotune)
    plan: Plan                    # predicted cost at that operating point
    backend: str                  # which executor ran it
    x: np.ndarray                 # final weights (n,)
    losses: np.ndarray            # full objective every loss_every rounds
    final_loss: float             # full objective at the final iterate
    wall_time_s: float            # measured solver wall (excl. build)
    comm_words: dict[str, float]  # modeled per-rank comm volume

    def time_to_target(self, target: float) -> tuple[float, int, float, bool]:
        """(seconds, rounds, loss, hit) to reach ``target`` on this
        run's per-round loss trace: the wall time scaled by the first
        crossing round (the paper's §7.5 protocol). When the trace never
        crosses, returns the full wall/rounds/final loss with hit=False."""
        losses = np.asarray(self.losses)
        if not len(losses):
            raise ValueError("time_to_target needs a loss trace (schedule loss_every > 0)")
        rounds = len(losses)
        hit = np.nonzero(losses <= target)[0]
        if len(hit):
            r = int(hit[0]) + 1
            return self.wall_time_s * r / rounds, r, float(losses[hit[0]]), True
        return self.wall_time_s, rounds, float(losses[-1]), False

    def summary(self) -> str:
        sched = self.spec.schedule
        trace = f", trace[{len(self.losses)}]" if len(self.losses) else ""
        return (
            f"{self.spec.name or self.spec.dataset} [{self.backend}] "
            f"s={sched.s} b={sched.b} τ={sched.tau} p_r×p_c="
            f"{self.spec.mesh.p_r}×{self.spec.mesh.p_c}: loss {self.final_loss:.4f} "
            f"in {self.wall_time_s:.2f}s{trace}; modeled comm "
            f"{self.comm_words['total_words']:.3g} words/rank"
        )

    def to_dict(self) -> dict:
        """JSON-serializable record (weights elided — they belong in a
        checkpoint, not a report)."""
        return {
            "spec": self.spec.to_dict(),
            "backend": self.backend,
            "final_loss": self.final_loss,
            "wall_time_s": self.wall_time_s,
            "losses": [float(v) for v in np.asarray(self.losses)],
            "comm_words": self.comm_words,
            "predicted": {
                "compute": self.plan.cost.compute,
                "latency": self.plan.cost.latency,
                "gram_bw": self.plan.cost.gram_bw,
                "sync_bw": self.plan.cost.sync_bw,
                "total": self.plan.cost.total,
                "regime": self.plan.regime,
            },
        }
