"""Objective-sweep benchmark — its own driver entry so the table11
time-to-loss run isn't doubled.

    PYTHONPATH=src:. python -m benchmarks.run --only objectives

Sweeps the registered convex objectives (± L2) through one hybrid
operating point on the repro.api front door and persists
``BENCH_objectives.json`` (the objective-parity CI job uploads it as an
artifact, so per-objective convergence/wall trends are trackable).
"""

from __future__ import annotations

from benchmarks.bench_time_to_loss import run_objectives as run  # noqa: F401
