"""§Roofline — aggregate the dry-run results into the per-(arch × shape
× mesh) three-term table. Reads results/dryrun/*.json (produced by
``python -m repro.launch.dryrun``); emits one CSV row per combination.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def run() -> None:
    if not RESULTS.exists():
        emit("roofline/missing", 0.0, "run `python -m repro.launch.dryrun` first")
        return
    rows = []
    for f in sorted(RESULTS.glob("*.json")):
        rec = json.loads(f.read_text())
        tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh'].split(':')[0]}"
        if rec.get("status") == "skipped":
            emit(f"roofline/{tag}", 0.0, f"SKIPPED:{rec['reason'][:60]}")
            continue
        if rec.get("status") != "ok":
            emit(f"roofline/{tag}", 0.0, f"FAILED:{rec.get('error', '')[:80]}")
            continue
        mem = rec.get("memory", {})
        peak = mem.get("peak_bytes", 0) / 1e9
        r = rec.get("roofline")
        if r is None:
            emit(f"roofline/{tag}", 0.0, f"peak_gb={peak:.2f};memory-only")
            continue
        emit(
            f"roofline/{tag}",
            r["compute_s"] * 1e6,
            f"memory_us={r['memory_s'] * 1e6:.0f};collective_us={r['collective_s'] * 1e6:.0f};"
            f"dominant={r['dominant']};useful={r['useful_ratio']:.2f};peak_gb={peak:.2f}",
        )
        rows.append(r)
