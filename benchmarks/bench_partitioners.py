"""Paper Table 9 — partitioner statistics and per-iteration runtime.

Three measurements per dataset:
  (a) structural κ / max n_local of each partitioner on the scaled
      synthetic analogue (reproduces the Table 9 *structure* columns);
  (b) the refined cost model's predicted ms/iter at the paper's own
      measured profiles (reproduces the Table 9 *ranking*);
  (c) measured per-iteration wall time of the real shard-mapped-
      semantics solver on this CPU (single device, simulated ranks) —
      the ordering, not the absolute value, is the claim.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.api import ExperimentSpec, MeshSpec
from repro.api import run as api_run
from repro.core import ParallelSGDSchedule
from repro.costmodel import PERLMUTTER, PartitionerProfile, rank_partitioners
from repro.sparse.partition import PARTITIONERS, partition_columns, partition_stats
from repro.sparse.synthetic import make_dataset

PAPER_TABLE9 = {
    "url": (3_231_961, 116, (4, 64), {
        "rows": (33.83, 50_499), "nnz": (1.31, 1_409_992), "cyclic": (1.91, 50_499)}),
    "news20": (1_355_191, 455, (1, 64), {
        "rows": (18.73, 21_174), "nnz": (1.05, 59_103), "cyclic": (1.18, 21_174)}),
    "rcv1": (47_236, 74, (1, 16), {
        "rows": (1.62, 2_952), "nnz": (1.01, 4_333), "cyclic": (1.01, 2_952)}),
}


def run() -> None:
    # (a) structural stats on synthetic analogues
    for name in ("url-sm", "news20-sm", "rcv1-sm"):
        ds = make_dataset(name, seed=0)
        for kind in PARTITIONERS:
            st = partition_stats(ds.A, partition_columns(ds.A, 16, kind))
            emit(
                f"table9/stats/{name}/{kind}",
                0.0,
                f"kappa={st.kappa:.2f};max_n_local={st.max_n_local}",
            )

    # (b) model-predicted ranking at the paper's measured profiles
    for name, (n, zbar, (p_r, p_c), prof) in PAPER_TABLE9.items():
        profiles = [PartitionerProfile(k, *v) for k, v in prof.items()]
        ranked = rank_partitioners(n, zbar, profiles, p_r, p_c, 4, 32, 10, PERLMUTTER)
        order = ">".join(nm for nm, _ in ranked)
        for nm, bd in ranked:
            emit(f"table9/predicted/{name}/{nm}", bd.total * 1e6, f"rank_order={order}")

    # (c) measured per-iteration on CPU (simulated-rank solver)
    s, b, tau = 4, 8, 8
    for kind in PARTITIONERS:
        # partitioner affects the distributed layout; the simulated-rank
        # numerics are partition-independent, so time a fixed front-door
        # solver round as the per-iteration proxy
        spec = ExperimentSpec(
            dataset="url-sm",
            schedule=ParallelSGDSchedule.hybrid(4, s, b, 0.05, tau, rounds=1),
            mesh=MeshSpec(p_r=4, partitioner=kind),
            name=f"table9-{kind}",
        )
        api_run(spec)  # warmup: jit compile (the front door memoizes the dataset)
        t = float(np.mean([api_run(spec).wall_time_s for _ in range(3)]))
        emit(f"table9/measured-cpu/url-sm/{kind}", t / tau * 1e6, "per-inner-iter")
