"""The measured-vs-modeled communication ledger, per dataset × mesh.

For each (dataset, p_r × p_c) point this benchmark reports the run's
communication three ways and persists them to ``BENCH_comm.json`` (a CI
artifact — the counted/modeled identity and measured round walls are
trackable over time):

  modeled    the Table 2–3 closed form (costmodel.schedule_comm_volume)
             — what Eq. 4 charges β for;
  counted    the CommLedger of the run (repro.core.comm): spans and
             payloads captured from the collectives the round body
             actually issued;
  measured   per-round wall seconds from the timed collectives, on the
             shard_map backend when the process has enough devices for
             the mesh (run through ``benchmarks.run --only comm`` under
             XLA_FLAGS=--xla_force_host_platform_device_count=8, as CI
             does), and on the simulated backend otherwise.

The timed points then close the §6.5 loop in-process: ``calibrate()``
fits α/β/γ from them and the fitted constants are persisted next to the
machine presets they replace.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax

from benchmarks.common import emit
from repro.api import ExperimentSpec, MeshSpec, calibrate
from repro.api import run as api_run
from repro.core import ParallelSGDSchedule
from repro.costmodel import MACHINES

OUT_JSON = Path("BENCH_comm.json")

# dataset × mesh × delay grid: the four schedule corners appear as mesh
# limits (pure row = FedAvg-style sync traffic, pure column =
# s-step-style Gram traffic, square = both); delay ≥ 1 points rerun a
# p_c > 1 mesh with the DaSGD overlap pipeline so the exposed-vs-total
# split is tracked over time.
POINTS = [
    ("rcv1-sm", 1, 1, 0),
    ("rcv1-sm", 4, 1, 0),
    ("rcv1-sm", 1, 4, 0),
    ("rcv1-sm", 2, 2, 0),
    ("rcv1-sm", 2, 2, 1),
    ("uniform-sm", 2, 2, 0),
    ("uniform-sm", 2, 4, 0),
    ("uniform-sm", 2, 4, 2),
]


def _spec(dataset: str, p_r: int, p_c: int, delay: int, backend: str) -> ExperimentSpec:
    return ExperimentSpec(
        dataset=dataset,
        schedule=ParallelSGDSchedule.hybrid(
            p_r, 2, 8, 0.05, 8, rounds=4, delay=delay
        ),
        mesh=MeshSpec(p_r=p_r, p_c=p_c, backend=backend),
        comm_timing=True,
        name=f"comm/{dataset}/{p_r}x{p_c}/d{delay}/{backend}",
    )


def run() -> None:
    records = []
    timed_reports = []
    n_dev = jax.device_count()
    for dataset, p_r, p_c, delay in POINTS:
        backend = "shard_map" if n_dev >= p_r * p_c else "simulated"
        rep = api_run(_spec(dataset, p_r, p_c, delay, backend))
        led = rep.ledger
        counted = led.counted_words()
        spr = led.seconds_per_round
        drift = counted["total_words"] - rep.comm_words["total_words"]
        emit(
            f"comm/{dataset}/{p_r}x{p_c}/d{delay}",
            spr * 1e6,
            f"backend={backend} modeled={rep.comm_words['total_words']:.0f}w "
            f"counted={counted['total_words']:.0f}w drift={drift:.0f}w",
        )
        emit(
            f"comm/{dataset}/{p_r}x{p_c}/d{delay}/overlap",
            led.exposed_comm_s * 1e6,
            f"total_comm_us={led.total_comm_s * 1e6:.1f};"
            f"efficiency={led.overlap_efficiency:.3f};delay={delay}",
        )
        timed_reports.append(rep)
        records.append({
            "dataset": dataset,
            "mesh": [p_r, p_c],
            "delay": delay,
            "backend": backend,
            "modeled_words": rep.comm_words,
            "counted_words": counted,
            "counted_calls": led.counted_calls(),
            "rates": [r.to_dict() for r in led.rates],
            "measured_seconds_per_round": spr,
            "round_seconds": led.round_seconds,
            "wall_time_s": rep.wall_time_s,
            "exposed_comm_s": led.exposed_comm_s,
            "total_comm_s": led.total_comm_s,
            "overlap_efficiency": led.overlap_efficiency,
        })

    # §6.5 in-process: fit constants from the measured points and place
    # them next to the presets they would replace in plan().
    cal = calibrate([rep.calibration_point() for rep in timed_reports])
    machine = MACHINES["perlmutter-cpu"]
    emit(
        "comm/calibration",
        cal.gamma * 1e6,
        f"alpha={cal.alpha:.3g} beta={cal.beta:.3g} gamma={cal.gamma:.3g} "
        f"rel_rms={cal.rel_rms:.2f}",
    )
    payload = {
        "points": records,
        "calibration": cal.to_dict(),
        "preset": {
            "machine": machine.name,
            "alpha_64": machine.alpha(64),
            "beta_64": machine.beta(64),
            "gamma_flop_dram": machine.gamma_flop(1 << 30),
        },
    }
    OUT_JSON.write_text(json.dumps(payload, indent=2))
    print(f"# wrote {OUT_JSON} ({len(records)} points)")
