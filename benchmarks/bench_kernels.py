"""Pallas kernel micro-benchmarks (interpret mode — correctness-scale
numbers only; the BlockSpec VMEM analysis is the TPU-relevant output).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.ell_gram import ell_gram_and_v
from repro.kernels.ref import ell_gram_and_v_ref
from repro.kernels.sstep_inner import sstep_inner


def run() -> None:
    # ---- engine bundle primitive: Pallas ELL-Gram vs dense-reference ----
    # The engine's inner loop runs the scatter-free ELL path; the dense
    # scatter (the retired pre-engine path, kernels/ref.py) is the
    # baseline. README "Benchmarks" documents how to run this.
    for s, b, width, n in [(4, 16, 24, 4096), (8, 16, 24, 16384), (4, 32, 48, 65536)]:
        sb = s * b
        rng = np.random.default_rng(7)
        idx = jnp.asarray(rng.integers(0, n, size=(sb, width)).astype(np.int32))
        val = jnp.asarray(rng.standard_normal((sb, width)).astype(np.float32))
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))

        pallas_fn = jax.jit(lambda i, v, z: ell_gram_and_v(i, v, z, n=n, bk=512))
        dense_fn = jax.jit(lambda i, v, z: ell_gram_and_v_ref(i, v, z, n))
        t_pallas = time_fn(lambda: pallas_fn(idx, val, x), repeats=3, warmup=1)
        t_dense = time_fn(lambda: dense_fn(idx, val, x), repeats=3, warmup=1)
        tag = f"s={s};b={b};w={width};n={n}"
        emit(f"kernels/bundle/pallas-ell-gram/{sb}x{n}", t_pallas * 1e6, tag)
        emit(f"kernels/bundle/dense-ref/{sb}x{n}", t_dense * 1e6, tag)
        emit(
            f"kernels/bundle/speedup/{sb}x{n}",
            0.0,
            f"{tag};dense_over_pallas={t_dense / max(t_pallas, 1e-12):.2f}x;"
            f"hbm_bytes_dense={sb * n * 4};vmem_bytes_pallas={sb * 512 * 4 + sb * sb * 4}",
        )

    # ---- fused s-step correction loop (VMEM-resident G, v, u) ----
    for s, b in [(4, 16), (8, 16)]:
        sb = s * b
        rng = np.random.default_rng(11)
        y = rng.standard_normal((sb, 512)).astype(np.float32)
        g = jnp.asarray(np.tril(y @ y.T, -1))
        v = jnp.asarray(rng.standard_normal(sb).astype(np.float32))
        t = time_fn(lambda: sstep_inner(g, v, s, b, 0.1), repeats=3, warmup=1)
        emit(
            f"kernels/sstep-inner/{sb}",
            t * 1e6,
            f"s={s};b={b};vmem_bytes={sb * sb * 4 + 2 * sb * 4}",
        )
