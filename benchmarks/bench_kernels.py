"""Pallas kernel micro-benchmarks (interpret mode — correctness-scale
numbers only; the BlockSpec VMEM analysis is the TPU-relevant output).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.ell_gram import ell_gram_and_v
from repro.kernels.ops import sparse_linear_op, sstep_gram_and_v
from repro.kernels.ref import ell_gram_and_v_ref
from repro.sparse.bsr import bsr_from_csr
from repro.sparse.synthetic import make_skewed_csr


def run() -> None:
    a = make_skewed_csr(512, 2048, 40, 1.0, seed=0)
    bsr = bsr_from_csr(a)
    emit(
        "kernels/bsr/layout",
        0.0,
        f"tile=8x128;tiles_per_row={bsr.max_blocks};density={bsr.density:.3f};"
        f"vmem_per_step_bytes={8 * 128 * 4 + 128 * 4 + 8 * 4}",
    )
    op = sparse_linear_op(a)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(2048).astype(np.float32))
    t = time_fn(lambda: op.matvec(x), repeats=3, warmup=1)
    emit("kernels/bsr/matvec-interp", t * 1e6, "y=Ax 512x2048 interpret-mode")
    u = jnp.asarray(np.random.default_rng(1).standard_normal(512).astype(np.float32))
    t = time_fn(lambda: op.rmatvec(u), repeats=3, warmup=1)
    emit("kernels/bsr/rmatvec-interp", t * 1e6, "g=ATu via BSR(AT) forward kernel")

    y = jnp.asarray(np.random.default_rng(2).standard_normal((128, 4096)).astype(np.float32))
    xx = jnp.asarray(np.random.default_rng(3).standard_normal(4096).astype(np.float32))
    t = time_fn(lambda: sstep_gram_and_v(y, xx, bk=512), repeats=3, warmup=1)
    vmem = 128 * 512 * 4 + 128 * 128 * 4 + 512 * 4
    emit("kernels/gram/fused-interp", t * 1e6, f"sb=128 n=4096 bk=512;vmem_bytes={vmem}")

    # ---- engine bundle primitive: Pallas ELL-Gram vs dense-reference ----
    # The engine's inner loop runs the scatter-free ELL path; the dense
    # scatter (the retired pre-engine path, kernels/ref.py) is the
    # baseline. README "Benchmarks" documents how to run this.
    for s, b, width, n in [(4, 16, 24, 4096), (8, 16, 24, 16384), (4, 32, 48, 65536)]:
        sb = s * b
        rng = np.random.default_rng(7)
        idx = jnp.asarray(rng.integers(0, n, size=(sb, width)).astype(np.int32))
        val = jnp.asarray(rng.standard_normal((sb, width)).astype(np.float32))
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))

        pallas_fn = jax.jit(lambda i, v, z: ell_gram_and_v(i, v, z, n=n, bk=512))
        dense_fn = jax.jit(lambda i, v, z: ell_gram_and_v_ref(i, v, z, n))
        t_pallas = time_fn(lambda: pallas_fn(idx, val, x), repeats=3, warmup=1)
        t_dense = time_fn(lambda: dense_fn(idx, val, x), repeats=3, warmup=1)
        tag = f"s={s};b={b};w={width};n={n}"
        emit(f"kernels/bundle/pallas-ell-gram/{sb}x{n}", t_pallas * 1e6, tag)
        emit(f"kernels/bundle/dense-ref/{sb}x{n}", t_dense * 1e6, tag)
        emit(
            f"kernels/bundle/speedup/{sb}x{n}",
            0.0,
            f"{tag};dense_over_pallas={t_dense / max(t_pallas, 1e-12):.2f}x;"
            f"hbm_bytes_dense={sb * n * 4};vmem_bytes_pallas={sb * 512 * 4 + sb * sb * 4}",
        )
