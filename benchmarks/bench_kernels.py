"""Pallas kernel micro-benchmarks (interpret mode — correctness-scale
numbers only; the BlockSpec VMEM analysis is the TPU-relevant output).

Besides the historical CSV rows, every run sweeps the autotuner's
(bk, bm) panel grid over registry dataset profiles at both precisions
and persists the table to ``BENCH_kernels.json`` (gated against
``benchmarks/baselines/kernels.json`` by ``check_regression.py``; the
kernels CI job uploads it). ``--sweep-panels`` widens the grid to every
small registry dataset and a second bundle size:

    PYTHONPATH=src python -m benchmarks.bench_kernels --sweep-panels
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.engine import ParallelSGDSchedule
from repro.kernels import tune
from repro.kernels.ell_gram import ell_gram_and_v
from repro.kernels.ref import ell_gram_and_v_ref
from repro.kernels.sstep_inner import sstep_inner
from repro.sparse.synthetic import SM_STATS

OUT_JSON = Path("BENCH_kernels.json")

# default (CI) grid — --sweep-panels widens both axes
SWEEP_DATASETS = ("rcv1-sm", "epsilon-sm", "uniform-sm")
SWEEP_ROWS = ((4, 16),)  # (s, b) → 64-row bundles
FULL_ROWS = ((4, 16), (8, 16))


def _sweep_panels(datasets, rows_grid) -> dict:
    """The tuner's own candidate tables, (dataset × bundle × dtype),
    re-run fresh (force=True into a scratch cache) so the JSON is a
    measurement, not a cache read."""
    import tempfile

    out: dict = {"device": tune.device_kind(), "kernel_version": tune.KERNEL_VERSION}
    sweep: dict = {}
    with tempfile.TemporaryDirectory() as scratch:
        for name in datasets:
            st = SM_STATS[name]
            for s, b in rows_grid:
                for precision in ("fp32", "bf16"):
                    sched = ParallelSGDSchedule.hybrid(
                        2, s, b, 0.05, s, rounds=1, precision=precision
                    )
                    prof = tune.PanelProfile.from_stats(st, sched, p_c=2)
                    rec = tune.tune_panel(
                        prof, cache_dir=Path(scratch), force=True, repeats=3
                    )
                    entry: dict = {
                        "best_bk": rec["bk"],
                        "best_bm": rec["bm"],
                        "best_us": rec["measured_s"] * 1e6,
                    }
                    static = None
                    for c in rec["candidates"]:
                        if c.get("skipped") is not None:
                            continue
                        bm_tag = "" if c["bm"] is None else f"_bm{c['bm']}"
                        entry[f"bk{c['bk']}{bm_tag}_us"] = c["measured_s"] * 1e6
                        if c["bk"] == tune.FALLBACK_BK and c["bm"] is None:
                            static = c["measured_s"]
                    if static is not None:
                        entry["static512_us"] = static * 1e6
                        entry["tuned_speedup"] = static / rec["measured_s"]
                        entry["beats_static"] = bool(
                            rec["measured_s"] < static
                            and (rec["bk"], rec["bm"]) != (tune.FALLBACK_BK, None)
                        )
                    key = f"{name}/sb{s * b}"
                    sweep.setdefault(key, {})[precision] = entry
                    emit(
                        f"kernels/panel-sweep/{key}/{precision}",
                        entry["best_us"],
                        f"best_bk={rec['bk']};best_bm={rec['bm']};"
                        f"speedup_vs_512={entry.get('tuned_speedup', 1.0):.2f}x",
                    )
    out["panel_sweep"] = sweep
    return out


def run(sweep_panels: bool = False) -> None:
    # ---- engine bundle primitive: Pallas ELL-Gram vs dense-reference ----
    # The engine's inner loop runs the scatter-free ELL path; the dense
    # scatter (the retired pre-engine path, kernels/ref.py) is the
    # baseline. README "Benchmarks" documents how to run this.
    for s, b, width, n in [(4, 16, 24, 4096), (8, 16, 24, 16384), (4, 32, 48, 65536)]:
        sb = s * b
        rng = np.random.default_rng(7)
        idx = jnp.asarray(rng.integers(0, n, size=(sb, width)).astype(np.int32))
        val = jnp.asarray(rng.standard_normal((sb, width)).astype(np.float32))
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))

        pallas_fn = jax.jit(lambda i, v, z: ell_gram_and_v(i, v, z, n=n, bk=512))
        dense_fn = jax.jit(lambda i, v, z: ell_gram_and_v_ref(i, v, z, n))
        t_pallas = time_fn(lambda: pallas_fn(idx, val, x), repeats=3, warmup=1)
        t_dense = time_fn(lambda: dense_fn(idx, val, x), repeats=3, warmup=1)
        tag = f"s={s};b={b};w={width};n={n}"
        emit(f"kernels/bundle/pallas-ell-gram/{sb}x{n}", t_pallas * 1e6, tag)
        emit(f"kernels/bundle/dense-ref/{sb}x{n}", t_dense * 1e6, tag)
        emit(
            f"kernels/bundle/speedup/{sb}x{n}",
            0.0,
            f"{tag};dense_over_pallas={t_dense / max(t_pallas, 1e-12):.2f}x;"
            f"hbm_bytes_dense={sb * n * 4};vmem_bytes_pallas={sb * 512 * 4 + sb * sb * 4}",
        )

    # ---- fused s-step correction loop (VMEM-resident G, v, u) ----
    for s, b in [(4, 16), (8, 16)]:
        sb = s * b
        rng = np.random.default_rng(11)
        y = rng.standard_normal((sb, 512)).astype(np.float32)
        g = jnp.asarray(np.tril(y @ y.T, -1))
        v = jnp.asarray(rng.standard_normal(sb).astype(np.float32))
        t = time_fn(lambda: sstep_inner(g, v, s, b, 0.1), repeats=3, warmup=1)
        emit(
            f"kernels/sstep-inner/{sb}",
            t * 1e6,
            f"s={s};b={b};vmem_bytes={sb * sb * 4 + 2 * sb * 4}",
        )

    # ---- autotuner panel sweep → BENCH_kernels.json ----
    datasets = tuple(SM_STATS) if sweep_panels else SWEEP_DATASETS
    rows_grid = FULL_ROWS if sweep_panels else SWEEP_ROWS
    results = _sweep_panels(datasets, rows_grid)
    OUT_JSON.write_text(json.dumps(results, indent=1, sort_keys=True))
    winners = [
        (k, p, e["best_bk"])
        for k, per in results["panel_sweep"].items()
        for p, e in per.items()
        if e.get("beats_static")
    ]
    print(f"# panel sweep → {OUT_JSON} ({len(winners)} configs beat static bk=512)")


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(prog="bench_kernels")
    ap.add_argument(
        "--sweep-panels",
        action="store_true",
        help="full (dataset × bundle × dtype) panel grid instead of the CI subset",
    )
    args = ap.parse_args(argv)
    run(sweep_panels=args.sweep_panels)


if __name__ == "__main__":
    main()
