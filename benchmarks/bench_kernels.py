"""Pallas kernel micro-benchmarks (interpret mode — correctness-scale
numbers only; the BlockSpec VMEM analysis is the TPU-relevant output).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.ops import sparse_linear_op, sstep_gram_and_v
from repro.sparse.bsr import bsr_from_csr
from repro.sparse.synthetic import make_skewed_csr


def run() -> None:
    a = make_skewed_csr(512, 2048, 40, 1.0, seed=0)
    bsr = bsr_from_csr(a)
    emit(
        "kernels/bsr/layout",
        0.0,
        f"tile=8x128;tiles_per_row={bsr.max_blocks};density={bsr.density:.3f};"
        f"vmem_per_step_bytes={8 * 128 * 4 + 128 * 4 + 8 * 4}",
    )
    op = sparse_linear_op(a)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(2048).astype(np.float32))
    t = time_fn(lambda: op.matvec(x), repeats=3, warmup=1)
    emit("kernels/bsr/matvec-interp", t * 1e6, "y=Ax 512x2048 interpret-mode")
    u = jnp.asarray(np.random.default_rng(1).standard_normal(512).astype(np.float32))
    t = time_fn(lambda: op.rmatvec(u), repeats=3, warmup=1)
    emit("kernels/bsr/rmatvec-interp", t * 1e6, "g=ATu via BSR(AT) forward kernel")

    y = jnp.asarray(np.random.default_rng(2).standard_normal((128, 4096)).astype(np.float32))
    xx = jnp.asarray(np.random.default_rng(3).standard_normal(4096).astype(np.float32))
    t = time_fn(lambda: sstep_gram_and_v(y, xx, bk=512), repeats=3, warmup=1)
    vmem = 128 * 512 * 4 + 128 * 128 * 4 + 512 * 4
    emit("kernels/gram/fused-interp", t * 1e6, f"sb=128 n=4096 bk=512;vmem_bytes={vmem}")
