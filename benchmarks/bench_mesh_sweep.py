"""Paper Figure 5 — per-iteration runtime vs p_r across all
factorizations p_r·p_c = p (the solver-family transition).

Two reproductions:
  (a) the cost model traces the transition on the paper's full-size
      stats — url must be U-shaped with an interior optimum; news20 and
      rcv1 must be monotone with the optimum at the 1D s-step corner;
  (b) measured CPU wall time of the simulated-rank solver on the scaled
      url-sm dataset across p_r ∈ {1, 2, 4, 8} (fixed total work), each
      point an ``ExperimentSpec`` through the repro.api front door.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.api import ExperimentSpec, MeshSpec
from repro.api import run as api_run
from repro.costmodel import PERLMUTTER, HybridConfig, hybrid_epoch_cost
from repro.core import ParallelSGDSchedule
from repro.sparse.synthetic import DATASET_STATS


def run() -> None:
    # (a) model transition curves
    for name, p in (("url", 256), ("news20", 64), ("rcv1", 16)):
        st = DATASET_STATS[name]
        curve = {}
        p_r = 1
        while p_r <= p:
            cfg = HybridConfig(p_r, p // p_r, 4, 32, 10)
            curve[p_r] = hybrid_epoch_cost(st.m, st.n, st.zbar, cfg, PERLMUTTER).total
            p_r *= 2
        best_pr = min(curve, key=curve.get)
        interior = 1 < best_pr < p
        shape = "U-interior" if interior else ("sstep-corner" if best_pr == 1 else "fedavg-corner")
        for p_r, t in curve.items():
            emit(f"fig5/model/{name}/pr={p_r}", t * 1e6, f"best_pr={best_pr};shape={shape}")

    # (b) measured on CPU: simulated-rank solver, fixed epoch work
    s, b, tau, eta = 4, 8, 8, 0.05
    for p_r in (1, 2, 4, 8):
        spec = ExperimentSpec(
            dataset="url-sm",
            schedule=ParallelSGDSchedule.hybrid(p_r, s, b, eta, tau, rounds=1),
            mesh=MeshSpec(p_r=p_r),
            name=f"fig5-pr{p_r}",
        )
        api_run(spec)  # warmup: jit compile (the front door memoizes the dataset)
        t = float(np.mean([api_run(spec).wall_time_s for _ in range(3)]))
        # simulated ranks execute sequentially on one CPU; wall/p_r is
        # the parallel per-team proxy
        emit(f"fig5/measured-cpu/url-sm/pr={p_r}", t / p_r * 1e6,
             "per-team wall proxy (one tau-round / p_r)")
