"""Paper Figure 5 — per-iteration runtime vs p_r across all
factorizations p_r·p_c = p (the solver-family transition).

Two reproductions:
  (a) the cost model traces the transition on the paper's full-size
      stats — url must be U-shaped with an interior optimum; news20 and
      rcv1 must be monotone with the optimum at the 1D s-step corner;
  (b) measured CPU wall time of the simulated-rank solver on the scaled
      url-sm dataset across p_r ∈ {1, 2, 4, 8} (fixed total work).
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import run_hybrid_sgd, stack_row_teams
from repro.costmodel import PERLMUTTER, HybridConfig, hybrid_epoch_cost
from repro.sparse.synthetic import DATASET_STATS, make_dataset


def run() -> None:
    # (a) model transition curves
    for name, p in (("url", 256), ("news20", 64), ("rcv1", 16)):
        st = DATASET_STATS[name]
        curve = {}
        p_r = 1
        while p_r <= p:
            cfg = HybridConfig(p_r, p // p_r, 4, 32, 10)
            curve[p_r] = hybrid_epoch_cost(st.m, st.n, st.zbar, cfg, PERLMUTTER).total
            p_r *= 2
        best_pr = min(curve, key=curve.get)
        interior = 1 < best_pr < p
        shape = "U-interior" if interior else ("sstep-corner" if best_pr == 1 else "fedavg-corner")
        for p_r, t in curve.items():
            emit(f"fig5/model/{name}/pr={p_r}", t * 1e6, f"best_pr={best_pr};shape={shape}")

    # (b) measured on CPU: simulated-rank solver, fixed epoch work
    ds = make_dataset("url-sm", seed=0)
    s, b, tau, eta = 4, 8, 8, 0.05
    for p_r in (1, 2, 4, 8):
        tp = stack_row_teams(ds.A, ds.y, p_r, row_multiple=s * b)
        x0 = jnp.zeros(ds.A.n)
        t = time_fn(lambda: run_hybrid_sgd(tp, x0, s, b, eta, tau, 1)[0], repeats=3, warmup=1)
        # simulated ranks execute sequentially on one CPU; wall/p_r is
        # the parallel per-team proxy
        emit(f"fig5/measured-cpu/url-sm/pr={p_r}", t / p_r * 1e6,
             "per-team wall proxy (one tau-round / p_r)")
