"""Paper Table 11 + Figure 6 — time-to-target-loss across solvers.

On this single CPU the wall-clock of the *simulated-rank* solvers
reflects compute only (communication is free on one device), so the
measured speedups are sample-efficiency + compute effects; the
cluster-level claim (53× on url etc.) is carried by the cost model
(bench_costmodel) — both are reported, clearly labeled.

Solvers run at each one's paper-style configuration on url-sm (sparse,
high-dimensional, column-skewed — HybridSGD's home regime) and
epsilon-sm (dense — FedAvg's home regime), every one an
``ExperimentSpec`` through the repro.api front door with a first-class
``StopPolicy(target_loss=…)``: the session *stops at the crossing*, so
the reported seconds are measured time-to-target (§7.5), not post-hoc
scaling of a full run. Per-spec results (wall split into compile vs
steady-state solve, rounds, hit/miss) are persisted to
``BENCH_time_to_loss.json`` for trend tracking.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit
from repro.api import ExperimentSpec, MeshSpec, StopPolicy
from repro.api import run as api_run
from repro.core import ParallelSGDSchedule
from repro.core.objective import OBJECTIVES

ETA = 1.0
OUT_JSON = Path("BENCH_time_to_loss.json")
OUT_OBJECTIVES_JSON = Path("BENCH_objectives.json")


def _run_to_target(spec: ExperimentSpec):
    """One front-door run that stops at the target crossing. Returns
    (seconds, rounds, loss, hit, record-dict). ``seconds`` is the
    steady-state solve time: the solvers compile different programs
    (vmap vs lax.map + Gram), so first-chunk jit wall would otherwise
    dominate short to-the-crossing runs and the speedup ratio would
    compare compilation, not the solver."""
    rep = api_run(spec)
    hit = rep.stop_reason == "target_loss"
    loss = float(rep.losses[-1]) if len(rep.losses) else rep.final_loss
    record = {
        "name": spec.name,
        "dataset": spec.dataset,
        "target_loss": spec.stop.target_loss,
        "seconds_to_target": rep.solve_time_s,   # steady state (excl. compile)
        "wall_time_s": rep.wall_time_s,          # incl. first-chunk compile
        "compile_time_s": rep.compile_time_s,
        "solve_time_s": rep.solve_time_s,
        "rounds": rep.rounds_completed,
        "loss": loss,
        "hit": hit,
    }
    return rep.solve_time_s, rep.rounds_completed, loss, hit, record


def run_objectives(rounds: int = 20) -> None:
    """Sweep the registered convex objectives (± L2) through one hybrid
    operating point on the front door — rounds-to-loss and wall split
    per objective, persisted to ``BENCH_objectives.json`` (a CI
    artifact: objective-layer perf/convergence trends over time)."""
    s, b, tau, p_r = 2, 8, 8, 2
    records = []
    for obj in sorted(OBJECTIVES):
        for l2 in (0.0, 1e-3):
            spec = ExperimentSpec(
                dataset="rcv1-sm",
                schedule=ParallelSGDSchedule.hybrid(
                    p_r, s, b, 0.5, tau, rounds=rounds, loss_every=rounds // 4,
                    gram="dense",
                ),
                mesh=MeshSpec(p_r=p_r),
                row_multiple=s * b,
                objective=obj,
                l2=l2,
                name=f"objectives/{obj}/l2={l2:g}",
            )
            rep = api_run(spec)
            records.append({
                "objective": obj,
                "l2": l2,
                "dataset": spec.dataset,
                "rounds": rep.rounds_completed,
                "final_loss": rep.final_loss,
                "losses": [float(v) for v in rep.losses],
                "wall_time_s": rep.wall_time_s,
                "compile_time_s": rep.compile_time_s,
                "solve_time_s": rep.solve_time_s,
            })
            emit(f"objectives/{obj}/l2={l2:g}", rep.solve_time_s * 1e6,
                 f"final_loss={rep.final_loss:.4f}")
    OUT_OBJECTIVES_JSON.write_text(json.dumps(records, indent=2))
    print(f"# wrote {OUT_OBJECTIVES_JSON} ({len(records)} record(s))")


def run() -> None:
    records = []
    # targets calibrated to the slower solver's 60-round terminal loss
    # (the paper's own calibration protocol, §7.5)
    for ds_name, target in (("url-sm", 0.675), ("epsilon-sm", 0.54)):
        s, b, tau = 4, 16, 16
        p_r_hybrid = 2
        p_fed = 8
        R = 60

        # One front door, three corners of the (p_r, s, τ) family. This
        # bench measures *sample efficiency* (rounds to target) on
        # simulated ranks, so the bundle backend is pinned to the dense
        # oracle: on these paper-scale shapes (url-sm ELL width ≫ sb)
        # the scatter-free expansion is MXU work that interpret mode
        # serializes on CPU — kernel wall-clock is bench_kernels' job.
        def spec(schedule, p_r=1, name=""):
            return ExperimentSpec(dataset=ds_name, schedule=schedule,
                                  mesh=MeshSpec(p_r=p_r), row_multiple=s * b,
                                  stop=StopPolicy(target_loss=target),
                                  name=f"{ds_name}/{name}")

        t_f, r_f, l_f, hit_f, rec = _run_to_target(
            spec(ParallelSGDSchedule.fedavg(p_fed, b, ETA, tau, rounds=R, loss_every=1),
                 p_r=p_fed, name="fedavg"))
        records.append(rec)
        emit(f"table11/{ds_name}/fedavg", t_f * 1e6, f"rounds={r_f};loss={l_f:.4f}")

        t_h, r_h, l_h, hit_h, rec = _run_to_target(
            spec(ParallelSGDSchedule.hybrid(p_r_hybrid, s, b, ETA, tau, rounds=R,
                                            loss_every=1, gram="dense"),
                 p_r=p_r_hybrid, name="hybrid"))
        records.append(rec)
        emit(f"table11/{ds_name}/hybrid", t_h * 1e6, f"rounds={r_h};loss={l_h:.4f}")

        t_s, r_s, l_s, hit_s, rec = _run_to_target(
            spec(ParallelSGDSchedule.sstep(s, b, ETA, R * tau, loss_every=tau,
                                           gram="dense"),
                 name="sstep1d"))
        records.append(rec)
        emit(f"table11/{ds_name}/sstep1d", t_s * 1e6, f"rounds={r_s};loss={l_s:.4f}")

        speedup = t_f / max(t_h, 1e-9)
        # On one CPU the engine's Gram path runs the Pallas ELL kernel
        # in interpret mode, so hybrid wall-clock is correctness-scale;
        # on a cluster, communication dominates — the 183× per-sample
        # model prediction in table11-model carries the cluster claim.
        # The *sample-efficiency* comparison (rounds to equal loss) is
        # the machine-independent part measured here.
        emit(
            f"table11/{ds_name}/speedup-hybrid-over-fedavg",
            0.0,
            f"cpu_wall={speedup:.2f}x;rounds_fed={r_f};rounds_hyb={r_h};"
            f"regime={'hybrid-favored-on-cluster' if 'url' in ds_name else 'fedavg-favored'}",
        )

    OUT_JSON.write_text(json.dumps(records, indent=2))
    print(f"# wrote {OUT_JSON} ({len(records)} record(s))")
