"""Paper Table 11 + Figure 6 — time-to-target-loss across solvers.

On this single CPU the wall-clock of the *simulated-rank* solvers
reflects compute only (communication is free on one device), so the
measured speedups are sample-efficiency + compute effects; the
cluster-level claim (53× on url etc.) is carried by the cost model
(bench_costmodel) — both are reported, clearly labeled.

Solvers run at each one's paper-style configuration on url-sm (sparse,
high-dimensional, column-skewed — HybridSGD's home regime) and
epsilon-sm (dense — FedAvg's home regime).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (
    ParallelSGDSchedule,
    make_problem,
    run_parallel_sgd,
    single_team,
    stack_row_teams,
)
from repro.sparse.synthetic import make_dataset

ETA = 1.0


def _time_to_target(run_traced, target: float, max_rounds: int = 60):
    """One timed run with a per-round loss trace; time-to-target =
    (first crossing round / max_rounds) × total wall. Single
    compilation, correct cyclic sample sequence."""
    t0 = time.perf_counter()
    losses = np.asarray(run_traced(max_rounds))
    total = time.perf_counter() - t0
    hit = np.nonzero(losses <= target)[0]
    if len(hit):
        r = int(hit[0]) + 1
        return total * r / max_rounds, r, float(losses[hit[0]])
    return total, max_rounds, float(losses[-1])


def run() -> None:
    # targets calibrated to the slower solver's 60-round terminal loss
    # (the paper's own calibration protocol, §7.5)
    for ds_name, target in (("url-sm", 0.675), ("epsilon-sm", 0.54)):
        ds = make_dataset(ds_name, seed=0)
        s, b, tau = 4, 16, 16
        p_r_hybrid = 2
        p_fed = 8

        # One engine, three corners of the (p_r, s, τ) family. This
        # bench measures *sample efficiency* (rounds to target) on
        # simulated ranks, so the bundle backend is pinned to the dense
        # oracle: on these paper-scale shapes (url-sm ELL width ≫ sb)
        # the scatter-free expansion is MXU work that interpret mode
        # serializes on CPU — kernel wall-clock is bench_kernels' job.
        x0 = jnp.zeros(ds.A.n)

        # FedAvg at p=8
        tp_f = stack_row_teams(ds.A, ds.y, p_fed, row_multiple=b)

        def fed_run(R, _tp=tp_f, _x0=x0):
            sched = ParallelSGDSchedule.fedavg(p_fed, b, ETA, tau, rounds=R, loss_every=1)
            return run_parallel_sgd(_tp, _x0, sched)[1]

        t_f, r_f, l_f = _time_to_target(fed_run, target)
        emit(f"table11/{ds_name}/fedavg", t_f * 1e6, f"rounds={r_f};loss={l_f:.4f}")

        # HybridSGD at p_r=2
        tp_h = stack_row_teams(ds.A, ds.y, p_r_hybrid, row_multiple=s * b)

        def hyb_run(R, _tp=tp_h, _x0=x0):
            sched = ParallelSGDSchedule.hybrid(
                p_r_hybrid, s, b, ETA, tau, rounds=R, loss_every=1, gram="dense"
            )
            return run_parallel_sgd(_tp, _x0, sched)[1]

        t_h, r_h, l_h = _time_to_target(hyb_run, target)
        emit(f"table11/{ds_name}/hybrid", t_h * 1e6, f"rounds={r_h};loss={l_h:.4f}")

        # 1D s-step (p_r=1 corner)
        prob = make_problem(ds.A, ds.y, row_multiple=s * b)

        def ss_run(R, _p=prob, _x0=x0):
            sched = ParallelSGDSchedule.sstep(
                s, b, ETA, R * tau, loss_every=tau, gram="dense"
            )
            return run_parallel_sgd(single_team(_p), _x0, sched)[1]

        t_s, r_s, l_s = _time_to_target(ss_run, target)
        emit(f"table11/{ds_name}/sstep1d", t_s * 1e6, f"rounds={r_s};loss={l_s:.4f}")

        speedup = t_f / max(t_h, 1e-9)
        # On one CPU the engine's Gram path runs the Pallas ELL kernel
        # in interpret mode, so hybrid wall-clock is correctness-scale;
        # on a cluster, communication dominates — the 183× per-sample
        # model prediction in table11-model carries the cluster claim.
        # The *sample-efficiency* comparison (rounds to equal loss) is
        # the machine-independent part measured here.
        emit(
            f"table11/{ds_name}/speedup-hybrid-over-fedavg",
            0.0,
            f"cpu_wall={speedup:.2f}x;rounds_fed={r_f};rounds_hyb={r_h};"
            f"regime={'hybrid-favored-on-cluster' if 'url' in ds_name else 'fedavg-favored'}",
        )
