"""Paper Tables 5 + 7 + 11-model + Figure 4 — the α-β-γ model itself.

  * Table 7: the measured Perlmutter constants (hard-coded machine
    model) + the TPU v5e retarget;
  * Table 5: regime classification on each dataset at its paper config;
  * Table 11 (model side): predicted per-sample solver costs and the
    hybrid/FedAvg crossover on url vs epsilon;
  * Figure 4: predicted-vs-"measured" partitioner cells where measured
    = the paper's published Table 9 numbers (ranking fidelity check).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.costmodel import (
    PERLMUTTER,
    TPU_V5E,
    HybridConfig,
    PartitionerProfile,
    classify_regime,
    joint_sb_star,
    per_sample_costs,
    rank_partitioners,
    s_star,
)
from repro.sparse.synthetic import DATASET_STATS


def run() -> None:
    # Table 7: machine parameter lookups (spot values)
    for q in (8, 64, 256, 4096):
        emit(f"table7/perlmutter/beta_q={q}", PERLMUTTER.beta(q) * 1e15, "fs/B")
    for w in (8_192, 524_288, 67_108_864):
        emit(f"table7/perlmutter/gamma_W={w}", PERLMUTTER.gamma_bytes(w) * 1e15, "fs/B")
    emit("table7/tpu/beta_intra", TPU_V5E.beta(256) * 1e15, "fs/B (ICI)")
    emit("table7/tpu/beta_inter", TPU_V5E.beta(512) * 1e15, "fs/B (DCI)")

    # Table 5: regimes at each dataset's paper config
    configs = {
        "url": (256, HybridConfig(4, 64, 4, 32, 10)),
        "news20": (64, HybridConfig(1, 64, 4, 32, 10)),
        "rcv1": (16, HybridConfig(1, 16, 4, 32, 10)),
        "epsilon": (512, HybridConfig(1, 512, 4, 32, 10)),
    }
    for name, (p, cfg) in configs.items():
        st = DATASET_STATS[name]
        r = classify_regime(st.m, st.n, st.zbar, cfg, PERLMUTTER)
        emit(
            f"table5/regime/{name}",
            r.breakdown.total * 1e6,
            f"dominant={r.name};balance={r.balance:.2f};action={r.action}",
        )

    # closed-form optima (Eq. 5/6) at the url mesh
    st = DATASET_STATS["url"]
    s_opt = s_star(32, 10, 4, 64, st.n, PERLMUTTER)
    s_b = joint_sb_star(10, 4, 64, st.n, PERLMUTTER)
    emit("eq5/url/s_star", s_opt, f"joint=(s={s_b[0]:.1f},b={s_b[1]:.1f})")

    # Table 11 model side: per-sample crossover
    for name, p, mesh in (("url", 256, (4, 64)), ("epsilon", 512, (1, 512))):
        st = DATASET_STATS[name]
        hyb = sum(per_sample_costs("hybrid", st.m, st.n, st.zbar, p, 4, 32, 10, PERLMUTTER, *mesh).values())
        fed = sum(per_sample_costs("fedavg", st.m, st.n, st.zbar, 32 if name == "epsilon" else p, 1, 32, 10, PERLMUTTER).values())
        emit(
            f"table11-model/{name}",
            hyb * 1e9,
            f"fedavg_ns={fed * 1e9:.1f};fed_over_hyb={fed / hyb:.2f}x",
        )

    # Figure 4: predicted vs paper-measured per-iteration (9 cells)
    paper_measured_ms = {
        ("url", "rows"): 0.970, ("url", "nnz"): 2.280, ("url", "cyclic"): 0.520,
        ("news20", "rows"): 0.326, ("news20", "nnz"): 0.142, ("news20", "cyclic"): 0.093,
        ("rcv1", "rows"): 0.031, ("rcv1", "nnz"): 0.031, ("rcv1", "cyclic"): 0.029,
    }
    profs = {
        "url": (3_231_961, 116, (4, 64), [
            PartitionerProfile("rows", 33.83, 50_499),
            PartitionerProfile("nnz", 1.31, 1_409_992),
            PartitionerProfile("cyclic", 1.91, 50_499)]),
        "news20": (1_355_191, 455, (1, 64), [
            PartitionerProfile("rows", 18.73, 21_174),
            PartitionerProfile("nnz", 1.05, 59_103),
            PartitionerProfile("cyclic", 1.18, 21_174)]),
        "rcv1": (47_236, 74, (1, 16), [
            PartitionerProfile("rows", 1.62, 2_952),
            PartitionerProfile("nnz", 1.01, 4_333),
            PartitionerProfile("cyclic", 1.01, 2_952)]),
    }
    for ds, (n, zbar, (p_r, p_c), profiles) in profs.items():
        for nm, bd in rank_partitioners(n, zbar, profiles, p_r, p_c, 4, 32, 10, PERLMUTTER):
            meas = paper_measured_ms[(ds, nm)]
            emit(
                f"fig4/{ds}/{nm}",
                bd.total * 1e6,
                f"paper_measured_us={meas * 1e3:.0f};ratio={bd.total * 1e3 / meas:.2f}",
            )
