"""Paper Table 4 — topology-respecting mesh rule vs the empirical best.

The rule p_c* = max(⌈nw/L_cap⌉, min(R, p)) must reproduce the paper's
predictions on all four rows, and the cost model must place the rule's
mesh within a small factor of the best mesh in a full factorization
sweep (paper: within 9% on url).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.costmodel import PERLMUTTER, TPU_V5E, HybridConfig, hybrid_epoch_cost, topology_rule
from repro.sparse.synthetic import DATASET_STATS

TABLE4 = [
    ("url", 256, (4, 64), (8, 32)),
    ("synthetic_uniform", 128, (2, 64), (2, 64)),
    ("news20", 64, (1, 64), (1, 64)),
    ("rcv1", 16, (1, 16), (1, 16)),
]


def run() -> None:
    for name, p, paper_rule, paper_best in TABLE4:
        st = DATASET_STATS[name]
        got = topology_rule(p, st.n, PERLMUTTER)
        emit(
            f"table4/rule/{name}",
            0.0,
            f"rule={got};paper_rule={paper_rule};paper_best={paper_best};"
            f"match={'yes' if got == paper_rule else 'NO'}",
        )

    # full mesh sweep at p=256 on url stats: the rule's mesh must be
    # within 2x of the sweep's best under Eq. (4) (paper: within 9%
    # measured; our model is the ranking tool, not a clock)
    st = DATASET_STATS["url"]
    best = None
    costs = {}
    p = 256
    p_r = 1
    while p_r <= p:
        p_c = p // p_r
        cb = hybrid_epoch_cost(st.m, st.n, st.zbar, HybridConfig(p_r, p_c, 4, 32, 10), PERLMUTTER)
        costs[(p_r, p_c)] = cb.total
        if best is None or cb.total < costs[best]:
            best = (p_r, p_c)
        p_r *= 2
    rule = topology_rule(p, st.n, PERLMUTTER)
    ratio = costs[rule] / costs[best]
    emit(
        "table4/sweep/url",
        costs[best] * 1e6,
        f"sweep_best={best};rule={rule};rule_over_best={ratio:.3f}",
    )

    # TPU retarget: the rule keeps the frequent axis inside one pod
    for name in ("url", "news20"):
        st = DATASET_STATS[name]
        got = topology_rule(512, st.n, TPU_V5E)
        emit(f"table4/tpu-rule/{name}", 0.0, f"mesh={got};domain={TPU_V5E.ranks_per_domain}")
