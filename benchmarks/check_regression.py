"""Benchmark regression gate — fresh results vs committed baselines.

    python benchmarks/check_regression.py FRESH BASELINE [FRESH2 BASELINE2 ...] [--tol 10]

Compares every numeric leaf a baseline JSON carries against the same
leaf in a freshly produced benchmark JSON (``BENCH_*.json`` from e.g.
``benchmarks.bench_serve``). The gate is deliberately loose — an
order-of-magnitude ratio (default ``--tol 10``) — because CI machines
vary wildly in speed; what it catches is the catastrophic class of
regression (a 50× throughput collapse, a metric that stopped being
produced), not a 20% wobble.

Rules, per baseline leaf:

* numbers must exist in the fresh file and satisfy
  ``1/tol ≤ fresh/baseline ≤ tol`` (both ≈0 passes; exactly one ≈0
  fails — the signal died);
* strings must match exactly (they name what was measured);
* ``null`` / booleans are skipped (e.g. adapt-round fields that vary
  run to run);
* a baseline key missing from the fresh file fails — a metric that
  disappeared is a regression even when everything else is fast.

Keys present only in the fresh file are ignored, so adding metrics
never breaks the gate. Exits nonzero listing every violation.

Stdlib-only on purpose: the gate must run before (and regardless of)
any environment the benchmarks themselves need.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ZERO = 1e-12


def compare(fresh, base, tol: float, path: str = "") -> list[str]:
    """Violation strings for every baseline leaf the fresh tree fails."""
    where = path or "<root>"
    if isinstance(base, dict):
        if not isinstance(fresh, dict):
            return [f"{where}: baseline is an object, fresh is {type(fresh).__name__}"]
        out = []
        for k, bv in base.items():
            sub = f"{path}.{k}" if path else k
            if k not in fresh:
                out.append(f"{sub}: missing from fresh results")
                continue
            out += compare(fresh[k], bv, tol, sub)
        return out
    if isinstance(base, list):
        if not isinstance(fresh, list) or len(fresh) != len(base):
            return [f"{where}: list shape changed ({base!r} → {fresh!r})"]
        out = []
        for i, bv in enumerate(base):
            out += compare(fresh[i], bv, tol, f"{where}[{i}]")
        return out
    if base is None or isinstance(base, bool):
        return []  # run-to-run varying fields; not gated
    if isinstance(base, str):
        return [] if fresh == base else [f"{where}: {base!r} → {fresh!r}"]
    # numeric leaf
    if not isinstance(fresh, (int, float)) or isinstance(fresh, bool):
        return [f"{where}: baseline {base!r} is numeric, fresh is {fresh!r}"]
    b, f = float(base), float(fresh)
    if abs(b) <= ZERO and abs(f) <= ZERO:
        return []
    if abs(b) <= ZERO or abs(f) <= ZERO:
        return [f"{where}: {b:g} → {f:g} (signal vanished)"]
    if b * f < 0:
        return [f"{where}: sign flipped ({b:g} → {f:g})"]
    ratio = f / b
    if not (1.0 / tol <= ratio <= tol):
        return [f"{where}: {b:g} → {f:g} (ratio {ratio:.3g} outside [1/{tol:g}, {tol:g}])"]
    return []


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="check_regression", description="gate fresh benchmark JSON on baselines"
    )
    ap.add_argument("pairs", nargs="+", metavar="FRESH BASELINE",
                    help="alternating fresh-results / committed-baseline paths")
    ap.add_argument("--tol", type=float, default=10.0,
                    help="allowed fresh/baseline ratio band [1/tol, tol] (default 10)")
    args = ap.parse_args(argv)
    if args.tol <= 1.0:
        ap.error(f"--tol {args.tol} must be > 1")
    if len(args.pairs) % 2:
        ap.error("paths come in FRESH BASELINE pairs")

    failed = False
    for i in range(0, len(args.pairs), 2):
        fresh_p, base_p = Path(args.pairs[i]), Path(args.pairs[i + 1])
        try:
            fresh = json.loads(fresh_p.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"[gate ] FAIL {fresh_p}: unreadable fresh results ({e})")
            failed = True
            continue
        base = json.loads(base_p.read_text())
        problems = compare(fresh, base, args.tol)
        if problems:
            failed = True
            print(f"[gate ] FAIL {fresh_p} vs {base_p}:")
            for p in problems:
                print(f"        {p}")
        else:
            print(f"[gate ] ok   {fresh_p} vs {base_p} (tol {args.tol:g}×)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
