"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable[[], object], repeats: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call (blocks on jax arrays)."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r) if r is not None else None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn()
        if r is not None:
            jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}")
