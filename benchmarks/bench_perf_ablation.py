"""§Perf ablation — reproduce the hillclimb effects on reduced-depth
compiles (fast enough for the bench driver; the full-depth numbers are
in EXPERIMENTS.md §Perf and results/dryrun/).

Runs in a subprocess so the 512-device XLA flag never leaks into the
bench process.
"""

from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit

REPO = Path(__file__).resolve().parent.parent

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses, json, jax
import repro.configs as C
from repro import compat
from repro.launch.dryrun import lower_step, _cost_and_collectives
from repro.launch.input_specs import SHAPES, resolve_config
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()
shape = SHAPES["train_4k"]
out = {}
for prof in ("tp", "dp"):
    cfg = dataclasses.replace(resolve_config("gemma-2b", shape),
                              sharding_profile=prof, n_layers=2)
    with compat.use_mesh(mesh):
        f, b, coll = _cost_and_collectives(cfg, shape, mesh, 2)
    out[prof] = {"flops": f, "bytes": b, "coll": coll.total_bytes}
print("RESULT " + json.dumps(out))
"""


def run() -> None:
    env = {
        "PYTHONPATH": str(REPO / "src"),
        "PATH": "/usr/bin:/bin:/usr/local/bin",
        "JAX_PLATFORMS": "cpu",
    }
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env, timeout=900
    )
    if proc.returncode != 0:
        emit("perf-ablation/error", 0.0, proc.stderr.splitlines()[-1][:100] if proc.stderr else "?")
        return
    line = next(l for l in proc.stdout.splitlines() if l.startswith("RESULT "))
    res = json.loads(line[len("RESULT "):])
    for prof, v in res.items():
        emit(
            f"perf-ablation/gemma-train-2L/{prof}",
            v["coll"] / 50e9 * 1e6,  # collective term µs
            f"flops={v['flops']:.3g};coll_bytes={v['coll']:.3g}",
        )
    ratio = res["tp"]["coll"] / max(res["dp"]["coll"], 1)
    emit("perf-ablation/gemma-train-2L/dp-win", 0.0, f"collective_ratio_tp_over_dp={ratio:.1f}x")
