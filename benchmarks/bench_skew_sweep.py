"""Paper Figure 3 — partitioner behaviour vs the column-skew exponent.

Synthetic sweep over α ∈ [0, 1.4]: cyclic is skew-invariant (n_local
exact, κ near-optimal), rows degrades smoothly as κ rises, nnz-greedy
keeps κ≈1 but its max n_local (cache slab) grows with skew — measured
structurally and through the refined cost model's per-iteration
prediction (the sync-skew and cache-tier terms).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.costmodel import PERLMUTTER, PartitionerProfile, predict_hybrid_iter
from repro.sparse.partition import PARTITIONERS, partition_columns, partition_stats
from repro.sparse.synthetic import make_skewed_csr

M, N, ZBAR, P_C = 4000, 16384, 50, 16


def run() -> None:
    for alpha in (0.0, 0.5, 1.0, 1.4):
        a = make_skewed_csr(M, N, ZBAR, alpha, seed=42)
        for kind in PARTITIONERS:
            st = partition_stats(a, partition_columns(a, P_C, kind))
            prof = PartitionerProfile(kind, st.kappa, st.max_n_local)
            pred = predict_hybrid_iter(N, ZBAR, prof, 4, P_C, 4, 32, 10, PERLMUTTER)
            emit(
                f"fig3/alpha={alpha}/{kind}",
                pred.total * 1e6,
                f"kappa={st.kappa:.2f};max_n_local={st.max_n_local};"
                f"sync_skew_us={pred.sync_skew * 1e6:.2f}",
            )
