"""Serving-plane benchmark: sustained predictions/sec and time-to-adapt.

Two numbers the ISSUE tracks per release (persisted to
``BENCH_serve.json``; the serve-plane CI job uploads it as an
artifact, and ROADMAP.md carries the trajectory):

* ``predictions_per_sec`` — sustained throughput of the batched
  prediction service under concurrent in-process clients (request
  micro-batching amortizes store reads: many callers, one matvec batch).
* ``time_to_adapt_rounds`` — after an injected concept flip, how many
  online rounds until served accuracy against the *new* concept beats
  accuracy against the old one (the crossover; measured on twin probe
  streams, trained and served by one process with hot swaps on).

CSV rows (name,us_per_call,derived) go to stdout like every other
bench module.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit

OUT_JSON = Path("BENCH_serve.json")

ROUNDS = 120
DRIFT_AT = 60
PROBE_EVERY = 6


def _spec():
    from repro.api import ExperimentSpec, MeshSpec, StreamSpec
    from repro.core.engine import ParallelSGDSchedule

    return ExperimentSpec(
        dataset="rcv1-sm",
        schedule=ParallelSGDSchedule.hybrid(
            p_r=2, s=2, b=4, eta=0.2, tau=8, rounds=ROUNDS, loss_every=0
        ),
        mesh=MeshSpec(p_r=2, p_c=1, backend="simulated"),
        stream=StreamSpec(source="drift", seed=3, drift_at=DRIFT_AT, swap_every=8),
        name="bench-serve",
    )


def _acc(x: np.ndarray, stream, base: int, probes: int = 4) -> float:
    vals = []
    for k in range(probes):
        b = stream.batch(base + k)
        m = np.einsum("rw,rw->r", x[b.indices], b.values)
        vals.append(np.mean(np.where(m >= 0, 1.0, -1.0) == b.y))
    return float(np.mean(vals))


def bench_prediction_throughput(results: dict) -> None:
    """Sustained predictions/sec: N client threads hammering one
    service for a fixed window (each request 64 rows)."""
    from repro.serve import ModelStore, PredictionService

    store = ModelStore()
    rng = np.random.default_rng(0)
    store.publish(rng.standard_normal(4736).astype(np.float32))
    idx = rng.integers(0, 4736, size=(64, 16)).astype(np.int32)
    val = rng.standard_normal((64, 16)).astype(np.float32)

    window_s = 2.0
    n_clients = 4
    with PredictionService(store, max_batch_rows=512, max_wait_s=0.001) as svc:
        stop = time.monotonic() + window_s

        def client():
            while time.monotonic() < stop:
                svc.predict(idx, val)

        threads = [threading.Thread(target=client) for _ in range(n_clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        stats = svc.stats()

    pps = stats["rows_served"] / elapsed
    results["predictions_per_sec"] = pps
    results["mean_batch_rows"] = stats["mean_batch_rows"]
    results["predict_clients"] = n_clients
    emit("serve/predictions_per_sec", 1e6 / max(pps, 1e-9), f"{pps:.0f}/s")
    emit(
        "serve/mean_coalesced_batch",
        0.0,
        f"{stats['mean_batch_rows']:.1f} rows/batch",
    )


def bench_time_to_adapt(results: dict) -> None:
    """Inject a concept flip mid-stream; report rounds (and seconds)
    until accuracy-vs-new-concept overtakes accuracy-vs-old."""
    import dataclasses

    from repro.api import Session
    from repro.serve import ModelStore, OnlineController, make_stream_source

    spec = _spec()
    src = make_stream_source(spec)
    pre = dataclasses.replace(src, drift_at=0)  # always the old concept
    post = dataclasses.replace(src, drift_at=1)  # always the new one

    sess = Session(spec)
    ctrl = OnlineController(sess, src, ModelStore())
    adapt_round = None
    t_drift = None
    t0 = time.perf_counter()
    while sess.rounds_done < ROUNDS:
        ctrl.run(PROBE_EVERY)
        r = sess.rounds_done
        if r >= DRIFT_AT:
            if t_drift is None:
                t_drift = time.perf_counter()
            x = sess.current_x()
            a_new = _acc(x, post, 90_000 + 10 * r)
            a_old = _acc(x, pre, 90_000 + 10 * r)
            if adapt_round is None and a_new > a_old:
                adapt_round = r
                break
    wall = time.perf_counter() - t0
    rounds_per_sec = sess.rounds_done / max(wall, 1e-9)

    adapted = adapt_round is not None
    results["time_to_adapt_rounds"] = (adapt_round - DRIFT_AT) if adapted else None
    results["adapted_within_budget"] = adapted
    results["train_rounds_per_sec"] = rounds_per_sec
    results["swaps"] = ctrl.metrics().swaps
    emit(
        "serve/time_to_adapt",
        0.0,
        f"{results['time_to_adapt_rounds']} rounds post-drift"
        if adapted
        else f"no crossover within {ROUNDS - DRIFT_AT} rounds",
    )
    emit("serve/train_rounds_per_sec", 1e6 / max(rounds_per_sec, 1e-9),
         f"{rounds_per_sec:.1f} rounds/s")


def run() -> None:
    results: dict = {"bench": "serve", "rounds": ROUNDS, "drift_at": DRIFT_AT}
    bench_prediction_throughput(results)
    bench_time_to_adapt(results)
    OUT_JSON.write_text(json.dumps(results, indent=2))
    print(f"# wrote {OUT_JSON}")


if __name__ == "__main__":
    run()
