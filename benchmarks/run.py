"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table9,...]

Every row is ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import argparse
import sys
import traceback

MODULES = {
    "table4": "benchmarks.bench_mesh_rule",
    "table5+7+fig4": "benchmarks.bench_costmodel",
    "table9": "benchmarks.bench_partitioners",
    "table11": "benchmarks.bench_time_to_loss",
    "objectives": "benchmarks.bench_objectives",
    "comm": "benchmarks.bench_comm",
    "fig3": "benchmarks.bench_skew_sweep",
    "fig5": "benchmarks.bench_mesh_sweep",
    "kernels": "benchmarks.bench_kernels",
    "perf-ablation": "benchmarks.bench_perf_ablation",
    "roofline": "benchmarks.bench_roofline",
    "serve": "benchmarks.bench_serve",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    selected = args.only.split(",") if args.only else list(MODULES)

    import importlib

    failures = []
    for key in selected:
        mod_name = MODULES[key]
        print(f"# ==== {key} ({mod_name}) ====", flush=True)
        try:
            importlib.import_module(mod_name).run()
        except Exception:
            failures.append(key)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
