"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table9,...]

Every row is ``name,us_per_call,derived`` CSV. Per-module wall seconds
land in the ``repro.obs`` metrics registry
(``bench.module_seconds{module=...}`` gauges plus a
``bench.modules_failed_total`` counter) and print as ``[bench]``
summary lines after the CSV. Unknown ``--only`` keys and module
failures both exit nonzero — CI gates on this.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = {
    "table4": "benchmarks.bench_mesh_rule",
    "table5+7+fig4": "benchmarks.bench_costmodel",
    "table9": "benchmarks.bench_partitioners",
    "table11": "benchmarks.bench_time_to_loss",
    "objectives": "benchmarks.bench_objectives",
    "comm": "benchmarks.bench_comm",
    "fig3": "benchmarks.bench_skew_sweep",
    "fig5": "benchmarks.bench_mesh_sweep",
    "kernels": "benchmarks.bench_kernels",
    "perf-ablation": "benchmarks.bench_perf_ablation",
    "roofline": "benchmarks.bench_roofline",
    "serve": "benchmarks.bench_serve",
}


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args(argv)
    selected = args.only.split(",") if args.only else list(MODULES)
    unknown = [k for k in selected if k not in MODULES]
    if unknown:
        ap.error(f"unknown module(s) {unknown}; choose from: {','.join(MODULES)}")

    import importlib

    from repro.obs import metrics as obs_metrics

    reg = obs_metrics.registry()
    failures = []
    for key in selected:
        mod_name = MODULES[key]
        print(f"# ==== {key} ({mod_name}) ====", flush=True)
        t0 = time.perf_counter()
        try:
            importlib.import_module(mod_name).run()
        except Exception:
            failures.append(key)
            reg.counter("bench.modules_failed_total").inc()
            traceback.print_exc()
        reg.gauge("bench.module_seconds", module=key).set(time.perf_counter() - t0)
    for key in selected:
        wall = reg.gauge("bench.module_seconds", module=key).value
        status = "FAIL" if key in failures else "ok"
        print(f"[bench] {key:16s} {wall:8.2f}s  {status}", flush=True)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
