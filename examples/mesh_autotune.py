"""Mesh auto-tuning demo: the paper's §6 selection flow as a tool.

Given (dataset stats, machine, processor count), produce the full
recommendation: mesh split (topology rule), (s, b, τ) (Eq. 4 ranking),
partitioner (refined model ranking), and operating regime.

    PYTHONPATH=src python examples/mesh_autotune.py --n 3231961 --m 2396130 --zbar 116 --p 256
"""

import argparse

from repro.costmodel import (
    MACHINES,
    PERLMUTTER,
    PartitionerProfile,
    classify_regime,
    grid_search_config,
    rank_partitioners,
    topology_rule,
    HybridConfig,
)


def recommend(m: int, n: int, zbar: float, p: int, machine, kappa_rows: float = 10.0):
    p_r, p_c = topology_rule(p, n, machine)
    cfg, cb = grid_search_config(m, n, zbar, p_r, p_c, machine)
    regime = classify_regime(m, n, zbar, cfg, machine)
    # partitioner profiles: rows gets the dataset's skew-driven κ;
    # nnz balances κ but may blow the slab; cyclic bounds both
    profiles = [
        PartitionerProfile("rows", kappa_rows, -(-n // p_c)),
        PartitionerProfile("nnz", 1.1, min(4 * -(-n // p_c), n)),
        PartitionerProfile("cyclic", 1.5, -(-n // p_c)),
    ]
    ranked = rank_partitioners(n, zbar, profiles, p_r, p_c, cfg.s, cfg.b, cfg.tau, machine)
    return {
        "mesh": (p_r, p_c),
        "config": cfg,
        "regime": regime.name,
        "balance": regime.balance,
        "partitioner": ranked[0][0],
        "ranking": [nm for nm, _ in ranked],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2_396_130)
    ap.add_argument("--n", type=int, default=3_231_961)
    ap.add_argument("--zbar", type=float, default=116)
    ap.add_argument("--p", type=int, default=256)
    ap.add_argument("--kappa-rows", type=float, default=33.8)
    args = ap.parse_args()

    for name, machine in MACHINES.items():
        r = recommend(args.m, args.n, args.zbar, args.p, machine, args.kappa_rows)
        cfg: HybridConfig = r["config"]
        print(f"{name}:")
        print(f"  mesh p_r×p_c      = {r['mesh'][0]}×{r['mesh'][1]}")
        print(f"  s, b, τ           = {cfg.s}, {cfg.b}, {cfg.tau}")
        print(f"  regime            = {r['regime']} (balance {r['balance']:.2f})")
        print(f"  partitioner       = {r['partitioner']}  (ranked {'>'.join(r['ranking'])})")


if __name__ == "__main__":
    main()
