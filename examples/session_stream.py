"""Round-streaming lifecycle: step / observe / checkpoint / resume.

The Session opens the solver loop at round granularity — the same
iterates as ``run(spec)``, bitwise, but control returns after every
chunk so a driver (dashboard, early-stopper, async averager) can watch
the loss move, checkpoint, and decide whether to continue:

    PYTHONPATH=src python examples/session_stream.py
"""

import dataclasses
import tempfile
from pathlib import Path

import numpy as np

from repro.api import ExperimentSpec, MeshSpec, Session, StopPolicy
from repro.core import ParallelSGDSchedule


def main() -> None:
    sched = ParallelSGDSchedule.hybrid(4, 4, 8, 0.5, 16, rounds=12, loss_every=2)
    spec = ExperimentSpec(
        dataset="rcv1-sm",
        schedule=sched,
        mesh=MeshSpec(p_r=4),
        name="stream-demo",
    )

    # --- stream rounds, watching the objective move ---
    sess = Session(spec)
    print(f"streaming {sess.total_rounds} rounds of {spec.name}:")
    while not sess.done:
        ev = sess.step_rounds()  # one loss-sampling chunk per call
        loss = f"{ev.loss:.4f}" if ev.loss is not None else "   —  "
        print(
            f"  round {ev.rounds_done:3d}/{sess.total_rounds}  loss {loss}  "
            f"wall {ev.wall_time_s:6.2f}s  comm {ev.comm_words['total_words']:,.0f} words"
        )

    # --- interrupt / resume: identical iterates, guaranteed ---
    with tempfile.TemporaryDirectory() as d:
        ck = Path(d) / "demo"
        half = Session(spec)
        half.step_rounds(sess.total_rounds // 2)
        half.save(ck)  # keyed by the spec's content hash
        resumed = Session.restore(ck).run()
        same = np.array_equal(resumed.x, sess.current_x())
        print(f"\nsave@{sess.total_rounds // 2} → restore → finish: "
              f"weights identical to the uninterrupted run: {same}")

    # --- the paper's §7.5 protocol as a first-class stop ---
    target = float(sess.losses[len(sess.losses) // 2])  # mid-trace: hit early
    early = Session(dataclasses.replace(spec, stop=StopPolicy(target_loss=target)))
    rep = early.run()
    print(
        f"target_loss={target:.4f} stop: finished at round "
        f"{rep.rounds_completed}/{sched.rounds} ({rep.stop_reason}), "
        f"wall {rep.wall_time_s:.2f}s = compile {rep.compile_time_s:.2f}s "
        f"+ solve {rep.solve_time_s:.2f}s"
    )


if __name__ == "__main__":
    main()
