"""End-to-end driver (the paper's kind: convex training to target loss).

Trains logistic regression on a synthetic url-like (sparse, high-dim,
column-skewed) dataset with all four solvers, measuring time-to-target
and reporting the cost model's cluster-level prediction alongside.

    PYTHONPATH=src python examples/train_logreg_hybrid.py [--dataset url-sm]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    full_loss,
    global_problem,
    make_problem,
    run_fedavg,
    run_hybrid_sgd,
    run_sgd,
    run_sstep_sgd,
    stack_row_teams,
)
from repro.costmodel import PERLMUTTER, grid_search_config, topology_rule
from repro.sparse.synthetic import make_dataset

ETA = 1.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="url-sm")
    ap.add_argument("--target", type=float, default=0.675)
    ap.add_argument("--max-rounds", type=int, default=60)
    args = ap.parse_args()

    ds = make_dataset(args.dataset, seed=0)
    a, y = ds.A, ds.y
    print(f"dataset {ds.name}: m={a.m} n={a.n} z̄={a.zbar:.0f} target={args.target}")

    # model-driven configuration (the paper's §6 selection flow)
    p = 256
    p_r, p_c = topology_rule(p, a.n, PERLMUTTER)
    cfg, cb = grid_search_config(a.m, a.n, a.zbar, p_r, p_c, PERLMUTTER)
    print(f"topology rule: mesh {p_r}×{p_c}; model-ranked config s={cfg.s} b={cfg.b} "
          f"τ={cfg.tau} (dominant {cb.dominant})")
    s, b, tau = 4, 16, 16  # scaled for the -sm dataset
    p_r_run = min(p_r, 4) if p_r > 1 else 2

    x0 = jnp.zeros(a.n)
    results = {}
    R = args.max_rounds

    def to_target(name, run_traced):
        """One timed run with a per-round loss trace (single compile)."""
        t0 = time.perf_counter()
        losses = np.asarray(run_traced(R))
        total = time.perf_counter() - t0
        hit = np.nonzero(losses <= args.target)[0]
        if len(hit):
            r = int(hit[0]) + 1
            results[name] = (total * r / R, r, float(losses[hit[0]]))
            ok = "hit "
        else:
            results[name] = (total, R, float(losses[-1]))
            ok = "MISS"
        t, r, l = results[name]
        print(f"  {name:12s}: {ok} target in {t:6.2f}s ({r} rounds, loss {l:.4f})")

    # CPU wall-clock comparison → dense-oracle bundle backend: url's ELL
    # width ≫ s·b, so the scatter-free expansion is MXU work that
    # interpret mode serializes off-TPU (kernel timings: bench_kernels).
    prob = make_problem(a, y, row_multiple=s * b)
    to_target("sgd", lambda r: run_sgd(prob, x0, b, ETA, r * tau, loss_every=tau)[1])
    to_target("sstep-1d", lambda r: run_sstep_sgd(prob, x0, s, b, ETA, r * tau,
                                                  loss_every=tau, gram="dense")[1])

    tp_f = stack_row_teams(a, y, 8, row_multiple=b)
    to_target("fedavg(p=8)", lambda r: run_fedavg(tp_f, x0, b, ETA, tau, rounds=r, loss_every=1)[1])

    tp_h = stack_row_teams(a, y, p_r_run, row_multiple=s * b)
    to_target(f"hybrid({p_r_run}x.)", lambda r: run_hybrid_sgd(tp_h, x0, s, b, ETA, tau, rounds=r,
                                                               loss_every=1, gram="dense")[1])

    t_fed = results["fedavg(p=8)"][0]
    t_hyb = results[f"hybrid({p_r_run}x.)"][0]
    print(f"\nCPU wall hybrid-vs-FedAvg: {t_fed / t_hyb:.2f}x (compute-only; the "
          "cluster-level win is communication-driven)")
    print("Cost-model cluster prediction: 183x per-sample on full-size url at "
          "p=256 (see `python -m benchmarks.run --only table5+7+fig4`)")


if __name__ == "__main__":
    main()
