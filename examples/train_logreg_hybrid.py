"""End-to-end driver (the paper's kind: convex training to target loss).

Trains logistic regression on a synthetic url-like (sparse, high-dim,
column-skewed) dataset with all four solvers — each one an
``ExperimentSpec`` through the repro.api front door — measuring
time-to-target and reporting the cost model's cluster-level prediction
alongside.

    PYTHONPATH=src python examples/train_logreg_hybrid.py [--dataset url-sm]
"""

import argparse

from repro.api import ExperimentSpec, MeshSpec, run
from repro.core import ParallelSGDSchedule
from repro.costmodel import PERLMUTTER, grid_search_config, topology_rule
from repro.sparse.synthetic import make_dataset

ETA = 1.0


def to_target(results, name, spec, target):
    """Run the spec once (per-round loss trace, single compile); the
    crossing arithmetic lives on RunReport.time_to_target."""
    t, r, loss, hit = run(spec).time_to_target(target)
    results[name] = (t, r, loss)
    ok = "hit " if hit else "MISS"
    print(f"  {name:12s}: {ok} target in {t:6.2f}s ({r} rounds, loss {loss:.4f})")


def main() -> None:
    from repro.core.objective import OBJECTIVES

    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="url-sm")
    ap.add_argument("--target", type=float, default=0.675)
    ap.add_argument("--max-rounds", type=int, default=60)
    ap.add_argument("--objective", default="logistic", choices=sorted(OBJECTIVES),
                    help="convex loss (pick --target to match its scale)")
    ap.add_argument("--l2", type=float, default=0.0, help="ridge coefficient λ")
    args = ap.parse_args()

    ds = make_dataset(args.dataset, seed=0)
    a = ds.A
    print(f"dataset {ds.name}: m={a.m} n={a.n} z̄={a.zbar:.0f} target={args.target}")

    # model-driven configuration (the paper's §6 selection flow)
    p = 256
    p_r, p_c = topology_rule(p, a.n, PERLMUTTER)
    cfg, cb = grid_search_config(a.m, a.n, a.zbar, p_r, p_c, PERLMUTTER)
    print(f"topology rule: mesh {p_r}×{p_c}; model-ranked config s={cfg.s} b={cfg.b} "
          f"τ={cfg.tau} (dominant {cb.dominant})")
    s, b, tau = 4, 16, 16  # scaled for the -sm dataset
    p_r_run = min(p_r, 4) if p_r > 1 else 2

    results = {}
    R = args.max_rounds

    # CPU wall-clock comparison → dense-oracle bundle backend: url's ELL
    # width ≫ s·b, so the scatter-free expansion is MXU work that
    # interpret mode serializes off-TPU (kernel timings: bench_kernels).
    def spec(schedule, p_r_=1, name=""):
        return ExperimentSpec(dataset=args.dataset, schedule=schedule,
                              mesh=MeshSpec(p_r=p_r_), row_multiple=s * b,
                              objective=args.objective, l2=args.l2, name=name)

    to_target(results, "sgd",
              spec(ParallelSGDSchedule.mb_sgd(b, ETA, R * tau, loss_every=tau)),
              args.target)
    to_target(results, "sstep-1d",
              spec(ParallelSGDSchedule.sstep(s, b, ETA, R * tau, loss_every=tau,
                                             gram="dense")),
              args.target)
    to_target(results, "fedavg(p=8)",
              spec(ParallelSGDSchedule.fedavg(8, b, ETA, tau, rounds=R, loss_every=1),
                   p_r_=8),
              args.target)
    to_target(results, f"hybrid({p_r_run}x.)",
              spec(ParallelSGDSchedule.hybrid(p_r_run, s, b, ETA, tau, rounds=R,
                                              loss_every=1, gram="dense"),
                   p_r_=p_r_run),
              args.target)

    t_fed = results["fedavg(p=8)"][0]
    t_hyb = results[f"hybrid({p_r_run}x.)"][0]
    print(f"\nCPU wall hybrid-vs-FedAvg: {t_fed / t_hyb:.2f}x (compute-only; the "
          "cluster-level win is communication-driven)")
    print("Cost-model cluster prediction: 183x per-sample on full-size url at "
          "p=256 (see `python -m benchmarks.run --only table5+7+fig4`)")


if __name__ == "__main__":
    main()
