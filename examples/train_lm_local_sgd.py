"""Hybrid-2D LM training demo: the paper's technique on a transformer.

Spawns 8 placeholder devices, builds a (2, 2, 2) = (pod, data, model)
mesh, and trains a small gemma-family model with pod-local steps and a
τ-deferred parameter sync (the HybridSGD schedule at pod scale —
DESIGN.md §2). Compares against fully-synchronous training on the same
data to show the τ trade-off.

    PYTHONPATH=src python examples/train_lm_local_sgd.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_config, reduced
from repro.models.init import init_params
from repro.models.transformer import lm_loss
from repro.optim.hybrid2d import make_hybrid_train_step, make_sync_step, stack_for_pods
from repro.optim.sgd import adamw
from repro.train.data import MarkovTextStream

STEPS, TAU, BATCH, SEQ = 60, 5, 8, 64


def run(mesh, tau: int, label: str) -> list[float]:
    cfg = reduced(get_config("gemma-2b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = adamw(3e-4)
    opt_state = opt.init(params)
    n_pods = dict(zip(mesh.axis_names, mesh.axis_sizes)).get("pod", 1)

    def loss_fn(p, tokens, targets):
        return lm_loss(cfg, p, tokens, targets)

    step = make_hybrid_train_step(mesh, loss_fn, opt)
    sync = make_sync_step(mesh)
    if n_pods > 1:
        params = stack_for_pods(params, n_pods)
        opt_state = stack_for_pods(opt_state, n_pods)
    state = (params, opt_state)

    stream = MarkovTextStream(cfg.vocab_size, seed=1)
    it = stream.batches(BATCH, SEQ)
    losses = []
    for s in range(STEPS):
        tokens, targets = next(it)
        state, loss = step(state, (jnp.asarray(tokens), jnp.asarray(targets)))
        if n_pods > 1 and (s + 1) % tau == 0:
            p, st_ = state
            state = (sync(p), st_)
        if (s + 1) % 10 == 0:
            losses.append(float(loss))
    print(f"  {label:24s} losses: " + " ".join(f"{l:.3f}" for l in losses))
    return losses


def main() -> None:
    print(f"devices: {len(jax.devices())}")
    mesh_hybrid = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    mesh_sync = compat.make_mesh((4, 2), ("data", "model"))
    print(f"hybrid-2D (2 pods, τ={TAU}) vs fully-synchronous, same data:")
    with compat.use_mesh(mesh_hybrid):
        l_h = run(mesh_hybrid, TAU, f"hybrid 2x2x2 tau={TAU}")
    with compat.use_mesh(mesh_sync):
        l_s = run(mesh_sync, 1, "synchronous 4x2")
    gap = l_h[-1] - l_s[-1]
    print(f"final-loss gap (hybrid − sync) = {gap:+.4f} — the τ-drift cost the "
          f"paper's convergence analysis bounds (Stich), bought with 1/{TAU} of "
          f"the cross-pod sync traffic.")


if __name__ == "__main__":
    main()
