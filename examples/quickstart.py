"""Quickstart: the unified (p_r, p_c, s, τ) engine on a synthetic
column-skewed dataset.

Runs the paper's four algorithms as corners of one schedule family,
shows the corner identities, and uses the cost model + topology rule
to pick a mesh for a production machine.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    ParallelSGDSchedule,
    full_loss,
    global_problem,
    make_problem,
    run_parallel_sgd,
    single_team,
    stack_row_teams,
)
from repro.costmodel import PERLMUTTER, TPU_V5E, grid_search_config, topology_rule
from repro.sparse.partition import PARTITIONERS, partition_columns, partition_stats
from repro.sparse.synthetic import make_dataset

ETA, B, S, TAU = 0.05, 8, 4, 16


def main() -> None:
    ds = make_dataset("rcv1-sm", seed=0)
    a, y = ds.A, ds.y
    print(f"dataset {ds.name}: m={a.m} n={a.n} z̄={a.zbar:.0f}")

    # --- partitioner stats (the two-objective problem, paper §6.5) ---
    for kind in PARTITIONERS:
        st = partition_stats(a, partition_columns(a, 8, kind))
        print(f"  partitioner {kind:7s}: κ={st.kappa:5.2f}  max n_local={st.max_n_local}")

    # --- one engine, four corners of the (p_r, s, τ) family ---
    prob = make_problem(a, y, row_multiple=S * B * 4)
    one = single_team(prob)
    x0 = jnp.zeros(a.n)
    f0 = float(full_loss(prob, x0))

    x_sgd, _ = run_parallel_sgd(one, x0, ParallelSGDSchedule.mb_sgd(B, ETA, 256))
    x_ss, _ = run_parallel_sgd(one, x0, ParallelSGDSchedule.sstep(S, B, ETA, 256))
    tp = stack_row_teams(a, y, 4, row_multiple=S * B)
    x_fa, _ = run_parallel_sgd(tp, x0, ParallelSGDSchedule.fedavg(4, B, ETA, TAU, rounds=4))
    x_hy, _ = run_parallel_sgd(tp, x0, ParallelSGDSchedule.hybrid(4, S, B, ETA, TAU, rounds=4))
    gp = global_problem(tp)
    print(f"\n  loss(x0)        = {f0:.4f}")
    print(f"  MB-SGD          → {float(full_loss(prob, x_sgd)):.4f}   (p_r=1, s=1, τ=1)")
    print(f"  s-step SGD      → {float(full_loss(prob, x_ss)):.4f}   "
          f"(p_r=1, τ=s; ‖x_sgd−x_ss‖∞ = {float(jnp.abs(x_sgd - x_ss).max()):.2e} "
          f"— same algorithm!)")
    print(f"  FedAvg (p=4)    → {float(full_loss(gp, x_fa)):.4f}   (s=1 — no Gram work)")
    print(f"  HybridSGD (4×·) → {float(full_loss(gp, x_hy)):.4f}   (general 2D point)")

    # --- mesh + config selection (paper Eq. 7 + Eq. 4) ---
    for machine in (PERLMUTTER, TPU_V5E):
        p = 256
        p_r, p_c = topology_rule(p, a.n, machine)
        cfg, cb = grid_search_config(a.m, a.n, a.zbar, p_r, p_c, machine)
        print(
            f"\n  {machine.name}: topology rule → mesh {p_r}×{p_c}; "
            f"model picks s={cfg.s} b={cfg.b} τ={cfg.tau} "
            f"(dominant: {cb.dominant})"
        )


if __name__ == "__main__":
    main()
