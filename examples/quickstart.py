"""Quickstart: the declarative front door (spec → plan → run → report)
on a synthetic column-skewed dataset.

One ``ExperimentSpec`` describes a run of the (p_r, p_c, s, τ) family;
``repro.api.plan`` prices it with the paper's cost model (Eq. 4) and
``repro.api.run`` executes it on the declared backend. The paper's four
algorithms are just four schedules — the corner identities fall out.
The convex loss is a spec field too: the same four corners run
unchanged under any registered objective.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --objective squared_hinge --l2 1e-3
"""

import argparse

import numpy as np

from repro.api import ExperimentSpec, MeshSpec, plan, run
from repro.core import ParallelSGDSchedule
from repro.core.objective import OBJECTIVES
from repro.costmodel import PERLMUTTER, TPU_V5E, grid_search_config, topology_rule
from repro.sparse.partition import PARTITIONERS, partition_columns, partition_stats
from repro.sparse.synthetic import make_dataset

ETA, B, S, TAU = 0.05, 8, 4, 16
DATASET = "rcv1-sm"
RM = S * B  # one row padding for every corner → identical sample sequences
OBJECTIVE, L2 = "logistic", 0.0  # overridden by --objective / --l2


def corner(schedule, p_r=1, name=""):
    return ExperimentSpec(
        dataset=DATASET, schedule=schedule, mesh=MeshSpec(p_r=p_r),
        row_multiple=RM, objective=OBJECTIVE, l2=L2, name=name,
    )


def main() -> None:
    global OBJECTIVE, L2
    ap = argparse.ArgumentParser()
    ap.add_argument("--objective", default="logistic", choices=sorted(OBJECTIVES),
                    help="convex loss every corner runs (repro.core.objective)")
    ap.add_argument("--l2", type=float, default=0.0, help="ridge coefficient λ")
    args = ap.parse_args()
    OBJECTIVE, L2 = args.objective, args.l2
    if OBJECTIVE != "logistic" or L2:
        print(f"objective {OBJECTIVE} (l2={L2:g})")

    ds = make_dataset(DATASET, seed=0)
    a = ds.A
    print(f"dataset {ds.name}: m={a.m} n={a.n} z̄={a.zbar:.0f}")

    # --- partitioner stats (the two-objective problem, paper §6.5) ---
    for kind in PARTITIONERS:
        st = partition_stats(a, partition_columns(a, 8, kind))
        print(f"  partitioner {kind:7s}: κ={st.kappa:5.2f}  max n_local={st.max_n_local}")

    # --- one front door, four corners of the (p_r, s, τ) family ---
    specs = {
        "MB-SGD": corner(ParallelSGDSchedule.mb_sgd(B, ETA, 256), name="mb-sgd"),
        "s-step SGD": corner(ParallelSGDSchedule.sstep(S, B, ETA, 256), name="sstep"),
        "FedAvg (p=4)": corner(
            ParallelSGDSchedule.fedavg(4, B, ETA, TAU, rounds=4), p_r=4, name="fedavg"),
        "HybridSGD (4×·)": corner(
            ParallelSGDSchedule.hybrid(4, S, B, ETA, TAU, rounds=4), p_r=4, name="hybrid"),
    }
    reports = {label: run(spec) for label, spec in specs.items()}

    gap = float(np.abs(reports["MB-SGD"].x - reports["s-step SGD"].x).max())
    print()
    notes = {
        "MB-SGD": "(p_r=1, s=1, τ=1)",
        "s-step SGD": f"(p_r=1, τ=s; ‖x_sgd−x_ss‖∞ = {gap:.2e} — same algorithm!)",
        "FedAvg (p=4)": "(s=1 — no Gram work)",
        "HybridSGD (4×·)": "(general 2D point)",
    }
    for label, rep in reports.items():
        print(f"  {label:15s} → {rep.final_loss:.4f}   {notes[label]}")

    # --- spec → plan: the cost model prices the run before it exists ---
    pl = plan(specs["HybridSGD (4×·)"])
    print(f"\n  plan({pl.spec.name}): predicted {pl.cost.total:.3g} s/epoch "
          f"(dominant: {pl.regime}); the same spec runs under shard_map by "
          f'setting mesh=MeshSpec(4, p_c, backend="shard_map")')

    # --- mesh + config selection (paper Eq. 7 + Eq. 4) ---
    for machine in (PERLMUTTER, TPU_V5E):
        p = 256
        p_r, p_c = topology_rule(p, a.n, machine)
        cfg, cb = grid_search_config(a.m, a.n, a.zbar, p_r, p_c, machine)
        print(
            f"\n  {machine.name}: topology rule → mesh {p_r}×{p_c}; "
            f"model picks s={cfg.s} b={cfg.b} τ={cfg.tau} "
            f"(dominant: {cb.dominant})"
        )


if __name__ == "__main__":
    main()
