"""Observability plane: the span-tracing seam, the metrics registry,
trace export, and the provably-free guarantee.

The load-bearing claims:

* uninstalled, the seam is inert — one shared no-op context, no
  recorder, no allocation on the round path;
* traced runs are bitwise-identical to untraced runs on BOTH backends
  (spans are host-side wall intervals; compiled numerics untouched);
* ``comm_timing`` runs split the round wall into the §6.5 phases
  (``CommLedger.phase_seconds``) and derive ``exposed_comm_s``;
* both export formats (Chrome trace-event JSON, JSONL) round-trip and
  carry valid Perfetto-loadable fields.
"""

import importlib.util
import json
import os
import subprocess
import sys
import textwrap
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.api import ExperimentSpec, MeshSpec, Session, StreamSpec, run, sweep
from repro.core.comm import CommLedger
from repro.core.engine import ParallelSGDSchedule
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import make_stream_source
from repro.serve.controller import StageMetrics

REPO = Path(__file__).resolve().parent.parent


def spec(rounds=4, loss_every=2, p_c=2, tau=8, **kw):
    return ExperimentSpec(
        dataset="rcv1-sm",
        schedule=ParallelSGDSchedule.hybrid(
            p_r=2, s=2, b=4, eta=0.2, tau=tau, rounds=rounds, loss_every=loss_every
        ),
        mesh=MeshSpec(p_r=2, p_c=p_c, backend="simulated"),
        **kw,
    )


def run_in_subprocess(body: str, devices: int = 8) -> str:
    code = textwrap.dedent(body)
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": str(REPO / "src"),
        "PATH": "/usr/bin:/bin:/usr/local/bin",
        "JAX_PLATFORMS": "cpu",
    }
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


# ---------------- the tracing seam ----------------


class TestSeam:
    def test_uninstalled_is_one_shared_noop(self):
        assert obs_trace.active() is None
        c1 = obs_trace.span("round")
        c2 = obs_trace.span("ingest", name="whatever", rows=3)
        assert c1 is c2, "uninstalled span() must reuse one no-op context"
        with c1:
            pass
        assert obs_trace.active() is None

    def test_install_records_nesting_and_restores(self):
        with obs_trace.install() as rec:
            assert obs_trace.active() is rec
            with obs_trace.span("round", name="r0", idx=0):
                with obs_trace.span("ckpt_save", name="inner"):
                    pass
        assert obs_trace.active() is None
        by = {s.category: s for s in rec.spans}
        assert set(by) == {"round", "ckpt_save"}
        assert by["ckpt_save"].depth == 1 and by["round"].depth == 0
        assert rec.spans[0].category == "ckpt_save"  # inner exits first
        assert by["round"].dur >= by["ckpt_save"].dur >= 0.0
        assert by["round"].args == {"idx": 0}

    def test_nested_installs_restore_outer(self):
        with obs_trace.install() as outer:
            with obs_trace.install() as inner:
                with obs_trace.span("round"):
                    pass
                assert obs_trace.active() is inner
            assert obs_trace.active() is outer
        assert len(inner) == 1 and len(outer) == 0

    def test_unknown_category_raises(self):
        rec = obs_trace.TraceRecorder()
        with pytest.raises(ValueError, match="category"):
            with rec.span("bogus"):
                pass
        with pytest.raises(ValueError, match="category"):
            rec.add_span("also_bogus", "x", dur=0.1)

    def test_add_span_post_hoc(self):
        rec = obs_trace.TraceRecorder()
        s = rec.add_span("allreduce_gv", "probe:allreduce_gv", dur=0.25, calls=3)
        assert s.dur == 0.25 and s.args == {"calls": 3}
        assert len(rec) == 1
        assert rec.total_seconds("allreduce_gv") == 0.25
        assert rec.total_seconds("param_avg") == 0.0

    def test_worker_threads_see_installed_recorder(self):
        # ContextVars don't propagate into threading.Thread — the serve
        # plane's producer/batcher threads rely on the module fallback.
        seen = []

        def worker():
            with obs_trace.span("ingest", name="from-thread"):
                seen.append(obs_trace.active())

        with obs_trace.install() as rec:
            t = threading.Thread(target=worker)
            t.start()
            t.join()
        assert seen == [rec]
        assert rec.spans[0].category == "ingest"
        assert rec.spans[0].tid != threading.get_ident()


# ---------------- the metrics registry ----------------


class TestMetrics:
    def test_counter_gauge_histogram(self):
        reg = obs_metrics.MetricsRegistry()
        c = reg.counter("points_total")
        c.inc()
        c.inc(2)
        assert c.value == 3
        with pytest.raises(ValueError, match="decrease"):
            c.inc(-1)
        g = reg.gauge("depth")
        g.set(7)
        g.set(3)
        assert g.value == 3.0
        h = reg.histogram("rows")
        for v in (4, 1, 7):
            h.observe(v)
        assert (h.count, h.sum, h.min, h.max, h.mean) == (3, 12.0, 1.0, 7.0, 4.0)

    def test_labels_key_identity(self):
        reg = obs_metrics.MetricsRegistry()
        a = reg.gauge("wall", module="serve")
        b = reg.gauge("wall", module="comm")
        assert a is not b
        assert reg.gauge("wall", module="serve") is a
        snap = reg.snapshot()
        assert set(snap) == {"wall{module=comm}", "wall{module=serve}"}

    def test_kind_conflict_raises(self):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x")

    def test_snapshot_delta_reset(self):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("c").inc(5)
        reg.gauge("g").set(1)
        reg.histogram("h").observe(2.0)
        before = reg.snapshot()
        assert reg.delta(before) == {}
        reg.counter("c").inc(3)
        reg.gauge("g").set(9)
        reg.histogram("h").observe(4.0)
        d = reg.delta(before)
        assert d["c"] == {"kind": "counter", "value": 3}
        assert d["g"]["value"] == 9.0
        assert d["h"]["count"] == 1 and d["h"]["sum"] == 4.0
        reg.reset()
        assert reg.snapshot() == {}

    def test_process_default_registry_is_stable(self):
        assert obs_metrics.registry() is obs_metrics.registry()


# ---------------- export ----------------


def make_recorder() -> obs_trace.TraceRecorder:
    rec = obs_trace.TraceRecorder()
    with rec.span("round", name="rounds[0+2]", start_round=0):
        with rec.span("ckpt_save", name="swap-2"):
            pass
    rec.add_span("allreduce_gv", "probe:allreduce_gv", dur=0.5, calls_per_round=2)
    return rec


class TestExport:
    def test_chrome_trace_fields(self):
        rec = make_recorder()
        blob = obs_export.chrome_trace_dict(
            rec, metrics={"m": {"kind": "counter", "value": 1}}
        )
        assert blob["schemaVersion"] == obs_export.TRACE_SCHEMA_VERSION
        json.dumps(blob)  # fully JSON-serializable
        xs = [e for e in blob["traceEvents"] if e["ph"] == "X"]
        ms = [e for e in blob["traceEvents"] if e["ph"] == "M"]
        assert len(xs) == 3
        assert {e["name"] for e in ms} == {"process_name", "thread_name"}
        pid = ms[0]["pid"]
        for e in xs:
            assert e["cat"] in obs_trace.SPAN_CATEGORIES
            assert e["pid"] == pid and isinstance(e["tid"], int)
            assert e["dur"] >= 0.0 and e["ts"] >= 0.0 or e["cat"] == "allreduce_gv"
        probe = next(e for e in xs if e["cat"] == "allreduce_gv")
        assert probe["dur"] == pytest.approx(0.5e6)  # microseconds
        assert probe["args"]["calls_per_round"] == 2
        assert blob["otherData"]["metrics"]["m"]["value"] == 1
        assert blob["otherData"]["categories"] == list(obs_trace.SPAN_CATEGORIES)

    def test_both_formats_round_trip(self, tmp_path):
        rec = make_recorder()
        cj = obs_export.write_chrome_trace(rec, tmp_path / "t.json")
        jl = obs_export.write_jsonl(rec, tmp_path / "t.jsonl")
        a, b = obs_export.load_trace(cj), obs_export.load_trace(jl)
        assert (
            a["schemaVersion"] == b["schemaVersion"] == obs_export.TRACE_SCHEMA_VERSION
        )
        assert len(a["spans"]) == len(b["spans"]) == len(rec.spans)
        for sa, sb, s in zip(a["spans"], b["spans"], rec.spans):
            assert sa["cat"] == sb["cat"] == s.category
            assert sa["name"] == sb["name"] == s.name
            assert sa["dur"] == pytest.approx(s.dur, abs=1e-9)
            assert sb["dur"] == pytest.approx(s.dur, abs=1e-12)

    def test_category_table_and_summary_line(self):
        rec = make_recorder()
        rows = obs_export.category_table(rec.spans)
        assert sum(r["share"] for r in rows) == pytest.approx(1.0)
        assert rows[0]["category"] == "allreduce_gv"  # 0.5 s dominates
        assert rows[0]["count"] == 1
        line = obs_export.summary_line(rec)
        assert line.startswith("[trace] 3 spans over ")
        assert "allreduce_gv" in line and "%" in line

    def test_summarize_text(self, tmp_path):
        rec = make_recorder()
        path = obs_export.write_chrome_trace(rec, tmp_path / "t.json")
        text = obs_export.summarize_text(path)
        assert "schema v1" in text and "3 spans" in text
        assert "allreduce_gv" in text and "round" in text


# ---------------- session integration (simulated backend) ----------------


class TestSessionTracing:
    def test_traced_equals_untraced_bitwise(self):
        s = spec(rounds=4)
        a = Session(s)
        while not a.done:
            a.step_rounds()
        with obs_trace.install() as rec:
            b = Session(s)
            while not b.done:
                b.step_rounds()
        assert np.array_equal(a.current_x(), b.current_x())
        assert np.array_equal(np.asarray(a.losses), np.asarray(b.losses))
        cats = rec.by_category()
        assert "compile" in cats and "round" in cats
        assert cats["compile"][0].args["start_round"] == 0

    def test_comm_timing_populates_phases_and_exposed(self):
        sess = Session(spec(rounds=4, comm_timing=True))
        while not sess.done:
            sess.step_rounds()
        led = sess.ledger
        assert set(led.phase_seconds) == {"bundle_compute", "allreduce_gv", "param_avg"}
        assert all(v >= 0.0 for v in led.phase_seconds.values())
        per_round = sum(
            v for k, v in led.phase_seconds.items() if k != "bundle_compute"
        )
        assert led.exposed_comm_s == pytest.approx(per_round * led.rounds)
        d = led.to_dict()
        assert d["exposed_comm_s"] == pytest.approx(led.exposed_comm_s)
        back = CommLedger.from_dict(d)
        assert back.phase_seconds == pytest.approx(led.phase_seconds)
        assert back.exposed_comm_s == pytest.approx(led.exposed_comm_s)

    def test_untimed_run_has_no_phase_seconds(self):
        sess = Session(spec(rounds=2))
        while not sess.done:
            sess.step_rounds()
        assert sess.ledger.phase_seconds == {}
        assert sess.ledger.exposed_comm_s is None
        assert "phase_seconds" not in sess.ledger.to_dict()

    def test_probe_spans_recorded_on_traced_timed_run(self):
        with obs_trace.install() as rec:
            sess = Session(spec(rounds=4, comm_timing=True))
            while not sess.done:
                sess.step_rounds()
        cats = rec.by_category()
        for c in ("bundle_compute", "allreduce_gv", "param_avg"):
            assert c in cats, sorted(cats)
            assert cats[c][0].name == f"probe:{c}"
            assert cats[c][0].args["calls_per_round"] >= 1

    def test_report_summary_mentions_exposed(self):
        rep = run(spec(rounds=2, comm_timing=True))
        assert "exposed" in rep.summary()
        assert "exposed" not in run(spec(rounds=2)).summary()

    def test_checkpoint_spans(self, tmp_path):
        sess = Session(spec(rounds=4))
        sess.step_rounds(2)
        with obs_trace.install() as rec:
            sess.save(tmp_path / "ck")
            Session.restore(tmp_path / "ck")
        cats = rec.by_category()
        assert "ckpt_save" in cats and "ckpt_verify" in cats
        assert cats["ckpt_save"][0].args["rounds_done"] == 2

    def test_stream_ingest_spans(self):
        sp = spec(rounds=3, loss_every=0, p_c=1,
                  stream=StreamSpec(source="drift", seed=3))
        with obs_trace.install() as rec:
            sess = Session(sp)
            src = make_stream_source(sp)
            while not sess.done:
                sess.step_stream(src)
        assert len(rec.by_category().get("ingest", [])) == 3

    def test_sweep_counters(self):
        reg = obs_metrics.registry()
        before = reg.snapshot()
        sweep([spec(rounds=2, name="obs-a"), spec(rounds=2, name="obs-b")])
        d = reg.delta(before)
        assert d["sweep.points_total"]["value"] == 2


# ---------------- StageMetrics on the registry ----------------


class TestStageMetrics:
    FIELDS = {
        "rounds_done", "rounds_per_sec", "last_loss", "ingest_lag",
        "queue_depth", "predictions_per_sec", "predictions_served",
        "staleness_rounds", "model_version", "swaps", "failed_swaps",
    }

    def make(self, **kw):
        base = dict(
            rounds_done=4, rounds_per_sec=2.0, last_loss=0.5, ingest_lag=1,
            queue_depth=2, predictions_per_sec=None, predictions_served=None,
            staleness_rounds=0, model_version=3, swaps=2, failed_swaps=0,
        )
        base.update(kw)
        return StageMetrics(**base)

    def test_to_dict_keys_unchanged(self):
        # bench_serve and the serve CLI read these keys — the registry
        # re-base must not move them.
        assert set(self.make().to_dict()) == self.FIELDS

    def test_publish_mirrors_fields_into_gauges(self):
        reg = obs_metrics.MetricsRegistry()
        self.make().publish(reg)
        snap = reg.snapshot()
        assert snap["serve.stage.rounds_done"]["value"] == 4
        assert snap["serve.stage.model_version"]["value"] == 3
        # None fields are skipped, not published as 0
        assert "serve.stage.predictions_per_sec" not in snap
        self.make(predictions_per_sec=9.0).publish(reg)
        assert reg.snapshot()["serve.stage.predictions_per_sec"]["value"] == 9.0


# ---------------- shard_map backend (real 8-device mesh) ----------------


def test_shard_map_traced_bitwise_probes_and_export():
    """The whole plane on the real mesh backend, in one subprocess: a
    traced+timed run is bitwise-identical to an untraced one, the phase
    probes populate the ledger, and the trace exports round-trip."""
    out = run_in_subprocess(
        """
        import tempfile
        import numpy as np
        from pathlib import Path
        from repro.api import ExperimentSpec, MeshSpec, Session
        from repro.core.engine import ParallelSGDSchedule
        from repro.obs import export as obs_export, trace as obs_trace

        def make():
            return ExperimentSpec(
                dataset="rcv1-sm",
                schedule=ParallelSGDSchedule.hybrid(
                    p_r=2, s=2, b=4, eta=0.2, tau=4, rounds=4, loss_every=2),
                mesh=MeshSpec(p_r=2, p_c=4, backend="shard_map"),
                comm_timing=True,
            )

        a = Session(make())
        while not a.done:
            a.step_rounds()
        with obs_trace.install() as rec:
            b = Session(make())
            while not b.done:
                b.step_rounds()
        assert np.array_equal(a.current_x(), b.current_x()), "tracing changed numerics"
        assert np.array_equal(np.asarray(a.losses), np.asarray(b.losses))
        cats = set(rec.by_category())
        want = {"compile", "round", "bundle_compute", "allreduce_gv", "param_avg"}
        assert want <= cats, cats
        assert b.ledger.exposed_comm_s is not None and b.ledger.exposed_comm_s >= 0.0

        with tempfile.TemporaryDirectory() as td:
            p = obs_export.write_chrome_trace(rec, Path(td) / "t.json")
            jl = obs_export.write_jsonl(rec, Path(td) / "t.jsonl")
            for blob in (obs_export.load_trace(p), obs_export.load_trace(jl)):
                assert blob["schemaVersion"] == 1
                assert len(blob["spans"]) == len(rec.spans)
        print("OBS_MESH_OK", len(rec.spans))
        """
    )
    assert "OBS_MESH_OK" in out


# ---------------- the benchmark regression gate ----------------


def _load_check_regression():
    path = REPO / "benchmarks" / "check_regression.py"
    mod_spec = importlib.util.spec_from_file_location("check_regression", path)
    mod = importlib.util.module_from_spec(mod_spec)
    mod_spec.loader.exec_module(mod)
    return mod


class TestRegressionGate:
    def test_compare_rules(self):
        cr = _load_check_regression()
        base = {"pps": 100.0, "name": "serve", "maybe": None, "ok": True,
                "nest": {"v": 2.0}}
        assert cr.compare(dict(base, pps=500.0), base, 10.0) == []
        assert cr.compare(dict(base, pps=11.0), base, 10.0) == []
        assert len(cr.compare(dict(base, pps=1.0), base, 10.0)) == 1
        missing = {k: v for k, v in base.items() if k != "nest"}
        assert any("missing" in p for p in cr.compare(missing, base, 10.0))
        # null/bool baseline leaves are never gated; strings must match
        assert cr.compare(dict(base, maybe=123, ok=False), base, 10.0) == []
        assert any("name" in p for p in cr.compare(dict(base, name="x"), base, 10.0))
        assert any("vanished" in p for p in cr.compare(dict(base, pps=0.0), base, 10.0))
        assert any("sign" in p for p in cr.compare(dict(base, pps=-100.0), base, 10.0))
        deep = cr.compare({**base, "nest": {"v": 2000.0}}, base, 10.0)
        assert len(deep) == 1 and deep[0].startswith("nest.v")

    def test_cli_pass_and_fail(self, tmp_path):
        cr = _load_check_regression()
        base, fresh = tmp_path / "base.json", tmp_path / "fresh.json"
        base.write_text(json.dumps({"v": 10.0}))
        fresh.write_text(json.dumps({"v": 20.0}))
        assert cr.main([str(fresh), str(base)]) == 0
        fresh.write_text(json.dumps({"v": 2000.0}))
        assert cr.main([str(fresh), str(base)]) == 1
        assert cr.main(["/nonexistent.json", str(base)]) == 1

    def test_committed_serve_baseline_self_compares(self):
        cr = _load_check_regression()
        base = json.loads(
            (REPO / "benchmarks" / "baselines" / "serve.json").read_text()
        )
        assert cr.compare(base, base, 10.0) == []
        # the run-varying crossover field must stay ungated (null)
        assert base["time_to_adapt_rounds"] is None


def test_bench_driver_rejects_unknown_module():
    env = dict(os.environ, PYTHONPATH=f"{REPO}/src:{REPO}")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "nope"],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=120,
    )
    assert proc.returncode != 0
    assert "unknown module" in proc.stderr
