"""CSR / ELL / BSR format correctness, incl. hypothesis sweeps."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.sparse.csr import CSRMatrix, csr_from_dense, csr_matvec, csr_rmatvec
from repro.sparse.ell import ell_from_csr, ell_matvec, ell_matmat, ell_rmatvec, ell_rmatmat
from repro.sparse.bsr import bsr_from_csr, bsr_matvec_ref, bsr_to_dense


def random_dense(rng, m, n, density):
    return (rng.random((m, n)) < density) * rng.standard_normal((m, n))


@pytest.mark.parametrize("m,n,density", [(17, 23, 0.1), (64, 32, 0.3), (5, 200, 0.02)])
def test_csr_roundtrip_and_matvec(m, n, density):
    rng = np.random.default_rng(m * n)
    a = random_dense(rng, m, n, density)
    csr = csr_from_dense(a)
    np.testing.assert_allclose(csr.to_dense(), a)
    x, u = rng.standard_normal(n), rng.standard_normal(m)
    np.testing.assert_allclose(csr_matvec(csr, x), a @ x, atol=1e-10)
    np.testing.assert_allclose(csr_rmatvec(csr, u), a.T @ u, atol=1e-10)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 40),
    n=st.integers(2, 60),
    density=st.floats(0.01, 0.5),
    seed=st.integers(0, 2**16),
)
def test_ell_matches_dense(m, n, density, seed):
    rng = np.random.default_rng(seed)
    a = random_dense(rng, m, n, density)
    ell = ell_from_csr(csr_from_dense(a))
    x = rng.standard_normal(n).astype(np.float32)
    u = rng.standard_normal(m).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ell_matvec(ell, jnp.asarray(x))), a @ x, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ell_rmatvec(ell, jnp.asarray(u))), a.T @ u, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 50),
    n=st.integers(2, 300),
    density=st.floats(0.01, 0.4),
    bm=st.sampled_from([4, 8]),
    bn=st.sampled_from([16, 128]),
    seed=st.integers(0, 2**16),
)
def test_bsr_roundtrip_and_matvec(m, n, density, bm, bn, seed):
    rng = np.random.default_rng(seed)
    a = random_dense(rng, m, n, density)
    bsr = bsr_from_csr(csr_from_dense(a), bm=bm, bn=bn)
    np.testing.assert_allclose(bsr_to_dense(bsr), a, atol=1e-6)
    x = rng.standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(bsr_matvec_ref(bsr, jnp.asarray(x))), a @ x, atol=2e-3
    )


def test_ell_matmat(skewed_csr):
    rng = np.random.default_rng(0)
    a = skewed_csr.to_dense()
    ell = ell_from_csr(skewed_csr)
    X = rng.standard_normal((skewed_csr.n, 5)).astype(np.float32)
    U = rng.standard_normal((skewed_csr.m, 5)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ell_matmat(ell, jnp.asarray(X))), a @ X, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(ell_rmatmat(ell, jnp.asarray(U))), a.T @ U, rtol=1e-3, atol=1e-3)


def test_scale_rows(skewed_csr):
    y = np.where(np.random.default_rng(1).random(skewed_csr.m) < 0.5, 1.0, -1.0)
    scaled = skewed_csr.scale_rows(y)
    np.testing.assert_allclose(scaled.to_dense(), skewed_csr.to_dense() * y[:, None])
