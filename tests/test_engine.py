"""The unified engine: bundle-primitive parity + corner trajectories.

E1  The scatter-free Pallas ELL-Gram bundle primitive matches the dense
    densify oracle (kernels/ref.py) across (s, b, width) shapes — and
    so does the pure-jnp "blocked" variant used inside shard_map.
E2  Engine corners reproduce the legacy solver entry points
    (run_sgd / run_sstep_sgd / run_fedavg / run_hybrid_sgd)
    bit-for-bit — the wrappers and the named-corner schedules are the
    same computation.
E3  The gram backend never changes the trajectory (pallas ≡ blocked ≡
    dense through a full multi-round run).
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (
    ParallelSGDSchedule,
    make_problem,
    run_fedavg,
    run_hybrid_sgd,
    run_parallel_sgd,
    run_sgd,
    run_sstep_sgd,
    single_team,
    stack_row_teams,
)
from repro.kernels.ell_gram import ell_gram_and_v, ell_gram_and_v_blocked
from repro.kernels.ref import ell_gram_and_v_ref
from repro.sparse.synthetic import make_skewed_csr

B, ETA = 8, 0.05


# ---------------- E1: bundle primitive vs densify oracle ----------------


@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([1, 2, 4, 8]),
    b=st.sampled_from([4, 8, 16]),
    width=st.integers(1, 40),
    n=st.integers(8, 1500),
    bk=st.sampled_from([128, 256, 512]),
    seed=st.integers(0, 999),
)
def test_bundle_primitive_matches_dense_ref(s, b, width, n, bk, seed):
    rng = np.random.default_rng(seed)
    sb = s * b
    idx = jnp.asarray(rng.integers(0, n, size=(sb, width)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((sb, width)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    g_ref, v_ref = ell_gram_and_v_ref(idx, val, x, n)
    for impl in (ell_gram_and_v, ell_gram_and_v_blocked):
        g, v = impl(idx, val, x, n=n, bk=bk)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), rtol=1e-4, atol=1e-4)


def test_bundle_primitive_duplicate_columns():
    """Duplicate column ids within a row must accumulate (scatter-add
    semantics), not overwrite."""
    idx = jnp.asarray([[2, 2, 5], [0, 1, 1]], jnp.int32)
    val = jnp.asarray([[1.0, 2.0, 3.0], [4.0, 5.0, -1.0]], jnp.float32)
    x = jnp.arange(8, dtype=jnp.float32)
    g, v = ell_gram_and_v(idx, val, x, n=8, bk=4)
    g_ref, v_ref = ell_gram_and_v_ref(idx, val, x, 8)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), rtol=1e-6)


def test_bundle_primitive_ell_padding_is_inert():
    """ELL pad entries (idx 0, val 0) must not pollute column 0."""
    rng = np.random.default_rng(3)
    idx = rng.integers(0, 64, size=(16, 6)).astype(np.int32)
    val = rng.standard_normal((16, 6)).astype(np.float32)
    idx[:, 4:] = 0
    val[:, 4:] = 0.0
    x = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    g, v = ell_gram_and_v(jnp.asarray(idx), jnp.asarray(val), x, n=64, bk=32)
    g_ref, v_ref = ell_gram_and_v_ref(jnp.asarray(idx), jnp.asarray(val), x, 64)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), rtol=1e-5, atol=1e-5)


# ---------------- E2: engine corners == legacy trajectories ----------------


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    a = make_skewed_csr(256, 128, 12, 0.8, seed=3)
    y = np.where(rng.random(256) < 0.5, 1.0, -1.0)
    return a, y


def test_engine_mb_sgd_corner_bitwise(dataset):
    a, y = dataset
    prob = make_problem(a, y, row_multiple=64)
    x0 = jnp.zeros(prob.n)
    x_legacy, l_legacy = run_sgd(prob, x0, B, ETA, 64, loss_every=16)
    sched = ParallelSGDSchedule.mb_sgd(B, ETA, 64, loss_every=16)
    x_eng, l_eng = run_parallel_sgd(single_team(prob), x0, sched)
    np.testing.assert_array_equal(np.asarray(x_legacy), np.asarray(x_eng))
    np.testing.assert_array_equal(np.asarray(l_legacy), np.asarray(l_eng))


@pytest.mark.parametrize("s", [2, 4, 8])
def test_engine_sstep_corner_bitwise(dataset, s):
    a, y = dataset
    prob = make_problem(a, y, row_multiple=64)
    x0 = jnp.zeros(prob.n)
    x_legacy, _ = run_sstep_sgd(prob, x0, s, B, ETA, 64)
    sched = ParallelSGDSchedule.sstep(s, B, ETA, 64)
    x_eng, _ = run_parallel_sgd(single_team(prob), x0, sched)
    np.testing.assert_array_equal(np.asarray(x_legacy), np.asarray(x_eng))


def test_engine_fedavg_corner_bitwise(dataset):
    a, y = dataset
    tp = stack_row_teams(a, y, 4, row_multiple=B)
    x0 = jnp.zeros(tp.n)
    x_legacy, _ = run_fedavg(tp, x0, B, ETA, tau=16, rounds=4)
    sched = ParallelSGDSchedule.fedavg(4, B, ETA, tau=16, rounds=4)
    x_eng, _ = run_parallel_sgd(tp, x0, sched)
    np.testing.assert_array_equal(np.asarray(x_legacy), np.asarray(x_eng))


def test_engine_hybrid_corner_bitwise(dataset):
    a, y = dataset
    s, tau = 4, 16
    tp = stack_row_teams(a, y, 2, row_multiple=s * B)
    x0 = jnp.zeros(tp.n)
    x_legacy, _ = run_hybrid_sgd(tp, x0, s, B, ETA, tau, rounds=4)
    sched = ParallelSGDSchedule.hybrid(2, s, B, ETA, tau, rounds=4)
    x_eng, _ = run_parallel_sgd(tp, x0, sched)
    np.testing.assert_array_equal(np.asarray(x_legacy), np.asarray(x_eng))


# ---------------- E3: gram backend invariance ----------------


@pytest.mark.parametrize("gram", ["blocked", "dense"])
def test_engine_gram_backend_invariant(dataset, gram):
    a, y = dataset
    s, tau = 4, 16
    tp = stack_row_teams(a, y, 2, row_multiple=s * B)
    x0 = jnp.zeros(tp.n)
    base = ParallelSGDSchedule.hybrid(2, s, B, ETA, tau, rounds=3)
    x_pallas, _ = run_parallel_sgd(tp, x0, base)
    x_other, _ = run_parallel_sgd(tp, x0, dataclasses.replace(base, gram=gram))
    np.testing.assert_allclose(
        np.asarray(x_pallas), np.asarray(x_other), rtol=1e-6, atol=1e-7
    )


def test_schedule_validation(dataset):
    # s ∤ τ is a *solver* constraint (the NN trainer legally carries
    # s = grad-accum with no τ coupling), enforced at run time:
    a, y = dataset
    tp = stack_row_teams(a, y, 1, row_multiple=64)
    with pytest.raises(ValueError):
        run_parallel_sgd(tp, jnp.zeros(tp.n), ParallelSGDSchedule(s=3, tau=8, rounds=1))
    with pytest.raises(ValueError):
        ParallelSGDSchedule(gram="nope")
    with pytest.raises(ValueError):
        ParallelSGDSchedule.sstep(3, B, ETA, 64)  # s ∤ iters
    with pytest.raises(ValueError):
        ParallelSGDSchedule.mb_sgd(B, ETA, 2, loss_every=8)  # le ∤ rounds
    with pytest.raises(ValueError):
        ParallelSGDSchedule.fedavg(2, B, ETA, 4, rounds=10, loss_every=4)


@pytest.mark.parametrize(
    "bad",
    [
        dict(s=0), dict(s=-2), dict(b=0), dict(b=-8), dict(bk=0), dict(bk=-512),
        dict(tau=0), dict(p_r=0), dict(p_c=0), dict(rounds=0), dict(rounds=-1),
        dict(loss_every=-1), dict(eta=-0.05),
    ],
)
def test_schedule_rejects_nonpositive_knobs(bad):
    """Satellite: every loop-shape knob must be positive (loss_every ≥ 0,
    eta ≥ 0 — η = 0 is reserved for the engine's internal jit-cache
    normalization and rejected at the solver entries instead)."""
    (knob, value), = bad.items()
    with pytest.raises(ValueError, match=knob):
        ParallelSGDSchedule(**bad)


def test_solver_entries_reject_eta_zero(dataset):
    """η = 0 passes construction (the chunk cache normalizes to it) but
    no solver entry may run a zero-step schedule."""
    from repro.core.engine import run_engine_chunk

    a, y = dataset
    tp = stack_row_teams(a, y, 1, row_multiple=64)
    sched = ParallelSGDSchedule(eta=0.0, rounds=1)
    with pytest.raises(ValueError, match="eta"):
        run_parallel_sgd(tp, jnp.zeros(tp.n), sched)
    with pytest.raises(ValueError, match="eta"):
        run_engine_chunk(tp, jnp.zeros(tp.n), 0, 1, sched)


def test_eta_is_traced_not_static(dataset):
    """An η-sweep over otherwise-identical schedules must reuse one
    compiled executable (η enters as a traced operand)."""
    from repro.core.engine import _run_engine

    a, y = dataset
    tp = stack_row_teams(a, y, 2, row_multiple=32)
    x0 = jnp.zeros(tp.n)
    before = _run_engine._cache_size()
    for eta in (0.01, 0.05, 0.25):
        run_parallel_sgd(tp, x0, ParallelSGDSchedule.hybrid(2, 4, B, eta, 8, rounds=1))
    assert _run_engine._cache_size() - before <= 1


def test_legacy_hybrid_schedule_signature():
    """Old (tau, s) constructor keeps working (deprecated shim)."""
    from repro.optim import HybridSchedule

    assert HybridSchedule().tau == 10
    assert HybridSchedule(5).tau == 5
    assert HybridSchedule(s=2).s == 2 and HybridSchedule(s=2).tau == 10
    # NN grad-accum s is not coupled to τ (unlike the solver corners)
    assert HybridSchedule(tau=10, s=4).s == 4


def test_engine_rejects_mismatched_teams(dataset):
    a, y = dataset
    tp = stack_row_teams(a, y, 4, row_multiple=B)
    with pytest.raises(ValueError):
        run_parallel_sgd(tp, jnp.zeros(tp.n), ParallelSGDSchedule.fedavg(2, B, ETA, 8, 1))
