"""Partitioner invariants (paper §6.5, §7.3) — incl. hypothesis
property tests on the two-objective formulation."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.sparse.partition import (
    PARTITIONERS,
    partition_2d,
    partition_columns,
    partition_rows,
    partition_stats,
)
from repro.sparse.synthetic import make_skewed_csr


@pytest.mark.parametrize("kind", PARTITIONERS)
def test_partition_is_permutation(skewed_csr, kind):
    cp = partition_columns(skewed_csr, 8, kind)
    assert np.array_equal(np.sort(cp.order), np.arange(skewed_csr.n))
    assert cp.starts[0] == 0 and cp.starts[-1] == skewed_csr.n
    assert (np.diff(cp.starts) > 0).all()


def test_cyclic_nlocal_exact(skewed_csr):
    """Paper: cyclic bounds n_local to exactly ⌈n/p⌉ (§6.5)."""
    for p in (2, 4, 8, 16):
        cp = partition_columns(skewed_csr, p, "cyclic")
        assert cp.n_local.max() - cp.n_local.min() <= 1
        assert cp.n_local.max() == -(-skewed_csr.n // p)


def test_rows_nlocal_exact(skewed_csr):
    cp = partition_columns(skewed_csr, 8, "rows")
    assert cp.n_local.max() - cp.n_local.min() <= 1


def test_nnz_partitioner_balances_nnz_on_skewed_data(skewed_csr):
    """κ(nnz) ≤ κ(rows) on column-skewed data — the greedy partitioner's
    one design goal (paper Table 9)."""
    p = 8
    st_rows = partition_stats(skewed_csr, partition_columns(skewed_csr, p, "rows"))
    st_nnz = partition_stats(skewed_csr, partition_columns(skewed_csr, p, "nnz"))
    assert st_nnz.kappa <= st_rows.kappa


def test_cyclic_beats_rows_kappa_on_skew():
    """On strongly column-skewed data cyclic's κ ≈ 1 while contiguous
    rows-partitioning concentrates hot columns (paper Fig 3)."""
    a = make_skewed_csr(2000, 4096, 30, 1.2, seed=11)
    p = 16
    st_rows = partition_stats(a, partition_columns(a, p, "rows"))
    st_cyc = partition_stats(a, partition_columns(a, p, "cyclic"))
    assert st_cyc.kappa < st_rows.kappa
    # paper measures κ=1.9 for cyclic on url — near-optimal, not 1.0,
    # because single hot columns cannot be split
    assert st_cyc.kappa < 2.5


@settings(max_examples=20, deadline=None)
@given(
    p=st.sampled_from([2, 4, 8]),
    alpha=st.floats(0.0, 1.5),
    seed=st.integers(0, 1000),
)
def test_partition_2d_preserves_nnz(p, alpha, seed):
    a = make_skewed_csr(120, 160, 8, alpha, seed=seed)
    for kind in PARTITIONERS:
        blocks, cp, rb = partition_2d(a, 2, p, kind)
        assert sum(blk.nnz for row in blocks for blk in row) == a.nnz
        # reconstruct column content: every column appears exactly once
        assert np.array_equal(np.sort(np.concatenate([cp.rank_cols(j) for j in range(p)])), np.arange(a.n))


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 500), p=st.integers(1, 17))
def test_row_bounds(m, p):
    rb = partition_rows(m, p)
    assert rb[0] == 0 and rb[-1] == m and len(rb) == p + 1
    assert (np.diff(rb) >= 0).all()
    assert np.diff(rb).max() - np.diff(rb).min() <= 1


def test_two_objective_tradeoff_exists_on_heavy_skew():
    """The paper's central partitioning observation: nnz-greedy achieves
    κ≈1 but can blow up max n_local (cache spill); cyclic achieves both
    objectives in expectation (§6.5, url case)."""
    a = make_skewed_csr(4000, 8192, 50, 1.3, seed=5)
    p = 32
    stats = {k: partition_stats(a, partition_columns(a, p, k)) for k in PARTITIONERS}
    # nnz-greedy achieves its one goal (κ≈1) ...
    assert stats["nnz"].kappa <= 1.5
    assert stats["nnz"].kappa < stats["rows"].kappa
    # greedy must over-allocate columns somewhere vs the uniform share
    assert stats["nnz"].max_n_local > stats["cyclic"].max_n_local
    # cyclic: both objectives
    assert stats["cyclic"].max_n_local == -(-a.n // p)
    assert stats["cyclic"].kappa < 2.0
