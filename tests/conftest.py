"""Shared fixtures + optional-dependency shims.

NOTE: do NOT set XLA_FLAGS device-count here — smoke tests and benches
must see the real single CPU device; multi-device tests run in
subprocesses (test_distributed_subprocess.py).

``hypothesis`` is an optional dev dependency (requirements-dev.txt).
When it is missing the stub below lets every module still *collect*:
property tests decorated with @given skip with a clear message while
ordinary tests in the same file run normally — so the tier-1 command
``PYTHONPATH=src python -m pytest -x -q`` works on a bare interpreter.
"""

import sys
import types

import numpy as np
import pytest


def _install_hypothesis_stub() -> None:
    """Register a minimal fake ``hypothesis`` that turns @given tests
    into clean skips (only when the real package is absent)."""
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    hyp = types.ModuleType("hypothesis")
    hyp.__stub__ = True

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipped():
                pytest.skip(
                    "hypothesis not installed — pip install -r requirements-dev.txt"
                )

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def assume(_condition):
        return True

    strategies = types.ModuleType("hypothesis.strategies")

    def _strategy(*_args, **_kwargs):
        return None

    strategies.__getattr__ = lambda _name: _strategy  # PEP 562

    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    hyp.strategies = strategies
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_stub()


@pytest.fixture(scope="session")
def small_problem():
    """A small skewed logistic problem shared across solver tests."""
    from repro.sparse.synthetic import make_skewed_csr

    rng = np.random.default_rng(0)
    a = make_skewed_csr(256, 128, 12, 0.8, seed=3)
    y = np.where(rng.random(256) < 0.5, 1.0, -1.0)
    return a, y


@pytest.fixture(scope="session")
def skewed_csr():
    from repro.sparse.synthetic import make_skewed_csr

    return make_skewed_csr(400, 600, 20, 1.0, seed=7)
