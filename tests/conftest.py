"""Shared fixtures. NOTE: do NOT set XLA_FLAGS device-count here — smoke
tests and benches must see the real single CPU device; multi-device
tests run in subprocesses (test_distributed_subprocess.py)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_problem():
    """A small skewed logistic problem shared across solver tests."""
    from repro.sparse.synthetic import make_skewed_csr

    rng = np.random.default_rng(0)
    a = make_skewed_csr(256, 128, 12, 0.8, seed=3)
    y = np.where(rng.random(256) < 0.5, 1.0, -1.0)
    return a, y


@pytest.fixture(scope="session")
def skewed_csr():
    from repro.sparse.synthetic import make_skewed_csr

    return make_skewed_csr(400, 600, 20, 1.0, seed=7)
