"""The serving plane: hot-swap store, batched prediction service,
Session.step_stream, and the online controller.

The load-bearing guarantees:

* offline specs are untouched — no ``stream`` key on the wire, same
  content hash, ``step_rounds`` never consults the stream plane;
* streaming is deterministic — same seed → bitwise-identical weights,
  including resume-mid-stream from an autosave (no dup/drop, enforced
  structurally by the batch-index check);
* a swap is never torn — weights go through the integrity-hashed
  checkpoint format and verify *before* install; a corrupt swap leaves
  the old model serving.
"""

import dataclasses
import json
import tempfile
import urllib.request
from pathlib import Path

import numpy as np
import pytest

from repro.api import ExperimentSpec, FaultPolicy, MeshSpec, Session, StreamSpec
from repro.core.engine import ParallelSGDSchedule
from repro.serve import (
    DriftStream,
    ModelStore,
    OnlineController,
    PredictionService,
    StreamDesyncError,
    StreamFeed,
    make_stream_source,
    serve_http,
)
from repro.train.checkpoint import CheckpointCorruptError, load_model_weights


def sched(rounds=8, loss_every=4, eta=0.2):
    return ParallelSGDSchedule.hybrid(
        p_r=2, s=2, b=4, eta=eta, tau=8, rounds=rounds, loss_every=loss_every
    )


MESH = MeshSpec(p_r=2, p_c=1, backend="simulated")


def stream_spec(rounds=8, loss_every=4, **stream_kw):
    stream_kw.setdefault("source", "drift")
    stream_kw.setdefault("seed", 3)
    return ExperimentSpec(
        dataset="rcv1-sm",
        schedule=sched(rounds, loss_every),
        mesh=MESH,
        stream=StreamSpec(**stream_kw),
    )


# ---------------- StreamSpec (spec layer) ----------------


def test_stream_spec_roundtrip():
    spec = stream_spec(drift_at=5, width=8, swap_every=2)
    again = ExperimentSpec.from_dict(spec.to_dict())
    assert again == spec
    assert again.stream.drift_at == 5
    assert ExperimentSpec.from_json(spec.to_json()) == spec


def test_default_spec_has_no_stream_on_the_wire():
    """Offline specs serialize (and content-hash) exactly as before the
    serving plane existed — old JSON and checkpoints stay valid."""
    spec = ExperimentSpec(dataset="rcv1-sm", schedule=sched(), mesh=MESH)
    d = spec.to_dict()
    assert "stream" not in d
    assert ExperimentSpec.from_dict(d) == spec  # old JSON (no key) loads
    assert spec.content_hash() == dataclasses.replace(
        spec, stream=StreamSpec()
    ).content_hash()
    assert spec.content_hash() != stream_spec().content_hash()


def test_stream_spec_validation():
    with pytest.raises(ValueError, match="source"):
        StreamSpec(source="firehose")
    with pytest.raises(ValueError, match="queue_capacity"):
        StreamSpec(queue_capacity=0)
    # pinned rows_per_round must equal one round's consumption
    with pytest.raises(ValueError, match="rows_per_round"):
        stream_spec(rows_per_round=63)
    ok = stream_spec(rows_per_round=64)  # p_r·τ·b = 2·8·4
    assert ok.stream_rows_per_round() == 64
    assert stream_spec().stream_rows_per_round() == 64  # derived


def test_make_stream_source_follows_the_spec():
    src = make_stream_source(stream_spec(drift_at=7))
    assert isinstance(src, DriftStream)
    assert src.rows == 64 and src.drift_at == 7
    from repro.serve import ReplayStream

    rep = make_stream_source(stream_spec(source="replay"))
    assert isinstance(rep, ReplayStream)
    with pytest.raises(ValueError, match="no stream"):
        make_stream_source(
            ExperimentSpec(dataset="rcv1-sm", schedule=sched(), mesh=MESH)
        )


# ---------------- checkpoint → weights door ----------------


def test_load_model_weights_roundtrip(tmp_path):
    spec = stream_spec()
    sess = Session(spec)
    sess.step_stream(make_stream_source(spec), 4)
    path = tmp_path / "ck"
    sess.save(path)
    x, meta = load_model_weights(path)
    assert np.array_equal(x, sess.current_x())
    assert meta["rounds_done"] == 4
    assert meta["spec_hash"] == spec.content_hash()


def test_load_model_weights_rejects_corruption(tmp_path):
    spec = stream_spec()
    sess = Session(spec)
    sess.step_stream(make_stream_source(spec), 2)
    path = tmp_path / "ck"
    sess.save(path)
    npz = path.with_suffix(".npz")
    blob = bytearray(npz.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    npz.write_bytes(bytes(blob))
    with pytest.raises(CheckpointCorruptError):
        load_model_weights(path)


# ---------------- ModelStore ----------------


def test_store_publish_and_snapshot_immutability():
    store = ModelStore()
    x = np.arange(5, dtype=np.float32)
    snap = store.publish(x, rounds_done=3)
    x[0] = 99.0  # publisher's buffer — must not reach the served model
    assert snap.x[0] == 0.0
    with pytest.raises(ValueError):
        snap.x[1] = 7.0  # served weights are frozen
    assert store.version == 1 and snap.rounds_done == 3


def test_store_empty_raises():
    store = ModelStore()
    with pytest.raises(RuntimeError, match="empty"):
        store.snapshot()
    assert store.version == 0


def test_store_swap_from_checkpoint(tmp_path):
    spec = stream_spec()
    sess = Session(spec)
    sess.step_stream(make_stream_source(spec), 4)
    path = tmp_path / "ck"
    sess.save(path)
    store = ModelStore()
    store.publish(np.zeros(sess.current_x().shape[0], np.float32))
    snap = store.swap_from_checkpoint(path)
    assert snap.version == 2
    assert np.array_equal(snap.x, sess.current_x())
    assert snap.rounds_done == 4 and snap.spec_hash == spec.content_hash()


def test_corrupt_swap_keeps_the_old_model_serving(tmp_path):
    spec = stream_spec()
    sess = Session(spec)
    sess.step_stream(make_stream_source(spec), 2)
    path = tmp_path / "ck"
    sess.save(path)
    npz = path.with_suffix(".npz")
    blob = bytearray(npz.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    npz.write_bytes(bytes(blob))

    store = ModelStore()
    old = store.publish(np.ones(4, np.float32), rounds_done=1)
    with pytest.raises(CheckpointCorruptError):
        store.swap_from_checkpoint(path)
    assert store.snapshot() is old  # untouched — never a torn install
    assert store.failed_swaps == 1 and store.version == 1


def test_store_predict_pins_one_version():
    store = ModelStore()
    store.publish(np.array([1.0, 2.0, -1.0], np.float32))
    idx = np.array([[0, 1], [2, 2]], np.int32)
    val = np.array([[1.0, 1.0], [1.0, 0.0]], np.float32)
    margins, version = store.predict(idx, val)
    assert version == 1
    np.testing.assert_allclose(margins, [3.0, -1.0])


# ---------------- PredictionService ----------------


def test_service_batches_and_answers():
    store = ModelStore()
    store.publish(np.array([2.0, -3.0], np.float32))
    with PredictionService(store, max_wait_s=0.01) as svc:
        res = svc.predict([[0, 1]], [[1.0, 0.5]])
        np.testing.assert_allclose(res.margins, [0.5])
        assert res.labels.tolist() == [1.0]
        assert res.model_version == 1
        # a single flat row is promoted to a batch of one
        res2 = svc.predict([0, 0], [1.0, 1.0])
        np.testing.assert_allclose(res2.margins, [4.0])
        st = svc.stats()
        assert st["rows_served"] == 2 and st["errors"] == 0


def test_service_coalesces_concurrent_requests():
    import threading

    store = ModelStore()
    store.publish(np.ones(8, np.float32))
    results = []
    with PredictionService(store, max_wait_s=0.05) as svc:
        def ask(i):
            results.append(svc.predict([[i % 8]], [[1.0]]))

        threads = [threading.Thread(target=ask, args=(i,)) for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st = svc.stats()
    assert len(results) == 6
    assert all(r.margins.shape == (1,) for r in results)
    assert st["batches"] < 6  # at least some coalescing happened


def test_service_survives_a_swap_mid_traffic():
    """Predictions keep answering while the model hot-swaps, and every
    answer is computed by exactly one version (never a mix)."""
    store = ModelStore()
    store.publish(np.full(4, 1.0, np.float32))
    with PredictionService(store, max_wait_s=0.001) as svc:
        seen = set()
        for i in range(50):
            if i == 25:
                store.publish(np.full(4, 2.0, np.float32))
            res = svc.predict([[0, 1, 2, 3]], [[1.0, 1.0, 1.0, 1.0]])
            # margin must match the version that served it exactly
            want = 4.0 if res.model_version == 1 else 8.0
            np.testing.assert_allclose(res.margins, [want])
            seen.add(res.model_version)
    assert seen == {1, 2}


def test_service_propagates_errors():
    store = ModelStore()  # empty: predict must fail loudly
    with PredictionService(store) as svc:
        with pytest.raises(RuntimeError, match="empty"):
            svc.predict([[0]], [[1.0]])
        assert svc.stats()["errors"] == 1


def test_http_front(tmp_path):
    store = ModelStore()
    store.publish(np.array([1.0, -1.0, 0.5], np.float32))
    with PredictionService(store) as svc:
        server, _ = serve_http(svc, port=0)
        host, port = server.server_address[:2]
        base = f"http://{host}:{port}"
        try:
            with urllib.request.urlopen(f"{base}/healthz", timeout=10) as r:
                health = json.loads(r.read())
            assert health["ok"] and health["model_version"] == 1

            body = json.dumps(
                {"rows": [{"idx": [0, 2], "val": [1.0, 2.0]}, {"idx": [1], "val": [1.0]}]}
            ).encode()
            req = urllib.request.Request(
                f"{base}/predict", data=body, headers={"Content-Type": "application/json"}
            )
            with urllib.request.urlopen(req, timeout=10) as r:
                out = json.loads(r.read())
            np.testing.assert_allclose(out["margins"], [2.0, -1.0])
            assert out["labels"] == [1.0, -1.0]
            assert out["model_version"] == 1

            with urllib.request.urlopen(f"{base}/stats", timeout=10) as r:
                stats = json.loads(r.read())
            assert stats["service"]["rows_served"] == 2
            assert stats["store"]["version"] == 1

            bad = urllib.request.Request(f"{base}/predict", data=b"{}")
            with pytest.raises(urllib.error.HTTPError) as e:
                urllib.request.urlopen(bad, timeout=10)
            assert e.value.code == 400
        finally:
            server.shutdown()


# ---------------- Session.step_stream ----------------


def test_step_stream_is_deterministic():
    spec = stream_spec(rounds=8)
    runs = []
    for _ in range(2):
        sess = Session(spec)
        while not sess.done:
            sess.step_stream(make_stream_source(spec))
        runs.append((sess.current_x(), list(sess.losses)))
    assert np.array_equal(runs[0][0], runs[1][0])  # bitwise
    assert runs[0][1] == runs[1][1]


def test_step_stream_chunking_never_changes_the_trace():
    spec = stream_spec(rounds=8)
    a = Session(spec)
    while not a.done:
        a.step_stream(make_stream_source(spec))  # default chunks
    b = Session(spec)
    src = make_stream_source(spec)
    while not b.done:
        b.step_stream(src, 1)  # one round at a time, one shared source
    assert np.array_equal(a.current_x(), b.current_x())
    assert a.losses == b.losses


def test_step_stream_through_a_feed_matches_bare_source():
    spec = stream_spec(rounds=6, loss_every=3)
    a = Session(spec)
    while not a.done:
        a.step_stream(make_stream_source(spec))
    b = Session(spec)
    with StreamFeed(make_stream_source(spec), capacity=4) as feed:
        while not b.done:
            b.step_stream(feed, 1)
    assert np.array_equal(a.current_x(), b.current_x())


def test_resume_mid_stream_is_bitwise(tmp_path):
    spec = dataclasses.replace(stream_spec(rounds=12), faults=FaultPolicy(autosave_every=4))
    ref = Session(spec)
    while not ref.done:
        ref.step_stream(make_stream_source(spec))

    interrupted = Session(spec, autosave_dir=tmp_path)
    interrupted.step_stream(make_stream_source(spec), 7)  # autosave hit at 4
    resumed = Session.restore(
        interrupted.autosave_path, spec=spec, autosave_dir=tmp_path
    )
    assert resumed.rounds_done == 4  # last durable boundary
    # re-attach the (replaying) source at the restored round: no
    # duplicated and no dropped micro-batch, by construction
    while not resumed.done:
        resumed.step_stream(make_stream_source(spec))
    assert np.array_equal(ref.current_x(), resumed.current_x())
    assert ref.losses == resumed.losses


def test_step_stream_desync_raises():
    spec = stream_spec(rounds=8)
    sess = Session(spec)
    src = make_stream_source(spec)

    class OffByOne:
        def micro_batches(self, start=0):
            return src.micro_batches(start + 1)

    with pytest.raises(StreamDesyncError, match="duplicated, dropped"):
        sess.step_stream(OffByOne(), 1)


def test_step_stream_rejects_wrong_batch_size():
    spec = stream_spec(rounds=8)
    sess = Session(spec)
    wrong = DriftStream(n=4736, rows=32, seed=3)  # round needs 64
    with pytest.raises(ValueError, match="p_r·τ·b"):
        sess.step_stream(wrong, 1)


def test_step_stream_honors_budget_and_stop():
    spec = stream_spec(rounds=6, loss_every=3)
    sess = Session(spec)
    ev = sess.step_stream(make_stream_source(spec), 100)  # capped at budget
    assert ev.rounds_done == 6 and ev.stop == "rounds"
    assert sess.done
    with pytest.raises(RuntimeError, match="finished"):
        sess.step_stream(make_stream_source(spec), 1)


def test_step_stream_samples_loss_on_boundaries():
    spec = stream_spec(rounds=8, loss_every=4)
    sess = Session(spec)
    src = make_stream_source(spec)
    ev1 = sess.step_stream(src)  # default: to the next boundary
    assert sess.rounds_done == 4 and ev1.loss is not None
    assert len(sess.losses) == 1
    sess.step_stream(src)
    assert len(sess.losses) == 2


def test_offline_sessions_never_touch_the_stream_plane():
    """A stream-less spec steps through step_rounds exactly as before —
    and step_stream is a loud error, not a silent no-data loop."""
    spec = ExperimentSpec(dataset="rcv1-sm", schedule=sched(rounds=4), mesh=MESH)
    sess = Session(spec)
    ev = sess.step_rounds(4)
    assert ev.rounds_done == 4
    with pytest.raises(ValueError, match="no stream"):
        make_stream_source(spec)


# ---------------- OnlineController ----------------


def test_controller_end_to_end_with_service(tmp_path):
    spec = stream_spec(rounds=12, swap_every=4, drift_at=6)
    store = ModelStore()
    with PredictionService(store) as svc:
        ctrl = OnlineController(
            Session(spec), make_stream_source(spec), store, service=svc,
            swap_dir=tmp_path,
        )
        assert store.version == 1  # serving from round 0
        # predictions answer during training/swaps
        src = make_stream_source(spec)
        m = None
        for _ in range(3):
            ctrl.run(4)
            b = src.batch(ctrl.session.rounds_done)
            res = svc.predict(b.indices, b.values)
            assert res.margins.shape == (64,)
        m = ctrl.metrics()
    assert m.rounds_done == 12
    assert m.swaps >= 3  # one per swap_every boundary at least
    assert m.failed_swaps == 0
    assert m.staleness_rounds == 0  # final swap caught the store up
    assert m.predictions_served == 3 * 64
    # swap checkpoints are real integrity-hashed checkpoints on disk
    assert ctrl.swap_rounds and all(
        (tmp_path / f"swap-{r}").with_suffix(".npz").exists() for r in ctrl.swap_rounds
    )


def test_controller_swap_cadence_follows_the_spec():
    spec = stream_spec(rounds=8, swap_every=2)
    ctrl = OnlineController(Session(spec), make_stream_source(spec), ModelStore())
    ctrl.run()
    assert ctrl.swap_rounds == [2, 4, 6, 8]


def test_controller_matches_bare_session_bitwise(tmp_path):
    """The controller's swap machinery (save/load every k rounds) must
    never perturb training: same weights as a bare step_stream loop."""
    spec = stream_spec(rounds=8, swap_every=2)
    bare = Session(spec)
    while not bare.done:
        bare.step_stream(make_stream_source(spec))
    ctrl = OnlineController(Session(spec), make_stream_source(spec), ModelStore(),
                            swap_dir=tmp_path)
    ctrl.run()
    assert np.array_equal(bare.current_x(), ctrl.session.current_x())
    # and the served model IS the trained model
    assert np.array_equal(ctrl.store.snapshot().x, bare.current_x())


def test_controller_recovers_from_drift(tmp_path):
    """The ISSUE's end-to-end criterion: accuracy against the *current*
    concept collapses at the drift and recovers without a restart."""
    spec = ExperimentSpec(
        dataset="rcv1-sm",
        schedule=sched(rounds=120, loss_every=0),
        mesh=MESH,
        stream=StreamSpec(source="drift", seed=3, drift_at=60, swap_every=8),
    )
    src = make_stream_source(spec)
    post_twin = dataclasses.replace(src, drift_at=1)  # always-new-concept probe
    ctrl = OnlineController(Session(spec), src, ModelStore(), swap_dir=tmp_path)

    def acc_new(r):
        vals = []
        for k in range(4):
            b = post_twin.batch(50_000 + 10 * r + k)
            m = np.einsum(
                "rw,rw->r", ctrl.session.current_x()[b.indices], b.values
            )
            vals.append(np.mean(np.where(m >= 0, 1.0, -1.0) == b.y))
        return float(np.mean(vals))

    ctrl.run(60)
    at_drift = acc_new(60)  # the old model scored against the new concept
    ctrl.run(60)
    recovered = acc_new(120)
    assert at_drift < 0.5  # the flip inverted every learned margin
    assert recovered > 0.55  # adapted online, same process, no restart
    assert ctrl.metrics().failed_swaps == 0
