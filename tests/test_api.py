"""The repro.api front door: spec round-tripping, plan parity with the
raw cost model, run() dispatch, autotune, and the deprecation shims on
the legacy distributed entry points.

Multi-device shard_map runs live in test_distributed_subprocess.py;
here the shard_map backend is exercised on the 1×1 mesh the single CPU
device can host — the full dispatch path, no fake devices needed.
"""

import dataclasses
import json
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import ExperimentSpec, MeshSpec, StopPolicy, build_problem, plan, run
from repro.api.spec import dataset_stats
from repro.core import ParallelSGDSchedule, run_parallel_sgd
from repro.costmodel import MACHINES, HybridConfig, hybrid_epoch_cost

DATASET = "rcv1-sm"


def hybrid_spec(**kw) -> ExperimentSpec:
    sched = kw.pop("schedule", None) or ParallelSGDSchedule.hybrid(
        2, 2, 8, 0.05, 8, rounds=4, loss_every=2
    )
    mesh = kw.pop("mesh", None) or MeshSpec(p_r=2, p_c=2)
    return ExperimentSpec(dataset=DATASET, schedule=sched, mesh=mesh, **kw)


# ---------------- spec: validation + JSON round-trip ----------------


def test_spec_json_round_trip():
    spec = hybrid_spec(name="rt", autotune=True, row_multiple=32, seed=7)
    # through a real JSON string, not just dicts
    restored = ExperimentSpec.from_json(json.dumps(spec.to_dict()))
    assert restored == spec
    # and the canonicalized schedule survives (p_c copied from the mesh)
    assert restored.schedule.p_c == spec.mesh.p_c


def test_spec_canonicalizes_schedule_p_c():
    spec = hybrid_spec(mesh=MeshSpec(p_r=2, p_c=4))
    assert spec.schedule.p_c == 4  # schedule default p_c=1 → mesh wins


def test_spec_rejects_geometry_mismatch():
    sched = ParallelSGDSchedule.hybrid(2, 2, 8, 0.05, 8, rounds=1)
    with pytest.raises(ValueError):  # p_r is numerical — must agree
        ExperimentSpec(dataset=DATASET, schedule=sched, mesh=MeshSpec(p_r=4))
    with pytest.raises(ValueError):  # conflicting explicit p_c
        ExperimentSpec(
            dataset=DATASET,
            schedule=dataclasses.replace(sched, p_c=2),
            mesh=MeshSpec(p_r=2, p_c=4),
        )


def test_spec_rejects_unknown_names():
    sched = ParallelSGDSchedule.mb_sgd(8, 0.05, 4)
    with pytest.raises(KeyError):
        ExperimentSpec(dataset="no-such-data", schedule=sched)
    with pytest.raises(ValueError):
        ExperimentSpec(dataset=DATASET, schedule=sched, machine="no-such-machine")
    with pytest.raises(ValueError):
        MeshSpec(backend="no-such-backend")
    with pytest.raises(ValueError):
        MeshSpec(partitioner="no-such-partitioner")


def test_spec_rejects_degenerate_mesh_and_gram():
    with pytest.raises(ValueError, match="1×1"):
        MeshSpec(p_r=0)
    with pytest.raises(ValueError, match="1×1"):
        MeshSpec(p_c=-1)
    with pytest.raises(ValueError, match="gram"):
        ParallelSGDSchedule(gram="no-such-gram")


def test_stop_policy_validation():
    with pytest.raises(ValueError, match="max_seconds"):
        StopPolicy(max_seconds=-1.0)
    with pytest.raises(ValueError, match="max_rounds"):
        StopPolicy(max_rounds=0)
    # target_loss is only observable on loss-sampling boundaries
    sched = ParallelSGDSchedule.hybrid(1, 2, 8, 0.05, 8, rounds=4)  # loss_every=0
    with pytest.raises(ValueError, match="loss_every"):
        ExperimentSpec(dataset=DATASET, schedule=sched,
                       stop=StopPolicy(target_loss=0.5))
    assert StopPolicy().trivial and not StopPolicy(max_rounds=1).trivial


def test_spec_json_round_trip_with_partitioner_and_stop():
    """Satellite: non-default partitioner + every StopPolicy knob must
    survive the JSON round trip (and the content hash must track it)."""
    spec = hybrid_spec(
        mesh=MeshSpec(p_r=2, p_c=4, backend="shard_map", partitioner="nnz"),
        stop=StopPolicy(target_loss=0.6, max_seconds=12.5, max_rounds=3),
        name="rt-stop",
    )
    restored = ExperimentSpec.from_json(spec.to_json())
    assert restored == spec
    assert restored.mesh.partitioner == "nnz"
    assert restored.stop == StopPolicy(target_loss=0.6, max_seconds=12.5, max_rounds=3)
    assert restored.content_hash() == spec.content_hash()
    # old spec JSON (no "stop" key) still loads, with the trivial policy
    d = spec.to_dict()
    del d["stop"]
    assert ExperimentSpec.from_dict(d).stop.trivial
    # the hash keys on content: any field change moves it
    assert (
        dataclasses.replace(spec, stop=StopPolicy()).content_hash()
        != spec.content_hash()
    )


def test_spec_and_report_predate_comm_ledger():
    """PR 5 back-compat, alongside the hash tests above: spec JSON and
    report JSON written before the comm plane existed (no comm_timing /
    comm_ledger keys) load with defaults, and a default spec's dict —
    hence its content hash, checkpoints, and sweep resume dirs — is
    byte-identical to the pre-ledger layout."""
    spec = hybrid_spec(name="pre-ledger")
    d = spec.to_dict()
    assert "comm_timing" not in d and "comm_ledger" not in d
    assert ExperimentSpec.from_dict(d) == spec
    assert ExperimentSpec.from_dict(d).content_hash() == spec.content_hash()
    # a timed spec round-trips and moves the hash (resume dirs never
    # mix timed with untimed runs)
    timed = dataclasses.replace(spec, comm_timing=True)
    assert ExperimentSpec.from_json(timed.to_json()) == timed
    assert timed.content_hash() != spec.content_hash()
    # pre-ledger report JSON: rehydrates with ledger=None
    from repro.api import RunReport

    rep = run(spec)
    old = rep.to_dict()
    del old["comm_ledger"]
    assert RunReport.from_dict(old).ledger is None
    assert RunReport.from_dict(rep.to_dict()).ledger == rep.ledger


# ---------------- plan: cost-model parity + autotune ----------------


def test_plan_matches_direct_cost_model_call():
    spec = hybrid_spec(mesh=MeshSpec(p_r=2, p_c=4))
    pl = plan(spec)
    st = dataset_stats(DATASET)
    cfg = HybridConfig(p_r=2, p_c=4, s=spec.schedule.s, b=spec.schedule.b,
                       tau=spec.schedule.tau)
    direct = hybrid_epoch_cost(st.m, st.n, st.zbar, cfg, MACHINES[spec.machine])
    assert pl.cost == direct
    assert pl.regime == direct.dominant
    assert not pl.autotuned and pl.s_star is None


def test_plan_autotune_rewrites_schedule_validly():
    spec = hybrid_spec(autotune=True)
    pl = plan(spec)
    sched = pl.spec.schedule
    assert pl.autotuned and pl.s_star is not None and pl.b_star is not None
    assert sched.s >= 1 and sched.b >= 1
    assert sched.tau % sched.s == 0  # still a runnable schedule
    # the rewritten spec must itself survive a JSON round trip
    assert ExperimentSpec.from_json(pl.spec.to_json()) == pl.spec


# ---------------- run: simulated backend ----------------


def test_run_simulated_matches_direct_engine_call():
    spec = hybrid_spec()
    rep = run(spec)
    bundle = build_problem(spec)
    x_direct, losses_direct = run_parallel_sgd(
        bundle.team, jnp.zeros(bundle.dataset.A.n), spec.schedule
    )
    np.testing.assert_array_equal(rep.x, np.asarray(x_direct))
    np.testing.assert_array_equal(rep.losses, np.asarray(losses_direct))
    assert rep.backend == "simulated"
    assert len(rep.losses) == spec.schedule.rounds // spec.schedule.loss_every
    assert rep.wall_time_s > 0
    assert rep.comm_words["total_words"] > 0
    json.dumps(rep.to_dict())  # report is JSON-serializable


def test_run_shard_map_1x1_through_front_door():
    """The full shard_map dispatch path on the single real device."""
    sched = ParallelSGDSchedule.hybrid(1, 2, 8, 0.05, 8, rounds=2, loss_every=1)
    sim = run(hybrid_spec(schedule=sched, mesh=MeshSpec(p_r=1, p_c=1)))
    dist = run(hybrid_spec(schedule=sched,
                           mesh=MeshSpec(p_r=1, p_c=1, backend="shard_map")))
    assert dist.backend == "shard_map"
    np.testing.assert_allclose(dist.x, sim.x, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dist.losses, sim.losses, rtol=1e-5, atol=1e-6)


def test_run_shard_map_rejects_oversized_mesh():
    spec = hybrid_spec(mesh=MeshSpec(p_r=2, p_c=2, backend="shard_map"))
    with pytest.raises(RuntimeError, match="devices"):
        run(spec)  # main test process sees exactly one device


# ---------------- satellite: sstep loss_every validation ----------------


def test_sstep_loss_every_must_divide():
    # silently changing the cadence (the old max(loss_every // s, 1))
    # is now a hard error …
    with pytest.raises(ValueError, match="loss_every"):
        ParallelSGDSchedule.sstep(4, 8, 0.05, 64, loss_every=6)
    with pytest.raises(ValueError, match="loss_every"):
        ParallelSGDSchedule.sstep(8, 8, 0.05, 64, loss_every=4)
    # … while exact multiples keep the engine-round cadence
    sched = ParallelSGDSchedule.sstep(4, 8, 0.05, 64, loss_every=16)
    assert sched.loss_every == 4  # 16 iterations = 4 rounds of s=4
    assert ParallelSGDSchedule.sstep(4, 8, 0.05, 64).loss_every == 0


# ---------------- satellite: legacy distributed shims ----------------


@pytest.fixture()
def tiny_2d():
    from repro.core.distributed import build_2d_problem
    from repro.sparse.synthetic import make_skewed_csr
    from repro import compat

    rng = np.random.default_rng(0)
    a = make_skewed_csr(64, 50, 8, 0.8, seed=3)
    y = np.where(rng.random(64) < 0.5, 1.0, -1.0)
    prob, cp = build_2d_problem(a, y, 1, 1, "cyclic", row_multiple=8)
    mesh = compat.make_mesh((1, 1), ("rows", "cols"))
    return mesh, prob, cp


def test_run_hybrid_distributed_legacy_scalars_warn(tiny_2d):
    from repro.core.distributed import run_hybrid_distributed

    mesh, prob, cp = tiny_2d
    sched = ParallelSGDSchedule.hybrid(1, 2, 4, 0.05, 4, rounds=2, gram="blocked")
    x_new, losses = run_hybrid_distributed(mesh, prob, cp, np.zeros(50, np.float32), sched)
    assert losses.shape == (0,)

    with pytest.warns(DeprecationWarning):
        x_pos = run_hybrid_distributed(
            mesh, prob, cp, np.zeros(50, np.float32), 2, 4, 0.05, 4, 2
        )
    with pytest.warns(DeprecationWarning):
        x_kw = run_hybrid_distributed(
            mesh, prob, cp, np.zeros(50, np.float32), s=2, b=4, eta=0.05, tau=4, rounds=2
        )
    # old contract: bare x, same numerics as the schedule path
    np.testing.assert_array_equal(x_pos, x_new)
    np.testing.assert_array_equal(x_kw, x_new)


def test_distributed_rejects_schedule_plus_scalars(tiny_2d):
    """A scalar knob alongside a schedule would be silently ignored —
    must be a hard error instead."""
    from repro.core.distributed import make_hybrid_step, run_hybrid_distributed

    mesh, prob, cp = tiny_2d
    sched = ParallelSGDSchedule.hybrid(1, 2, 4, 0.05, 4, rounds=2, gram="blocked")
    with pytest.raises(TypeError, match="gram"):
        make_hybrid_step(mesh, prob, sched, gram="dense")
    with pytest.raises(TypeError, match="rounds"):
        run_hybrid_distributed(mesh, prob, cp, np.zeros(50, np.float32), sched, rounds=10)


def test_make_hybrid_step_legacy_scalars_warn(tiny_2d):
    from repro.core.distributed import make_hybrid_step

    mesh, prob, _cp = tiny_2d
    with pytest.warns(DeprecationWarning):
        step = make_hybrid_step(mesh, prob, 2, 4, 4, 0.05)
    assert callable(step)
    with pytest.raises(TypeError):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            make_hybrid_step(mesh, prob)  # neither schedule nor scalars
