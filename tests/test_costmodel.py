"""Cost model validation — the paper's own tables are the oracle.

V5  Topology rule reproduces paper Table 4 on all four rows.
V4  Refined predictor reproduces the partitioner ranking on all 9
    (dataset × partitioner) cells (paper §6.5 Validation / Fig 4).
V6  Crossover: hybrid ≪ FedAvg per-sample on url; FedAvg < hybrid on
    dense epsilon (paper Table 11 regime boundary).
V7  Regime analysis + bandwidth-balance behaviour (Table 5).
Plus hypothesis property tests: corner limits and convexity of s*.
"""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.costmodel import (
    PERLMUTTER,
    TPU_V5E,
    HybridConfig,
    PartitionerProfile,
    b_star,
    bandwidth_balance,
    classify_regime,
    fedavg_epoch_cost,
    grid_search_config,
    hybrid_epoch_cost,
    per_sample_costs,
    rank_partitioners,
    s_star,
    sstep_epoch_cost,
    topology_rule,
    cache_term_binding,
)
from repro.sparse.synthetic import DATASET_STATS


# ---------------- V5: topology rule (paper Table 4) ----------------

@pytest.mark.parametrize(
    "dataset,p,expected",
    [
        ("url", 256, (4, 64)),
        ("synthetic_uniform", 128, (2, 64)),
        ("news20", 64, (1, 64)),
        ("rcv1", 16, (1, 16)),
    ],
)
def test_topology_rule_reproduces_table4(dataset, p, expected):
    stats = DATASET_STATS[dataset]
    assert topology_rule(p, stats.n, PERLMUTTER) == expected


def test_cache_term_nonbinding_on_libsvm():
    """Paper: n·w ≤ R·L_cap = 64 MB on every LIBSVM dataset."""
    for name in ("url", "news20", "rcv1", "epsilon"):
        assert not cache_term_binding(DATASET_STATS[name].n, PERLMUTTER)


def test_cache_term_binds_on_huge_n():
    """A hypothetical n·w > R·L_cap must push p_c above R."""
    n = 2 * PERLMUTTER.ranks_per_domain * PERLMUTTER.l_cap // PERLMUTTER.word_bytes
    assert cache_term_binding(n, PERLMUTTER)
    p_r, p_c = topology_rule(1024, n, PERLMUTTER)
    assert p_c > PERLMUTTER.ranks_per_domain


# ------- V4: partitioner ranking on all 9 measured cells (Table 9) -------

TABLE9 = {
    # dataset: (n, zbar, mesh, profiles with measured κ / max n_local)
    "url": (
        3_231_961, 116, (4, 64),
        [
            PartitionerProfile("rows", 33.83, 50_499),
            PartitionerProfile("nnz", 1.31, 1_409_992),
            PartitionerProfile("cyclic", 1.91, 50_499),
        ],
        ["cyclic", "rows", "nnz"],  # paper's measured order (ms/iter)
    ),
    "news20": (
        1_355_191, 455, (1, 64),
        [
            PartitionerProfile("rows", 18.73, 21_174),
            PartitionerProfile("nnz", 1.05, 59_103),
            PartitionerProfile("cyclic", 1.18, 21_174),
        ],
        # Paper §6.5: "On url and news20 the predicted ranking is
        # cyclic < rows < nnz". (Table 9's *measured* news20 order is
        # cyclic < nnz < rows — the paper's text and table disagree; we
        # assert the paper's stated model prediction, which our model
        # reproduces, and record the discrepancy in EXPERIMENTS.md.)
        ["cyclic", "rows", "nnz"],
    ),
    "rcv1": (
        47_236, 74, (1, 16),
        [
            PartitionerProfile("rows", 1.62, 2_952),
            PartitionerProfile("nnz", 1.01, 4_333),
            PartitionerProfile("cyclic", 1.01, 2_952),
        ],
        ["cyclic", "rows", "nnz"],  # all tied within 5-7%
    ),
}


@pytest.mark.parametrize("dataset", list(TABLE9))
def test_partitioner_ranking_matches_paper(dataset):
    n, zbar, (p_r, p_c), profiles, order = TABLE9[dataset]
    ranked = rank_partitioners(n, zbar, profiles, p_r, p_c, 4, 32, 10, PERLMUTTER)
    got = [nm for nm, _ in ranked]
    if dataset == "rcv1":
        # paper: tied within 5% predicted and measured — assert the tie
        times = [bd.total for _, bd in ranked]
        assert max(times) / min(times) < 1.10
        assert got[0] == "cyclic"
    else:
        assert got == order, f"{dataset}: predicted {got}, paper {order}"


def test_winner_is_cyclic_everywhere_sparse():
    """Paper headline: cyclic is the consistent winner on skewed data."""
    for dataset, (n, zbar, (p_r, p_c), profiles, _) in TABLE9.items():
        ranked = rank_partitioners(n, zbar, profiles, p_r, p_c, 4, 32, 10, PERLMUTTER)
        assert ranked[0][0] == "cyclic", dataset


# ---------------- V6: solver crossover (Table 11) ----------------

def test_crossover_url_vs_epsilon():
    url = DATASET_STATS["url"]
    hyb = per_sample_costs("hybrid", url.m, url.n, url.zbar, 256, 4, 32, 10, PERLMUTTER, 4, 64)
    fed = per_sample_costs("fedavg", url.m, url.n, url.zbar, 256, 1, 32, 10, PERLMUTTER)
    assert sum(fed.values()) > 10 * sum(hyb.values()), "url: hybrid must win big"

    eps = DATASET_STATS["epsilon"]
    hyb = per_sample_costs("hybrid", eps.m, eps.n, eps.zbar, 512, 4, 32, 10, PERLMUTTER, 1, 512)
    fed = per_sample_costs("fedavg", eps.m, eps.n, eps.zbar, 32, 1, 32, 10, PERLMUTTER)
    assert sum(fed.values()) < sum(hyb.values()), "epsilon: FedAvg must win"


# ---------------- V7: regimes & bandwidth balance ----------------

def test_url_is_communication_bound():
    st_ = DATASET_STATS["url"]
    r = classify_regime(st_.m, st_.n, st_.zbar, HybridConfig(4, 64, 4, 32, 10), PERLMUTTER)
    assert r.name in ("gram_bw", "sync_bw", "latency")


def test_balance_separates_regimes():
    """Above the balance ⇒ Gram-BW dominates comm; below ⇒ sync-BW."""
    n = 3_231_961
    hi = HybridConfig(4, 64, 16, 64, 16)  # large s·b·τ·p_c
    lo = HybridConfig(4, 64, 2, 8, 2)
    assert bandwidth_balance(hi.s, hi.b, hi.tau, hi.p_c, n) > 1
    assert bandwidth_balance(lo.s, lo.b, lo.tau, lo.p_c, n) < 1
    cb_hi = hybrid_epoch_cost(2_396_130, n, 116, hi, PERLMUTTER)
    cb_lo = hybrid_epoch_cost(2_396_130, n, 116, lo, PERLMUTTER)
    assert cb_hi.gram_bw > cb_hi.sync_bw
    assert cb_lo.sync_bw > cb_lo.gram_bw


# ---------------- corner limits (Eq. 4 subsumes Table 3) ----------------

@settings(max_examples=30, deadline=None)
@given(
    s=st.sampled_from([1, 2, 4, 8]),
    b=st.sampled_from([8, 32, 128]),
    p=st.sampled_from([16, 64, 256]),
)
def test_sstep_limit(s, b, p):
    """p_r=1, τ→∞: Eq. (4) reduces to the 1D s-step cost."""
    m, n, zbar = 100_000, 500_000, 100
    cb = sstep_epoch_cost(m, n, zbar, s, b, p, PERLMUTTER)
    big_tau = 10**9
    full = hybrid_epoch_cost(m, n, zbar, HybridConfig(1, p, s, b, big_tau), PERLMUTTER)
    assert math.isclose(cb.compute, full.compute, rel_tol=1e-9)
    assert math.isclose(cb.gram_bw, full.gram_bw, rel_tol=1e-9)
    assert full.sync_bw < cb.total * 1e-6  # vanishes
    assert math.isclose(cb.latency, full.latency, rel_tol=1e-6)


@settings(max_examples=30, deadline=None)
@given(b=st.sampled_from([8, 32, 128]), tau=st.sampled_from([1, 5, 10]), p=st.sampled_from([16, 64, 256]))
def test_fedavg_limit(b, tau, p):
    """p_r=p, p_c=1, s=1: Eq. (4) reduces to the FedAvg cost."""
    m, n, zbar = 100_000, 500_000, 100
    cb = fedavg_epoch_cost(m, n, zbar, b, tau, p, PERLMUTTER)
    full = hybrid_epoch_cost(m, n, zbar, HybridConfig(p, 1, 1, b, tau), PERLMUTTER)
    assert math.isclose(cb.compute, full.compute, rel_tol=0.25)  # 6z̄+2b vs 4z̄+2n/b differ by design
    assert full.gram_bw == 0.0
    assert math.isclose(cb.sync_bw, full.sync_bw, rel_tol=1e-9)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([8, 16, 32, 64]),
    tau=st.sampled_from([5, 10, 20]),
    p_r=st.sampled_from([1, 2, 4]),
    p_c=st.sampled_from([16, 64]),
)
def test_s_star_minimizes(b, tau, p_r, p_c):
    """s* (Eq. 5) must beat every integer s on the Eq. (4) objective
    (evaluated at fixed γ/β as in the derivation)."""
    m, n, zbar = 500_000, 1_000_000, 100
    opt = s_star(b, tau, p_r, p_c, n, PERLMUTTER)
    gamma = PERLMUTTER.gamma_flop(n * PERLMUTTER.word_bytes / p_c)

    def T(s):
        return hybrid_epoch_cost(
            m, n, zbar, HybridConfig(p_r, p_c, s, b, tau), PERLMUTTER, gamma=gamma
        ).total

    t_opt = min(T(max(int(opt), 1)), T(int(opt) + 1))
    for s in (1, 2, 4, 8, 16, 32, 64):
        assert t_opt <= T(s) * 1.02


@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([2, 4, 8]),
    tau=st.sampled_from([5, 10, 20]),
    p_c=st.sampled_from([16, 64]),
)
def test_b_star_minimizes(s, tau, p_c):
    m, n, zbar = 500_000, 1_000_000, 100
    p_r = 4
    opt = b_star(s, tau, p_r, p_c, n, PERLMUTTER)
    gamma = PERLMUTTER.gamma_flop(n * PERLMUTTER.word_bytes / p_c)

    def T(b):
        return hybrid_epoch_cost(
            m, n, zbar, HybridConfig(p_r, p_c, s, b, tau), PERLMUTTER, gamma=gamma
        ).total

    t_opt = min(T(max(int(opt), 1)), T(int(opt) + 1))
    for b in (1, 4, 16, 64, 256, 1024):
        assert t_opt <= T(b) * 1.02


def test_grid_search_returns_valid_config():
    st_ = DATASET_STATS["url"]
    cfg, cb = grid_search_config(st_.m, st_.n, st_.zbar, 4, 64, PERLMUTTER)
    assert cfg.tau >= cfg.s and cfg.tau % cfg.s == 0
    assert cb.total > 0


def test_tpu_machine_topology():
    """On the TPU machine the domain is a 256-chip pod: the rule keeps
    the frequent axis intra-pod."""
    p_r, p_c = topology_rule(512, 3_231_961, TPU_V5E)
    assert p_c <= TPU_V5E.ranks_per_domain
    assert p_r * p_c == 512
