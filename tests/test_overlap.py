"""The delay-D overlap pipeline (DaSGD-style delayed averaging).

Contracts under test:

* D=0 is bitwise-identical to the pre-overlap engine — pinned against
  reference iterates generated on the pre-change tree
  (tests/data/delay0_ref.npz), so no refactor of the round body can
  silently move the synchronous trajectory;
* D ≥ 1 changes the iterates (it is a real staleness knob) but still
  converges, monolithic and chunked execution stay bitwise at any D,
  and the ledger's counted volume is invariant in D (overlap hides
  time, not bytes);
* the ledger's exposed/total/efficiency closed form, the Eq. 4 overlap
  pricing (max(comm, compute) per bundle) + recommend_delay, the
  issue/await span split, spec serialization compatibility, and the
  decaying-τ compensation schedule (One-Shot Averaging).
"""

import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import ExperimentSpec, MeshSpec, plan, run, run_decaying_tau
from repro.api.report import RunReport
from repro.api.session import Session
from repro.core.comm import CommLedger, CommRate
from repro.core.engine import ParallelSGDSchedule, run_parallel_sgd
from repro.core.teams import stack_row_teams
from repro.costmodel.hockney import HybridConfig, hybrid_epoch_cost, recommend_delay
from repro.costmodel.machines import MACHINES
from repro.sparse.synthetic import make_skewed_csr

REF = Path(__file__).parent / "data" / "delay0_ref.npz"


def _ref_problem():
    a = make_skewed_csr(256, 100, 12, 0.8, seed=3)
    rng = np.random.default_rng(0)
    y = np.where(rng.random(256) < 0.5, 1.0, -1.0)
    return a, y


def _hybrid_sched(delay=0):
    return ParallelSGDSchedule.hybrid(
        2, 2, 4, 0.05, 8, rounds=3, loss_every=1, delay=delay
    )


# ---- D=0: bitwise against the pre-overlap engine ----


def test_delay0_hybrid_bitwise_vs_pinned_reference():
    a, y = _ref_problem()
    ref = np.load(REF)
    sched = _hybrid_sched()
    tp = stack_row_teams(a, y, 2, row_multiple=sched.s * sched.b)
    x, losses = run_parallel_sgd(tp, jnp.zeros(100), sched)
    np.testing.assert_array_equal(np.asarray(x), ref["hybrid_x"])
    np.testing.assert_array_equal(np.asarray(losses), ref["hybrid_losses"])


def test_delay0_fedavg_bitwise_vs_pinned_reference():
    a, y = _ref_problem()
    ref = np.load(REF)
    sched = ParallelSGDSchedule.fedavg(4, 4, 0.05, 8, rounds=3, loss_every=1)
    assert sched.delay == 0  # the default stays synchronous
    tp = stack_row_teams(a, y, 4, row_multiple=sched.s * sched.b)
    x, losses = run_parallel_sgd(tp, jnp.zeros(100), sched)
    np.testing.assert_array_equal(np.asarray(x), ref["fedavg_x"])
    np.testing.assert_array_equal(np.asarray(losses), ref["fedavg_losses"])


# ---- D ≥ 1: real staleness, still converges, chunking stays bitwise ----


@pytest.mark.parametrize("delay", [1, 2, 4])
def test_delayed_iterates_differ_but_converge(delay):
    a, y = _ref_problem()
    sched = _hybrid_sched()
    tp = stack_row_teams(a, y, 2, row_multiple=sched.s * sched.b)
    x0, l0 = run_parallel_sgd(tp, jnp.zeros(100), sched)
    xd, ld = run_parallel_sgd(
        tp, jnp.zeros(100), dataclasses.replace(sched, delay=delay)
    )
    assert not np.array_equal(np.asarray(x0), np.asarray(xd))
    # staleness costs a little loss, not convergence: monotone decrease
    # and a final objective within 1% of the synchronous run's.
    ld = np.asarray(ld)
    assert np.all(np.diff(ld) < 0)
    assert ld[-1] < ld[0]
    assert abs(float(ld[-1]) - float(np.asarray(l0)[-1])) < 0.01 * float(ld[-1])


def test_delay_validation():
    with pytest.raises(ValueError, match="delay"):
        _hybrid_sched(delay=-1)
    a, y = _ref_problem()
    sched = _hybrid_sched(delay=5)  # τ/s = 4 bundles per round
    tp = stack_row_teams(a, y, 2, row_multiple=sched.s * sched.b)
    with pytest.raises(ValueError, match="τ/s"):
        run_parallel_sgd(tp, jnp.zeros(100), sched)


@pytest.mark.parametrize("delay", [1, 3])
def test_chunked_session_bitwise_at_delay(delay):
    """Session.step_rounds(1) × rounds == the monolithic engine scan at
    D ≥ 1: the staging buffer drains inside each round, so round
    boundaries stay clean for chunking/checkpointing at any D."""
    spec = ExperimentSpec(
        dataset="rcv1-sm",
        schedule=ParallelSGDSchedule.hybrid(
            2, 2, 4, 0.05, 8, rounds=4, loss_every=0, delay=delay
        ),
        mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"),
    )
    mono = run(spec)
    ses = Session(spec)
    while ses.rounds_done < spec.schedule.rounds:
        ses.step_rounds(1)
    np.testing.assert_array_equal(mono.x, ses.current_x())


# ---- ledger: closed-form volume invariant in D, exposed < total ----


def test_counted_volume_invariant_in_delay():
    """Overlap hides seconds, never bytes: counted words/calls at D > 0
    equal the Table 2–3 closed form — i.e. exactly the D=0 ledger."""
    from repro.core.engine import engine_comm_ledger
    from repro.costmodel import schedule_comm_volume

    n = 100
    for delay in (0, 2):
        sched = dataclasses.replace(_hybrid_sched(), p_c=4, delay=delay)
        led = engine_comm_ledger(sched, n)
        led.add_rounds(3)
        assert led.delay == delay
        cv = schedule_comm_volume(
            n, sched.p_r, sched.p_c, sched.s, sched.b, sched.tau, rounds=3
        )
        assert led.counted_words() == cv.words_dict()
        assert led.counted_calls()["gram_calls"] == cv.gram_calls


def _ledger(delay, gv=4.0, compute=1.5, pa=2.0, rounds=2):
    return CommLedger(
        rates=(CommRate("allreduce", "cols", 4, 272, 4),),
        rounds=rounds,
        phase_seconds={
            "bundle_compute": compute, "allreduce_gv": gv, "param_avg": pa
        },
        delay=delay,
    )


def test_exposed_comm_closed_form():
    # D=0: exposed ≡ total (the PR 8 identity)
    led0 = _ledger(0)
    assert led0.total_comm_s == pytest.approx((4.0 + 2.0) * 2)
    assert led0.exposed_comm_s == led0.total_comm_s
    assert led0.overlap_efficiency == pytest.approx(1.0)
    # D=1: gv loses one bundle-compute of exposure; param_avg stays
    led1 = _ledger(1)
    assert led1.exposed_comm_s == pytest.approx((4.0 - 1.5 + 2.0) * 2)
    assert led1.exposed_comm_s < led1.total_comm_s
    assert led1.overlap_efficiency == pytest.approx((4.0 - 1.5 + 2.0) / 6.0)
    # deep pipeline: gv fully hidden, clamped at zero — only the sync
    # param average remains exposed
    led9 = _ledger(9)
    assert led9.exposed_comm_s == pytest.approx(2.0 * 2)
    # untimed ledger: no phases → all three derived values are None
    bare = CommLedger(delay=1)
    assert bare.total_comm_s is None
    assert bare.exposed_comm_s is None
    assert bare.overlap_efficiency is None


def test_ledger_roundtrip_carries_delay():
    led = _ledger(2)
    d = led.to_dict()
    assert d["delay"] == 2
    assert d["overlap_efficiency"] == pytest.approx(led.overlap_efficiency)
    back = CommLedger.from_dict(json.loads(json.dumps(d)))
    assert back.delay == 2
    assert back.exposed_comm_s == pytest.approx(led.exposed_comm_s)
    # delay-0 ledgers serialize without the key (pre-overlap byte
    # compatibility), and load back as delay 0
    d0 = _ledger(0).to_dict()
    assert "delay" not in d0
    assert CommLedger.from_dict(d0).delay == 0


def test_timed_simulated_run_exposes_overlap():
    """A timed D=1 run on the simulated backend: exposed strictly below
    total, the efficiency ratio surfaced in RunReport.summary(), and
    the report JSON round-trips the split."""
    spec = ExperimentSpec(
        dataset="rcv1-sm",
        schedule=ParallelSGDSchedule.hybrid(
            2, 2, 4, 0.05, 8, rounds=3, loss_every=0, delay=1
        ),
        mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"),
        comm_timing=True,
    )
    rep = run(spec)
    led = rep.ledger
    assert led.delay == 1
    assert led.exposed_comm_s < led.total_comm_s
    assert 0.0 < led.overlap_efficiency < 1.0
    assert "overlap-eff" in rep.summary()
    assert "delay D=1" in rep.summary()
    back = RunReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert back.ledger.overlap_efficiency == pytest.approx(led.overlap_efficiency)


def test_issue_await_span_split_in_trace():
    """Under the obs recorder, a timed D ≥ 1 run splits the allreduce_gv
    probe span into issue (dispatch cost) + await (exposed remainder),
    and their sum never exceeds the unsplit phase."""
    from repro.obs import trace as obs_trace

    spec = ExperimentSpec(
        dataset="rcv1-sm",
        schedule=ParallelSGDSchedule.hybrid(
            2, 2, 4, 0.05, 8, rounds=2, loss_every=0, delay=1
        ),
        mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"),
        comm_timing=True,
    )
    with obs_trace.install() as rec:
        rep = run(spec)
    cats = {s.category for s in rec.spans}
    assert "allreduce_gv_issue" in cats
    assert "allreduce_gv_await" in cats
    assert "allreduce_gv" not in cats  # fully replaced at D ≥ 1
    split = sum(
        s.dur for s in rec.spans
        if s.category in ("allreduce_gv_issue", "allreduce_gv_await")
    )
    assert split <= rep.ledger.phase_seconds["allreduce_gv"] + 1e-9


# ---- cost model: max(comm, compute) pricing + delay recommendation ----


def test_cost_model_overlap_pricing():
    machine = MACHINES["perlmutter-cpu"]
    m, n, zbar = 20_000, 47_000, 50.0
    cfg = HybridConfig(p_r=2, p_c=4, s=2, b=8, tau=8)
    sync = hybrid_epoch_cost(m, n, zbar, cfg, machine)
    assert sync.overlap_saved == 0.0
    over = hybrid_epoch_cost(m, n, zbar, cfg, machine, delay=1)
    assert over.overlap_saved > 0.0
    assert over.total == pytest.approx(sync.total - over.overlap_saved)
    # the decomposed terms keep their synchronous values
    for f in ("compute", "latency", "gram_bw", "sync_bw"):
        assert getattr(over, f) == getattr(sync, f)
    # savings cap: never more than the whole Gram-phase comm, and deep
    # pipelines saturate there
    deep = hybrid_epoch_cost(m, n, zbar, cfg, machine, delay=1000)
    assert deep.overlap_saved <= sync.gram_bw + sync.latency
    assert deep.overlap_saved >= over.overlap_saved
    # p_c = 1: no row-team Allreduce, nothing to hide
    cfg1 = HybridConfig(p_r=8, p_c=1, s=1, b=8, tau=8)
    assert hybrid_epoch_cost(m, n, zbar, cfg1, machine, delay=3).overlap_saved == 0.0


def test_recommend_delay_bounds():
    machine = MACHINES["perlmutter-cpu"]
    m, n, zbar = 20_000, 47_000, 50.0
    cfg = HybridConfig(p_r=2, p_c=4, s=2, b=8, tau=8)
    d = recommend_delay(m, n, zbar, cfg, machine)
    assert 1 <= d <= cfg.tau // cfg.s
    # the recommended D prices at least as well as any shallower one
    totals = [
        hybrid_epoch_cost(m, n, zbar, cfg, machine, delay=k).total
        for k in range(0, d + 1)
    ]
    assert totals[d] == min(totals)
    # p_c = 1 → 0 (stay synchronous-exact)
    assert recommend_delay(m, n, zbar, HybridConfig(8, 1, 1, 8, 8), machine) == 0


def test_plan_surfaces_delay():
    sched = ParallelSGDSchedule.hybrid(2, 2, 8, 0.05, 8, rounds=2, delay=2)
    spec = ExperimentSpec(
        dataset="rcv1-sm", schedule=sched,
        mesh=MeshSpec(p_r=2, p_c=4, backend="simulated"),
    )
    pl = plan(spec)
    assert pl.recommended_delay >= 1
    assert pl.cost.overlap_saved > 0.0
    assert "delay D=2" in pl.summary()
    # synchronous spec on the same mesh: pricing unchanged, but the
    # recommendation still surfaces what overlap would buy
    pl0 = plan(dataclasses.replace(spec, schedule=dataclasses.replace(sched, delay=0)))
    assert pl0.cost.overlap_saved == 0.0
    assert pl0.recommended_delay == pl.recommended_delay


# ---- spec serialization: delay-0 byte compatibility ----


def test_spec_serialization_compat():
    sched = _hybrid_sched()
    spec = ExperimentSpec(
        dataset="rcv1-sm", schedule=sched,
        mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"),
    )
    d = spec.to_dict()
    assert "delay" not in d["schedule"]  # D=0 invisible on the wire
    assert ExperimentSpec.from_dict(d).schedule.delay == 0
    spec1 = dataclasses.replace(
        spec, schedule=dataclasses.replace(sched, delay=1)
    )
    d1 = spec1.to_dict()
    assert d1["schedule"]["delay"] == 1
    assert ExperimentSpec.from_dict(d1).schedule.delay == 1
    # the knob moves the content hash, so D ≥ 1 runs never collide with
    # synchronous resume dirs
    assert spec1.content_hash() != spec.content_hash()


def test_sweep_cli_delay_override():
    from repro.launch.sweep import load_specs

    path = Path(__file__).parent.parent / "examples" / "specs" / "overlap_mesh.json"
    (loaded,) = load_specs(path)
    assert loaded.schedule.delay == 1
    bumped = dataclasses.replace(
        loaded, schedule=dataclasses.replace(loaded.schedule, delay=2)
    )
    assert bumped.schedule.delay == 2  # what `--delay 2` applies


# ---- decaying-τ compensation (One-Shot Averaging) ----


def test_decaying_tau_converges_with_delay():
    """The compensation knob: a delayed run under the decaying-τ
    schedule (sync often early, then progressively less) reaches the
    same neighborhood as the synchronous fixed-τ run."""
    sched = ParallelSGDSchedule.hybrid(
        2, 2, 4, 0.05, 4, rounds=6, loss_every=0, delay=1
    )
    spec = ExperimentSpec(
        dataset="rcv1-sm", schedule=sched,
        mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"),
    )
    reps = run_decaying_tau(spec, stages=3, growth=2)
    assert [r.spec.schedule.tau for r in reps] == [4, 8, 16]
    assert sum(r.spec.schedule.rounds for r in reps) == 6
    sync = run(
        dataclasses.replace(spec, schedule=dataclasses.replace(sched, delay=0))
    )
    assert reps[-1].final_loss < reps[0].final_loss  # still descending
    assert abs(reps[-1].final_loss - sync.final_loss) < 0.01
    with pytest.raises(ValueError, match="stages"):
        run_decaying_tau(spec, stages=0)
    with pytest.raises(ValueError, match="cannot cover"):
        run_decaying_tau(spec, stages=7)
