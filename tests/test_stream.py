"""The streaming data plane: sources, replay, feed, token conformance.

The contract under test is determinism: micro-batch k is a pure
function of (source config, seed, k), so ``micro_batches(start=k)``
replays the identical suffix — what makes resume-mid-stream exact
(tests/test_serve.py drives that through a Session).
"""

import threading

import numpy as np
import pytest

from repro.serve.stream import (
    DriftStream,
    MicroBatch,
    ReplayStream,
    StreamFeed,
    StreamSource,
)
from repro.train.data import MarkovTextStream, TokenMicroBatch, bigram_entropy_floor


def batches_equal(a: MicroBatch, b: MicroBatch) -> bool:
    return (
        a.index == b.index
        and np.array_equal(a.indices, b.indices)
        and np.array_equal(a.values, b.values)
        and np.array_equal(a.y, b.y)
    )


# ---------------- DriftStream ----------------


def test_drift_stream_is_deterministic_and_pure_in_k():
    s1 = DriftStream(n=500, rows=16, width=8, seed=7, drift_at=5)
    s2 = DriftStream(n=500, rows=16, width=8, seed=7, drift_at=5)
    for k in (0, 3, 5, 11):
        assert batches_equal(s1.batch(k), s2.batch(k))
    # drawing batches out of order changes nothing (pure in k)
    b3 = s1.batch(3)
    s1.batch(9), s1.batch(0)
    assert batches_equal(b3, s1.batch(3))


def test_drift_stream_replay_from_k():
    src = DriftStream(n=300, rows=8, width=4, seed=1)
    full = [b for b, _ in zip(src.micro_batches(0), range(10))]
    tail = [b for b, _ in zip(src.micro_batches(6), range(4))]
    for got, want in zip(tail, full[6:]):
        assert batches_equal(got, want)
    assert [b.index for b in full] == list(range(10))


def test_drift_stream_shapes_and_labels():
    src = DriftStream(n=400, rows=12, width=6, seed=2)
    b = src.batch(0)
    assert b.indices.shape == b.values.shape == (12, 6)
    assert b.indices.dtype == np.int32 and b.values.dtype == np.float32
    assert set(np.unique(b.y)) <= {-1.0, 1.0}
    assert b.indices.min() >= 0 and b.indices.max() < 400
    # label folding: ya = diag(y)·values
    assert np.array_equal(b.ya_values(), b.values * b.y[:, None])


def test_drift_flips_the_concept_at_drift_at():
    src = DriftStream(n=500, rows=16, width=8, seed=7, drift_at=5)
    w_pre, w_post = src.truth(4), src.truth(5)
    assert np.array_equal(w_post, -w_pre)  # "flip" mode inverts exactly
    # no drift configured → the concept never moves
    still = DriftStream(n=500, rows=16, width=8, seed=7)
    assert np.array_equal(still.truth(0), still.truth(10_000))


def test_drift_stream_labels_are_learnable():
    """The hidden concept must actually predict the labels (the support
    is frequency-aligned — a uniform support on Zipf-skewed rows leaves
    most rows with zero margin)."""
    src = DriftStream(n=1000, rows=256, width=16, seed=4)
    b = src.batch(0)
    w = src.truth(0)
    margins = np.einsum("rw,rw->r", b.values.astype(np.float64), w[b.indices])
    bayes = np.mean(np.where(margins >= 0, 1.0, -1.0) == b.y)
    assert bayes > 0.6


def test_drift_stream_validates():
    with pytest.raises(ValueError):
        DriftStream(n=0, rows=4)
    with pytest.raises(ValueError):
        DriftStream(n=10, rows=4, drift_mode="teleport")


# ---------------- ReplayStream ----------------


def test_replay_stream_cycles_dataset_rows():
    src = ReplayStream(dataset="rcv1-sm", rows=32, seed=0)
    b0, b1 = src.batch(0), src.batch(1)
    assert b0.rows == b1.rows == 32
    assert not np.array_equal(b0.indices, b1.indices)
    # pure in k + cyclic: batch k repeats after m/rows batches
    assert batches_equal(b0, ReplayStream(dataset="rcv1-sm", rows=32, seed=0).batch(0))
    from repro.sparse.synthetic import dataset_stats

    period = dataset_stats("rcv1-sm").m // 32
    wrapped = src.batch(period)
    assert np.array_equal(wrapped.indices, b0.indices)


def test_sources_conform_to_protocol():
    assert isinstance(DriftStream(n=10, rows=2), StreamSource)
    assert isinstance(ReplayStream(dataset="rcv1-sm", rows=8), StreamSource)
    assert isinstance(MarkovTextStream(vocab_size=50), StreamSource)


# ---------------- StreamFeed ----------------


def test_feed_preserves_order_and_counts():
    src = DriftStream(n=200, rows=8, width=4, seed=9)
    want = [b for b, _ in zip(src.micro_batches(0), range(12))]
    with StreamFeed(src, capacity=3) as feed:
        got = [feed.get() for _ in range(12)]
        assert feed.consumed == 12
        assert feed.produced >= 12
        stats = feed.stats()
    for g, w in zip(got, want):
        assert batches_equal(g, w)
    assert stats["ingest_lag"] == stats["produced"] - stats["consumed"]
    assert stats["queue_depth"] <= 3


def test_feed_starts_mid_stream():
    src = DriftStream(n=200, rows=8, width=4, seed=9)
    with StreamFeed(src, start=7, capacity=2) as feed:
        assert feed.get().index == 7
        assert feed.get().index == 8


def test_feed_backpressure_is_bounded():
    src = DriftStream(n=100, rows=4, width=2, seed=0)
    with StreamFeed(src, capacity=2) as feed:
        # let the producer run without a consumer: it must park at the
        # bound, not buffer unboundedly
        deadline = threading.Event()
        deadline.wait(0.3)
        assert feed.queue_depth <= 2
        assert feed.produced <= 3  # capacity + the one in-flight put


def test_feed_surfaces_producer_errors():
    class Exploding:
        def micro_batches(self, start=0):
            raise RuntimeError("boom at construction")
            yield  # pragma: no cover

    with StreamFeed(Exploding(), capacity=2) as feed:
        with pytest.raises(RuntimeError, match="stream producer failed"):
            feed.get(timeout=2.0)


def test_feed_rejects_bad_capacity():
    with pytest.raises(ValueError):
        StreamFeed(DriftStream(n=10, rows=2), capacity=0)


# ---------------- token stream conformance (satellite) ----------------


def test_markov_stream_micro_batches_replay():
    st = MarkovTextStream(vocab_size=64, seed=5, batch=4, seq_len=8)
    full = [b for b, _ in zip(st.micro_batches(0), range(8))]
    tail = [b for b, _ in zip(st.micro_batches(5), range(3))]
    assert [b.index for b in full] == list(range(8))
    for got, want in zip(tail, full[5:]):
        assert isinstance(got, TokenMicroBatch)
        assert got.index == want.index
        assert np.array_equal(got.tokens, want.tokens)
        assert np.array_equal(got.targets, want.targets)


def test_markov_batches_api_unchanged():
    """The pre-serving-plane iterator contract stays intact (the train
    loop and the LM example consume it)."""
    st = MarkovTextStream(vocab_size=32, seed=1)
    toks, targs = next(st.batches(4, 16))
    assert toks.shape == targs.shape == (4, 16)
    assert np.array_equal(toks[:, 1:], targs[:, :-1])


def test_bigram_entropy_floor_sampling_cap():
    st = MarkovTextStream(vocab_size=128, seed=3)
    sampled = bigram_entropy_floor(st)  # default: 64-state sample
    exact = bigram_entropy_floor(st, sample_states=None)  # all 128 states
    assert sampled == bigram_entropy_floor(st, sample_states=64)
    # every state draws from the same Zipf recipe: the sample estimates
    # the exact mean closely
    assert abs(sampled - exact) < 0.1 * max(exact, 1e-9)
    small = MarkovTextStream(vocab_size=16, seed=3)
    assert bigram_entropy_floor(small) == bigram_entropy_floor(
        small, sample_states=None
    )  # cap beyond vocab = exact
    with pytest.raises(ValueError):
        bigram_entropy_floor(st, sample_states=0)
