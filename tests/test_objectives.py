"""The pluggable convex-objective layer (repro.core.objective).

Cross-objective × cross-backend parity:

O1  Calculus: for every registered objective, residual(z) == -ℓ′(z) by
    jax.grad, and problem_loss matches a dense numpy computation
    (including the L2 term).
O2  Bundle math: inner_corrections (incl. the decay-aware λ > 0
    recurrence) matches a jax.grad-derived sequential-SGD oracle, per
    objective, to fp32 tolerance.
O3  Engine invariances, per objective: gram backend ("pallas" /
    "blocked" / "dense") never changes the trajectory, and chunked
    run_engine_chunk execution is bitwise-identical to the monolithic
    scan.
O4  Front door: ExperimentSpec(objective=..., l2=...) runs end-to-end
    (plan → Session.step_rounds → report) on the simulated engine and
    on the shard_map backend (1×1 mesh — the full dispatch on one real
    device; multi-device parity lives in test_distributed_subprocess),
    and the two agree.
O5  Compatibility: the default logistic spec routes through the same
    path as before (full_loss/sigmoid_residual shims agree bitwise and
    warn); the spec JSON round-trips the new fields.
"""

import dataclasses
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import ExperimentSpec, MeshSpec, Session, build_problem, run
from repro.core import (
    LOGISTIC,
    OBJECTIVES,
    ParallelSGDSchedule,
    get_objective,
    inner_corrections,
    make_problem,
    problem_loss,
    run_engine_chunk,
    run_parallel_sgd,
    stack_row_teams,
)
from repro.kernels.ref import densify_bundle_ref, ell_gram_and_v_ref
from repro.sparse.synthetic import make_skewed_csr

OBJ_POINTS = [
    ("logistic", 0.0), ("logistic", 1e-3),
    ("squared_hinge", 0.0), ("squared_hinge", 1e-3),
    ("least_squares", 0.0), ("least_squares", 1e-3),
]
DATASET = "rcv1-sm"


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    a = make_skewed_csr(256, 128, 12, 0.8, seed=3)
    y = np.where(rng.random(256) < 0.5, 1.0, -1.0)
    return a, y


# ---------------- O1: the objective layer's calculus ----------------


@pytest.mark.parametrize("name", sorted(OBJECTIVES))
def test_residual_is_negative_loss_gradient(name):
    """residual(z) must equal -ℓ′(z) — the engine's update direction is
    defined by the loss, so autodiff is the ground truth."""
    obj = get_objective(name)
    z = jnp.linspace(-6.0, 6.0, 101)
    grad = jax.vmap(jax.grad(lambda t: obj.pointwise_loss(t)))(z)
    np.testing.assert_allclose(
        np.asarray(obj.residual(z)), -np.asarray(grad), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("name,l2", OBJ_POINTS)
def test_problem_loss_matches_dense_numpy(dataset, name, l2):
    a, y = dataset
    prob = make_problem(a, y, row_multiple=64, objective=get_objective(name, l2=l2))
    rng = np.random.default_rng(1)
    x = rng.standard_normal(a.n).astype(np.float32) * 0.1
    margin = (a.to_dense() * y[:, None]).astype(np.float32) @ x
    z = margin.astype(np.float64)
    if name == "logistic":
        pointwise = np.logaddexp(0.0, -z)
    elif name == "squared_hinge":
        pointwise = np.maximum(0.0, 1.0 - z) ** 2
    else:
        pointwise = 0.5 * (1.0 - z) ** 2
    expect = pointwise.mean() + 0.5 * l2 * float(x.astype(np.float64) @ x)
    got = float(problem_loss(prob, jnp.asarray(x)))
    np.testing.assert_allclose(got, expect, rtol=2e-4)


def test_registry_validation():
    with pytest.raises(ValueError, match="registry"):
        get_objective("hinge^3")
    with pytest.raises(ValueError, match="l2"):
        get_objective("logistic", l2=-1.0)
    with pytest.raises(ValueError, match="l2"):
        get_objective(get_objective("logistic", l2=0.1), l2=0.2)
    assert get_objective(LOGISTIC) is LOGISTIC
    assert get_objective("logistic") == LOGISTIC


# ---------------- O2: bundle recurrence vs autodiff oracle ----------------


@pytest.mark.parametrize("name,l2", OBJ_POINTS)
@pytest.mark.parametrize("s", [1, 2, 4])
def test_inner_corrections_match_sequential_autodiff_sgd(name, l2, s):
    """The s-step bundle (Gram + corrections + decay-folded update) is
    an algebraic identity of s sequential SGD steps on the regularized
    objective — checked against jax.grad, which knows nothing about the
    recurrence."""
    obj = get_objective(name, l2=l2)
    rng = np.random.default_rng(11)
    b, n, w = 8, 64, 6
    sb = s * b
    idx = jnp.asarray(rng.integers(0, n, size=(sb, w)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((sb, w)).astype(np.float32))
    x0 = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    eta = 0.1
    dense_y = densify_bundle_ref(idx, val, n)

    def batch_loss(x, j):
        z = jax.lax.dynamic_slice_in_dim(dense_y, j * b, b) @ x
        return jnp.mean(obj.pointwise_loss(z)) + 0.5 * l2 * jnp.sum(x * x)

    x_seq = x0
    for j in range(s):
        x_seq = x_seq - eta * jax.grad(batch_loss)(x_seq, j)

    g, v = ell_gram_and_v_ref(idx, val, x0, n)
    u = inner_corrections(g, v, s, b, jnp.float32(eta), obj)
    rho_s = jnp.float32(1.0 - eta * l2) ** s
    x_bundle = rho_s * x0 + (eta / b) * (dense_y.T @ u)
    np.testing.assert_allclose(
        np.asarray(x_seq), np.asarray(x_bundle), rtol=1e-5, atol=1e-6
    )


# ---------------- O3: engine invariances per objective ----------------


@pytest.mark.parametrize("name,l2", OBJ_POINTS)
def test_gram_backend_invariant_per_objective(dataset, name, l2):
    a, y = dataset
    s, b, tau = 4, 8, 16
    tp = stack_row_teams(a, y, 2, row_multiple=s * b,
                         objective=get_objective(name, l2=l2))
    x0 = jnp.zeros(tp.n)
    base = ParallelSGDSchedule.hybrid(2, s, b, 0.05, tau, rounds=3)
    x_pallas, _ = run_parallel_sgd(tp, x0, base)
    for gram in ("blocked", "dense"):
        x_other, _ = run_parallel_sgd(tp, x0, dataclasses.replace(base, gram=gram))
        np.testing.assert_allclose(
            np.asarray(x_pallas), np.asarray(x_other), rtol=1e-6, atol=1e-7
        )


@pytest.mark.parametrize("name,l2", OBJ_POINTS)
def test_chunked_execution_bitwise_per_objective(dataset, name, l2):
    """run_engine_chunk over offsets 0,1,2,… must reproduce the
    monolithic scan bitwise under every objective (the Session's
    correctness foundation)."""
    a, y = dataset
    s, b = 2, 8
    tp = stack_row_teams(a, y, 2, row_multiple=s * b,
                         objective=get_objective(name, l2=l2))
    sched = ParallelSGDSchedule.hybrid(2, s, b, 0.05, 8, rounds=4)
    x_mono, _ = run_parallel_sgd(tp, jnp.zeros(tp.n), sched)
    x = jnp.zeros(tp.n)
    for r in range(sched.rounds):
        x = run_engine_chunk(tp, x, r, 1, sched)
    np.testing.assert_array_equal(np.asarray(x_mono), np.asarray(x))


# ---------------- O4: front door end-to-end, both backends ----------------


def spec_for(name, l2, backend="simulated"):
    return ExperimentSpec(
        dataset=DATASET,
        schedule=ParallelSGDSchedule.hybrid(1, 2, 8, 0.05, 8, rounds=4, loss_every=2),
        mesh=MeshSpec(p_r=1, p_c=1, backend=backend),
        objective=name,
        l2=l2,
        name=f"{name}-l2={l2}",
    )


@pytest.mark.parametrize("name,l2", OBJ_POINTS)
def test_spec_end_to_end_simulated(name, l2):
    spec = spec_for(name, l2)
    sess = Session(spec)
    events = []
    while not sess.done:
        events.append(sess.step_rounds(1))
    rep = sess.report()
    assert rep.spec.objective == name and rep.spec.l2 == l2
    assert rep.losses.shape == (2,)
    assert np.isfinite(rep.final_loss)
    # the streamed session equals run() bitwise (same chunked engine)
    rep2 = run(spec)
    np.testing.assert_array_equal(rep.x, rep2.x)
    np.testing.assert_array_equal(rep.losses, rep2.losses)
    # and the engine really optimizes this objective
    bundle = build_problem(spec)
    f0 = float(problem_loss(bundle.global_problem, jnp.zeros(bundle.dataset.A.n)))
    assert rep.final_loss < f0


@pytest.mark.parametrize("name,l2", [("squared_hinge", 0.0), ("least_squares", 1e-3)])
def test_spec_backend_parity_1x1(name, l2):
    """Same spec, both executors, 1×1 mesh: the shard_map dispatch path
    (scatter → shard_map rounds → gather, objective threaded through
    Hybrid2DProblem) must agree with the simulated oracle."""
    r_sim = run(spec_for(name, l2, backend="simulated"))
    r_dist = run(spec_for(name, l2, backend="shard_map"))
    np.testing.assert_allclose(r_sim.x, r_dist.x, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(r_sim.losses, r_dist.losses, rtol=1e-5)


def test_make_hybrid_step_rejects_eta_zero(dataset):
    from repro import compat
    from repro.core.distributed import build_2d_problem, make_hybrid_step

    a, y = dataset
    prob, _cp = build_2d_problem(a, y, 1, 1, "cyclic", row_multiple=8)
    mesh = compat.make_mesh((1, 1), ("rows", "cols"))
    with pytest.raises(ValueError, match="eta"):
        make_hybrid_step(mesh, prob, ParallelSGDSchedule(eta=0.0))


# ---------------- O5: compatibility ----------------


def test_default_logistic_spec_unchanged_by_objective_field():
    """A spec that never mentions objectives must execute the identical
    computation as one that names the defaults explicitly (bitwise)."""
    base = ExperimentSpec(
        dataset=DATASET,
        schedule=ParallelSGDSchedule.hybrid(2, 2, 8, 0.05, 8, rounds=4, loss_every=2),
        mesh=MeshSpec(p_r=2),
    )
    explicit = dataclasses.replace(base, objective="logistic", l2=0.0)
    r1, r2 = run(base), run(explicit)
    np.testing.assert_array_equal(r1.x, r2.x)
    np.testing.assert_array_equal(r1.losses, r2.losses)


def test_spec_json_round_trips_objective_and_l2():
    spec = spec_for("squared_hinge", 1e-3)
    restored = ExperimentSpec.from_json(spec.to_json())
    assert restored == spec
    assert restored.objective == "squared_hinge" and restored.l2 == 1e-3
    # old JSON (pre-objective) still loads with the logistic default
    d = spec.to_dict()
    del d["objective"], d["l2"]
    old = ExperimentSpec.from_dict(d)
    assert old.objective == "logistic" and old.l2 == 0.0
    # the content hash keys on the objective (resume dirs never mix)
    assert old.content_hash() != spec.content_hash()


def test_spec_rejects_unknown_objective_and_bad_l2():
    sched = ParallelSGDSchedule.mb_sgd(8, 0.05, 4)
    with pytest.raises(ValueError, match="objective"):
        ExperimentSpec(dataset=DATASET, schedule=sched, objective="hinge^3")
    with pytest.raises(ValueError, match="l2"):
        ExperimentSpec(dataset=DATASET, schedule=sched, l2=-0.5)


def test_deprecated_shims_warn_and_agree(dataset):
    """Satellite: sigmoid_residual / full_loss keep working (one
    release) — same values as the objective layer, plus a
    DeprecationWarning."""
    from repro.core.problem import full_loss, sigmoid_residual

    a, y = dataset
    prob = make_problem(a, y, row_multiple=64)
    z = jnp.linspace(-4.0, 4.0, 17)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(a.n).astype(np.float32))
    with pytest.warns(DeprecationWarning):
        u_old = sigmoid_residual(z)
    np.testing.assert_array_equal(np.asarray(u_old), np.asarray(LOGISTIC.residual(z)))
    with pytest.warns(DeprecationWarning):
        f_old = full_loss(prob, x)
    np.testing.assert_array_equal(np.asarray(f_old), np.asarray(problem_loss(prob, x)))
    # LogisticProblem remains importable as an alias of Problem
    from repro.core.problem import LogisticProblem, Problem

    assert LogisticProblem is Problem
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # the alias itself must not warn
        assert isinstance(prob, LogisticProblem)
