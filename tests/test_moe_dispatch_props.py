"""Hypothesis property tests for the MoE dispatch/capacity logic and
the Mamba chunked scan — host-checkable invariants of the EP path."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.moe_ep import _dispatch_slots
from repro.models.init import padded_experts


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(1, 300),
    n_dst=st.integers(1, 12),
    cap=st.integers(1, 40),
    seed=st.integers(0, 999),
)
def test_dispatch_slots_invariants(n, n_dst, cap, seed):
    rng = np.random.default_rng(seed)
    dst = jnp.asarray(rng.integers(0, n_dst, size=n), jnp.int32)
    slot = np.asarray(_dispatch_slots(dst, n_dst, cap))
    dst = np.asarray(dst)
    # 1. every assigned slot lands in its destination's bucket
    ok = slot >= 0
    assert np.all(slot[ok] // cap == dst[ok])
    # 2. no slot collisions
    assert len(np.unique(slot[ok])) == ok.sum()
    # 3. per-destination assignment = min(count, cap) — capacity tight
    for d in range(n_dst):
        want = min(int((dst == d).sum()), cap)
        got = int(((slot >= 0) & (dst == d)).sum())
        assert got == want, (d, got, want)


@settings(max_examples=30, deadline=None)
@given(e=st.integers(1, 300))
def test_padded_experts(e):
    p = padded_experts(e)
    assert p >= e
    if e >= 16:
        assert p % 16 == 0 and p - e < 16
    else:
        assert p == e


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    n_chunks=st.integers(1, 4),
    chunk=st.sampled_from([4, 8]),
    d=st.sampled_from([4, 8]),
    nstate=st.sampled_from([2, 4]),
    seed=st.integers(0, 99),
)
def test_mamba_chunked_scan_matches_sequential(b, n_chunks, chunk, d, nstate, seed):
    """Chunked associative scan == naive sequential recurrence."""
    from repro.models.blocks import _ssm_scan_chunked

    rng = np.random.default_rng(seed)
    S = n_chunks * chunk
    dt = jnp.asarray(rng.random((b, S, d)).astype(np.float32) * 0.1)
    xi = jnp.asarray(rng.standard_normal((b, S, d)).astype(np.float32))
    Bc = jnp.asarray(rng.standard_normal((b, S, nstate)).astype(np.float32))
    Cc = jnp.asarray(rng.standard_normal((b, S, nstate)).astype(np.float32))
    A = jnp.asarray(-rng.random((d, nstate)).astype(np.float32))
    h0 = jnp.zeros((b, d, nstate), jnp.float32)

    y, h_last = _ssm_scan_chunked(dt, xi, Bc, Cc, A, h0, chunk)

    # naive reference
    h = np.zeros((b, d, nstate))
    ys = np.zeros((b, S, d))
    for t in range(S):
        a_bar = np.exp(np.asarray(dt)[:, t, :, None] * np.asarray(A))
        bx = (np.asarray(dt)[:, t] * np.asarray(xi)[:, t])[..., None] * np.asarray(Bc)[:, t, None, :]
        h = a_bar * h + bx
        ys[:, t] = np.einsum("bdn,bn->bd", h, np.asarray(Cc)[:, t])
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), h, rtol=1e-4, atol=1e-4)
