"""Multi-device shard_map equivalence — runs in a subprocess so that
XLA_FLAGS=--xla_force_host_platform_device_count is set before jax
initializes, without polluting the main test process (which must see
exactly one device)."""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_in_subprocess(body: str, devices: int = 8) -> str:
    code = textwrap.dedent(body)
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": str(REPO / "src"),
        "PATH": "/usr/bin:/bin:/usr/local/bin",
        "JAX_PLATFORMS": "cpu",
    }
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.parametrize("partitioner", ["cyclic", "rows", "nnz"])
@pytest.mark.parametrize("mesh_shape", [(2, 4), (4, 2), (1, 8), (8, 1)])
def test_hybrid_distributed_matches_simulated(partitioner, mesh_shape):
    p_r, p_c = mesh_shape
    out = run_in_subprocess(
        f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro import compat
        from repro.sparse.synthetic import make_skewed_csr
        from repro.core.teams import stack_row_teams
        from repro.core.hybrid import run_hybrid_sgd
        from repro.core.distributed import build_2d_problem, run_hybrid_distributed

        rng = np.random.default_rng(0)
        A = make_skewed_csr(256, 100, 12, 0.8, seed=3)
        y = np.where(rng.random(256) < 0.5, 1.0, -1.0)
        s, b, tau, eta, rounds = 2, 4, 8, 0.05, 3
        p_r, p_c = {p_r}, {p_c}
        mesh = compat.make_mesh((p_r, p_c), ("rows", "cols"))
        tp = stack_row_teams(A, y, p_r, row_multiple=s * b)
        x_sim, _ = run_hybrid_sgd(tp, jnp.zeros(100), s, b, eta, tau, rounds)
        prob, cp = build_2d_problem(A, y, p_r, p_c, "{partitioner}", row_multiple=s * b)
        x_dist = run_hybrid_distributed(mesh, prob, cp, np.zeros(100, np.float32),
                                        s, b, eta, tau, rounds)
        diff = float(np.abs(np.asarray(x_sim) - x_dist).max())
        assert diff < 1e-5, diff
        print("OK", diff)
        """
    )
    assert "OK" in out


def test_distributed_fedavg_corner():
    """p_c=1, s=1 mesh executes FedAvg; cross-check against run_fedavg."""
    out = run_in_subprocess(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro import compat
        from repro.sparse.synthetic import make_skewed_csr
        from repro.core.teams import stack_row_teams
        from repro.core.fedavg import run_fedavg
        from repro.core.distributed import build_2d_problem, run_hybrid_distributed

        rng = np.random.default_rng(0)
        A = make_skewed_csr(256, 100, 12, 0.8, seed=3)
        y = np.where(rng.random(256) < 0.5, 1.0, -1.0)
        b, tau, eta, rounds = 4, 8, 0.05, 3
        mesh = compat.make_mesh((8, 1), ("rows", "cols"))
        tp = stack_row_teams(A, y, 8, row_multiple=b)
        x_f, _ = run_fedavg(tp, jnp.zeros(100), b, eta, tau, rounds)
        prob, cp = build_2d_problem(A, y, 8, 1, "rows", row_multiple=b)
        x_d = run_hybrid_distributed(mesh, prob, cp, np.zeros(100, np.float32),
                                     1, b, eta, tau, rounds)
        diff = float(np.abs(np.asarray(x_f) - x_d).max())
        assert diff < 1e-5, diff
        print("OK", diff)
        """
    )
    assert "OK" in out


@pytest.mark.parametrize("mesh_shape", [(2, 4), (4, 2)])
def test_front_door_backend_parity(mesh_shape):
    """The repro.api acceptance check: run(spec) with backend="simulated"
    and backend="shard_map" agree — same weights, same loss trace — on
    the same spec (only the mesh backend field differs)."""
    p_r, p_c = mesh_shape
    out = run_in_subprocess(
        f"""
        import dataclasses
        import numpy as np
        from repro.api import ExperimentSpec, MeshSpec, run
        from repro.core import ParallelSGDSchedule

        sched = ParallelSGDSchedule.hybrid({p_r}, 2, 4, 0.05, 8, rounds=3, loss_every=1)
        spec = ExperimentSpec(
            dataset="rcv1-sm",
            schedule=sched,
            mesh=MeshSpec(p_r={p_r}, p_c={p_c}, backend="simulated"),
            name="parity",
        )
        r_sim = run(spec)
        r_dist = run(dataclasses.replace(
            spec, mesh=MeshSpec(p_r={p_r}, p_c={p_c}, backend="shard_map")))
        dx = float(np.abs(r_sim.x - r_dist.x).max())
        dl = float(np.abs(r_sim.losses - r_dist.losses).max())
        assert r_sim.losses.shape == (3,), r_sim.losses.shape
        assert dx < 1e-5, dx
        assert dl < 1e-5, dl
        print("OK", dx, dl)
        """
    )
    assert "OK" in out


def test_front_door_objective_parity_multidevice():
    """Cross-objective backend parity on a real 2×2 mesh: the spec's
    objective (+ L2, exercising the decay-aware bundle recurrence under
    column-sharded psum) must produce the same weights and trace on
    both executors."""
    out = run_in_subprocess(
        """
        import dataclasses
        import numpy as np
        from repro.api import ExperimentSpec, MeshSpec, run
        from repro.core import ParallelSGDSchedule

        for obj, l2 in (("squared_hinge", 1e-3), ("least_squares", 0.0)):
            sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=3,
                                               loss_every=1)
            spec = ExperimentSpec(
                dataset="rcv1-sm",
                schedule=sched,
                mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"),
                objective=obj,
                l2=l2,
                name=f"obj-parity-{obj}",
            )
            r_sim = run(spec)
            r_dist = run(dataclasses.replace(
                spec, mesh=MeshSpec(p_r=2, p_c=2, backend="shard_map")))
            dx = float(np.abs(r_sim.x - r_dist.x).max())
            dl = float(np.abs(r_sim.losses - r_dist.losses).max())
            assert dx < 1e-5, (obj, dx)
            assert dl < 1e-5, (obj, dl)
            print("OK", obj, dx, dl)
        """,
        devices=4,
    )
    assert out.count("OK") == 2


def test_session_shard_map_mesh_stream_and_resume(tmp_path):
    """The Session lifecycle on a real 2×4 device mesh: streamed rounds
    match run() bitwise, and a save → restore mid-run (off a loss
    boundary) reproduces the uninterrupted weights and trace."""
    out = run_in_subprocess(
        f"""
        import numpy as np
        from repro.api import ExperimentSpec, MeshSpec, Session, run
        from repro.core import ParallelSGDSchedule

        sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=4, loss_every=2)
        spec = ExperimentSpec(
            dataset="rcv1-sm",
            schedule=sched,
            mesh=MeshSpec(p_r=2, p_c=4, backend="shard_map"),
            name="sess-mesh",
        )
        full = run(spec)

        sess = Session(spec)
        while not sess.done:
            sess.step_rounds(1)
        assert np.array_equal(sess.current_x(), full.x)
        assert np.array_equal(np.asarray(sess.losses, np.float32), full.losses)

        half = Session(spec)
        half.step_rounds(3)  # mid-chunk: not a loss boundary
        half.save(r"{tmp_path}/ck")
        rep = Session.restore(r"{tmp_path}/ck").run()
        assert np.array_equal(rep.x, full.x)
        assert np.array_equal(rep.losses, full.losses)
        assert rep.stop_reason == "rounds"
        print("OK")
        """
    )
    assert "OK" in out


@pytest.mark.parametrize("mesh_shape", [(2, 4), (4, 2)])
def test_comm_ledger_backend_parity(mesh_shape):
    """The comm-plane acceptance identity on real devices: the ledger a
    shard_map Session produces — captured from the round body the mesh
    actually executes — is identical to the simulated Session's ledger
    for the same spec, and both match the Table 2–3 closed form
    (costmodel.schedule_comm_volume) exactly."""
    p_r, p_c = mesh_shape
    out = run_in_subprocess(
        f"""
        import dataclasses
        import numpy as np
        from repro.api import ExperimentSpec, MeshSpec, Session, dataset_stats
        from repro.core import ParallelSGDSchedule
        from repro.costmodel import schedule_comm_volume

        sched = ParallelSGDSchedule.hybrid({p_r}, 2, 4, 0.05, 8, rounds=3, loss_every=1)
        spec = ExperimentSpec(
            dataset="rcv1-sm",
            schedule=sched,
            mesh=MeshSpec(p_r={p_r}, p_c={p_c}, backend="simulated"),
            name="ledger-parity",
        )
        r_sim = Session(spec).run()
        r_dist = Session(dataclasses.replace(
            spec, mesh=MeshSpec(p_r={p_r}, p_c={p_c}, backend="shard_map"))).run()
        assert r_sim.ledger.rates == r_dist.ledger.rates, (
            r_sim.ledger.rates, r_dist.ledger.rates)
        assert r_sim.ledger.rounds == r_dist.ledger.rounds == 3
        counted = r_dist.ledger.counted_words()
        assert counted == r_sim.ledger.counted_words()
        n = dataset_stats("rcv1-sm").n
        cv = schedule_comm_volume(n, {p_r}, {p_c}, 2, 4, 8, rounds=3)
        assert counted == cv.words_dict(), (counted, cv.words_dict())
        assert counted == r_dist.comm_words  # counted == modeled
        print("OK", counted["total_words"])
        """
    )
    assert "OK" in out


@pytest.mark.parametrize("delay", [1, 2])
def test_delayed_pipeline_backend_parity(delay):
    """The DaSGD delay-D pipeline on a real 2×4 mesh: both executors run
    the shared ``delayed_bundle_scan`` (issue at bundle t, consume at
    t+D, drain before the round's parameter average), so the stale
    iterates must agree across backends — and must differ from the
    synchronous D=0 trajectory (the knob is real)."""
    out = run_in_subprocess(
        f"""
        import dataclasses
        import numpy as np
        from repro.api import ExperimentSpec, MeshSpec, run
        from repro.core import ParallelSGDSchedule

        sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=3,
                                           loss_every=1, delay={delay})
        spec = ExperimentSpec(
            dataset="rcv1-sm",
            schedule=sched,
            mesh=MeshSpec(p_r=2, p_c=4, backend="simulated"),
            name="delay-parity",
        )
        r_sim = run(spec)
        r_dist = run(dataclasses.replace(
            spec, mesh=MeshSpec(p_r=2, p_c=4, backend="shard_map")))
        dx = float(np.abs(r_sim.x - r_dist.x).max())
        dl = float(np.abs(r_sim.losses - r_dist.losses).max())
        assert dx < 1e-5, dx
        assert dl < 1e-5, dl
        r_sync = run(dataclasses.replace(
            spec, schedule=dataclasses.replace(sched, delay=0)))
        assert not np.array_equal(r_sync.x, r_dist.x)
        assert r_sim.ledger.delay == r_dist.ledger.delay == {delay}
        assert r_sim.ledger.counted_words() == r_dist.ledger.counted_words()
        print("OK", dx, dl)
        """
    )
    assert "OK" in out


def test_timed_mesh_run_measures_and_calibrates():
    """comm_timing on a real 2×2 mesh: per-round wall seconds land in
    the ledger, the iterates are unchanged, and calibrate() fits from
    the measured report."""
    out = run_in_subprocess(
        """
        import dataclasses
        import numpy as np
        from repro.api import ExperimentSpec, MeshSpec, RunReport, calibrate, plan, run
        from repro.core import ParallelSGDSchedule

        sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=3, loss_every=1)
        spec = ExperimentSpec(
            dataset="rcv1-sm",
            schedule=sched,
            mesh=MeshSpec(p_r=2, p_c=2, backend="shard_map"),
            name="timed-mesh",
        )
        base = run(spec)
        assert base.ledger.round_seconds == []  # untimed: counted only
        timed = run(dataclasses.replace(spec, comm_timing=True))
        assert np.array_equal(timed.x, base.x)
        assert len(timed.ledger.round_seconds) == 3
        assert timed.ledger.seconds_per_round > 0
        # measured report JSON → calibration point → fitted plan
        rehydrated = RunReport.from_json(timed.to_json())
        pt = rehydrated.calibration_point()
        assert pt is not None and pt.bytes_per_round > 0
        cal = calibrate([pt])
        pl = plan(spec, calibration=cal)
        assert pl.calibrated and pl.cost.total > 0
        print("OK", cal.summary())
        """,
        devices=4,
    )
    assert "OK" in out


def test_x64_strict_sstep_identity():
    """With float64 the s-step identity holds to ~1e-12 (paper runs
    FP64 for Gram conditioning)."""
    out = run_in_subprocess(
        """
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np, jax.numpy as jnp
        from repro.sparse.synthetic import make_skewed_csr
        from repro.core.problem import make_problem
        from repro.core.sgd import run_sgd
        from repro.core.sstep import run_sstep_sgd

        rng = np.random.default_rng(0)
        A = make_skewed_csr(256, 128, 12, 0.8, seed=3)
        y = np.where(rng.random(256) < 0.5, 1.0, -1.0)
        prob = make_problem(A, y, row_multiple=64, dtype=jnp.float64)
        x0 = jnp.zeros(128, jnp.float64)
        x_sgd, _ = run_sgd(prob, x0, 8, 0.05, 64)
        x_ss, _ = run_sstep_sgd(prob, x0, 8, 8, 0.05, 64)
        diff = float(jnp.abs(x_sgd - x_ss).max())
        assert diff < 1e-12, diff
        print("OK", diff)
        """,
        devices=1,
    )
    assert "OK" in out


def test_stream_rounds_match_simulated_and_replay_bitwise():
    """Streaming parity + determinism on the real mesh: the same drift
    stream trained through shard_map ``step_stream`` matches the
    simulated oracle, and a second shard_map run is bitwise-identical
    (the streaming door — HybridDriver.advance_stream — is as
    deterministic as the resident path)."""
    out = run_in_subprocess(
        """
        import numpy as np
        from repro.api import ExperimentSpec, MeshSpec, Session, StreamSpec
        from repro.core.engine import ParallelSGDSchedule
        from repro.serve import make_stream_source

        sched = ParallelSGDSchedule.hybrid(
            p_r=2, s=2, b=4, eta=0.2, tau=8, rounds=6, loss_every=3
        )
        base = dict(dataset="rcv1-sm", schedule=sched,
                    stream=StreamSpec(source="drift", seed=3, drift_at=3))
        sim = ExperimentSpec(mesh=MeshSpec(p_r=2, p_c=1, backend="simulated"), **base)
        dist = ExperimentSpec(mesh=MeshSpec(p_r=2, p_c=2, backend="shard_map"), **base)

        a = Session(sim)
        while not a.done:
            a.step_stream(make_stream_source(sim))
        runs = []
        for _ in range(2):
            s = Session(dist)
            while not s.done:
                s.step_stream(make_stream_source(dist))
            runs.append((s.current_x(), list(s.losses)))

        assert np.array_equal(runs[0][0], runs[1][0]), "shard_map stream not deterministic"
        assert runs[0][1] == runs[1][1]
        diff = float(np.abs(a.current_x() - runs[0][0]).max())
        assert diff == 0.0, f"stream parity broke: max |diff|={diff}"
        print("OK", diff)
        """,
        devices=4,
    )
    assert "OK" in out


def test_stream_resume_mid_stream_shard_map_bitwise(tmp_path):
    """Kill-free resume check on the mesh: autosave at round 4, restore
    in the same process, finish — bitwise equal to uninterrupted."""
    out = run_in_subprocess(
        f"""
        import numpy as np
        from pathlib import Path
        from repro.api import (ExperimentSpec, FaultPolicy, MeshSpec, Session,
                               StreamSpec)
        from repro.core.engine import ParallelSGDSchedule
        from repro.serve import make_stream_source

        d = Path({str(tmp_path)!r})
        sched = ParallelSGDSchedule.hybrid(
            p_r=2, s=2, b=4, eta=0.2, tau=8, rounds=8, loss_every=4
        )
        spec = ExperimentSpec(
            dataset="rcv1-sm", schedule=sched,
            mesh=MeshSpec(p_r=2, p_c=2, backend="shard_map"),
            stream=StreamSpec(source="drift", seed=3),
            faults=FaultPolicy(autosave_every=4),
        )
        ref = Session(spec)
        while not ref.done:
            ref.step_stream(make_stream_source(spec))

        interrupted = Session(spec, autosave_dir=d)
        interrupted.step_stream(make_stream_source(spec), 5)
        resumed = Session.restore(interrupted.autosave_path, spec=spec)
        assert resumed.rounds_done == 4, resumed.rounds_done
        while not resumed.done:
            resumed.step_stream(make_stream_source(spec))
        assert np.array_equal(ref.current_x(), resumed.current_x())
        assert ref.losses == resumed.losses
        print("OK")
        """,
        devices=4,
    )
    assert "OK" in out
