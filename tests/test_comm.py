"""The explicit communication plane (repro.core.comm): ledger word
counts at the four schedule corners against the Table 2–3 closed forms
(costmodel.hockney.schedule_comm_volume) across (p_r, p_c, s, τ, b)
grids, cross-backend rate parity (captured without devices), ledger
mechanics and JSON round trips, report/spec back-compat alongside the
PR 4 hash tests, and the §6.5 calibration fit.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.api import (
    CalPoint,
    Calibration,
    ExperimentSpec,
    MeshSpec,
    RunReport,
    Session,
    calibrate,
    modeled_comm_words,
    plan,
    run,
)
from repro.core import ParallelSGDSchedule, engine_comm_ledger, hybrid_comm_ledger
from repro.core.comm import COUNTING, MESH, TIMED, Collectives, CommLedger, CommRate
from repro.core.distributed import build_2d_problem
from repro.costmodel import MACHINES, schedule_comm_volume
from repro.sparse.synthetic import make_skewed_csr

DATASET = "rcv1-sm"


def _assert_ledger_matches_closed_form(sched: ParallelSGDSchedule, n: int):
    led = engine_comm_ledger(sched, n)
    cv = schedule_comm_volume(
        n, sched.p_r, sched.p_c, sched.s, sched.b, sched.tau, rounds=sched.rounds
    )
    assert led.counted_words(rounds=sched.rounds) == cv.words_dict()
    assert led.counted_calls(rounds=sched.rounds) == {
        "gram_calls": cv.gram_calls,
        "sync_calls": cv.sync_calls,
    }
    # the wire payload is bounded below by Table 3's tril information
    assert cv.gram_words_min <= cv.gram_words


# ---------------- the four corners, across knob grids ----------------


@pytest.mark.parametrize("p_c", [1, 2, 4, 8])
@pytest.mark.parametrize("b", [1, 4, 8])
def test_mbsgd_corner_counts(p_c, b):
    """MB-SGD (p_r=1, s=1, τ=1): one (b²+b)-word Gram Allreduce per
    round when columns are sharded; never a sync Allreduce."""
    sched = ParallelSGDSchedule.mb_sgd(b, 0.05, 3, p_c=p_c)
    _assert_ledger_matches_closed_form(sched, n=97)
    led = engine_comm_ledger(sched, 97)
    words = led.counted_words(rounds=3)
    assert words["sync_words"] == 0.0
    assert words["gram_words"] == (3.0 * (b * b + b) if p_c > 1 else 0.0)


@pytest.mark.parametrize("p_c", [1, 2, 8])
@pytest.mark.parametrize("s,b", [(2, 2), (2, 8), (4, 4), (8, 2)])
def test_sstep_corner_counts(p_c, s, b):
    """1D s-step (p_r=1, τ=s): one (s²b²+sb)-word bundle Allreduce per
    round — communication amortized s-fold versus MB-SGD."""
    sched = ParallelSGDSchedule.sstep(s, b, 0.05, 4 * s, p_c=p_c)
    _assert_ledger_matches_closed_form(sched, n=211)
    led = engine_comm_ledger(sched, 211)
    sb = s * b
    expected = float(sched.rounds * (sb * sb + sb)) if p_c > 1 else 0.0
    assert led.counted_words(rounds=sched.rounds)["gram_words"] == expected
    assert led.counted_words(rounds=sched.rounds)["sync_words"] == 0.0


@pytest.mark.parametrize("p_r", [1, 2, 4, 8])
@pytest.mark.parametrize("tau", [1, 4, 8])
def test_fedavg_corner_counts(p_r, tau):
    """FedAvg (s=1, p_c=1): one n-word weight average per round when
    there is more than one team; no Gram traffic ever."""
    sched = ParallelSGDSchedule.fedavg(p_r, 4, 0.05, tau, 3)
    _assert_ledger_matches_closed_form(sched, n=157)
    led = engine_comm_ledger(sched, 157)
    words = led.counted_words(rounds=3)
    assert words["gram_words"] == 0.0
    assert words["sync_words"] == (3.0 * 157 if p_r > 1 else 0.0)


@pytest.mark.parametrize("p_r,p_c", [(1, 1), (2, 1), (1, 4), (2, 2), (4, 2), (2, 8)])
@pytest.mark.parametrize("s,b,tau", [(1, 4, 4), (2, 4, 8), (4, 2, 8)])
def test_hybrid_counts_across_grid(p_r, p_c, s, b, tau):
    """The general 2D point: τ/s Gram Allreduces of (s²b²+sb) words plus
    one ⌈n/p_c⌉-word sync per round, each active only when its mesh
    axis spans more than one rank."""
    sched = ParallelSGDSchedule.hybrid(p_r, s, b, 0.05, tau, rounds=5, p_c=p_c)
    n = 301
    _assert_ledger_matches_closed_form(sched, n)
    led = engine_comm_ledger(sched, n)
    words = led.counted_words(rounds=5)
    sb = s * b
    bundles = 5 * (tau // s)
    assert words["gram_words"] == (float(bundles * (sb * sb + sb)) if p_c > 1 else 0.0)
    assert words["sync_words"] == (float(5 * -(-n // p_c)) if p_r > 1 else 0.0)


def test_modeled_comm_words_is_the_closed_form():
    """The report's modeled volume and the hockney closed form are one
    computation — the refactor must not have moved a single word."""
    spec = ExperimentSpec(
        dataset=DATASET,
        schedule=ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=4),
        mesh=MeshSpec(p_r=2, p_c=4),
    )
    from repro.api.spec import dataset_stats

    n = dataset_stats(DATASET).n
    sched = spec.schedule
    cv = schedule_comm_volume(n, 2, 4, sched.s, sched.b, sched.tau, rounds=4)
    assert modeled_comm_words(spec) == cv.words_dict()
    # and the rounds override scales the round-linear terms
    half = modeled_comm_words(spec, rounds=2)
    assert half["total_words"] == pytest.approx(cv.total_words / 2)


# ---------------- cross-backend rate parity (no devices) ----------------


@pytest.mark.parametrize("p_r,p_c", [(1, 1), (2, 2), (4, 2), (1, 8), (8, 1)])
def test_mesh_and_engine_capture_identical_rates(p_r, p_c):
    """hybrid_comm_ledger traces the real shard_map round body
    abstractly — no device mesh needed — and must record exactly the
    rates the simulated engine records for the same schedule (the
    acceptance identity; the subprocess test re-checks it on real
    devices end to end)."""
    rng = np.random.default_rng(0)
    a = make_skewed_csr(256, 100, 12, 0.8, seed=3)
    y = np.where(rng.random(256) < 0.5, 1.0, -1.0)
    sched = ParallelSGDSchedule.hybrid(p_r, 2, 4, 0.05, 8, rounds=3, p_c=p_c)
    prob, _cp = build_2d_problem(a, y, p_r, p_c, "cyclic", row_multiple=8)
    mesh_led = hybrid_comm_ledger(prob, sched)
    sim_led = engine_comm_ledger(sched, 100)
    assert mesh_led.rates == sim_led.rates
    assert mesh_led.counted_words(rounds=3) == sim_led.counted_words(rounds=3)


def test_s1_corner_counts_full_bundle_payload():
    """At s=1 the simulated body only materializes v, but the mesh body
    psums the full (G, v) — the engine pins its counted payload to the
    same b²+b words so the two ledgers cannot disagree at the corner."""
    rng = np.random.default_rng(0)
    a = make_skewed_csr(64, 40, 6, 0.8, seed=3)
    y = np.where(rng.random(64) < 0.5, 1.0, -1.0)
    sched = ParallelSGDSchedule.hybrid(2, 1, 4, 0.05, 4, rounds=2, p_c=2)
    prob, _cp = build_2d_problem(a, y, 2, 2, "cyclic", row_multiple=4)
    assert hybrid_comm_ledger(prob, sched).rates == engine_comm_ledger(sched, 40).rates


# ---------------- ledger + collectives mechanics ----------------


def test_ledger_accumulation_and_round_trip():
    rate = CommRate(op="allreduce", axis="cols", span=4,
                    words_per_call=72, calls_per_round=4)
    led = CommLedger(rates=(rate,))
    led.add_rounds(3)
    led.add_round_seconds(0.5)
    led.add_round_seconds(0.1)
    led.add_round_seconds(0.2)
    assert led.counted_words() == {
        "gram_words": 3 * 4 * 72.0, "sync_words": 0.0, "total_words": 864.0,
    }
    assert led.counted_calls() == {"gram_calls": 12, "sync_calls": 0}
    assert led.phases_per_round() == 4 * 2 * 2  # 4 calls × 2⌈log₂4⌉
    assert led.bytes_per_round(8) == 8 * 4 * 72.0
    assert led.seconds_per_round == 0.2  # median
    restored = CommLedger.from_dict(json.loads(json.dumps(led.to_dict())))
    assert restored == led
    # snapshot is independent
    snap = led.snapshot()
    led.add_rounds(1)
    assert snap.rounds == 3 and led.rounds == 4


def test_span1_collective_moves_nothing():
    rate = CommRate(op="allmean", axis="rows", span=1,
                    words_per_call=1000, calls_per_round=1)
    led = CommLedger(rates=(rate,), rounds=10)
    assert led.counted_words()["total_words"] == 0.0
    assert led.counted_calls() == {"gram_calls": 0, "sync_calls": 0}
    assert led.phases_per_round() == 0
    assert rate.phases_per_call == 0


def test_collectives_kinds():
    assert COUNTING.kind == "counting" and not COUNTING.on_mesh
    assert MESH.on_mesh and not MESH.timed
    assert TIMED.on_mesh and TIMED.timed
    with pytest.raises(ValueError, match="kind"):
        Collectives("no-such-kind")


# ---------------- session / report threading ----------------


@pytest.fixture(scope="module")
def small_spec():
    return ExperimentSpec(
        dataset=DATASET,
        schedule=ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=3, loss_every=1),
        mesh=MeshSpec(p_r=2, p_c=2),
        name="comm-sess",
    )


@pytest.fixture(scope="module")
def small_report(small_spec):
    return run(small_spec)


def test_report_counted_equals_modeled_on_simulated(small_report):
    """Closing the loop: for the simulated backend the counted volume
    must equal the Table 2–3 model exactly — the model now describes
    collectives the code demonstrably issues."""
    rep = small_report
    assert rep.ledger is not None
    assert rep.ledger.rounds == rep.rounds_completed == 3
    assert rep.ledger.counted_words() == rep.comm_words
    assert "counted" in rep.summary()


def test_round_events_carry_ledger_snapshots(small_spec):
    sess = Session(small_spec)
    ev1 = sess.step_rounds(1)
    ev2 = sess.step_rounds(2)
    assert ev1.ledger.rounds == 1 and ev2.ledger.rounds == 3
    # the event snapshot is frozen at its boundary, not a live view
    assert ev1.ledger.counted_words()["total_words"] == pytest.approx(
        ev2.ledger.counted_words()["total_words"] / 3
    )


def test_report_json_round_trips_ledger(small_report):
    rep2 = RunReport.from_json(small_report.to_json())
    assert rep2.ledger == small_report.ledger


def test_pre_ledger_report_json_loads(small_report):
    """Back-compat: a report persisted before the comm plane existed
    (no comm_ledger key) rehydrates with ledger=None and no counted
    column in its summary."""
    d = small_report.to_dict()
    del d["comm_ledger"]
    rep = RunReport.from_dict(d)
    assert rep.ledger is None
    assert "counted" not in rep.summary()
    assert rep.calibration_point() is None


def test_spec_comm_timing_back_compat(small_spec):
    """Alongside the PR 4 hash tests: comm_timing is emitted only when
    on, so old spec JSON loads with the default and default specs keep
    their content hash (checkpoints/resume dirs stay valid)."""
    d = small_spec.to_dict()
    assert "comm_timing" not in d
    restored = ExperimentSpec.from_dict(d)
    assert restored == small_spec and not restored.comm_timing
    assert restored.content_hash() == small_spec.content_hash()
    timed = dataclasses.replace(small_spec, comm_timing=True)
    assert timed.to_dict()["comm_timing"] is True
    assert ExperimentSpec.from_json(timed.to_json()) == timed
    assert timed.content_hash() != small_spec.content_hash()


def test_timed_simulated_run_measures_without_changing_iterates(small_spec, small_report):
    rep = run(dataclasses.replace(small_spec, comm_timing=True))
    np.testing.assert_array_equal(rep.x, small_report.x)
    np.testing.assert_array_equal(rep.losses, small_report.losses)
    assert len(rep.ledger.round_seconds) == 3
    assert rep.ledger.seconds_per_round > 0
    pt = rep.calibration_point()
    assert pt is not None and pt.seconds_per_round > 0
    assert pt.phases_per_round == rep.ledger.phases_per_round()


# ---------------- calibration ----------------


def test_calibrate_recovers_planted_constants():
    """Synthesize per-round times from known (α, β, γ) over a spread of
    operating points; the least-squares fit must recover them."""
    alpha, beta, gamma = 3e-6, 2e-9, 5e-11
    rng = np.random.default_rng(7)
    points = []
    for _ in range(12):
        phases = float(rng.integers(2, 40))
        byts = float(rng.integers(1_000, 1_000_000))
        flops = float(rng.integers(10_000, 10_000_000))
        t = alpha * phases + beta * byts + gamma * flops
        points.append(CalPoint(phases, byts, flops, t))
    cal = calibrate(points)
    assert cal.alpha == pytest.approx(alpha, rel=1e-6)
    assert cal.beta == pytest.approx(beta, rel=1e-6)
    assert cal.gamma == pytest.approx(gamma, rel=1e-6)
    assert cal.rel_rms == pytest.approx(0.0, abs=1e-9)
    assert cal.points == 12
    # round trip
    assert Calibration.from_dict(cal.to_dict()) == cal


def test_calibration_machine_retarget():
    cal = Calibration(alpha=1e-5, beta=4e-9, gamma=2e-11, rel_rms=0.0, points=3)
    base = MACHINES["perlmutter-cpu"]
    fitted = cal.machine(base)
    assert fitted.name == "perlmutter-cpu+calibrated"
    for q in (2, 64, 4096):
        assert fitted.alpha(q) == pytest.approx(1e-5)
        assert fitted.beta(q) == pytest.approx(4e-9)
    # γ is stored as s/B tiers; the fitted s/flop must survive the trip
    assert fitted.gamma_flop(1 << 30) == pytest.approx(2e-11)
    # unidentified terms keep the preset tables
    partial = Calibration(alpha=0.0, beta=4e-9, gamma=0.0, rel_rms=0.0, points=1)
    kept = partial.machine(base)
    assert kept.alpha(64) == base.alpha(64)
    assert kept.gamma_tiers == base.gamma_tiers


def test_calibrate_ignores_dead_columns_and_clamps():
    # no comm columns at all → only γ fits, α/β stay 0
    pts = [CalPoint(0.0, 0.0, f, 1e-9 * f) for f in (1e6, 2e6, 5e6)]
    cal = calibrate(pts)
    assert cal.alpha == 0.0 and cal.beta == 0.0
    assert cal.gamma == pytest.approx(1e-9)
    with pytest.raises(ValueError, match="at least one"):
        calibrate([])
    with pytest.raises(ValueError, match="seconds_per_round"):
        CalPoint(1.0, 1.0, 1.0, 0.0)


def test_plan_with_calibration_reranks(small_spec):
    """plan(spec, calibration=...) must predict with the fitted machine
    — a bandwidth-free calibration collapses the comm terms and can
    invert a preset ranking."""
    base = plan(small_spec)
    assert not base.calibrated
    cal = Calibration(alpha=0.0, beta=1e-3, gamma=0.0, rel_rms=0.0, points=2)
    pl = plan(small_spec, calibration=cal)
    assert pl.calibrated and "+calibrated" in pl.summary()
    # β inflated 6 orders of magnitude → bandwidth must now dominate
    assert pl.cost.total > base.cost.total
    assert pl.cost.gram_bw + pl.cost.sync_bw > base.cost.gram_bw + base.cost.sync_bw
