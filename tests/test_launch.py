"""Launch-layer units: input shapes, applicability, roofline parser."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.launch.input_specs import SHAPES, resolve_config, shape_applicable
from repro.launch.roofline import (
    RooflineTerms,
    extrapolate_depth,
    model_flops_per_step,
    parse_collectives,
    _shape_bytes,
)


def test_shapes_registry():
    assert SHAPES["train_4k"].seq_len == 4_096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32_768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32_768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524_288 and SHAPES["long_500k"].global_batch == 1


def test_long500k_applicability():
    runs = {
        a: shape_applicable(resolve_config(a, SHAPES["long_500k"]), SHAPES["long_500k"])[0]
        for a in REGISTRY
    }
    assert runs["falcon-mamba-7b"] and runs["jamba-1.5-large-398b"] and runs["mistral-nemo-12b"]
    for a in ("gemma-2b", "granite-34b", "qwen2.5-3b", "musicgen-medium",
              "llava-next-mistral-7b", "deepseek-v2-lite-16b", "granite-moe-3b-a800m"):
        assert not runs[a], a


def test_mistral_nemo_swa_overlay():
    cfg = resolve_config("mistral-nemo-12b", SHAPES["long_500k"])
    assert cfg.sliding_window == 4096
    assert all(s.attn == "swa" for s in cfg.period)
    # other shapes stay full attention
    cfg2 = resolve_config("mistral-nemo-12b", SHAPES["train_4k"])
    assert all(s.attn == "full" for s in cfg2.period)


def test_shape_bytes_parser():
    assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4,4]{1,0}, bf16[2,2]{1,0})") == 64 + 8
    assert _shape_bytes("pred[10]") == 10


def test_parse_collectives_counts_and_bytes():
    hlo = """
HloModule test
ENTRY %main (x: f32[64]) -> f32[64] {
  %x = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%x), replica_groups={}
  %ag = f32[128]{0} all-gather(%ar), dimensions={0}
  %a2a = f32[64]{0} all-to-all(%ag), dimensions={0}
  ROOT %out = f32[64]{0} add(%a2a, %ar)
}
"""
    stats = parse_collectives(hlo)
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.bytes_by_kind["all-reduce"] == 64 * 4
    assert stats.bytes_by_kind["all-gather"] == 128 * 4
    assert stats.bytes_by_kind["all-to-all"] == 64 * 4
    assert not stats.in_while_body


def test_extrapolate_depth_linear():
    # cost(P) = 10 + 5P measured at P=1, 2 → exact at any P
    assert extrapolate_depth(15.0, 20.0, 9) == pytest.approx(10 + 5 * 9)
    # non-increasing guard
    assert extrapolate_depth(10.0, 10.0, 5) == pytest.approx(10.0)


def test_roofline_terms_math():
    t = RooflineTerms(
        arch="x", shape="train_4k", mesh="m",
        flops=197e12, hbm_bytes=819e9, collective_bytes=50e9,
        collective_breakdown={}, model_flops=98.5e12,
    )
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    assert t.useful_flops_ratio == pytest.approx(0.5)


def test_model_flops_train_vs_decode():
    from repro.configs import get_config

    cfg = get_config("gemma-2b")
    tr = model_flops_per_step(cfg, SHAPES["train_4k"], "train")
    de = model_flops_per_step(cfg, SHAPES["decode_32k"], "decode")
    # train: 6·N·(B·S) vs decode: 2·N·B → ratio 3·S·(256/128)
    assert tr / de == pytest.approx(3 * 4096 * 256 / 128, rel=1e-6)


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_param_pspecs_cover_tree(arch):
    """Every param leaf gets a PartitionSpec of matching rank on the
    production mesh (constructed abstractly — no devices needed)."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.input_specs import params_shape
    from repro.models.init import param_pspecs

    cfg = resolve_config(arch, SHAPES["train_4k"])
    pshape = params_shape(cfg)
    from repro.compat import abstract_mesh

    mesh = abstract_mesh((16, 16), ("data", "model"))
    specs = param_pspecs(cfg, pshape, mesh)
    flat_p = jax.tree.leaves(pshape)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape)
        # divisibility honored
        sizes = {"data": 16, "model": 16}
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= sizes[a]
            assert dim % total == 0, (arch, leaf.shape, spec)
