"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each assigned architecture runs one forward + train-grad step and one
cached decode step on CPU; output shapes and finiteness are asserted.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config, reduced, with_sliding_window
from repro.models.init import init_params
from repro.models.transformer import decode_step, forward, init_cache, lm_loss

ARCHS = sorted(REGISTRY)
B, S = 2, 32


def _tokens(cfg, key):
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


def _prefix(cfg, key):
    if cfg.frontend == "vision":
        return jax.random.normal(key, (B, 8, cfg.d_model), jnp.float32)
    return None


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, rng, dtype=jnp.float32)
    tokens = _tokens(cfg, jax.random.fold_in(rng, 1))
    prefix = _prefix(cfg, jax.random.fold_in(rng, 2))
    logits = jax.jit(lambda p, t, e: forward(cfg, p, t, e))(params, tokens, prefix)
    s_total = S + (prefix.shape[1] if prefix is not None else 0)
    assert logits.shape == (B, s_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch, rng):
    """One SGD step: loss + grads all finite, loss decreases over a few
    steps on a repeated batch (sanity that gradients point downhill)."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, rng, dtype=jnp.float32)
    tokens = _tokens(cfg, jax.random.fold_in(rng, 3))
    targets = jnp.roll(tokens, -1, axis=1)
    prefix = _prefix(cfg, jax.random.fold_in(rng, 4))

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: lm_loss(cfg, q, tokens, targets, prefix_emb=prefix))(p)
        p = jax.tree.map(lambda w, gw: w - 0.05 * gw, p, g)
        return p, loss

    losses = []
    for _ in range(4):
        params, loss = step(params)
        assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease: {losses}"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, rng):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, rng, dtype=jnp.float32)
    cache = init_cache(cfg, batch=B, max_len=64, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite decode logits"
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "jamba-1.5-large-398b"])
def test_decode_matches_forward_ssm(arch, rng):
    """Recurrent decode must agree with the full-sequence scan — the
    SSM/hybrid correctness property behind long_500k."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, rng, dtype=jnp.float32)
    tokens = _tokens(cfg, jax.random.fold_in(rng, 5))[:, :8]
    full_logits = forward(cfg, params, tokens)
    cache = init_cache(cfg, batch=B, max_len=16, dtype=jnp.float32)
    outs = []
    for i in range(8):
        logits, cache = decode_step(cfg, params, cache, tokens[:, i : i + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_swa_variant_decode():
    """Sliding-window overlay: ring-buffer decode agrees with full-seq
    SWA attention inside the window."""
    cfg = reduced(with_sliding_window(get_config("mistral-nemo-12b"), 4096))
    assert cfg.sliding_window == 64
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    full_logits = forward(cfg, params, tokens)
    cache = init_cache(cfg, batch=B, max_len=16, dtype=jnp.float32)
    outs = []
    for i in range(8):
        logits, cache = decode_step(cfg, params, cache, tokens[:, i : i + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_attention():
    """GQA cached decode == full forward (gemma: MQA + GeGLU + tied)."""
    cfg = reduced(get_config("gemma-2b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    full_logits = forward(cfg, params, tokens)
    cache = init_cache(cfg, batch=B, max_len=16, dtype=jnp.float32)
    outs = []
    for i in range(8):
        logits, cache = decode_step(cfg, params, cache, tokens[:, i : i + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_mla_decode_matches_forward():
    cfg = reduced(get_config("deepseek-v2-lite-16b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    full_logits = forward(cfg, params, tokens)
    cache = init_cache(cfg, batch=B, max_len=16, dtype=jnp.float32)
    outs = []
    for i in range(8):
        logits, cache = decode_step(cfg, params, cache, tokens[:, i : i + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_param_counts_sane():
    """Full configs land near their nameplate sizes."""
    expect = {
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "granite-34b": (30e9, 50e9),
        "jamba-1.5-large-398b": (350e9, 450e9),
        "falcon-mamba-7b": (6e9, 9e9),
        "mistral-nemo-12b": (11e9, 14e9),
        "gemma-2b": (2e9, 3.2e9),
    }
    for name, (lo, hi) in expect.items():
        pc = get_config(name).param_count()
        assert lo <= pc <= hi, f"{name}: {pc / 1e9:.1f}B outside [{lo / 1e9}, {hi / 1e9}]"


def test_chunked_attention_matches_dense():
    """Query-chunked (flash-style) attention == dense-mask attention."""
    import repro.models.blocks as bl

    cfg = reduced(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    layer = jax.tree.map(lambda a: a[0], params["layers"][0])
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model), jnp.float32)
    spec = cfg.period[0]
    dense = bl.attn_train(layer, cfg, spec, x)
    old_thr, old_chunk = bl.CHUNKED_ATTN_THRESHOLD, bl.ATTN_Q_CHUNK
    try:
        bl.CHUNKED_ATTN_THRESHOLD, bl.ATTN_Q_CHUNK = 32, 16
        chunked = bl.attn_train(layer, cfg, spec, x)
    finally:
        bl.CHUNKED_ATTN_THRESHOLD, bl.ATTN_Q_CHUNK = old_thr, old_chunk
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=2e-3, atol=2e-3)


def test_chunked_swa_matches_dense():
    import repro.models.blocks as bl

    cfg = reduced(with_sliding_window(get_config("mistral-nemo-12b"), 4096))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    layer = jax.tree.map(lambda a: a[0], params["layers"][0])
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 128, cfg.d_model), jnp.float32)
    spec = cfg.period[0]
    dense = bl.attn_train(layer, cfg, spec, x)
    old_thr, old_chunk = bl.CHUNKED_ATTN_THRESHOLD, bl.ATTN_Q_CHUNK
    try:
        bl.CHUNKED_ATTN_THRESHOLD, bl.ATTN_Q_CHUNK = 64, 32
        chunked = bl.attn_train(layer, cfg, spec, x)
    finally:
        bl.CHUNKED_ATTN_THRESHOLD, bl.ATTN_Q_CHUNK = old_thr, old_chunk
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=2e-3, atol=2e-3)


def test_chunked_mla_matches_dense():
    import repro.models.blocks as bl

    cfg = reduced(get_config("deepseek-v2-lite-16b"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    layer = jax.tree.map(lambda a: a[0], params["layers"][0])
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, cfg.d_model), jnp.float32)
    spec = cfg.period[0]
    dense = bl.mla_train(layer, cfg, spec, x)
    old_thr, old_chunk = bl.CHUNKED_ATTN_THRESHOLD, bl.ATTN_Q_CHUNK
    try:
        bl.CHUNKED_ATTN_THRESHOLD, bl.ATTN_Q_CHUNK = 32, 16
        chunked = bl.mla_train(layer, cfg, spec, x)
    finally:
        bl.CHUNKED_ATTN_THRESHOLD, bl.ATTN_Q_CHUNK = old_thr, old_chunk
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=2e-3, atol=2e-3)
