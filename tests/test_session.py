"""The round-streaming Session lifecycle (repro.api.session).

The acceptance bar: chunked session execution is *bitwise* identical to
the monolithic single-scan engine path — weights and loss trace — and a
save → restore mid-run reproduces the uninterrupted trace exactly.
shard_map-backend parity on a real multi-device mesh lives in
tests/test_distributed_subprocess.py; here the 1×1 mesh covers the full
shard_map session dispatch on the single real device.
"""

import dataclasses
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import (
    ExperimentSpec,
    MeshSpec,
    RunReport,
    Session,
    StopPolicy,
    build_problem,
    run,
    sweep,
)
from repro.core import ParallelSGDSchedule, run_parallel_sgd
from repro.train.checkpoint import SpecMismatchError, load_session_checkpoint

DATASET = "rcv1-sm"


def hybrid_spec(**kw) -> ExperimentSpec:
    sched = kw.pop("schedule", None) or ParallelSGDSchedule.hybrid(
        2, 2, 8, 0.05, 8, rounds=6, loss_every=2
    )
    mesh = kw.pop("mesh", None) or MeshSpec(p_r=2, p_c=2)
    return ExperimentSpec(dataset=DATASET, schedule=sched, mesh=mesh, **kw)


# ---------------- parity: chunked session ≡ monolithic scan ----------------


def test_session_bitwise_matches_single_scan_engine():
    """The acceptance criterion: run() (now a chunked session loop)
    produces bitwise-identical weights and loss trace to the
    pre-redesign single-scan engine path."""
    spec = hybrid_spec()
    rep = run(spec)
    bundle = build_problem(spec)
    x_mono, losses_mono = run_parallel_sgd(
        bundle.team, jnp.zeros(bundle.dataset.A.n), spec.schedule
    )
    np.testing.assert_array_equal(rep.x, np.asarray(x_mono))
    np.testing.assert_array_equal(rep.losses, np.asarray(losses_mono))


def test_session_single_round_steps_bitwise():
    """Chunk size never changes the iterates: stepping 1 round at a
    time equals the monolithic scan bitwise, and the loss trace is
    sampled at exactly the loss_every boundaries."""
    spec = hybrid_spec()
    bundle = build_problem(spec)
    x_mono, losses_mono = run_parallel_sgd(
        bundle.team, jnp.zeros(bundle.dataset.A.n), spec.schedule
    )
    sess = Session(spec)
    events = []
    while not sess.done:
        events.append(sess.step_rounds(1))
    np.testing.assert_array_equal(events[-1].x, np.asarray(x_mono))
    np.testing.assert_array_equal(
        np.asarray(sess.losses, np.float32), np.asarray(losses_mono)
    )
    # loss samples appear exactly on loss_every boundaries
    assert [e.loss is not None for e in events] == [
        (i + 1) % spec.schedule.loss_every == 0 for i in range(len(events))
    ]
    assert events[-1].stop == "rounds"


def test_session_odd_chunk_spanning_boundaries():
    """A single step_rounds(k) spanning several loss boundaries still
    samples every boundary (the advance is split internally)."""
    spec = hybrid_spec()
    sess = Session(spec)
    ev = sess.step_rounds(5)  # crosses boundaries at rounds 2 and 4
    assert ev.rounds_done == 5
    assert len(sess.losses) == 2
    ev = sess.step_rounds(1)
    assert len(sess.losses) == 3 and ev.loss is not None
    rep_full = run(spec)
    np.testing.assert_array_equal(ev.x, rep_full.x)
    np.testing.assert_array_equal(
        np.asarray(sess.losses, np.float32), rep_full.losses
    )


def test_session_shard_map_1x1_resume_bitwise(tmp_path):
    """Full shard_map session dispatch on the single real device:
    save → restore mid-run reproduces the uninterrupted run bitwise."""
    sched = ParallelSGDSchedule.hybrid(1, 2, 8, 0.05, 8, rounds=4, loss_every=2)
    spec = hybrid_spec(schedule=sched,
                       mesh=MeshSpec(p_r=1, p_c=1, backend="shard_map"))
    full = run(spec)
    sess = Session(spec)
    sess.step_rounds(3)  # not a loss boundary — restore mid-chunk
    sess.save(tmp_path / "ck")
    rep = Session.restore(tmp_path / "ck").run()
    np.testing.assert_array_equal(rep.x, full.x)
    np.testing.assert_array_equal(rep.losses, full.losses)


# ---------------- checkpoint / resume ----------------


def test_session_save_restore_midrun_bitwise(tmp_path):
    spec = hybrid_spec()
    full = run(spec)
    sess = Session(spec)
    sess.step_rounds(3)
    sess.save(tmp_path / "ck")
    resumed = Session.restore(tmp_path / "ck")
    assert resumed.rounds_done == 3
    rep = resumed.run()
    np.testing.assert_array_equal(rep.x, full.x)
    np.testing.assert_array_equal(rep.losses, full.losses)
    assert rep.rounds_completed == spec.schedule.rounds


def test_session_restore_under_different_spec_is_hard_error(tmp_path):
    spec = hybrid_spec()
    sess = Session(spec)
    sess.step_rounds(2)
    sess.save(tmp_path / "ck")
    for other in (
        dataclasses.replace(spec, seed=1),
        dataclasses.replace(spec, name="renamed"),
        dataclasses.replace(
            spec, schedule=dataclasses.replace(spec.schedule, eta=0.1)
        ),
    ):
        with pytest.raises(SpecMismatchError):
            Session.restore(tmp_path / "ck", spec=other)
    # the identical spec restores fine
    assert Session.restore(tmp_path / "ck", spec=spec).rounds_done == 2


def test_session_checkpoint_is_spec_hash_keyed(tmp_path):
    spec = hybrid_spec()
    sess = Session(spec)
    sess.step_rounds(2)
    sess.save(tmp_path / "ck")
    ck = load_session_checkpoint(tmp_path / "ck")
    assert ck.spec_hash == spec.content_hash()
    assert ck.rounds_done == 2
    with pytest.raises(SpecMismatchError):
        load_session_checkpoint(tmp_path / "ck", expect_spec_hash="0" * 16)
    with pytest.raises(FileNotFoundError):
        load_session_checkpoint(tmp_path / "absent")


# ---------------- StopPolicy ----------------


def test_stop_target_loss_ends_early():
    probe = run(hybrid_spec())
    target = float(probe.losses[0])  # reachable at the first sample
    rep = run(hybrid_spec(stop=StopPolicy(target_loss=target)))
    assert rep.stop_reason == "target_loss"
    assert rep.rounds_completed == rep.spec.schedule.loss_every
    assert rep.losses[-1] <= target
    # wall time is the measured time to the crossing, and it splits
    assert rep.wall_time_s == pytest.approx(
        rep.compile_time_s + rep.solve_time_s, abs=1e-9
    )


def test_stop_target_hit_on_final_round_is_still_a_hit():
    """A crossing on the last budgeted round is a target_loss stop, not
    a 'rounds' budget exhaustion — the hit/miss verdict the benchmarks
    persist must not depend on where in the budget the crossing lands."""
    probe = run(hybrid_spec())
    target = float(probe.losses[-1])  # only the terminal sample crosses
    rep = run(hybrid_spec(stop=StopPolicy(target_loss=target)))
    assert rep.stop_reason == "target_loss"
    assert rep.rounds_completed == rep.spec.schedule.rounds


def test_step_spanning_boundaries_stops_at_intermediate_crossing():
    """The StopPolicy is evaluated at every loss boundary inside one
    step_rounds call — a target crossed mid-step ends the step there."""
    probe = run(hybrid_spec())
    target = float(probe.losses[0])
    sess = Session(hybrid_spec(stop=StopPolicy(target_loss=target)))
    ev = sess.step_rounds(6)  # spans boundaries at rounds 2, 4, 6
    assert ev.stop == "target_loss"
    assert ev.rounds_done == sess.spec.schedule.loss_every  # stopped at 2
    assert sess.done


def test_stop_max_rounds_is_exact():
    rep = run(hybrid_spec(stop=StopPolicy(max_rounds=3)))
    assert rep.stop_reason == "max_rounds"
    assert rep.rounds_completed == 3
    # the trace only holds boundaries actually crossed
    assert len(rep.losses) == 1


def test_stop_max_seconds_stops_after_chunk():
    rep = run(hybrid_spec(stop=StopPolicy(max_seconds=0.0)))
    assert rep.stop_reason == "max_seconds"
    # the running chunk finishes; nothing after it starts
    assert rep.rounds_completed == rep.spec.schedule.loss_every


def test_stopped_session_refuses_further_steps():
    sess = Session(hybrid_spec(stop=StopPolicy(max_rounds=2)))
    sess.step_rounds(2)
    assert sess.done and sess.stop_reason == "max_rounds"
    with pytest.raises(RuntimeError, match="finished"):
        sess.step_rounds(1)


# ---------------- sweep + resume ----------------


def test_sweep_resume_skips_finished_points(tmp_path):
    specs = [hybrid_spec(name=f"pt{i}", seed=i) for i in range(2)]
    first = sweep(specs, resume_dir=tmp_path, max_points=1)
    assert first.resumed == [False] and len(first.skipped) == 1
    second = sweep(specs, resume_dir=tmp_path)
    assert second.resumed == [True, False] and not second.skipped
    # the rehydrated report carries the first run's measurements
    assert second.reports[0].wall_time_s == first.reports[0].wall_time_s
    np.testing.assert_array_equal(second.reports[0].losses, first.reports[0].losses)
    # a third invocation re-runs nothing
    third = sweep(specs, resume_dir=tmp_path)
    assert third.resumed == [True, True]
    table = third.time_to_loss_table(target=1.0)
    assert "pt0" in table and "pt1" in table


def test_sweep_without_resume_dir_runs_everything():
    specs = [hybrid_spec(name=f"pt{i}") for i in range(2)]
    result = sweep(specs)
    assert result.resumed == [False, False]
    json.dumps(result.to_dict())  # persistable


# ---------------- report round-trip + dataset cache aliasing ----------------


def test_report_json_round_trip():
    rep = run(hybrid_spec(stop=StopPolicy(max_rounds=4), name="rt"))
    back = RunReport.from_json(rep.to_json())
    assert back.spec == rep.spec
    assert back.x is None  # weights live in checkpoints, not reports
    assert back.final_loss == rep.final_loss
    assert back.wall_time_s == rep.wall_time_s
    assert back.compile_time_s == rep.compile_time_s
    assert back.solve_time_s == rep.solve_time_s
    assert back.rounds_completed == rep.rounds_completed == 4
    assert back.stop_reason == rep.stop_reason == "max_rounds"
    np.testing.assert_array_equal(back.losses, rep.losses)


def test_cached_dataset_is_read_only_and_unmutated():
    """Satellite regression: the memoized dataset must be immune to
    in-place writes — a second run() on the same (name, seed) sees
    pristine data."""
    from repro.api.run import _cached_dataset

    spec = hybrid_spec(name="aliasing")
    first = run(spec)
    ds = _cached_dataset(spec.dataset, seed=spec.seed)
    for arr in (ds.A.indptr, ds.A.indices, ds.A.data, ds.y, ds.x_true):
        assert not arr.flags.writeable
        with pytest.raises(ValueError):
            arr[...] = 0
    second = run(spec)
    np.testing.assert_array_equal(first.x, second.x)
    np.testing.assert_array_equal(first.losses, second.losses)
