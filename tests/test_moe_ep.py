"""Expert-parallel MoE (all_to_all path) vs the local oracle.

Runs in subprocesses with multiple fake devices (see
test_distributed_subprocess.py for the pattern)."""

from tests.test_distributed_subprocess import run_in_subprocess


def test_moe_ep_matches_local():
    """EP path (experts sharded over model, all_to_all) == local path,
    at generous capacity so nothing drops."""
    out = run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config, reduced
        from repro.models.init import init_params
        from repro.models import blocks
        from repro.models.moe_ep import moe_ep

        cfg = reduced(get_config("deepseek-v2-lite-16b"))  # 4 experts
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        layer = jax.tree.map(lambda a: a[0], params["layers"][0])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)

        y_local = blocks.moe(layer, cfg, x)  # no mesh -> local path

        mesh = compat.make_mesh((2, 4), ("data", "model"))
        with compat.set_mesh(mesh):
            y_ep = jax.jit(lambda l, x: moe_ep(cfg, l, x, cf=8.0))(layer, x)
        diff = float(jnp.abs(y_ep - y_local).max())
        scale = float(jnp.abs(y_local).max())
        assert diff < 1e-4 * max(scale, 1), (diff, scale)
        print("OK", diff)
        """,
        devices=8,
    )
    assert "OK" in out


def test_moe_ep_fallback_nondivisible_experts():
    """granite-moe: 40 experts on a 4-way model axis -> divisible, but
    on 16-wide it is not; emulate with a 3-expert config on 4 ranks
    (replicated-expert fallback) and check against local."""
    out = run_in_subprocess(
        """
        import dataclasses, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config, reduced
        from repro.models.config import MoEConfig
        from repro.models.init import init_params
        from repro.models import blocks
        from repro.models.moe_ep import moe_ep

        cfg = reduced(get_config("granite-moe-3b-a800m"))
        cfg = dataclasses.replace(cfg, moe=MoEConfig(n_experts=3, top_k=2, d_ff_expert=64))
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        layer = jax.tree.map(lambda a: a[0], params["layers"][0])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
        y_local = blocks.moe(layer, cfg, x)
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        with compat.set_mesh(mesh):
            y_ep = jax.jit(lambda l, x: moe_ep(cfg, l, x))(layer, x)
        diff = float(jnp.abs(y_ep - y_local).max())
        assert diff < 1e-4, diff
        print("OK", diff)
        """,
        devices=8,
    )
    assert "OK" in out


def test_moe_ep_decode_shape():
    """Few tokens (decode): T_loc smaller than the model axis still
    lowers and matches (token padding path)."""
    out = run_in_subprocess(
        """
        import jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config, reduced
        from repro.models.init import init_params
        from repro.models import blocks
        from repro.models.moe_ep import moe_ep

        cfg = reduced(get_config("deepseek-v2-lite-16b"))
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        layer = jax.tree.map(lambda a: a[0], params["layers"][0])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg.d_model), jnp.float32)
        y_local = blocks.moe(layer, cfg, x)
        mesh = compat.make_mesh((1, 8), ("data", "model"))
        with compat.set_mesh(mesh):
            y_ep = jax.jit(lambda l, x: moe_ep(cfg, l, x, cf=8.0))(layer, x)
        diff = float(jnp.abs(y_ep - y_local).max())
        assert diff < 1e-4, diff
        print("OK", diff)
        """,
        devices=8,
    )
    assert "OK" in out
