"""Solver-family identities — the paper's algebraic claims.

V1  s-step SGD ≡ SGD (Algorithm 3 is a reformulation of Algorithm 1).
V2  Corner recovery: hybrid(p_r=1) ≡ s-step, hybrid(p_r=p, s=1) ≡
    FedAvg, s-step(s=1) ≡ SGD, fedavg(τ=1) ≡ synchronous MB-SGD.
V3  All solvers descend the same convex objective.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    full_loss,
    make_problem,
    run_fedavg,
    run_hybrid_sgd,
    run_sgd,
    run_sstep_sgd,
    stack_row_teams,
    global_problem,
)

B, ETA, K = 8, 0.05, 64


@pytest.fixture(scope="module")
def prob(small_problem):
    a, y = small_problem
    return make_problem(a, y, row_multiple=64)


def test_sstep_s1_equals_sgd(prob):
    x0 = jnp.zeros(prob.n)
    x_sgd, _ = run_sgd(prob, x0, B, ETA, K)
    x_ss, _ = run_sstep_sgd(prob, x0, 1, B, ETA, K)
    np.testing.assert_allclose(np.asarray(x_sgd), np.asarray(x_ss), atol=1e-6)


@pytest.mark.parametrize("s", [2, 4, 8])
def test_sstep_equals_sgd(prob, s):
    """The paper's central communication-avoiding identity (§2, [14])."""
    x0 = jnp.zeros(prob.n)
    x_sgd, _ = run_sgd(prob, x0, B, ETA, K)
    x_ss, _ = run_sstep_sgd(prob, x0, s, B, ETA, K)
    np.testing.assert_allclose(np.asarray(x_sgd), np.asarray(x_ss), atol=5e-4)


def test_hybrid_pr1_equals_sstep(small_problem):
    a, y = small_problem
    prob = make_problem(a, y, row_multiple=64)
    s, tau = 4, 16
    tp = stack_row_teams(a, y, 1, row_multiple=s * B)
    x0 = jnp.zeros(prob.n)
    x_h, _ = run_hybrid_sgd(tp, x0, s, B, ETA, tau, rounds=K // tau)
    x_ss, _ = run_sstep_sgd(prob, x0, s, B, ETA, K)
    np.testing.assert_allclose(np.asarray(x_h), np.asarray(x_ss), atol=1e-6)


def test_hybrid_prp_s1_equals_fedavg(small_problem):
    a, y = small_problem
    tau, p = 16, 4
    tp = stack_row_teams(a, y, p, row_multiple=B)
    x0 = jnp.zeros(a.n)
    x_h, _ = run_hybrid_sgd(tp, x0, 1, B, ETA, tau, rounds=4)
    x_f, _ = run_fedavg(tp, x0, B, ETA, tau, rounds=4)
    np.testing.assert_allclose(np.asarray(x_h), np.asarray(x_f), atol=1e-6)


def test_fedavg_tau1_is_synchronous_minibatch(small_problem):
    """τ=1 ⇒ every step averages p local gradients computed at the same
    x: equivalent to one step on the averaged gradient (effective batch
    p·b). Verify against the explicit computation."""
    a, y = small_problem
    p = 4
    tp = stack_row_teams(a, y, p, row_multiple=B)
    x0 = jnp.zeros(a.n)
    x_f, _ = run_fedavg(tp, x0, B, ETA, tau=1, rounds=1)
    # manual: mean over teams of one local SGD step from x0
    from repro.core.fedavg import _local_sgd

    xs = [
        np.asarray(_local_sgd(tp.indices[i], tp.values[i], tp.n, x0, 0, 1, B, ETA))
        for i in range(p)
    ]
    np.testing.assert_allclose(np.asarray(x_f), np.mean(xs, axis=0), atol=1e-6)


def test_all_solvers_descend(small_problem):
    a, y = small_problem
    prob = make_problem(a, y, row_multiple=64)
    x0 = jnp.zeros(prob.n)
    f0 = float(full_loss(prob, x0))
    for name, run in {
        "sgd": lambda: run_sgd(prob, x0, B, ETA, 128)[0],
        "sstep": lambda: run_sstep_sgd(prob, x0, 4, B, ETA, 128)[0],
    }.items():
        f1 = float(full_loss(prob, run()))
        assert f1 < f0, f"{name} did not descend: {f1} >= {f0}"
    tp = stack_row_teams(a, y, 4, row_multiple=32)
    x_f, _ = run_fedavg(tp, x0, B, ETA, 8, rounds=4)
    assert float(full_loss(global_problem(tp), x_f)) < f0
    x_h, _ = run_hybrid_sgd(tp, x0, 4, B, ETA, 8, rounds=4)
    assert float(full_loss(global_problem(tp), x_h)) < f0


def test_hybrid_convergence_beats_fedavg_at_large_p(small_problem):
    """Table 1: HybridSGD converges at 1/(K̂·b·p_r) vs FedAvg's drift at
    large p — with equal data passes, hybrid at p_r<p should reach a loss
    ≤ FedAvg at p (each hybrid row team takes exact s-step updates)."""
    a, y = small_problem
    x0 = jnp.zeros(a.n)
    tau = 16
    tp_full = stack_row_teams(a, y, 8, row_multiple=16)
    x_f, _ = run_fedavg(tp_full, x0, 4, ETA, tau, rounds=8)
    tp_h = stack_row_teams(a, y, 2, row_multiple=16)
    x_h, _ = run_hybrid_sgd(tp_h, x0, 4, 4, ETA, tau, rounds=8)
    lf = float(full_loss(global_problem(tp_full), x_f))
    lh = float(full_loss(global_problem(tp_h), x_h))
    assert lh <= lf * 1.02, (lh, lf)
