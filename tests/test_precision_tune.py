"""Mixed precision + panel autotuner acceptance tests.

Three contracts from the precision/tuning PR:

* the default (fp32, untuned-fallback) path is **bitwise-identical** to
  the pre-precision engine on both backends — pinned against a frozen
  reference capture (tests/data/fp32_ref.npz, generated on the
  pre-change tree);
* ``precision="bf16"`` really computes in bf16 (kernel outputs deviate
  from fp32 by a measurable-but-bounded amount), both backends agree,
  and the CommLedger prices the (G, v) wire at 2-byte words while the
  Table 2–3 *word* counts are untouched;
* the tuner cache is deterministic (same profile → same key → cache
  hit; kernel-version bump → miss) and the autotune opt-in (bk=None)
  resolves through it at build time.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.plan import plan
from repro.api.spec import ExperimentSpec, MeshSpec
from repro.core.comm import CommLedger
from repro.core.engine import (
    ParallelSGDSchedule,
    engine_comm_ledger,
    run_parallel_sgd,
)
from repro.core.teams import stack_row_teams
from repro.costmodel.hockney import schedule_comm_volume
from repro.kernels import tune
from repro.kernels.ell_gram import ell_gram_and_v, ell_gram_and_v_blocked
from repro.kernels.sstep_inner import sstep_inner
from repro.sparse.synthetic import make_skewed_csr

from tests.test_distributed_subprocess import run_in_subprocess

REF = Path(__file__).parent / "data" / "fp32_ref.npz"


def _ref_problem():
    rng = np.random.default_rng(0)
    a = make_skewed_csr(256, 100, 12, 0.8, seed=3)
    y = np.where(rng.random(256) < 0.5, 1.0, -1.0)
    return a, y


def _sched(**kw):
    return ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=3, loss_every=1, **kw)


# ---- the frozen fp32 pin ----


def test_fp32_engine_bitwise_vs_reference():
    """Default schedule reproduces the pre-precision engine capture
    bit for bit (weights AND loss trace)."""
    a, y = _ref_problem()
    sched = _sched()
    tp = stack_row_teams(a, y, 2, row_multiple=sched.s * sched.b)
    x, losses = run_parallel_sgd(tp, jnp.zeros(100), sched)
    ref = np.load(REF)
    np.testing.assert_array_equal(np.asarray(x), ref["engine_x"])
    np.testing.assert_array_equal(np.asarray(losses), ref["engine_losses"])


def test_fp32_bm_and_bk_none_bitwise():
    """bm row-tiling and the bk=None engine fallback are bitwise
    no-ops at fp32 (rows are independent; None → static 512)."""
    a, y = _ref_problem()
    base = _sched()
    tp = stack_row_teams(a, y, 2, row_multiple=base.s * base.b)
    ref = np.load(REF)["engine_x"]
    for variant in (
        dataclasses.replace(base, bm=4),
        dataclasses.replace(base, bk=None),
        dataclasses.replace(base, bk=None, bm=2),
    ):
        x, _ = run_parallel_sgd(tp, jnp.zeros(100), variant)
        np.testing.assert_array_equal(np.asarray(x), ref)


def test_fp32_shard_map_bitwise_vs_reference():
    out = run_in_subprocess(
        f"""
        import numpy as np
        from repro.api import ExperimentSpec, MeshSpec, Session
        from repro.core import ParallelSGDSchedule

        sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=3, loss_every=1)
        spec = ExperimentSpec(dataset="rcv1-sm", schedule=sched,
                              mesh=MeshSpec(p_r=2, p_c=2, backend="shard_map"))
        x = Session(spec).step_rounds(3).x
        ref = np.load({str(REF)!r})["shard_map_x"]
        np.testing.assert_array_equal(x, ref)
        print("OK")
        """
    )
    assert "OK" in out


# ---- bf16 compute is real and bounded ----


def test_bf16_kernel_parity_and_deviation():
    """bf16 panels: pallas and the blocked twin agree to float32
    rounding (XLA may fuse the bf16 dots differently), outputs stay
    float32, and they deviate from fp32 by a small nonzero amount
    (proof the cast is live)."""
    rng = np.random.default_rng(5)
    sb, w, n = 64, 24, 2048
    idx = jnp.asarray(rng.integers(0, n, (sb, w)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((sb, w)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    g32, v32 = ell_gram_and_v(idx, val, x, n=n, bk=512)
    g16, v16 = ell_gram_and_v(idx, val, x, n=n, bk=512, precision="bf16")
    gb16, vb16 = ell_gram_and_v_blocked(idx, val, x, n=n, bk=512, precision="bf16")
    assert g16.dtype == v16.dtype == jnp.float32  # fp32 accumulate
    np.testing.assert_allclose(np.asarray(g16), np.asarray(gb16), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v16), np.asarray(vb16), rtol=1e-6, atol=1e-6)
    rel = float(jnp.abs(g16 - g32).max() / jnp.abs(g32).max())
    assert 0.0 < rel < 0.02, rel  # bf16 has ~8 mantissa bits

    u32 = sstep_inner(g32, v32, 4, 16, 0.1)
    u16 = sstep_inner(g32, v32, 4, 16, 0.1, precision="bf16")
    du = float(jnp.abs(u16 - u32).max())
    assert 0.0 < du < 1e-2, du


def test_bf16_engine_close_to_fp32():
    a, y = _ref_problem()
    tp = stack_row_teams(a, y, 2, row_multiple=8)
    x32, l32 = run_parallel_sgd(tp, jnp.zeros(100), _sched())
    x16, l16 = run_parallel_sgd(tp, jnp.zeros(100), _sched(precision="bf16"))
    # documented tolerance: bf16-compute/fp32-accumulate on a 3-round
    # logistic problem stays within 1e-3 of fp32
    assert float(jnp.abs(x16 - x32).max()) < 1e-3
    assert float(jnp.abs(l16 - l32).max()) < 1e-3
    # and is genuinely a different trajectory (the wire cast is live)
    assert not np.array_equal(np.asarray(x16), np.asarray(x32))


def test_bf16_backend_parity_multidevice():
    """shard_map bf16 matches the simulated engine bf16 (the wire cast
    is applied identically around psum and the COUNTING identity), and
    the mesh ledger prices the (G, v) site at 2-byte words."""
    out = run_in_subprocess(
        """
        import dataclasses
        import numpy as np
        from repro.api import ExperimentSpec, MeshSpec, Session
        from repro.core import ParallelSGDSchedule

        sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=3,
                                           loss_every=1, precision="bf16")
        spec = ExperimentSpec(dataset="rcv1-sm", schedule=sched,
                              mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"))
        r_sim = Session(spec).run()
        r_dist = Session(dataclasses.replace(
            spec, mesh=MeshSpec(p_r=2, p_c=2, backend="shard_map"))).run()
        dx = float(np.abs(r_sim.x - r_dist.x).max())
        dl = float(np.abs(r_sim.losses - r_dist.losses).max())
        assert dx < 1e-5, dx
        assert dl < 1e-5, dl
        assert r_sim.ledger.rates == r_dist.ledger.rates
        gram = [r for r in r_dist.ledger.rates if r.axis == "cols" and r.span > 1]
        assert gram and all(r.word_bytes == 2 for r in gram), gram
        sync = [r for r in r_dist.ledger.rates if r.axis == "rows" and r.span > 1]
        assert sync and all(r.word_bytes == 4 for r in sync), sync
        print("OK", dx, dl)
        """,
        devices=4,
    )
    assert "OK" in out


# ---- ledger bytes: halved payload, invariant word counts ----


def test_ledger_bf16_halves_gram_bytes_not_words():
    n = 4736
    led32 = engine_comm_ledger(_sched(p_c=2), n)
    led16 = engine_comm_ledger(_sched(p_c=2, precision="bf16"), n)
    led32.add_rounds(3)
    led16.add_rounds(3)
    # word counts: identical, and exactly the Table 2–3 closed form
    cv = schedule_comm_volume(n, 2, 2, 2, 4, 8, rounds=3)
    assert led32.counted_words() == led16.counted_words() == cv.words_dict()
    b32, b16 = led32.counted_bytes(), led16.counted_bytes()
    assert b16["gram_bytes"] == b32["gram_bytes"] / 2
    assert b16["sync_bytes"] == b32["sync_bytes"]  # weights stay fp32
    assert led16.bytes_per_round() == led32.bytes_per_round() - (
        led32.counted_bytes(1)["gram_bytes"] / 2
    )
    # the legacy uniform override is untouched (calibration pricing)
    assert led16.bytes_per_round(8) == led32.bytes_per_round(8)


def test_fp32_ledger_serialization_unchanged():
    """fp32 ledgers serialize byte-identically to the pre-precision
    schema: no word_bytes, no counted_bytes."""
    led = engine_comm_ledger(_sched(p_c=2), 100)
    led.add_rounds(3)
    d = led.to_dict()
    assert "counted_bytes" not in json.dumps(d)
    assert "word_bytes" not in json.dumps(d)
    assert CommLedger.from_dict(d).rates == led.rates
    # bf16 ledgers opt the new fields in, and round-trip
    led16 = engine_comm_ledger(_sched(p_c=2, precision="bf16"), 100)
    led16.add_rounds(3)
    d16 = led16.to_dict()
    assert "counted_bytes" in d16 and "word_bytes" in json.dumps(d16)
    assert CommLedger.from_dict(d16).rates == led16.rates


def test_spec_serialization_emits_only_non_default():
    mesh = MeshSpec(p_r=2, p_c=1, backend="simulated")
    spec = ExperimentSpec(dataset="rcv1-sm", schedule=_sched(), mesh=mesh)
    d = spec.to_dict()
    assert "bm" not in d["schedule"] and "precision" not in d["schedule"]
    assert ExperimentSpec.from_dict(d).content_hash() == spec.content_hash()
    spec16 = ExperimentSpec(
        dataset="rcv1-sm", schedule=_sched(precision="bf16", bm=16), mesh=mesh
    )
    d16 = spec16.to_dict()
    assert d16["schedule"]["precision"] == "bf16"
    assert d16["schedule"]["bm"] == 16
    rt = ExperimentSpec.from_dict(d16)
    assert rt.schedule.precision == "bf16" and rt.schedule.bm == 16
    assert rt.content_hash() == spec16.content_hash()
    assert spec16.content_hash() != spec.content_hash()


# ---- tuner cache ----


def _profile(**kw):
    defaults = dict(rows=64, width=74, n_local=2368, dense=False, precision="fp32")
    defaults.update(kw)
    return tune.PanelProfile(**defaults)


def test_cache_key_deterministic_and_content_addressed():
    p = _profile()
    assert tune.cache_key(p, "cpu:cpu") == tune.cache_key(p, "cpu:cpu")
    assert tune.cache_key(p, "cpu:cpu") != tune.cache_key(p, "tpu:TPU v5e")
    assert tune.cache_key(p, "cpu:cpu") != tune.cache_key(
        _profile(precision="bf16"), "cpu:cpu"
    )
    assert tune.cache_key(p, "cpu:cpu") != tune.cache_key(
        p, "cpu:cpu", kernel_version=tune.KERNEL_VERSION + 1
    )


def test_resolve_hits_cache_without_retuning(tmp_path):
    """A stored record IS the answer: resolve returns it verbatim (the
    sentinel shape proves no sweep ran) and a kernel-version bump
    misses back to a fresh tune/fallback."""
    p = _profile()
    key = tune.cache_key(p, "cpu:cpu")
    tune.store_record(
        {"key": key, "kernel_version": tune.KERNEL_VERSION, "device": "cpu:cpu",
         "profile": p.to_dict(), "bk": 192, "bm": 8, "measured_s": 1.0,
         "attainable_s": 0.5, "efficiency": 0.5, "candidates": []},
        cache_dir=tmp_path,
    )
    assert tune.resolve_panel(p, device="cpu:cpu", cache_dir=tmp_path) == (192, 8)
    # same profile, bumped kernel version → different key → miss
    stale = tune.cache_key(p, "cpu:cpu", kernel_version=tune.KERNEL_VERSION + 1)
    assert tune.load_record(stale, tmp_path) is None
    # miss without tuning allowed → static fallback
    assert tune.resolve_panel(
        _profile(rows=32), device="cpu:cpu", cache_dir=tmp_path, allow_tune=False
    ) == (tune.FALLBACK_BK, tune.FALLBACK_BM)


def test_tune_writes_once_then_hits(tmp_path):
    p = _profile(rows=16, width=8, n_local=512)
    rec = tune.tune_panel(p, cache_dir=tmp_path, repeats=1, max_n=512)
    files = list(Path(tmp_path).glob("*.json"))
    assert [f.stem for f in files] == [rec["key"]]
    hit = tune.tune_panel(p, cache_dir=tmp_path, repeats=1, max_n=512)
    assert hit == rec  # byte-identical cache read, no re-measure
    assert rec["bk"] >= 1 and rec["efficiency"] is not None
    # every audited candidate carries its roofline justification
    live = [c for c in rec["candidates"] if c.get("skipped") is None]
    assert live and all("attainable_s" in c for c in live)


def test_session_resolves_bk_none_and_reports(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    from repro.api.session import Session

    sched = _sched(bk=None)
    spec = ExperimentSpec(dataset="rcv1-sm", schedule=sched,
                          mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"))
    pl = plan(spec)
    assert "bk=auto (tuned at build)" in pl.summary()  # cold cache
    sess = Session(spec)
    assert sess.spec.schedule.bk is not None  # resolved
    assert sess.input_spec.schedule.bk is None  # checkpoints key pre-resolve
    pl2 = plan(spec)  # warm cache now
    assert pl2.tuned_panel == (sess.spec.schedule.bk, sess.spec.schedule.bm)
    assert f"bk=auto→{sess.spec.schedule.bk}" in pl2.summary()


def test_session_gram_autoselect_rides_autotune_optin(tmp_path, monkeypatch):
    """Heavy-tailed ELL width (w > 4·s·b) flips the tuned build to the
    dense oracle; the default bk=512 build never flips (bitwise pin)."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    from repro.api.session import Session

    # rcv1-sm built at s·b=8 has ELL width ≫ 32 → heavy-tailed
    tuned = ExperimentSpec(dataset="rcv1-sm", schedule=_sched(bk=None),
                           mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"))
    assert Session(tuned).spec.schedule.gram == "dense"
    static = ExperimentSpec(dataset="rcv1-sm", schedule=_sched(),
                            mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"))
    assert Session(static).spec.schedule.gram == "pallas"
    # an explicit gram choice is always honored
    manual = ExperimentSpec(dataset="rcv1-sm", schedule=_sched(bk=None, gram="blocked"),
                            mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"))
    assert Session(manual).spec.schedule.gram == "blocked"


def test_select_gram_path_rule():
    assert tune.select_gram_path(33, 8) == "dense"  # 33 > 4·8
    assert tune.select_gram_path(32, 8) == "pallas"
    assert tune.select_gram_path(104, 64) == "pallas"
    assert tune.select_gram_path(1000, 64, "pallas") == "dense"
    assert tune.select_gram_path(1000, 64, "blocked") == "blocked"  # honored


# ---- plan prices bytes ----


def test_plan_prices_bf16_gram_bytes():
    spec32 = ExperimentSpec(dataset="rcv1-sm", schedule=_sched(p_c=2),
                            mesh=MeshSpec(p_r=2, p_c=2, backend="simulated"))
    spec16 = dataclasses.replace(spec32, schedule=_sched(p_c=2, precision="bf16"))
    from repro.costmodel.machines import MACHINES

    w = MACHINES[spec32.machine].word_bytes
    p32, p16 = plan(spec32), plan(spec16)
    assert p16.cost.gram_bw == pytest.approx(p32.cost.gram_bw * 2 / w)
    assert p16.cost.sync_bw == p32.cost.sync_bw  # weights stay full words
    assert "2-byte Gram wire words" in p16.summary()
