"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype
sweeps per the kernel-validation contract."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.bsr_matmul import bsr_matmat, bsr_matvec
from repro.kernels.gram import gram_and_v, gram_tril
from repro.kernels.ops import sparse_linear_op, sstep_gram, sstep_gram_and_v
from repro.kernels import ref
from repro.sparse.bsr import bsr_from_csr
from repro.sparse.csr import csr_from_dense
from repro.sparse.synthetic import make_skewed_csr


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(4, 120),
    n=st.integers(8, 500),
    zbar=st.integers(2, 30),
    alpha=st.floats(0.0, 1.2),
    k=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 999),
)
def test_bsr_matmat_sweep(m, n, zbar, alpha, k, seed):
    a = make_skewed_csr(m, n, min(zbar, n), alpha, seed=seed)
    bsr = bsr_from_csr(a, bm=8, bn=128)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((bsr.shape[1], k)).astype(np.float32))
    got = bsr_matmat(bsr.tiles, bsr.block_cols, x)
    want = ref.bsr_matmat_ref(bsr.tiles, bsr.block_cols, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bm,bn", [(8, 128), (16, 128), (8, 256)])
def test_bsr_matvec_shapes_dtypes(dtype, bm, bn):
    a = make_skewed_csr(96, 640, 20, 0.9, seed=4)
    bsr = bsr_from_csr(a, bm=bm, bn=bn, dtype=dtype)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(bsr.shape[1]), dtype=dtype)
    got = bsr_matvec(bsr.tiles, bsr.block_cols, x)
    want = ref.bsr_matvec_ref(bsr.tiles, bsr.block_cols, x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@settings(max_examples=20, deadline=None)
@given(
    sb=st.sampled_from([8, 32, 64, 128]),
    n=st.integers(10, 2000),
    bk=st.sampled_from([128, 256, 512]),
    seed=st.integers(0, 999),
)
def test_gram_sweep(sb, n, bk, seed):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.standard_normal((sb, n)).astype(np.float32))
    got = gram_tril(y, bk=bk)
    want = ref.gram_tril_ref(y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_and_v_fused(dtype):
    rng = np.random.default_rng(1)
    y = jnp.asarray(rng.standard_normal((64, 900)), dtype=dtype)
    x = jnp.asarray(rng.standard_normal(900), dtype=dtype)
    g, v = gram_and_v(y, x, bk=256)
    gr, vr = ref.gram_and_v_ref(y, x)
    np.testing.assert_allclose(np.asarray(g, np.float32), np.asarray(gr, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(v, np.float32), np.asarray(vr, np.float32), **tol(dtype))


def test_gram_is_strictly_lower():
    rng = np.random.default_rng(2)
    y = jnp.asarray(rng.standard_normal((32, 300)).astype(np.float32))
    g = np.asarray(gram_tril(y, bk=128))
    assert np.all(np.triu(g) == 0.0)


def test_sparse_linear_op_against_dense(skewed_csr):
    op = sparse_linear_op(skewed_csr)
    dense = skewed_csr.to_dense()
    rng = np.random.default_rng(3)
    x = rng.standard_normal(skewed_csr.n).astype(np.float32)
    u = rng.standard_normal(skewed_csr.m).astype(np.float32)
    np.testing.assert_allclose(np.asarray(op.matvec(jnp.asarray(x))), dense @ x, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(op.rmatvec(jnp.asarray(u))), dense.T @ u, rtol=1e-3, atol=1e-3)


def test_kernel_backed_sgd_step_matches_ell():
    """End-to-end: one SGD gradient via BSR kernels == ELL path."""
    from repro.core.problem import make_problem, sigmoid_residual
    from repro.sparse.ell import ell_matvec, ell_rmatvec
    from repro.core.sgd import batch_rows

    rng = np.random.default_rng(5)
    a = make_skewed_csr(128, 300, 10, 0.8, seed=6)
    y = np.where(rng.random(128) < 0.5, 1.0, -1.0)
    prob = make_problem(a, y, row_multiple=128)
    x = jnp.asarray(rng.standard_normal(300).astype(np.float32))

    batch = batch_rows(prob.ya, jnp.int32(0), 32)
    u_ell = sigmoid_residual(ell_matvec(batch, x))
    g_ell = ell_rmatvec(batch, u_ell)

    ya = a.scale_rows(y)
    op = sparse_linear_op(ya.row_block(0, 32))
    u_bsr = sigmoid_residual(op.matvec(x))
    g_bsr = op.rmatvec(u_bsr)
    np.testing.assert_allclose(np.asarray(u_bsr), np.asarray(u_ell[:32]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g_bsr), np.asarray(g_ell), rtol=1e-3, atol=1e-3)


def test_sstep_bundle_gram_matches_core():
    """The Pallas gram on a densified bundle == the core solver's Gram."""
    rng = np.random.default_rng(7)
    a = make_skewed_csr(64, 257, 9, 0.5, seed=8)
    dense = jnp.asarray(a.to_dense()[:32].astype(np.float32))
    x = jnp.asarray(rng.standard_normal(257).astype(np.float32))
    g, v = sstep_gram_and_v(dense, x, bk=128)
    np.testing.assert_allclose(np.asarray(g), np.asarray(jnp.tril(dense @ dense.T, k=-1)), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(v), np.asarray(dense @ x), rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    s=st.sampled_from([1, 2, 4, 8]),
    b=st.sampled_from([4, 8, 16]),
    eta=st.floats(0.01, 1.0),
    seed=st.integers(0, 999),
)
def test_sstep_inner_kernel_sweep(s, b, eta, seed):
    """Fused correction-loop kernel == the core solver's scan (V1's
    inner loop, VMEM-resident)."""
    from repro.kernels.sstep_inner import sstep_inner, sstep_inner_ref

    rng = np.random.default_rng(seed)
    sb = s * b
    y = rng.standard_normal((sb, 200)).astype(np.float32)
    g = jnp.asarray(np.tril(y @ y.T, -1))
    v = jnp.asarray(rng.standard_normal(sb).astype(np.float32))
    got = sstep_inner(g, v, s, b, eta)
    want = sstep_inner_ref(g, v, s, b, eta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_sstep_inner_kernel_in_solver_context():
    """End-to-end: kernel-computed u reproduces one s-step bundle's
    update inside the real solver pipeline."""
    from repro.core.problem import make_problem
    from repro.core.sgd import batch_rows, run_sgd
    from repro.kernels.ops import sstep_gram_and_v
    from repro.kernels.sstep_inner import sstep_inner
    from repro.sparse.ell import ell_rmatvec
    from repro.sparse.synthetic import make_skewed_csr

    rng = np.random.default_rng(3)
    a = make_skewed_csr(128, 300, 10, 0.8, seed=9)
    y = np.where(rng.random(128) < 0.5, 1.0, -1.0)
    s, b, eta = 4, 8, 0.1
    prob = make_problem(a, y, row_multiple=s * b)
    x = jnp.asarray(rng.standard_normal(300).astype(np.float32))

    bundle = batch_rows(prob.ya, jnp.int32(0), s * b)
    dense = np.zeros((s * b, 300), np.float32)
    bi, bv = np.asarray(bundle.indices), np.asarray(bundle.values)
    for i in range(s * b):
        np.add.at(dense[i], bi[i], bv[i])
    g, v = sstep_gram_and_v(jnp.asarray(dense), x, bk=128)
    u = sstep_inner(g, v, s, b, eta)
    x_new = x + (eta / b) * ell_rmatvec(bundle, u)

    # oracle: s plain SGD steps
    x_ref, _ = run_sgd(prob, x, b, eta, s)
    np.testing.assert_allclose(np.asarray(x_new), np.asarray(x_ref), rtol=1e-4, atol=1e-4)
