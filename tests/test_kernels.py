"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype
sweeps per the kernel-validation contract. Covers the two live kernels
— the ELL-Gram bundle primitive and the fused s-step correction loop —
against the ``repro.kernels.ref`` oracles (the retired dense-panel and
BSR kernels are gone; their oracles remain the parity reference)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ell_gram import ell_gram_and_v, ell_gram_and_v_blocked
from repro.sparse.synthetic import make_skewed_csr


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    sb=st.sampled_from([8, 32, 64]),
    n=st.integers(10, 2000),
    width=st.integers(1, 24),
    bk=st.sampled_from([128, 256, 512]),
    seed=st.integers(0, 999),
)
def test_ell_gram_sweep(sb, n, width, bk, seed):
    """Both live bundle implementations == the densify oracle over
    random ELL bundles (duplicate column ids included)."""
    rng = np.random.default_rng(seed)
    width = min(width, n)
    idx = jnp.asarray(rng.integers(0, n, size=(sb, width)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((sb, width)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    g_ref, v_ref = ref.ell_gram_and_v_ref(idx, val, x, n)
    for impl in (
        lambda: ell_gram_and_v(idx, val, x, n=n, bk=bk),
        lambda: ell_gram_and_v_blocked(idx, val, x, n=n, bk=bk),
    ):
        g, v = impl()
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), rtol=1e-3, atol=1e-3)


def test_ell_gram_is_strictly_lower():
    rng = np.random.default_rng(2)
    idx = jnp.asarray(rng.integers(0, 300, size=(32, 9)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((32, 9)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(300).astype(np.float32))
    g, _ = ell_gram_and_v(idx, val, x, n=300, bk=128)
    assert np.all(np.triu(np.asarray(g)) == 0.0)


def test_densify_oracle_matches_csr():
    """The oracle's densify == the CSR dense expansion (the retired
    scatter path, kept as the reference the live kernels verify
    against)."""
    a = make_skewed_csr(64, 257, 9, 0.5, seed=8)
    from repro.core.problem import make_problem

    prob = make_problem(a, np.ones(64), row_multiple=64)
    dense = np.asarray(
        ref.densify_bundle_ref(prob.ya.indices, prob.ya.values, 257)
    )
    np.testing.assert_allclose(dense[:64], a.to_dense().astype(np.float32), rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    s=st.sampled_from([1, 2, 4, 8]),
    b=st.sampled_from([4, 8, 16]),
    eta=st.floats(0.01, 1.0),
    seed=st.integers(0, 999),
)
def test_sstep_inner_kernel_sweep(s, b, eta, seed):
    """Fused correction-loop kernel == the core solver's scan (V1's
    inner loop, VMEM-resident)."""
    from repro.kernels.sstep_inner import sstep_inner, sstep_inner_ref

    rng = np.random.default_rng(seed)
    sb = s * b
    y = rng.standard_normal((sb, 200)).astype(np.float32)
    g = jnp.asarray(np.tril(y @ y.T, -1))
    v = jnp.asarray(rng.standard_normal(sb).astype(np.float32))
    got = sstep_inner(g, v, s, b, eta)
    want = sstep_inner_ref(g, v, s, b, eta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_sstep_inner_kernel_in_solver_context():
    """End-to-end: kernel-computed u reproduces one s-step bundle's
    update inside the real solver pipeline (Gram/v from the live ELL
    kernel)."""
    from repro.core.problem import make_problem
    from repro.core.sgd import batch_rows, run_sgd
    from repro.kernels.sstep_inner import sstep_inner
    from repro.sparse.ell import ell_rmatvec

    rng = np.random.default_rng(3)
    a = make_skewed_csr(128, 300, 10, 0.8, seed=9)
    y = np.where(rng.random(128) < 0.5, 1.0, -1.0)
    s, b, eta = 4, 8, 0.1
    prob = make_problem(a, y, row_multiple=s * b)
    x = jnp.asarray(rng.standard_normal(300).astype(np.float32))

    bundle = batch_rows(prob.ya, jnp.int32(0), s * b)
    g, v = ell_gram_and_v(bundle.indices, bundle.values, x, n=300, bk=128)
    u = sstep_inner(g, v, s, b, eta)
    x_new = x + (eta / b) * ell_rmatvec(bundle, u)

    # oracle: s plain SGD steps
    x_ref, _ = run_sgd(prob, x, b, eta, s)
    np.testing.assert_allclose(np.asarray(x_new), np.asarray(x_ref), rtol=1e-4, atol=1e-4)
