"""Training substrate: data pipeline, checkpointing, loop, hybrid-2D."""

import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.data import MarkovTextStream, bigram_entropy_floor
from repro.train.loop import train
from tests.test_distributed_subprocess import run_in_subprocess


def test_markov_stream_is_deterministic_and_learnable():
    s1 = MarkovTextStream(256, seed=3)
    s2 = MarkovTextStream(256, seed=3)
    b1 = next(s1.batches(4, 32))
    b2 = next(s2.batches(4, 32))
    np.testing.assert_array_equal(b1[0], b2[0])
    # targets are shifted tokens
    np.testing.assert_array_equal(b1[0][:, 1:], b1[1][:, :-1])
    # real structure: entropy floor far below uniform log V
    assert bigram_entropy_floor(s1) < 0.8 * np.log(256)


def test_checkpoint_roundtrip():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.int32)},
        "tup": (jnp.zeros((2,)), jnp.full((1,), 7.0)),
    }
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(Path(d) / "ckpt", tree, step=42)
        restored, step = restore_checkpoint(Path(d) / "ckpt", tree)
        assert step == 42
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), tree, restored)


def test_checkpoint_missing_returns_none():
    with tempfile.TemporaryDirectory() as d:
        restored, step = restore_checkpoint(Path(d) / "nope", {"a": jnp.zeros(1)})
        assert restored is None and step == 0


def test_train_loop_loss_decreases():
    cfg = reduced(get_config("qwen2.5-3b"))
    report = train(cfg, steps=30, batch=4, seq_len=32, log_every=10)
    assert len(report.losses) >= 3
    assert report.losses[-1] < report.losses[0]


def test_train_loop_checkpoint_resume():
    cfg = reduced(get_config("gemma-2b"))
    with tempfile.TemporaryDirectory() as d:
        train(cfg, steps=10, batch=2, seq_len=16, checkpoint_dir=d, checkpoint_every=10, log_every=5)
        report = train(cfg, steps=20, batch=2, seq_len=16, checkpoint_dir=d, checkpoint_every=10, log_every=5)
        assert report.steps == 20


def test_hybrid2d_two_pods_matches_manual_local_sgd():
    """The pod-manual shard_map local step == hand-computed per-pod SGD
    + averaging (the FedAvg identity at NN scale)."""
    out = run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config, reduced
        from repro.models.init import init_params
        from repro.models.transformer import lm_loss
        from repro.optim.hybrid2d import make_hybrid_train_step, make_sync_step, stack_for_pods
        from repro.optim.sgd import sgd

        cfg = reduced(get_config("qwen2.5-3b"))
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        opt = sgd(0.1)
        mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
        targets = jnp.roll(tokens, -1, axis=1)

        def loss_fn(p, tok, tgt):
            return lm_loss(cfg, p, tok, tgt)

        compat.set_mesh(mesh)
        step = make_hybrid_train_step(mesh, loss_fn, opt)
        sync = make_sync_step(mesh)
        st = (stack_for_pods(params, 2), stack_for_pods(opt.init(params), 2))
        st, loss = step(st, (tokens, targets))
        synced = sync(st[0])
        got = jax.tree.map(lambda p: np.asarray(p[0]), synced)

        # manual: each pod does one SGD step on its half of the batch
        def one(p, tok, tgt):
            g = jax.grad(loss_fn)(p, tok, tgt)
            return jax.tree.map(lambda w, gw: w - 0.1 * gw, p, g)

        pa = one(params, tokens[:4], targets[:4])
        pb = one(params, tokens[4:], targets[4:])
        want = jax.tree.map(lambda a, b: (np.asarray(a) + np.asarray(b)) / 2, pa, pb)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4), got, want
        )
        print("OK")
        """,
        devices=8,
    )
    assert "OK" in out


def test_hybrid2d_pods_drift_between_syncs():
    """Between syncs the two pods' parameters must differ (local SGD),
    and the sync must make them equal again."""
    out = run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_config, reduced
        from repro.models.init import init_params
        from repro.models.transformer import lm_loss
        from repro.optim.hybrid2d import make_hybrid_train_step, make_sync_step, stack_for_pods
        from repro.optim.sgd import sgd

        cfg = reduced(get_config("gemma-2b"))
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        opt = sgd(0.1)
        mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
        compat.set_mesh(mesh)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
        targets = jnp.roll(tokens, -1, axis=1)
        step = make_hybrid_train_step(mesh, lambda p, a, b: lm_loss(cfg, p, a, b), opt)
        sync = make_sync_step(mesh)
        st = (stack_for_pods(params, 2), stack_for_pods(opt.init(params), 2))
        for _ in range(3):
            st, _ = step(st, (tokens, targets))
        emb = np.asarray(st[0]["embed"])
        drift = np.abs(emb[0] - emb[1]).max()
        assert drift > 1e-6, f"pods did not drift: {drift}"
        synced = sync(st[0])
        emb2 = np.asarray(synced["embed"])
        assert np.abs(emb2[0] - emb2[1]).max() < 1e-7
        print("OK", drift)
        """,
        devices=8,
    )
    assert "OK" in out
