"""Elastic re-planning: a checkpoint written on one mesh resumes on
another.

The contract has three regimes (and the tests pin each one):

* unchanged mesh → ``restore_elastic`` is exactly ``restore``: the
  continuation is bitwise-identical;
* changed p_c (communication-only) → the iterates are *still* bitwise-
  identical — column shards never touch the numerics;
* changed p_r (a numerical knob) → a different, equally valid member of
  the (p_r, p_c, s, τ) family: the resumed run must converge to the
  same target loss, not replay the same bits.

``replan_mesh`` itself is the §5 cost model doing the choosing: every
factorization of the surviving device count is priced and the cheapest
becomes the new geometry.
"""

import dataclasses

import numpy as np
import pytest

from chaos_util import run_chaos
from repro.api import (
    ExperimentSpec,
    MeshSpec,
    Session,
    plan,
    replan_mesh,
    run,
)
from repro.core import ParallelSGDSchedule


def _spec(p_r=4, p_c=1, rounds=8, **over):
    sched = ParallelSGDSchedule.hybrid(p_r, 2, 4, 0.05, 8, rounds=rounds, loss_every=2)
    base = dict(
        dataset="rcv1-sm",
        schedule=sched,
        mesh=MeshSpec(p_r=p_r, p_c=p_c),
        name="elastic",
    )
    base.update(over)
    return ExperimentSpec(**base)


def test_replan_enumerates_factorizations():
    spec = _spec()
    for devices in (1, 2, 4, 6, 8):
        pl = replan_mesh(spec, devices)
        assert pl.spec.mesh.p_r * pl.spec.mesh.p_c == devices
        assert pl.spec.schedule.p_r == pl.spec.mesh.p_r
        assert pl.spec.schedule.p_c == pl.spec.mesh.p_c
        # the winner is the argmin over every factorization
        for p_r in range(1, devices + 1):
            if devices % p_r:
                continue
            cand = dataclasses.replace(
                spec,
                schedule=dataclasses.replace(
                    spec.schedule, p_r=p_r, p_c=devices // p_r
                ),
                mesh=dataclasses.replace(spec.mesh, p_r=p_r, p_c=devices // p_r),
            )
            assert pl.cost.total <= plan(cand).cost.total + 1e-12


def test_replan_rejects_zero_devices():
    with pytest.raises(ValueError):
        replan_mesh(_spec(), 0)


def test_unchanged_mesh_is_bitwise(tmp_path):
    spec = _spec()
    clean = run(spec)
    half = Session(spec)
    half.step_rounds(5)  # off every boundary
    half.save(tmp_path / "ck")
    rep = Session.restore_elastic(tmp_path / "ck", mesh=spec.mesh).run()
    assert np.array_equal(rep.x, clean.x)
    assert np.array_equal(rep.losses, clean.losses)


def test_p_c_shrink_is_bitwise(tmp_path):
    """p_c is communication-only: an elastic resume that only re-shards
    columns continues the identical iterate sequence."""
    spec = _spec(p_r=2, p_c=4)
    clean = run(spec)
    half = Session(spec)
    half.step_rounds(3)
    half.save(tmp_path / "ck")
    rep = Session.restore_elastic(tmp_path / "ck", mesh=MeshSpec(p_r=2, p_c=2)).run()
    assert rep.spec.mesh.p_c == 2
    assert np.array_equal(rep.x, clean.x)
    assert np.array_equal(rep.losses, clean.losses)


def test_p_r_shrink_replans_and_converges(tmp_path):
    """Mesh shrink 4 → 2 devices mid-run: replan picks a new (p_r, p_c),
    the run continues from the checkpoint's round, and the re-teamed
    trajectory still reaches the target the uninterrupted run reached."""
    probe = run(_spec(rounds=16))
    target = float(probe.final_loss) * 1.02  # the §7.5 verdict, with slack

    spec = _spec(rounds=16)
    half = Session(spec)
    half.step_rounds(6)
    half.save(tmp_path / "ck")

    sess = Session.restore_elastic(tmp_path / "ck", devices=2)
    assert sess.spec.mesh.p == 2
    assert sess.rounds_done == 6
    assert len(sess.losses) == 3  # the trace carries over
    rep = sess.run()
    assert rep.rounds_completed == 16
    assert rep.final_loss <= target, (rep.final_loss, target)


def test_grow_replans(tmp_path):
    """Capacity arrives: 4 → 8 devices. Same contract, opposite sign."""
    spec = _spec(rounds=8)
    half = Session(spec)
    half.step_rounds(4)
    half.save(tmp_path / "ck")
    sess = Session.restore_elastic(tmp_path / "ck", devices=8)
    assert sess.spec.mesh.p == 8
    rep = sess.run()
    assert rep.rounds_completed == 8
    assert np.isfinite(rep.final_loss)


def test_restore_elastic_needs_exactly_one_target(tmp_path):
    spec = _spec()
    s = Session(spec)
    s.step_rounds(2)
    s.save(tmp_path / "ck")
    with pytest.raises(ValueError, match="exactly one"):
        Session.restore_elastic(tmp_path / "ck")
    with pytest.raises(ValueError, match="exactly one"):
        Session.restore_elastic(tmp_path / "ck", devices=2, mesh=spec.mesh)


def test_elastic_shard_map_p_c_shrink_bitwise(tmp_path):
    """The same p_c-only elastic contract on a real device mesh: save on
    2×4, resume on 2×2 — bitwise against the uninterrupted 2×4 run."""
    out = run_chaos(
        f"""
import numpy as np
from repro.api import ExperimentSpec, MeshSpec, Session, run
from repro.core import ParallelSGDSchedule

sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=4, loss_every=2)
spec = ExperimentSpec(
    dataset="rcv1-sm",
    schedule=sched,
    mesh=MeshSpec(p_r=2, p_c=4, backend="shard_map"),
    name="elastic-mesh",
)
clean = run(spec)
half = Session(spec)
half.step_rounds(2)
half.save(r"{tmp_path}/ck")
rep = Session.restore_elastic(
    r"{tmp_path}/ck", mesh=MeshSpec(p_r=2, p_c=2, backend="shard_map")
).run()
assert rep.spec.mesh.p_c == 2
assert np.array_equal(rep.x, clean.x), "p_c shrink changed the iterates"
assert np.array_equal(rep.losses, clean.losses)
print("OK")
""",
        devices=8,
    )
    assert "OK" in out
