"""Stream chaos: a serving-plane worker dies mid-stream, a fresh
process resumes from the autosave, and the finished weights are
bitwise-identical to the uninterrupted run — i.e. no micro-batch was
duplicated and none was dropped across the kill.

Exactly-once is structural, not bookkept: the round counter IS the
stream position, sources replay batch k purely from (seed, k), and
``step_stream`` refuses any batch whose index disagrees with the
counter. So if the resumed trajectory lands bitwise on the clean one,
the resumed process consumed precisely batches 4..N-1 — a duplicate or
a gap would change the weights (and trip ``StreamDesyncError`` first).

The seeded sweep variant runs the same kill round against several
stream seeds — the CI job's cheap chaos sweep for the serving plane.
"""

import numpy as np
import pytest

from chaos_util import SIGKILLED, run_chaos

_SPEC = """
from repro.api import ExperimentSpec, FaultPolicy, MeshSpec, StreamSpec
from repro.core import ParallelSGDSchedule

sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.2, 8, rounds=8, loss_every=2)
spec = ExperimentSpec(
    dataset="rcv1-sm",
    schedule=sched,
    mesh=MeshSpec(p_r=2, p_c={p_c}, backend="{backend}"),
    stream=StreamSpec(source="drift", seed={stream_seed}, drift_at=3),
    faults=FaultPolicy(autosave_every=1),
    name="chaos-stream",
)
"""

_RUN_CLEAN = """
import numpy as np
from repro.api import Session
from repro.serve import make_stream_source
sess = Session(spec)
while not sess.done:
    sess.step_stream(make_stream_source(spec))
np.savez(r"{tmp}/clean.npz", x=sess.current_x(),
         losses=np.asarray(sess.losses, np.float32))
print("CLEAN", sess.rounds_done)
"""

_RUN_VICTIM = """
from repro.api import Session
from repro.core.faults import FaultEvent, FaultPlan, install
from repro.serve import make_stream_source
plan = FaultPlan(events=[FaultEvent(kind="kill", site="round", at={kill_at})])
sess = Session(spec, autosave_dir=r"{tmp}")
with install(plan, hard_kill=True):
    while not sess.done:
        sess.step_stream(make_stream_source(spec))
print("UNREACHABLE")  # SIGKILL means this line never runs
"""

_RUN_RESUMER = """
import numpy as np
from repro.api import Session, autosave_base
from repro.serve import make_stream_source
sess = Session.restore(autosave_base(r"{tmp}", spec), spec=spec)
assert sess.rounds_done == {kill_at}, sess.rounds_done
# re-attach the stream AT the restored round: the source replays batch
# {kill_at} onward — the victim's consumed prefix is never re-trained.
while not sess.done:
    sess.step_stream(make_stream_source(spec))
clean = np.load(r"{tmp}/clean.npz")
assert np.array_equal(sess.current_x(), clean["x"]), "resumed weights diverged"
assert np.array_equal(
    np.asarray(sess.losses, np.float32), clean["losses"]
), "resumed loss trace diverged"
print("RESUMED_BITWISE", sess.rounds_done)
"""

BACKENDS = [("simulated", 1, 1), ("shard_map", 4, 8)]


@pytest.mark.parametrize("backend,p_c,devices", BACKENDS)
def test_kill_mid_stream_resumes_with_no_dup_no_drop(backend, p_c, devices, tmp_path):
    spec_code = _SPEC.format(backend=backend, p_c=p_c, stream_seed=3)
    kill_at = 4

    run_chaos(spec_code + _RUN_CLEAN.format(tmp=tmp_path), devices=devices)
    run_chaos(
        spec_code + _RUN_VICTIM.format(tmp=tmp_path, kill_at=kill_at),
        devices=devices,
        expect_returncode=SIGKILLED,
    )
    out = run_chaos(
        spec_code + _RUN_RESUMER.format(tmp=tmp_path, kill_at=kill_at),
        devices=devices,
    )
    assert "RESUMED_BITWISE 8" in out


@pytest.mark.parametrize("stream_seed", [0, 1, 2])
def test_seeded_stream_kill_sweep(stream_seed, tmp_path):
    """The seeded chaos sweep (simulated backend keeps it cheap): the
    same kill against different stream seeds — any bookkeeping bug that
    depends on what the data happens to be shows up here."""
    spec_code = _SPEC.format(backend="simulated", p_c=1, stream_seed=stream_seed)
    kill_at = 5

    run_chaos(spec_code + _RUN_CLEAN.format(tmp=tmp_path), devices=1)
    run_chaos(
        spec_code + _RUN_VICTIM.format(tmp=tmp_path, kill_at=kill_at),
        devices=1,
        expect_returncode=SIGKILLED,
    )
    out = run_chaos(
        spec_code + _RUN_RESUMER.format(tmp=tmp_path, kill_at=kill_at), devices=1
    )
    assert "RESUMED_BITWISE 8" in out


def test_hot_swap_never_serves_a_torn_model(tmp_path):
    """Chaos on the swap path: a checkpoint truncated mid-write (the
    ckpt_truncate fault) must be REJECTED by the swap — the service
    keeps answering from the previous version."""
    out = run_chaos(
        _SPEC.format(backend="simulated", p_c=1, stream_seed=3)
        + f"""
import numpy as np
from repro.core.faults import FaultEvent, FaultPlan, install
from repro.api import Session
from repro.serve import ModelStore, PredictionService, make_stream_source
from repro.train.checkpoint import CheckpointCorruptError

sess = Session(spec)
sess.step_stream(make_stream_source(spec), 4)
store = ModelStore()
store.publish(sess.current_x(), rounds_done=4)

# a truncated write: the save itself is atomic-temp+rename, so emulate
# the torn artifact the fault seam produces at the 'save' site
good = r"{tmp_path}/good"
sess.save(good)
import pathlib
npz = pathlib.Path(good).with_suffix(".npz")
npz.write_bytes(npz.read_bytes()[:-32])  # torn tail

with PredictionService(store) as svc:
    try:
        store.swap_from_checkpoint(good)
        raise AssertionError("torn checkpoint installed!")
    except CheckpointCorruptError:
        pass
    res = svc.predict([[0, 1]], [[1.0, 1.0]])
    assert res.model_version == 1  # still the pre-swap model
    assert store.failed_swaps == 1
print("TORN_REJECTED")
""",
        devices=1,
    )
    assert "TORN_REJECTED" in out
