"""Shared subprocess driver for the chaos harness.

Chaos tests run their victims in subprocesses for two reasons: a hard
``kill`` event SIGKILLs the process it fires in (the parent must stay
alive to assert on the wreckage), and shard_map victims need
``XLA_FLAGS=--xla_force_host_platform_device_count`` set before jax
initializes — which must not leak into the main pytest process (it has
to see exactly one device; see tests/conftest.py).
"""

import signal
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent.parent

SIGKILLED = -int(signal.SIGKILL)


def run_chaos(body: str, devices: int = 1, expect_returncode: int = 0) -> str:
    """Run ``body`` in a fresh interpreter with ``devices`` forced host
    devices; assert the exit status (``SIGKILLED`` for victims that are
    supposed to die) and return stdout."""
    code = textwrap.dedent(body)
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": str(REPO / "src"),
        "PATH": "/usr/bin:/bin:/usr/local/bin",
        "JAX_PLATFORMS": "cpu",
    }
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert proc.returncode == expect_returncode, (
        f"expected exit {expect_returncode}, got {proc.returncode}\n"
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    return proc.stdout
