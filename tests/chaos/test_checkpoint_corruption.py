"""Checkpoint-corruption matrix: every way a checkpoint can rot on disk
must surface as a typed ``CheckpointCorruptError`` naming the offending
file — never a raw zipfile/JSON/pickle traceback — and the atomic-write
path must leave no partial state behind when a fault lands inside it.

Also the ``SpecMismatchError`` regression: the message must carry both
content hashes *and* the first differing spec field.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.api import ExperimentSpec, MeshSpec, Session
from repro.core import ParallelSGDSchedule
from repro.core.faults import FaultEvent, FaultPlan, TransientIOError, install
from repro.train.checkpoint import (
    CheckpointCorruptError,
    SpecMismatchError,
    discard_session_checkpoint,
    load_session_checkpoint,
    restore_checkpoint,
    save_checkpoint,
    save_session_checkpoint,
)


def _spec(**overrides) -> ExperimentSpec:
    sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=4, loss_every=2)
    base = dict(
        dataset="rcv1-sm", schedule=sched, mesh=MeshSpec(p_r=2, p_c=1), name="corrupt"
    )
    base.update(overrides)
    return ExperimentSpec(**base)


def _write_ck(base, spec, rounds=2):
    save_session_checkpoint(
        base,
        spec_dict=spec.to_dict(),
        spec_hash=spec.content_hash(),
        rounds_done=rounds,
        x=np.arange(8, dtype=np.float32),
        losses=np.asarray([0.7, 0.6], np.float32),
        wall_time_s=1.0,
        compile_time_s=0.5,
    )


# ---- the corruption matrix ----


def test_truncated_npz_is_typed_and_names_the_file(tmp_path):
    base = tmp_path / "ck"
    spec = _spec()
    _write_ck(base, spec)
    npz = base.with_suffix(".npz")
    npz.write_bytes(npz.read_bytes()[:-64])
    with pytest.raises(CheckpointCorruptError) as ei:
        load_session_checkpoint(base)
    assert str(npz) in str(ei.value)
    assert "pickle" not in str(ei.value).lower()


def test_garbled_json_manifest(tmp_path):
    base = tmp_path / "ck"
    _write_ck(base, _spec())
    manifest = base.with_suffix(".json")
    manifest.write_text("{ not json ::")
    with pytest.raises(CheckpointCorruptError) as ei:
        load_session_checkpoint(base)
    assert str(manifest) in str(ei.value)


def test_binary_garbage_manifest(tmp_path):
    base = tmp_path / "ck"
    _write_ck(base, _spec())
    base.with_suffix(".json").write_bytes(b"\x89PNG\r\n\x1a\n\x00\xff\xfe")
    with pytest.raises(CheckpointCorruptError):
        load_session_checkpoint(base)


def test_manifest_not_an_object(tmp_path):
    base = tmp_path / "ck"
    _write_ck(base, _spec())
    base.with_suffix(".json").write_text('["a", "list"]')
    with pytest.raises(CheckpointCorruptError):
        load_session_checkpoint(base)


def test_missing_manifest_is_interrupted_save(tmp_path):
    base = tmp_path / "ck"
    _write_ck(base, _spec())
    base.with_suffix(".json").unlink()
    with pytest.raises(CheckpointCorruptError, match="interrupted save"):
        load_session_checkpoint(base)


def test_missing_npz_is_interrupted_save(tmp_path):
    base = tmp_path / "ck"
    _write_ck(base, _spec())
    base.with_suffix(".npz").unlink()
    with pytest.raises(CheckpointCorruptError, match="interrupted save"):
        load_session_checkpoint(base)


def test_stale_tmp_leftovers_only(tmp_path):
    base = tmp_path / "ck"
    base.with_suffix(".tmp.npz").write_bytes(b"half a write")
    with pytest.raises(CheckpointCorruptError, match="interrupted save"):
        load_session_checkpoint(base)


def test_nothing_at_all_is_file_not_found(tmp_path):
    # 'never written' stays FileNotFoundError — resume logic treats it as
    # 'start fresh', not as damage.
    with pytest.raises(FileNotFoundError):
        load_session_checkpoint(tmp_path / "absent")


def test_manifest_byte_flip_detected(tmp_path):
    base = tmp_path / "ck"
    _write_ck(base, _spec())
    manifest = base.with_suffix(".json")
    raw = bytearray(manifest.read_bytes())
    # flip inside the spec body (changes content, keeps JSON parseable)
    idx = raw.find(b'"rounds_done"') + len('"rounds_done": ')
    raw[idx] = ord("9")
    manifest.write_bytes(bytes(raw))
    with pytest.raises(CheckpointCorruptError, match="integrity"):
        load_session_checkpoint(base)


def test_manifest_payload_swap_detected(tmp_path):
    """A manifest paired with a payload from a *different* save (the
    two-rename crash window) fails the payload hash, not silently
    resumes the wrong weights."""
    a, b = tmp_path / "a", tmp_path / "b"
    spec = _spec()
    _write_ck(a, spec, rounds=2)
    save_session_checkpoint(
        b, spec_dict=spec.to_dict(), spec_hash=spec.content_hash(), rounds_done=4,
        x=np.ones(8, np.float32), losses=np.asarray([0.5], np.float32),
        wall_time_s=0, compile_time_s=0,
    )
    a.with_suffix(".npz").write_bytes(b.with_suffix(".npz").read_bytes())
    with pytest.raises(CheckpointCorruptError, match="integrity"):
        load_session_checkpoint(a)


def test_injected_truncation_at_save_site_caught_on_restore(tmp_path):
    """The seam's ckpt_truncate tears the durable payload right after a
    save; the next restore must detect it via the payload hash."""
    base = tmp_path / "ck"
    spec = _spec()
    plan = FaultPlan(events=[FaultEvent(kind="ckpt_truncate", site="save", at=2)])
    with install(plan) as inj:
        _write_ck(base, spec, rounds=2)
    assert inj.fired == [("ckpt_truncate", "save", 2)]
    with pytest.raises(CheckpointCorruptError):
        load_session_checkpoint(base)


# ---- atomicity under fault ----


def test_commit_fault_leaves_no_partial_state(tmp_path):
    """An io_error in the commit window (between temp-write and rename)
    must leave the destination untouched and zero temp files."""
    base = tmp_path / "ck"
    spec = _spec()
    plan = FaultPlan(events=[FaultEvent(kind="io_error", site="commit", at=2)])
    with install(plan):
        with pytest.raises(TransientIOError):
            _write_ck(base, spec, rounds=2)
    assert list(tmp_path.iterdir()) == []  # no temps, no halves

    # same fault with a previous good checkpoint in place: it survives
    _write_ck(base, spec, rounds=2)
    plan4 = FaultPlan(events=[FaultEvent(kind="io_error", site="commit", at=4)])
    with install(plan4):
        with pytest.raises(TransientIOError):
            _write_ck(base, spec, rounds=4)
    ck = load_session_checkpoint(base)
    assert ck.rounds_done == 2
    assert sorted(p.name for p in tmp_path.iterdir()) == ["ck.json", "ck.npz"]


def test_discard_removes_pair_and_temps(tmp_path):
    base = tmp_path / "ck"
    _write_ck(base, _spec())
    base.with_suffix(".tmp.npz").write_bytes(b"stale")
    discard_session_checkpoint(base)
    assert list(tmp_path.iterdir()) == []
    discard_session_checkpoint(base)  # idempotent


# ---- pytree checkpoints share the integrity layer ----


def test_pytree_checkpoint_corruption_is_typed(tmp_path):
    base = tmp_path / "tree"
    tree = {"w": np.arange(6, dtype=np.float32), "b": np.zeros(2, np.float32)}
    save_checkpoint(base, tree, step=3)
    restored, step = restore_checkpoint(base, tree)
    assert step == 3 and np.array_equal(restored["w"], tree["w"])
    npz = base.with_suffix(".npz")
    npz.write_bytes(npz.read_bytes()[:-32])
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(base, tree)


# ---- SpecMismatchError regression ----


def test_spec_mismatch_names_hashes_and_first_differing_field(tmp_path):
    base = tmp_path / "ck"
    spec = _spec()
    _write_ck(base, spec)
    other = dataclasses.replace(
        spec, schedule=dataclasses.replace(spec.schedule, eta=0.1)
    )
    with pytest.raises(SpecMismatchError) as ei:
        load_session_checkpoint(
            base,
            expect_spec_hash=other.content_hash(),
            expect_spec_dict=other.to_dict(),
        )
    msg = str(ei.value)
    assert spec.content_hash() in msg and other.content_hash() in msg
    assert "schedule.eta" in msg
    assert "0.05" in msg and "0.1" in msg
    assert "restore_elastic" in msg  # points at the deliberate door


def test_session_restore_mismatch_carries_field_detail(tmp_path):
    spec = _spec()
    sess = Session(spec)
    sess.step_rounds(2)
    sess.save(tmp_path / "ck")
    other = _spec(name="renamed")
    with pytest.raises(SpecMismatchError, match="name"):
        Session.restore(tmp_path / "ck", spec=other)


def test_corrupt_error_is_value_error():
    # retry/except-clauses written against ValueError keep working
    assert issubclass(CheckpointCorruptError, ValueError)
    assert issubclass(SpecMismatchError, ValueError)


def test_wrong_format_manifest(tmp_path):
    base = tmp_path / "ck"
    _write_ck(base, _spec())
    manifest = base.with_suffix(".json")
    meta = json.loads(manifest.read_text())
    # legitimate JSON, wrong format tag, hashes recomputed to match —
    # caught by the format check, not the integrity check
    from repro.train.checkpoint import _manifest_digest

    meta["format"] = "someone-elses-format"
    meta["manifest_sha256"] = _manifest_digest(meta)
    manifest.write_text(json.dumps(meta))
    with pytest.raises(CheckpointCorruptError, match="format"):
        load_session_checkpoint(base)
