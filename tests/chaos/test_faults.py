"""The chaos seam itself: plans are deterministic, events validate,
matching/audit semantics are exact, and the seam is inert when nothing
is installed."""

import pytest

from repro.core.faults import (
    FAULT_KINDS,
    FAULT_SITES,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    InjectedFault,
    TransientIOError,
    WorkerKilled,
    active,
    install,
    poke,
)


def test_plans_from_same_seed_are_identical():
    a = FaultPlan.from_seed(42, rounds=20)
    b = FaultPlan.from_seed(42, rounds=20)
    assert a == b
    assert a.events  # non-empty
    assert FaultPlan.from_seed(43, rounds=20) != a


def test_string_seed_is_stable():
    """Seeding from a spec content hash must give the same plan in every
    process — no PYTHONHASHSEED dependence."""
    a = FaultPlan.from_seed("c5e2c76d6dea3480", rounds=10)
    b = FaultPlan.from_seed("c5e2c76d6dea3480", rounds=10)
    assert a == b
    for ev in a.events:
        assert ev.kind in FAULT_KINDS
        assert ev.site in FAULT_SITES
        assert 1 <= ev.at < 10


def test_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent(kind="meteor")
    with pytest.raises(ValueError, match="site"):
        FaultEvent(kind="stall", site="nowhere")
    with pytest.raises(ValueError, match="times"):
        FaultEvent(kind="stall", times=0)


def test_injector_matches_site_round_and_budget():
    plan = FaultPlan(
        events=[
            FaultEvent(kind="io_error", site="round", at=2, times=2),
            FaultEvent(kind="stall", site="point", at=0, delay_s=0.0),
        ]
    )
    inj = FaultInjector(plan)
    inj.poke("round", 1)  # wrong round: nothing
    inj.poke("save", 2)  # wrong site: nothing
    with pytest.raises(TransientIOError):
        inj.poke("round", 2)
    with pytest.raises(TransientIOError):
        inj.poke("round", 2)
    inj.poke("round", 2)  # budget (times=2) spent: inert now
    inj.poke("point", 0)  # zero-delay stall: fires, returns
    assert inj.fired == [
        ("io_error", "round", 2),
        ("io_error", "round", 2),
        ("stall", "point", 0),
    ]


def test_transient_error_is_both_oserror_and_injected():
    # retry logic catches OSError; test oracles catch InjectedFault
    assert issubclass(TransientIOError, OSError)
    assert issubclass(TransientIOError, InjectedFault)
    assert issubclass(WorkerKilled, InjectedFault)


def test_soft_kill_raises():
    inj = FaultInjector(FaultPlan(events=[FaultEvent(kind="kill", at=1)]))
    with pytest.raises(WorkerKilled):
        inj.poke("round", 1)


def test_ckpt_truncate_shortens_file(tmp_path):
    victim = tmp_path / "payload.npz"
    victim.write_bytes(b"x" * 1000)
    inj = FaultInjector(
        FaultPlan(events=[FaultEvent(kind="ckpt_truncate", site="save", at=5,
                                     truncate_bytes=300)])
    )
    inj.poke("save", 5, path=victim)
    assert victim.stat().st_size == 700
    inj2 = FaultInjector(
        FaultPlan(events=[FaultEvent(kind="ckpt_truncate", site="save", at=5,
                                     truncate_bytes=10_000)])
    )
    inj2.poke("save", 5, path=victim)
    assert victim.stat().st_size == 0  # clamped, never negative


def test_module_seam_is_inert_without_install():
    assert active() is None
    poke("round", 1)  # no-op, no error
    plan = FaultPlan(events=[FaultEvent(kind="io_error", at=1)])
    with install(plan) as inj:
        assert active() is inj
        with pytest.raises(TransientIOError):
            poke("round", 1)
    assert active() is None
    poke("round", 1)  # inert again after the with-block


def test_at_none_fires_every_visit_until_spent():
    plan = FaultPlan(events=[FaultEvent(kind="stall", at=None, times=2, delay_s=0.0)])
    with install(plan) as inj:
        poke("round", 1)
        poke("round", 7)
        poke("round", 9)  # spent
    assert [at for _, _, at in inj.fired] == [1, 7]
