"""Kill–resume: a worker dies mid-run, a fresh process restores from the
autosave, and the finished trajectory is bitwise-identical to the
uninterrupted run — on both backends.

The victim installs a ``FaultPlan`` with a hard ``kill`` event
(``install(..., hard_kill=True)`` → real SIGKILL between two rounds, so
nothing after the fault can "clean up"); the resumer is a separate
process with no memory of the victim. The only channel between them is
the autosave checkpoint on disk — exactly a preemption.
"""

import numpy as np
import pytest

from chaos_util import SIGKILLED, run_chaos

# One spec, two backends. The autosave cadence (every round) plus the
# kill at round 4 means the victim leaves a round-4 checkpoint behind.
_SPEC = """
import dataclasses
from repro.api import ExperimentSpec, FaultPolicy, MeshSpec
from repro.core import ParallelSGDSchedule

sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=6, loss_every=2)
spec = ExperimentSpec(
    dataset="rcv1-sm",
    schedule=sched,
    mesh=MeshSpec(p_r=2, p_c={p_c}, backend="{backend}"),
    faults=FaultPolicy(autosave_every=1),
    name="chaos-kill",
)
"""

BACKENDS = [("simulated", 1, 1), ("shard_map", 4, 8)]


@pytest.mark.parametrize("backend,p_c,devices", BACKENDS)
def test_sigkill_between_rounds_resumes_bitwise(backend, p_c, devices, tmp_path):
    spec_code = _SPEC.format(backend=backend, p_c=p_c)

    # the reference: the same spec, uninterrupted
    run_chaos(
        spec_code
        + f"""
import numpy as np
from repro.api import Session
rep = Session(spec).run()
np.savez(r"{tmp_path}/clean.npz", x=rep.x, losses=rep.losses)
print("CLEAN", rep.rounds_completed)
""",
        devices=devices,
    )

    # the victim: autosaves every round, SIGKILLed by the seam at round 4
    run_chaos(
        spec_code
        + f"""
from repro.api import Session
from repro.core.faults import FaultEvent, FaultPlan, install
plan = FaultPlan(events=[FaultEvent(kind="kill", site="round", at=4)])
with install(plan, hard_kill=True):
    Session(spec, autosave_dir=r"{tmp_path}").run()
print("UNREACHABLE")  # SIGKILL means this line never runs
""",
        devices=devices,
        expect_returncode=SIGKILLED,
    )

    # the resumer: a fresh process, only the autosave to go on
    out = run_chaos(
        spec_code
        + f"""
import numpy as np
from repro.api import Session, autosave_base
sess = Session.restore(autosave_base(r"{tmp_path}", spec), spec=spec)
assert sess.rounds_done == 4, sess.rounds_done  # the kill landed after the round-4 save
rep = sess.run()
clean = np.load(r"{tmp_path}/clean.npz")
assert np.array_equal(rep.x, clean["x"]), "resumed weights diverged"
assert np.array_equal(rep.losses, clean["losses"]), "resumed loss trace diverged"
print("RESUMED-BITWISE", rep.rounds_completed)
""",
        devices=devices,
    )
    assert "RESUMED-BITWISE 6" in out


def test_parent_driven_sigkill_mid_run(tmp_path):
    """The parent kills the victim from outside (no cooperation from the
    seam): the victim prints a line per round, the parent SIGKILLs it
    after seeing round 2, then resumes from whatever autosave survived.
    Proves recovery doesn't depend on the victim dying at a point of the
    runtime's choosing."""
    import os
    import signal
    import subprocess
    import sys
    import textwrap

    from chaos_util import REPO

    victim = textwrap.dedent(
        _SPEC.format(backend="simulated", p_c=1)
        + f"""
import sys, time
from repro.api import Session
from repro.core.faults import FaultEvent, FaultPlan, install
sess = Session(spec, autosave_dir=r"{tmp_path}")
# stall every round so the parent's kill always lands mid-run
plan = FaultPlan(events=[FaultEvent(kind="stall", site="round", at=None,
                                    times=99, delay_s=0.5)])
with install(plan):
    while not sess.done:
        sess.step_rounds(1)
        print("ROUND", sess.rounds_done, flush=True)
"""
    )
    env = {
        "XLA_FLAGS": "--xla_force_host_platform_device_count=1",
        "PYTHONPATH": str(REPO / "src"),
        "PATH": "/usr/bin:/bin:/usr/local/bin",
        "JAX_PLATFORMS": "cpu",
    }
    proc = subprocess.Popen(
        [sys.executable, "-c", victim], stdout=subprocess.PIPE, text=True, env=env
    )
    try:
        rounds_seen = 0
        for line in proc.stdout:
            if line.startswith("ROUND"):
                rounds_seen = int(line.split()[1])
                if rounds_seen >= 2:
                    os.kill(proc.pid, signal.SIGKILL)
                    break
        proc.wait(timeout=60)
    finally:
        proc.kill()
    assert proc.returncode == SIGKILLED
    assert rounds_seen >= 2

    out = run_chaos(
        _SPEC.format(backend="simulated", p_c=1)
        + f"""
import numpy as np
from repro.api import Session, autosave_base, run
sess = Session.restore(autosave_base(r"{tmp_path}", spec), spec=spec)
assert sess.rounds_done >= 2, sess.rounds_done
rep = sess.run()
clean = run(spec)
assert np.array_equal(rep.x, clean.x)
assert np.array_equal(rep.losses, clean.losses)
print("RESUMED-BITWISE", rep.rounds_completed)
"""
    )
    assert "RESUMED-BITWISE 6" in out


def test_soft_kill_in_process(tmp_path):
    """The in-process flavor (``WorkerKilled`` instead of SIGKILL): same
    contract, no subprocess — the fast smoke the others generalize."""
    from repro.api import ExperimentSpec, FaultPolicy, MeshSpec, Session, autosave_base
    from repro.core import ParallelSGDSchedule
    from repro.core.faults import FaultEvent, FaultPlan, WorkerKilled, install

    sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=6, loss_every=2)
    spec = ExperimentSpec(
        dataset="rcv1-sm",
        schedule=sched,
        mesh=MeshSpec(p_r=2, p_c=1),
        faults=FaultPolicy(autosave_every=2),
        name="chaos-soft-kill",
    )
    clean = Session(spec).run()

    victim = Session(spec, autosave_dir=tmp_path)
    plan = FaultPlan(events=[FaultEvent(kind="kill", site="round", at=4)])
    with install(plan) as inj:
        with pytest.raises(WorkerKilled):
            victim.run()
    assert inj.fired == [("kill", "round", 4)]
    assert victim.rounds_done == 4

    rep = Session.restore(autosave_base(tmp_path, spec), spec=spec).run()
    assert np.array_equal(rep.x, clean.x)
    assert np.array_equal(rep.losses, clean.losses)
