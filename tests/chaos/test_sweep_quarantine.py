"""Sweep retry/quarantine under injected faults.

A sweep point's FaultPolicy is its recovery contract: transient faults
are retried (resuming from the point's autosave, so progress is kept),
persistent faults quarantine the point after 1 + max_retries attempts,
and the rest of the sweep always completes. The quarantine lands in
``SweepReport`` and survives ``to_json()`` — the artifact CI uploads.
"""

import json

import numpy as np
import pytest

from repro.api import (
    ExperimentSpec,
    FaultPolicy,
    MeshSpec,
    Session,
    autosave_base,
    run,
    sweep,
)
from repro.core import ParallelSGDSchedule
from repro.core.faults import FaultEvent, FaultPlan, install


def _spec(name, **over):
    sched = ParallelSGDSchedule.hybrid(2, 2, 4, 0.05, 8, rounds=6, loss_every=2)
    base = dict(
        dataset="rcv1-sm",
        schedule=sched,
        mesh=MeshSpec(p_r=2, p_c=1),
        name=name,
    )
    base.update(over)
    return ExperimentSpec(**base)


def test_persistent_failure_quarantines_and_sweep_completes(tmp_path):
    doomed = _spec("doomed", faults=FaultPolicy(max_retries=2))
    fine = _spec("fine")
    plan = FaultPlan(
        events=[FaultEvent(kind="io_error", site="point", at=0, times=99)]
    )
    with install(plan) as inj:
        report = sweep([doomed, fine], resume_dir=tmp_path)

    assert [r.spec.name for r in report.reports] == ["fine"]
    assert len(report.quarantined) == 1
    q = report.quarantined[0]
    assert q.name == "doomed"
    assert q.attempts == 3  # 1 + max_retries
    assert q.spec_hash == doomed.content_hash()
    assert "TransientIOError" in q.error
    # every attempt hit the seam, none leaked into the healthy point
    assert inj.fired == [("io_error", "point", 0)] * 3

    # the quarantine survives the JSON artifact round-trip
    blob = json.loads(report.to_json())
    assert blob["quarantined"] == [q.to_dict()]
    assert [r["spec"]["name"] for r in blob["reports"]] == ["fine"]
    assert "1 quarantined" in report.summary()


def test_transient_failure_retries_and_matches_clean_run(tmp_path):
    """One injected mid-run fault: the retry resumes from the autosave
    (not round 0) and the finished point is bitwise the clean run."""
    spec = _spec("transient", faults=FaultPolicy(autosave_every=2, max_retries=2))
    clean = run(_spec("transient"))

    plan = FaultPlan(events=[FaultEvent(kind="io_error", site="round", at=4, times=1)])
    with install(plan) as inj:
        report = sweep([spec], resume_dir=tmp_path)

    assert report.attempts == [2]  # failed once, succeeded on retry
    assert report.quarantined == []
    assert inj.fired == [("io_error", "round", 4)]
    assert np.array_equal(report.reports[0].x, clean.x)
    assert np.array_equal(report.reports[0].losses, clean.losses)
    # the retry resumed *past* the faulting round: round 4 was visited
    # once (the event had times=1 left but never re-fired)
    assert report.reports[0].rounds_completed == 6
    # success spends the autosave
    assert not autosave_base(tmp_path, spec).with_suffix(".npz").exists()


def test_retry_resumes_from_autosave_round(tmp_path):
    """Directly observe the resume: after the faulted first attempt the
    autosave sits at the fault round; opening it fast-forwards there."""
    spec = _spec("resume-probe", faults=FaultPolicy(autosave_every=1, max_retries=0))
    plan = FaultPlan(events=[FaultEvent(kind="io_error", site="round", at=3, times=1)])
    with install(plan):
        report = sweep([spec], resume_dir=tmp_path)
    # max_retries=0 → quarantined on the first failure, with progress
    assert report.quarantined[0].rounds_done == 3
    sess = Session.restore(autosave_base(tmp_path, spec), spec=spec)
    assert sess.rounds_done == 3

    # a later invocation (fault cleared) picks the autosave up and
    # finishes the point from round 3
    report2 = sweep([spec], resume_dir=tmp_path)
    assert report2.attempts == [1]
    assert report2.reports[0].rounds_completed == 6
    assert np.array_equal(report2.reports[0].x, run(_spec("resume-probe")).x)


def test_corrupt_autosave_is_discarded_not_fatal(tmp_path):
    """A torn autosave (truncated payload) must not wedge the point:
    the retry discards it and restarts the point from round 0."""
    spec = _spec("torn", faults=FaultPolicy(autosave_every=2, max_retries=1))
    # seed a deliberately torn autosave where the sweep will look
    base = autosave_base(tmp_path, spec)
    sess = Session(spec, autosave_dir=tmp_path)
    sess.step_rounds(2)
    sess.save(base)
    npz = base.with_suffix(".npz")
    npz.write_bytes(npz.read_bytes()[:-128])

    report = sweep([spec], resume_dir=tmp_path)
    assert report.quarantined == []
    assert report.attempts == [1]
    assert np.array_equal(report.reports[0].x, run(_spec("torn")).x)


def test_stall_fault_slows_but_never_fails(tmp_path):
    spec = _spec("slow")
    plan = FaultPlan(
        events=[FaultEvent(kind="stall", site="round", at=None, times=3, delay_s=0.01)]
    )
    with install(plan) as inj:
        report = sweep([spec], resume_dir=tmp_path)
    assert [k for k, _, _ in inj.fired] == ["stall"] * 3
    assert report.attempts == [1]
    assert report.quarantined == []


def test_quarantined_point_consumes_a_max_points_slot(tmp_path):
    doomed = _spec("doomed", faults=FaultPolicy(max_retries=0))
    later = _spec("later")
    plan = FaultPlan(events=[FaultEvent(kind="io_error", site="point", at=0, times=99)])
    with install(plan):
        report = sweep([doomed, later], resume_dir=tmp_path, max_points=1)
    assert len(report.quarantined) == 1
    assert report.reports == []
    assert report.skipped == [later.content_hash()]


def test_keyboard_interrupt_is_not_retried(tmp_path):
    """The user hitting ^C mid-point must propagate immediately, not
    burn the retry budget."""
    spec = _spec("interrupted", faults=FaultPolicy(max_retries=5))

    calls = {"n": 0}
    real_init = Session.__init__

    def exploding_init(self, *a, **k):
        calls["n"] += 1
        raise KeyboardInterrupt

    Session.__init__ = exploding_init
    try:
        with pytest.raises(KeyboardInterrupt):
            sweep([spec], resume_dir=tmp_path)
    finally:
        Session.__init__ = real_init
    assert calls["n"] == 1
