"""Property tests for checkpoint round-trips (hypothesis).

``hypothesis`` is an optional dev dependency: when it is absent the
stub in tests/conftest.py turns every @given test into a clean skip, so
these modules must keep all strategy *composition* out of module scope
(plain ``st.integers(...)`` arguments only — the stub returns None for
them, which @given never inspects).

Properties:

* restore(save(state)) == state, field for field, bitwise on arrays —
  for arbitrary round counts, array sizes, and contents;
* any single-byte flip inside the manifest's content is detected
  (``CheckpointCorruptError``) or — when the flip only rewrites
  JSON whitespace — loads back the identical state; it never loads
  *different* state silently;
* any truncation of the payload is detected;
* a checkpoint saved under one spec never loads under a spec whose
  content hash differs (``SpecMismatchError``), for arbitrary
  FaultPolicy/StopPolicy perturbations.
"""

import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import ExperimentSpec, FaultPolicy, MeshSpec
from repro.core import ParallelSGDSchedule
from repro.train.checkpoint import (
    CheckpointCorruptError,
    SpecMismatchError,
    load_session_checkpoint,
    save_session_checkpoint,
)


def _spec(autosave_every=0, max_retries=2, eta=0.05):
    sched = ParallelSGDSchedule.hybrid(2, 2, 4, eta, 8, rounds=4, loss_every=2)
    return ExperimentSpec(
        dataset="rcv1-sm",
        schedule=sched,
        mesh=MeshSpec(p_r=2, p_c=1),
        faults=FaultPolicy(autosave_every=autosave_every, max_retries=max_retries),
        name="props",
    )


def _save(base, spec, rng, rounds, n, n_losses):
    x = rng.standard_normal(n).astype(np.float32)
    losses = rng.standard_normal(n_losses).astype(np.float32)
    save_session_checkpoint(
        base,
        spec_dict=spec.to_dict(),
        spec_hash=spec.content_hash(),
        rounds_done=rounds,
        x=x,
        losses=losses,
        wall_time_s=float(rng.random()),
        compile_time_s=float(rng.random()),
    )
    return x, losses


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=1, max_value=512),
    st.integers(min_value=0, max_value=64),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_session_checkpoint_roundtrip(tmp_path_factory, rounds, n, n_losses, seed):
    base = tmp_path_factory.mktemp("props") / "ck"
    spec = _spec()
    rng = np.random.default_rng(seed)
    x, losses = _save(base, spec, rng, rounds, n, n_losses)
    ck = load_session_checkpoint(base, expect_spec_hash=spec.content_hash())
    assert ck.rounds_done == rounds
    assert ck.spec_hash == spec.content_hash()
    assert np.array_equal(np.asarray(ck.x), x)
    assert np.array_equal(np.asarray(ck.losses), losses)
    assert ExperimentSpec.from_dict(ck.spec_dict) == spec


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.integers(min_value=0, max_value=2**31 - 1),
    st.integers(min_value=1, max_value=255),
)
def test_manifest_single_byte_flip_never_loads_different_state(
    tmp_path_factory, seed, pos_seed, xor
):
    base = tmp_path_factory.mktemp("flip") / "ck"
    spec = _spec()
    rng = np.random.default_rng(seed)
    x, losses = _save(base, spec, rng, rounds=3, n=16, n_losses=2)
    manifest = base.with_suffix(".json")
    raw = bytearray(manifest.read_bytes())
    idx = int(np.random.default_rng(pos_seed).integers(len(raw)))
    raw[idx] ^= xor
    manifest.write_bytes(bytes(raw))
    try:
        ck = load_session_checkpoint(base, expect_spec_hash=spec.content_hash())
    except (CheckpointCorruptError, SpecMismatchError):
        return  # detected — the property holds
    # the only acceptable silent outcome: the flip changed nothing
    # semantic (whitespace-only), so the state is the identical state
    assert ck.rounds_done == 3
    assert np.array_equal(np.asarray(ck.x), x)
    assert np.array_equal(np.asarray(ck.losses), losses)
    assert ck.spec_dict == spec.to_dict()


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.integers(min_value=1, max_value=4096),
)
def test_payload_truncation_always_detected(tmp_path_factory, seed, cut):
    base = tmp_path_factory.mktemp("trunc") / "ck"
    spec = _spec()
    rng = np.random.default_rng(seed)
    _save(base, spec, rng, rounds=1, n=64, n_losses=1)
    npz = base.with_suffix(".npz")
    data = npz.read_bytes()
    npz.write_bytes(data[: max(0, len(data) - cut)])
    with pytest.raises(CheckpointCorruptError):
        load_session_checkpoint(base, expect_spec_hash=spec.content_hash())


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=0, max_value=20),
    st.integers(min_value=0, max_value=5),
    st.floats(min_value=0.01, max_value=0.5, allow_nan=False),
)
def test_spec_perturbation_never_resumes(tmp_path_factory, autosave_every, max_retries, eta):
    base = tmp_path_factory.mktemp("mismatch") / "ck"
    writer = _spec()
    rng = np.random.default_rng(0)
    _save(base, writer, rng, rounds=2, n=8, n_losses=1)
    reader = _spec(autosave_every=autosave_every, max_retries=max_retries, eta=round(eta, 4))
    if reader.content_hash() == writer.content_hash():
        # identical perturbation — must load cleanly instead
        load_session_checkpoint(base, expect_spec_hash=reader.content_hash())
        return
    with pytest.raises(SpecMismatchError):
        load_session_checkpoint(
            base,
            expect_spec_hash=reader.content_hash(),
            expect_spec_dict=reader.to_dict(),
        )


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=0, max_value=50),
    st.integers(min_value=0, max_value=10),
    st.floats(min_value=0.0, max_value=30.0, allow_nan=False),
)
def test_fault_policy_dict_roundtrip(autosave_every, max_retries, backoff_s):
    fp = FaultPolicy(
        autosave_every=autosave_every, max_retries=max_retries, backoff_s=backoff_s
    )
    assert FaultPolicy.from_dict(fp.to_dict()) == fp
    spec = dataclasses.replace(_spec(), faults=fp)
    rehydrated = ExperimentSpec.from_dict(json.loads(spec.to_json()))
    assert rehydrated == spec
    assert rehydrated.content_hash() == spec.content_hash()
